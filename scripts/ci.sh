#!/usr/bin/env bash
# Tier-1 verification, exactly as ROADMAP.md specifies, with the bounded-
# runtime guarantee made checkable: the suite must collect cleanly (no
# hypothesis ImportError — tests/_compat ships an offline shim), pass, and
# finish within TIMEOUT_S.
#
#   scripts/ci.sh            # full tier-1 (includes -m slow tests)
#   FAST=1 scripts/ci.sh     # quick signal: skip the slow marker
set -euo pipefail
cd "$(dirname "$0")/.."

TIMEOUT_S="${TIMEOUT_S:-1500}"
ARGS=(-x -q)
if [[ "${FAST:-0}" == "1" ]]; then
  ARGS+=(-m "not slow")
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec timeout "$TIMEOUT_S" python -m pytest "${ARGS[@]}" "$@"

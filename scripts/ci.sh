#!/usr/bin/env bash
# Tier-1 verification, exactly as ROADMAP.md specifies, with the bounded-
# runtime guarantee made checkable: the suite must collect cleanly (no
# hypothesis ImportError — tests/_compat ships an offline shim), pass, and
# finish within TIMEOUT_S.
#
#   scripts/ci.sh            # full tier-1 (includes -m slow tests)
#   FAST=1 scripts/ci.sh     # quick signal: skip the slow marker
#   FLEET=1 scripts/ci.sh    # fleet tier only: sweep smoke, preemption
#                            # signal path, elastic virtual-device tests
#   LINT=0 scripts/ci.sh     # skip the repro-lint static-analysis stage
set -euo pipefail
cd "$(dirname "$0")/.."

TIMEOUT_S="${TIMEOUT_S:-1500}"
ARGS=(-x -q)
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${LINT:-1}" == "1" ]]; then
  # Static-analysis stage (every tier, including FAST): repro-lint fails
  # on any finding that is neither inline-suppressed nor justified in
  # .repro-lint-baseline.json — so a reintroduced donated-buffer reuse,
  # interpret=True, or hot-path host sync breaks CI before any test runs.
  python -m repro.analysis.lint src benchmarks
  # Telemetry schema stage: every committed BENCH_*.json baseline must
  # validate against the v1 bench schema (repro.telemetry.schema), so a
  # half-written or hand-edited artifact fails before any test runs.
  python -m repro.telemetry.schema benchmarks
fi

if [[ "${FLEET:-0}" == "1" ]]; then
  # Fleet tier: the elastic-training acceptance surface in one bounded
  # command — the sweep driver (incl. the crash-mid-sweep resume proof),
  # the SIGTERM→checkpoint→exit-75→elastic-resume protocol, the chaos
  # bitwise-recovery harness, and the multi-virtual-device elastic
  # restore subprocess tests.  All slow-marked tests here fit the same
  # TIMEOUT_S budget as the full tier.
  exec timeout "$TIMEOUT_S" python -m pytest tests/fleet \
      tests/run/test_profiler.py -q "$@"
fi

if [[ "${FAST:-0}" == "1" ]]; then
  # Fast tier leads with the contract guards: the Opt v2 zero-recompile-
  # under-hparam-schedule assertions (tests/core/test_api.py), the
  # Run API smoke (tests/run: RunSpec JSON round-trip, a short synthetic
  # run + checkpoint resume through run(), the packed-batch equivalence
  # + fault-recovery rewind proofs, and the jit cache-size proof that
  # the hook pipeline adds zero steady-state recompiles), the
  # segment-packing layout invariants (tests/data), the telemetry
  # schema / probe / golden-report checks (tests/telemetry), and the
  # training-sentinel guard/policy/injected-fault proofs (tests/sentinel)
  # — so an accidental retrace, run-layer, packing, or anomaly-guard
  # regression fails in seconds, before
  # the wider suite runs (which then skips those paths to stay within
  # the single TIMEOUT_S wall-clock bound).
  SECONDS=0
  timeout "$TIMEOUT_S" python -m pytest tests/core/test_api.py tests/run \
      tests/data tests/telemetry tests/sentinel -m "not slow" -q
  TIMEOUT_S=$((TIMEOUT_S - SECONDS))
  # `timeout 0` would DISABLE the bound entirely — clamp to >= 1s.
  if (( TIMEOUT_S < 1 )); then TIMEOUT_S=1; fi
  ARGS+=(-m "not slow" --ignore=tests/core/test_api.py --ignore=tests/run
         --ignore=tests/data --ignore=tests/telemetry
         --ignore=tests/sentinel)
fi

exec timeout "$TIMEOUT_S" python -m pytest "${ARGS[@]}" "$@"

"""The fused backward engine is semantics-preserving: same updates as the
unfused jax.grad path, for every optimizer rule and model family pattern."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimizers as opt_lib
from repro.core.fused import (apply_gradients_unfused, fused_train_step,
                              init_fused_opt_state, unfused_loss_fn)
from repro.models.registry import get_arch

RULES = ["adalomo", "sgd", "sgd_momentum", "sgd_variance", "adamw",
         "adafactor"]


def _batch(arch, key, B=2, S=16):
    cfg = arch.cfg
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if arch.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.n_frames,
                                                  cfg.d_model))
    if getattr(cfg, "prefix_lm", False):
        batch["prefix_embed"] = jax.random.normal(
            key, (B, cfg.n_prefix_tokens, cfg.d_model))
        batch["prefix_len"] = jnp.full((B,), cfg.n_prefix_tokens, jnp.int32)
    if getattr(cfg, "mtp", False):
        batch["labels_mtp"] = batch["labels"]
    return batch


@pytest.mark.parametrize("rule_name", RULES)
def test_fused_equals_unfused_updates(rule_name):
    """One step of fused backward == grad-then-update, leafwise."""
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    rule = opt_lib.get_rule(rule_name)
    key = jax.random.PRNGKey(0)
    params = arch.init_params(key)
    opt_state = init_fused_opt_state(rule, params)
    batch = _batch(arch, key)
    lr = jnp.float32(1e-3)

    step_f = jax.jit(arch.make_fused_train_step(rule),
                     static_argnames=()).lower(
        params, opt_state, batch, lr=lr).compile()
    p_f, s_f, loss_f, _ = step_f(params, opt_state, batch, lr=lr)

    loss_fn = arch.make_loss_fn()
    (loss_u, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                   batch)
    p_u, s_u = apply_gradients_unfused(rule, params, grads, opt_state,
                                       lr=lr)
    np.testing.assert_allclose(loss_f, loss_u, rtol=1e-5)
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(p_f),
            jax.tree_util.tree_leaves_with_path(p_u)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-6,
            err_msg=f"{rule_name}: {jax.tree_util.keystr(kp)}")


@pytest.mark.parametrize("arch_id", ["zamba2-1.2b", "whisper-base",
                                     "deepseek-moe-16b"])
def test_fused_equals_unfused_special_families(arch_id):
    """Shared-weight grads (zamba2), cross-stream grads (whisper), and MoE
    aux-loss routing all survive the fused engine."""
    arch = get_arch(arch_id, smoke=True)
    rule = opt_lib.get_rule("adalomo")
    key = jax.random.PRNGKey(1)
    params = arch.init_params(key)
    opt_state = init_fused_opt_state(rule, params)
    batch = _batch(arch, key)
    lr = jnp.float32(1e-3)
    step = arch.make_fused_train_step(rule)
    p_f, s_f, loss_f, _ = jax.jit(
        lambda p, s, b: step(p, s, b, lr=lr))(params, opt_state, batch)

    loss_fn = arch.make_loss_fn()
    (loss_u, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                   batch)
    p_u, _ = apply_gradients_unfused(rule, params, grads, opt_state, lr=lr)
    np.testing.assert_allclose(loss_f, loss_u, rtol=1e-5)
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(p_f),
            jax.tree_util.tree_leaves_with_path(p_u)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-6,
            err_msg=f"{arch_id}: {jax.tree_util.keystr(kp)}")


def test_two_pass_global_grad_norm_mode():
    """LOMO's gradient-norm variant (paper §2.1): two backward passes, and
    when the norm is under the clip the result equals the one-pass run."""
    from repro.models.transformer import make_fused_spec
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    spec = make_fused_spec(arch.cfg)
    rule = opt_lib.get_rule("sgd")  # LOMO = fused SGD
    key = jax.random.PRNGKey(2)
    params = arch.init_params(key)
    opt_state = init_fused_opt_state(rule, params)
    batch = _batch(arch, key)

    p1, _, loss1, _ = jax.jit(lambda p, s, b: fused_train_step(
        spec, rule, p, s, b, lr=jnp.float32(1e-3),
        global_grad_norm=1e9))(params, opt_state, batch)
    p2, _, loss2, _ = jax.jit(lambda p, s, b: fused_train_step(
        spec, rule, p, s, b, lr=jnp.float32(1e-3)))(params, opt_state,
                                                    batch)
    np.testing.assert_allclose(loss1, loss2, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-7)
    # tight clip must change the result
    p3, _, _, _ = jax.jit(lambda p, s, b: fused_train_step(
        spec, rule, p, s, b, lr=jnp.float32(1e-3),
        global_grad_norm=1e-4))(params, opt_state, batch)
    diffs = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3))]
    assert max(diffs) > 0.0


def test_gradient_liveness_structure():
    """Structural check of the O(1)-gradient claim: the fused step's HLO
    must not allocate any buffer the size of the full stacked-gradient
    pytree (the unfused step must).  We compare temp memory."""
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    cfg = arch.cfg
    rule = opt_lib.get_rule("sgd")  # no optimizer state → isolates grads
    key = jax.random.PRNGKey(0)
    B, S = 8, 128
    params = arch.init_params(key)
    opt_state = init_fused_opt_state(rule, params)
    batch = _batch(arch, key, B=B, S=S)
    lr = jnp.float32(1e-3)
    step = arch.make_fused_train_step(rule)
    c_f = jax.jit(lambda p, s, b: step(p, s, b, lr=lr),
                  donate_argnums=(0, 1)).lower(
        params, opt_state, batch).compile()
    loss_fn = arch.make_loss_fn()

    def unfused(p, s, b):
        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        p2, s2 = apply_gradients_unfused(rule, p, g, s, lr=lr)
        return p2, s2, loss, m

    c_u = jax.jit(unfused, donate_argnums=(0, 1)).lower(
        params, opt_state, batch).compile()
    t_f = c_f.memory_analysis().temp_size_in_bytes
    t_u = c_u.memory_analysis().temp_size_in_bytes
    # fused must be no worse; at real scale the gap is the whole grad tree
    assert t_f <= t_u * 1.05, (t_f, t_u)

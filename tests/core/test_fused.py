"""The fused backward engine is semantics-preserving: same updates as the
unfused jax.grad path, for every optimizer rule, model family pattern, and
param-group hparam assignment (Opt v2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimizers as opt_lib
from repro.core.api import GroupSpec, no_decay_1d
from repro.core.fused import fused_train_step
from repro.models.registry import get_arch

RULES = ["adalomo", "sgd", "sgd_momentum", "sgd_variance", "adamw",
         "adafactor"]


def _batch(arch, key, B=2, S=16):
    cfg = arch.cfg
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if arch.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.n_frames,
                                                  cfg.d_model))
    if getattr(cfg, "prefix_lm", False):
        batch["prefix_embed"] = jax.random.normal(
            key, (B, cfg.n_prefix_tokens, cfg.d_model))
        batch["prefix_len"] = jnp.full((B,), cfg.n_prefix_tokens, jnp.int32)
    if getattr(cfg, "mtp", False):
        batch["labels_mtp"] = batch["labels"]
    return batch


def _assert_trees_close(a, b, err=""):
    for (kp, x), (_, y) in zip(
            jax.tree_util.tree_leaves_with_path(a),
            jax.tree_util.tree_leaves_with_path(b)):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=5e-4, atol=5e-6,
            err_msg=f"{err}: {jax.tree_util.keystr(kp)}")


@pytest.mark.parametrize("rule_name", RULES)
def test_fused_equals_unfused_updates(rule_name):
    """One step of fused backward == grad-then-update, leafwise."""
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    opt = opt_lib.get_opt(rule_name)
    key = jax.random.PRNGKey(0)
    params = arch.init_params(key)
    opt_state = opt.init(params)
    batch = _batch(arch, key)
    hp = {"lr": jnp.float32(1e-3)}

    step_f = jax.jit(arch.make_fused_train_step(opt)).lower(
        params, opt_state, batch, hparams=hp).compile()
    p_f, s_f, loss_f, _ = step_f(params, opt_state, batch, hparams=hp)

    loss_fn = arch.make_loss_fn()
    (loss_u, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                   batch)
    p_u, s_u = opt.step(params, grads, opt_state, hp)
    np.testing.assert_allclose(loss_f, loss_u, rtol=1e-5)
    assert int(s_f.step) == int(s_u.step) == 1
    _assert_trees_close(p_f, p_u, rule_name)


@pytest.mark.parametrize("rule_name", ["adalomo", "adamw"])
def test_fused_equals_unfused_grouped_hparams(rule_name):
    """Param-group labeling is path-consistent across the two engines:
    no-decay-on-1D + a per-group lr override produce identical per-tensor
    updates fused and unfused."""
    groups = (no_decay_1d(),
              GroupSpec("embed", match="outer/", hparams={"lr": 1e-4}))
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    opt = opt_lib.get_opt(rule_name, groups=groups)
    key = jax.random.PRNGKey(3)
    params = arch.init_params(key)
    opt_state = opt.init(params)
    batch = _batch(arch, key)
    hp = {"lr": jnp.float32(1e-3), "weight_decay": jnp.float32(0.1),
          "groups": {"embed": {"lr": jnp.float32(2e-4)}}}

    step_f = arch.make_fused_train_step(opt)
    p_f, s_f, loss_f, _ = jax.jit(
        lambda p, s, b, h: step_f(p, s, b, hparams=h))(
        params, opt_state, batch, hp)

    loss_fn = arch.make_loss_fn()
    (loss_u, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                   batch)
    p_u, _ = opt.step(params, grads, opt_state, hp)
    np.testing.assert_allclose(loss_f, loss_u, rtol=1e-5)
    _assert_trees_close(p_f, p_u, rule_name)


def test_group_overrides_change_the_right_tensors():
    """weight_decay decays exactly the non-1D default-group tensors, and a
    per-group lr=0 override freezes exactly that group."""
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    key = jax.random.PRNGKey(4)
    params = arch.init_params(key)
    batch = _batch(arch, key)
    loss_fn = arch.make_loss_fn()
    (_, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    # zero grads isolate the decay term: Δθ = -lr·wd·θ for decayed tensors
    zero_g = jax.tree.map(jnp.zeros_like, grads)

    opt = opt_lib.get_opt("adamw", groups=(no_decay_1d(),))
    st = opt.init(params)
    p2, _ = opt.step(params, zero_g, st,
                     {"lr": 0.1, "weight_decay": 0.5})
    labels = opt.labels(params)
    for (kp, a), b, lab in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree.leaves(p2), jax.tree.leaves(labels)):
        a, b = np.asarray(a), np.asarray(b)
        if lab == 1:    # no_decay group: 1-D → untouched
            np.testing.assert_array_equal(a, b, err_msg=str(kp))
        else:           # decayed: θ' = θ·(1 - 0.05)
            np.testing.assert_allclose(b, a * 0.95, rtol=1e-6,
                                       err_msg=str(kp))

    # per-group lr override of 0 freezes the group (with real grads)
    opt2 = opt_lib.get_opt("adamw", groups=(GroupSpec(
        "frozen", match=lambda i: i.tensor_ndim <= 1),))
    st2 = opt2.init(params)
    p3, _ = opt2.step(params, grads, st2,
                      {"lr": 0.1, "groups": {"frozen": {"lr": 0.0}}})
    for (kp, a), b, lab in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree.leaves(p3), jax.tree.leaves(opt2.labels(params))):
        if lab == 1:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=str(kp))


@pytest.mark.parametrize("arch_id", ["zamba2-1.2b", "whisper-base",
                                     "deepseek-moe-16b"])
def test_fused_equals_unfused_special_families(arch_id):
    """Shared-weight grads (zamba2), cross-stream grads (whisper), and MoE
    aux-loss routing all survive the fused engine."""
    arch = get_arch(arch_id, smoke=True)
    opt = opt_lib.get_opt("adalomo")
    key = jax.random.PRNGKey(1)
    params = arch.init_params(key)
    opt_state = opt.init(params)
    batch = _batch(arch, key)
    hp = {"lr": jnp.float32(1e-3)}
    step = arch.make_fused_train_step(opt)
    p_f, s_f, loss_f, _ = jax.jit(
        lambda p, s, b: step(p, s, b, hparams=hp))(params, opt_state, batch)

    loss_fn = arch.make_loss_fn()
    (loss_u, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                   batch)
    p_u, _ = opt.step(params, grads, opt_state, hp)
    np.testing.assert_allclose(loss_f, loss_u, rtol=1e-5)
    _assert_trees_close(p_f, p_u, arch_id)


def test_two_pass_global_grad_norm_mode():
    """LOMO's gradient-norm variant (paper §2.1): two backward passes, and
    when the norm is under the clip the result equals the one-pass run."""
    from repro.models.transformer import make_fused_spec
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    spec = make_fused_spec(arch.cfg)
    opt = opt_lib.get_opt("sgd")  # LOMO = fused SGD
    key = jax.random.PRNGKey(2)
    params = arch.init_params(key)
    opt_state = opt.init(params)
    batch = _batch(arch, key)
    hp = jnp.float32(1e-3)   # bare scalar == {"lr": scalar}

    p1, _, loss1, _ = jax.jit(lambda p, s, b: fused_train_step(
        spec, opt, p, s, b, hparams=hp,
        global_grad_norm=1e9))(params, opt_state, batch)
    p2, _, loss2, _ = jax.jit(lambda p, s, b: fused_train_step(
        spec, opt, p, s, b, hparams=hp))(params, opt_state, batch)
    np.testing.assert_allclose(loss1, loss2, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-7)
    # tight clip must change the result
    p3, _, _, _ = jax.jit(lambda p, s, b: fused_train_step(
        spec, opt, p, s, b, hparams=hp,
        global_grad_norm=1e-4))(params, opt_state, batch)
    diffs = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3))]
    assert max(diffs) > 0.0


def test_gradient_liveness_structure():
    """Structural check of the O(1)-gradient claim: the fused step's HLO
    must not allocate any buffer the size of the full stacked-gradient
    pytree (the unfused step must).  We compare temp memory."""
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    opt = opt_lib.get_opt("sgd")  # no optimizer state → isolates grads
    key = jax.random.PRNGKey(0)
    B, S = 8, 128
    params = arch.init_params(key)
    opt_state = opt.init(params)
    batch = _batch(arch, key, B=B, S=S)
    hp = {"lr": jnp.float32(1e-3)}
    step = arch.make_fused_train_step(opt)
    c_f = jax.jit(lambda p, s, b: step(p, s, b, hparams=hp),
                  donate_argnums=(0, 1)).lower(
        params, opt_state, batch).compile()
    loss_fn = arch.make_loss_fn()

    def unfused(p, s, b):
        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        p2, s2 = opt.step(p, g, s, hp)
        return p2, s2, loss, m

    c_u = jax.jit(unfused, donate_argnums=(0, 1)).lower(
        params, opt_state, batch).compile()
    t_f = c_f.memory_analysis().temp_size_in_bytes
    t_u = c_u.memory_analysis().temp_size_in_bytes
    # fused must be no worse; at real scale the gap is the whole grad tree
    assert t_f <= t_u * 1.05, (t_f, t_u)

"""Baseline optimizer math + registry validation + the paper's Appendix-A
two-well analysis: Adam and SGD-with-variance escape to the global optimum;
SGD and SGD-with-momentum get stuck in the local one."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimizers as opt_lib


def _hp(rule, **over):
    """Resolved hparam dict: rule defaults + overrides."""
    return {**rule.hparams, **over}


def test_adamw_matches_manual_step():
    p = jnp.array([[1.0, -2.0]])
    g = jnp.array([[0.5, 0.25]])
    rule = opt_lib.adamw(beta1=0.9, beta2=0.99, eps=1e-8, weight_decay=0.1)
    s = rule.init(p)
    p1, s1 = rule.update(p, g, s, _hp(rule, lr=jnp.float32(0.1)),
                         jnp.float32(1))
    m = 0.1 * g
    v = 0.01 * g ** 2
    m_hat = m / 0.1
    v_hat = v / 0.01
    expect = p * (1 - 0.1 * 0.1) - 0.1 * m_hat / (jnp.sqrt(v_hat) + 1e-8)
    np.testing.assert_allclose(p1, expect, rtol=1e-6)


def test_sgd_is_lomo_rule():
    p = jnp.ones((4, 4))
    g = jnp.full((4, 4), 2.0)
    rule = opt_lib.get_rule("lomo")
    p1, _ = rule.update(p, g, rule.init(p), _hp(rule, lr=jnp.float32(0.25)),
                        jnp.float32(1))
    np.testing.assert_allclose(p1, p - 0.5)


def test_adafactor_state_is_factored():
    rule = opt_lib.adafactor()
    s = rule.init(jnp.zeros((64, 32)))
    assert s.r.shape == (64,) and s.c.shape == (32,) and s.v is None
    assert rule.state_bytes(jnp.zeros((64, 32))) == (64 + 32) * 4


def test_table1_state_byte_ordering():
    """Table 1: AdamW state ≫ Adafactor/AdaLomo state."""
    p = jnp.zeros((1024, 1024), jnp.bfloat16)
    adamw_b = opt_lib.adamw().state_bytes(p)
    adaf_b = opt_lib.adafactor().state_bytes(p)
    adal_b = opt_lib.adalomo().state_bytes(p)
    lomo_b = opt_lib.sgd().state_bytes(p)
    assert adamw_b == 2 * 1024 * 1024 * 4
    assert adal_b == adaf_b == (1024 + 1024) * 4
    assert lomo_b == 0
    assert adal_b < adamw_b / 500


# ---------------------------------------------------------------------
# Registry kwarg validation (Opt v2): helpful errors, not bare TypeErrors
# ---------------------------------------------------------------------

def test_get_rule_unknown_name_lists_registry():
    with pytest.raises(KeyError, match="unknown optimizer"):
        opt_lib.get_rule("madgrad")


def test_get_rule_unknown_kwarg_lists_accepted():
    """get_rule('lomo', weight_decay=...) must raise a KeyError naming the
    accepted kwargs, not crash with a bare TypeError."""
    with pytest.raises(KeyError) as ei:
        opt_lib.get_rule("lomo", weight_decay=0.1)
    msg = str(ei.value)
    assert "weight_decay" in msg and "accepted" in msg and "lr" in msg


def test_get_rule_accepts_declared_hparam_defaults():
    rule = opt_lib.get_rule("adamw", weight_decay=0.1)
    assert rule.hparams["weight_decay"] == 0.1


def test_call_time_hparam_validation():
    """Unknown hparam keys at call time raise, naming the accepted set."""
    opt = opt_lib.get_opt("sgd")
    p = jnp.ones((4,))
    s = opt.init(p)
    with pytest.raises(KeyError, match="accepted hyperparameters"):
        opt.step(p, p, s, {"lr": 0.1, "momentum": 0.9})


# ---------------------------------------------------------------------
# Appendix A: f(x,y) = x² + y² - 2e^{-5[(x-1)²+y²]} - 3e^{-5[(x+1)²+y²]}
# global optimum near (-1, 0); local trap near (1, 0).
# ---------------------------------------------------------------------

def _f(xy):
    x, y = xy[0], xy[1]
    return (x ** 2 + y ** 2
            - 2 * jnp.exp(-5 * ((x - 1) ** 2 + y ** 2))
            - 3 * jnp.exp(-5 * ((x + 1) ** 2 + y ** 2)))


def _descend(opt, lr, steps=600, x0=(0.5, 1.0)):
    p = jnp.array(x0)
    s = opt.init(p)
    g_fn = jax.grad(_f)

    @jax.jit
    def step(p, s, hp):
        g = g_fn(p)
        return opt.step(p, g, s, hp)

    for _ in range(steps):
        p, s = step(p, s, {"lr": jnp.float32(lr)})
    return np.asarray(p), float(_f(p))


@pytest.mark.parametrize("name,lr,expect_global", [
    ("sgd", 0.02, False),
    ("sgd_momentum", 0.02, False),
    ("sgd_variance", 0.02, True),
    ("adamw", 0.02, True),
    ("adalomo", 0.05, True),
])
def test_two_well_escape(name, lr, expect_global):
    """Second-moment methods (incl. AdaLomo) reach the deeper left well;
    first-order methods converge to the shallow right well (paper Fig. 6)."""
    opt = opt_lib.get_opt(name)
    p, fv = _descend(opt, lr)
    reached_global = p[0] < 0
    assert reached_global == expect_global, (name, p, fv)

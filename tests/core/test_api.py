"""Opt v2 contract tests: hyperparameters as arguments, state as data.

Covers path-based param-group labeling, hparam resolution/validation, the
single serializable OptState layout, and the headline property: changing
any dynamic hyperparameter (lr/β/weight-decay/clip) between steps never
triggers a recompile — schedules are data, not code.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimizers as opt_lib
from repro.core.api import (GroupSpec, LeafInfo, Opt, OptState, no_decay_1d,
                            path_str)
from repro.models.registry import get_arch


# ---------------------------------------------------------------------
# Labeling
# ---------------------------------------------------------------------

def _params():
    return {
        "outer": {"embed": jnp.zeros((8, 4)), "norm": jnp.zeros((4,))},
        "shared": {},
        "stacks": {"blocks": {"w": jnp.zeros((3, 4, 4)),
                              "scale": jnp.zeros((3, 4))}},
    }


def test_leaf_info_sees_per_tensor_shape_for_stacks():
    opt = opt_lib.get_opt("adalomo")
    flat, _, infos, _ = opt._flat_infos(_params())
    by_path = {i.path: i for i in infos}
    assert by_path["stacks/blocks/w"].stacked
    assert by_path["stacks/blocks/w"].tensor_shape == (4, 4)
    assert by_path["stacks/blocks/scale"].tensor_ndim == 1
    assert not by_path["outer/embed"].stacked
    assert by_path["outer/embed"].tensor_ndim == 2


def test_labels_regex_and_predicate_first_match_wins():
    groups = (GroupSpec("norms", match=lambda i: i.tensor_ndim <= 1),
              GroupSpec("embed", match=r"outer/embed"))
    opt = opt_lib.get_opt("adamw", groups=groups)
    labels = opt.labels(_params())
    flat = {path_str(kp): lab for kp, lab
            in jax.tree_util.tree_flatten_with_path(labels)[0]}
    assert flat["outer/norm"] == 1          # predicate
    assert flat["stacks/blocks/scale"] == 1  # stacked 1-D joins norms
    assert flat["outer/embed"] == 2         # regex
    assert flat["stacks/blocks/w"] == 0     # default group


def test_duplicate_group_names_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        Opt(opt_lib.get_rule("sgd"),
            groups=(GroupSpec("a", match="x"), GroupSpec("a", match="y")))


def test_static_group_hparams_validated_at_construction():
    with pytest.raises(KeyError, match="accepted hyperparameters"):
        opt_lib.get_opt("sgd", groups=(GroupSpec(
            "g", match="x", hparams={"weight_decay": 0.0}),))


# ---------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------

def test_resolve_merge_order():
    """defaults < call-time base < static group < call-time group."""
    opt = opt_lib.get_opt(
        "adamw", weight_decay=0.3,
        groups=(GroupSpec("g", match="x", hparams={"weight_decay": 0.0,
                                                   "lr": 5e-4}),))
    base, g = opt.resolve({"lr": 1e-3,
                           "groups": {"g": {"lr": 7e-4}}})
    assert base["lr"] == 1e-3 and base["weight_decay"] == 0.3
    assert g["weight_decay"] == 0.0          # static group override
    assert g["lr"] == 7e-4                   # call-time group override wins
    assert base["beta1"] == 0.9              # untouched default


def test_resolve_scalar_shorthand_and_unknown_group():
    opt = opt_lib.get_opt("sgd")
    (base,) = opt.resolve(0.25)
    assert base["lr"] == 0.25
    with pytest.raises(KeyError, match="unknown group"):
        opt.resolve({"groups": {"nope": {"lr": 1.0}}})


def test_describe_reports_groups():
    opt = opt_lib.get_opt("adamw", groups=(no_decay_1d(),))
    d = opt.describe(_params())
    assert d["no_decay"]["hparams"]["weight_decay"] == 0.0
    assert "outer/norm" in d["no_decay"]["paths"]
    assert "outer/embed" in d["default"]["paths"]


# ---------------------------------------------------------------------
# State as data
# ---------------------------------------------------------------------

def test_optstate_is_a_plain_pytree_single_step_scalar():
    opt = opt_lib.get_opt("adalomo")
    p = _params()
    st = opt.init(p)
    assert isinstance(st, OptState)
    assert st.step.dtype == jnp.int32 and st.step.shape == ()
    # exactly one step scalar in the whole tree: every other leaf belongs
    # to moments and matches a param's factored/unfactored layout
    int_leaves = [x for x in jax.tree.leaves(st)
                  if jnp.issubdtype(x.dtype, jnp.integer)]
    assert len(int_leaves) == 1
    # serializable: flatten/unflatten round-trip preserves structure
    leaves, treedef = jax.tree_util.tree_flatten(st)
    st2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert jax.tree.structure(st) == jax.tree.structure(st2)


def test_factored_mask_per_group():
    """GroupSpec(factored=False) forces O(mn) state for its leaves only."""
    opt = opt_lib.get_opt("adalomo", groups=(GroupSpec(
        "unfactored", match=r"outer/embed", factored=False),))
    p = {"outer": {"embed": jnp.zeros((32, 64)), "w": jnp.zeros((32, 64))}}
    st = opt.init(p)
    m = st.moments["outer"]
    assert m["embed"].v is not None and m["embed"].v.shape == (32, 64)
    assert m["w"].v is None and m["w"].r.shape == (32,)
    assert opt.state_bytes(p) == (32 * 64 + 32 + 64) * 4


def test_state_bytes_matches_eval_shape():
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    params = arch.init_params(jax.random.PRNGKey(0))
    opt = opt_lib.get_opt("adalomo")
    st = opt.init(params)
    real = sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(st.moments))
    assert opt.state_bytes(params) == real


# ---------------------------------------------------------------------
# Zero recompiles under hparam schedules (the headline v2 property)
# ---------------------------------------------------------------------

def _hp(lr, beta, wd):
    return {"lr": jnp.float32(lr), "beta": jnp.float32(beta),
            "weight_decay": jnp.float32(wd)}


def test_zero_recompile_fused_step_under_schedule():
    """Changing lr/β/weight-decay between steps must not retrigger
    compilation of the fused train step (compile-counter assertion)."""
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    opt = opt_lib.get_opt("adalomo", groups=(no_decay_1d(),))
    key = jax.random.PRNGKey(0)
    params = arch.init_params(key)
    state = opt.init(params)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, arch.cfg.vocab),
             "labels": jax.random.randint(key, (2, 16), 0, arch.cfg.vocab)}
    step = jax.jit(arch.make_fused_train_step(opt))
    for lr, beta, wd in [(1e-3, 0.999, 0.0), (5e-4, 0.99, 0.1),
                         (1e-4, 0.9, 0.01)]:
        params, state, loss, _ = step(params, state, batch,
                                      hparams=_hp(lr, beta, wd))
    assert step._cache_size() == 1, \
        "hparam schedule recompiled the fused train step"
    assert int(state.step) == 3


def test_zero_recompile_unfused_step_under_schedule():
    opt = opt_lib.get_opt("adamw", groups=(no_decay_1d(),))
    p = _params()
    p = jax.tree.map(lambda x: jnp.ones_like(x) * 0.1, p)
    g = jax.tree.map(jnp.ones_like, p)
    st = opt.init(p)
    step = jax.jit(opt.step)
    for lr, wd in [(1e-3, 0.0), (5e-4, 0.1), (2e-3, 0.3)]:
        p, st = step(p, g, st, {"lr": jnp.float32(lr),
                                "weight_decay": jnp.float32(wd),
                                "groups": {"no_decay":
                                           {"lr": jnp.float32(lr / 2)}}})
    assert step._cache_size() == 1, \
        "hparam/group-override schedule recompiled Opt.step"
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p))


def test_trainer_cosine_schedule_zero_recompiles():
    """End-to-end: the Trainer's warmup-cosine lr schedule runs entirely
    through the one compiled step."""
    from repro.data.pipeline import DataConfig, batches
    from repro.train.loop import TrainConfig, Trainer
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    tcfg = TrainConfig(optimizer="adalomo", lr=1e-3, total_steps=6,
                       schedule="cosine", log_every=0)
    tr = Trainer(arch, tcfg, log_fn=lambda s: None)
    params, state = tr.init(0)
    dcfg = DataConfig(vocab=arch.cfg.vocab, seq_len=32, global_batch=4)
    tr.fit(params, state, batches(dcfg))
    assert tr._step._cache_size() == 1, \
        "lr schedule recompiled the Trainer step"

"""Unit + property tests for the AdaLomo optimizer math (paper Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic shim (tests/_compat)
    from hypothesis_stub import given, settings, strategies as st

from repro.core.adalomo import (DEFAULT_HPARAMS, AdaLomoConfig,
                                FactoredState, init_state, reconstruct_v,
                                state_bytes, update_moment, update_tensor)

CFG = AdaLomoConfig()


def test_state_is_o_m_plus_n():
    p = jnp.zeros((512, 1024))
    st_ = init_state(p, CFG)
    assert st_.r.shape == (512,) and st_.c.shape == (1024,)
    assert st_.v is None
    # Table 1: optimizer state negligible vs 4·m·n bytes of fp32 params
    assert state_bytes(p, CFG) == (512 + 1024) * 4


def test_1d_param_unfactored():
    p = jnp.zeros((768,))
    st_ = init_state(p, CFG)
    assert st_.v.shape == (768,) and st_.r is None


def test_stacked_param_factors_trailing_dims():
    p = jnp.zeros((4, 64, 128))
    st_ = init_state(p, CFG)
    assert st_.r.shape == (4, 64) and st_.c.shape == (4, 128)


def test_moment_update_matches_paper_eq67():
    g = jnp.array([[1.0, 2.0], [3.0, 4.0]])
    st0 = FactoredState(r=jnp.array([1.0, 1.0]), c=jnp.array([2.0, 2.0]),
                        v=None)
    cfg = AdaLomoConfig(eps_stat=0.0, min_dim_size_to_factor=1)
    st1 = update_moment(g, st0, beta=0.9, cfg=cfg)
    np.testing.assert_allclose(st1.r, 0.9 * 1.0 + 0.1 * jnp.array([5., 25.]))
    np.testing.assert_allclose(st1.c, 0.9 * 2.0 + 0.1 * jnp.array([10., 20.]))


def test_reconstruction_exact_for_rank1():
    """v = outer(r,c)/sum(r) recovers g² exactly when g² is rank-1 (Eq.5)."""
    a = jnp.array([1.0, 2.0, 4.0])
    b = jnp.array([0.5, 3.0])
    g = jnp.sqrt(jnp.outer(a, b))
    cfg = AdaLomoConfig(eps_stat=0.0, min_dim_size_to_factor=1,
                        bias_correction=False)
    st0 = FactoredState(r=jnp.zeros(3), c=jnp.zeros(2), v=None)
    st1 = update_moment(g, st0, beta=0.0, cfg=cfg)
    v = reconstruct_v(st1, cfg)
    np.testing.assert_allclose(v, jnp.outer(a, b), rtol=1e-6)


def test_grouped_norm_bounds_update_rms():
    """Alg.1 line 11: RMS of the applied update ≤ clip · max(ε₂, RMS(θ))."""
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (64, 64)) * 0.05
    g = jax.random.normal(jax.random.fold_in(key, 1), (64, 64)) * 100.0
    st0 = init_state(p, CFG)
    new_p, _ = update_tensor(p, g, st0, lr=jnp.float32(1.0),
                             step=jnp.float32(1), cfg=CFG)
    upd = (p - new_p)
    rms_upd = float(jnp.sqrt(jnp.mean(upd ** 2)))
    rms_p = float(jnp.sqrt(jnp.mean(p ** 2)))
    clip = DEFAULT_HPARAMS["clip"]
    assert rms_upd <= clip * max(CFG.eps_rms, rms_p) * 1.01


def test_update_scale_invariant_to_grad_scale():
    """With bias correction at t=1, û depends only on the *direction*
    structure of g (v̂ ≈ g²), so scaling g by 1000 barely changes the step —
    the adaptive-lr property that separates AdaLomo from LOMO/SGD."""
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (32, 32))
    g = jax.random.normal(jax.random.fold_in(key, 1), (32, 32))
    st0 = init_state(p, CFG)
    p1, _ = update_tensor(p, g, st0, lr=jnp.float32(1e-2),
                          step=jnp.float32(1), cfg=CFG)
    p2, _ = update_tensor(p, g * 1000.0, st0, lr=jnp.float32(1e-2),
                          step=jnp.float32(1), cfg=CFG)
    np.testing.assert_allclose(p1, p2, rtol=1e-3)


def test_literal_div_v_mode_differs():
    cfg_lit = AdaLomoConfig(literal_div_v=True)
    key = jax.random.PRNGKey(2)
    p = jax.random.normal(key, (16, 16))
    g = jax.random.normal(jax.random.fold_in(key, 3), (16, 16))
    s0 = init_state(p, CFG)
    a, _ = update_tensor(p, g, s0, lr=jnp.float32(1e-3),
                         step=jnp.float32(1), cfg=CFG)
    b, _ = update_tensor(p, g, s0, lr=jnp.float32(1e-3),
                         step=jnp.float32(1), cfg=cfg_lit)
    assert not np.allclose(a, b)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 48), n=st.integers(1, 48),
       scale=st.floats(1e-6, 1e3),
       zero_grad=st.booleans(), steps=st.integers(1, 4))
def test_property_no_nans_and_state_shape(m, n, scale, zero_grad, steps):
    """For any shape/scale (incl. zero grads), updates stay finite and the
    state layout is O(m+n) (or O(mn) only below the factor threshold)."""
    key = jax.random.PRNGKey(m * 100 + n)
    p = jax.random.normal(key, (m, n)) * 0.1
    g = jnp.zeros((m, n)) if zero_grad else \
        jax.random.normal(jax.random.fold_in(key, 7), (m, n)) * scale
    s = init_state(p, CFG)
    n_state = sum(x.size for x in jax.tree.leaves(s))
    if min(m, n) >= CFG.min_dim_size_to_factor:
        assert n_state == m + n
    else:
        assert n_state == m * n
    for t in range(1, steps + 1):
        p, s = update_tensor(p, g, s, lr=jnp.float32(1e-3),
                             step=jnp.float32(t), cfg=CFG)
    assert bool(jnp.isfinite(p).all())
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(s))

"""End-to-end behaviour tests: the paper's claims at smoke scale.

1. AdaLomo converges where plain-SGD LOMO struggles (paper Fig. 1/4).
2. Fused (LOMO-style) and unfused paths produce the same training
   trajectory — the memory optimization is semantics-preserving.
3. The full launcher round-trips: train → checkpoint → resume.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimizers as opt_lib
from repro.data.pipeline import DataConfig, batches
from repro.models.registry import get_arch
from repro.train.loop import TrainConfig, Trainer


@pytest.fixture(scope="module")
def arch():
    return get_arch("h2o-danube-1.8b", smoke=True)


def _fit(arch, optimizer, steps=30, lr=None, fused=True, seed=0):
    lrs = {"adalomo": 1e-2, "sgd": 3e-2, "adamw": 2e-3, "lomo": 3e-2}
    tcfg = TrainConfig(optimizer=optimizer, lr=lr or lrs[optimizer],
                       total_steps=steps, fused=fused, log_every=0,
                       schedule="constant")
    trainer = Trainer(arch, tcfg, log_fn=lambda s: None)
    params, opt_state = trainer.init(seed)
    dcfg = DataConfig(vocab=arch.cfg.vocab, seq_len=64, global_batch=8,
                      seed=seed)
    out = trainer.fit(params, opt_state, batches(dcfg))
    return out["history"]


@pytest.mark.slow
def test_adalomo_trains_and_beats_start(arch):
    h = _fit(arch, "adalomo")
    assert np.isfinite(h["loss"]).all()
    assert h["loss"][-1] < h["loss"][0] - 0.3, h["loss"][:5] + h["loss"][-5:]


@pytest.mark.slow
def test_adalomo_closes_gap_to_adamw(arch):
    """Paper headline (Table 2 ordering): AdaLomo ≫ LOMO, and within a
    modest band of AdamW.  Exact parity is a convergence-scale claim (the
    grouped-norm trust ratio caps early steps on tiny-init weights); the
    smoke horizon checks the ordering that motivates the paper.  120 steps
    (not 80): at 80 the AdaLomo-vs-LOMO margin sits exactly on the 0.05
    threshold (0.048 on the seed) — 120 puts it at ~0.17, robust across
    BLAS/threading variation without weakening the assertion."""
    h_al = _fit(arch, "adalomo", steps=120)
    h_aw = _fit(arch, "adamw", steps=120)
    h_lo = _fit(arch, "lomo", steps=120)
    assert h_al["loss"][-1] < h_lo["loss"][-1] - 0.05, (
        h_al["loss"][-1], h_lo["loss"][-1])
    assert h_al["loss"][-1] < h_aw["loss"][-1] + 0.5, (
        h_al["loss"][-1], h_aw["loss"][-1])


@pytest.mark.slow
def test_fused_equals_unfused_trajectory(arch):
    h_f = _fit(arch, "adalomo", steps=10, fused=True)
    h_u = _fit(arch, "adalomo", steps=10, fused=False)
    np.testing.assert_allclose(h_f["loss"], h_u["loss"], rtol=2e-4,
                               err_msg="fused backward changed semantics")


def test_checkpoint_resume_roundtrip(tmp_path, arch):
    from repro.checkpoint.manager import CheckpointManager
    tcfg = TrainConfig(optimizer="adalomo", lr=1e-3, total_steps=6,
                       fused=True, log_every=0, ckpt_every=3,
                       schedule="constant")
    trainer = Trainer(arch, tcfg, log_fn=lambda s: None)
    params, opt_state = trainer.init(0)
    dcfg = DataConfig(vocab=arch.cfg.vocab, seq_len=32, global_batch=4)
    ckpt = CheckpointManager(tmp_path / "ck", keep_last=2)
    out = trainer.fit(params, opt_state, batches(dcfg), ckpt_manager=ckpt)
    ckpt.wait()
    assert ckpt.latest_step() == 6
    # resume from step 3 and re-train to 6: same final loss
    p0, s0 = trainer.init(0)
    step, (p3, s3), _ = ckpt.restore(3, template=(p0, s0))
    assert step == 3
    out2 = trainer.fit(p3, s3, batches(dcfg, start_step=3), start_step=3)
    np.testing.assert_allclose(out2["history"]["loss"][-1],
                               out["history"]["loss"][-1], rtol=1e-4)


def test_optstate_step_roundtrips_bitwise(tmp_path, arch):
    """Opt v2 keeps exactly ONE step counter (OptState.step), and the
    checkpoint manager round-trips it: save → restore → the next step is
    bitwise identical to never having checkpointed (the step scalar feeds
    bias correction, so any drift would change the math)."""
    from repro.checkpoint.manager import CheckpointManager
    opt = opt_lib.get_opt("adalomo")
    key = jax.random.PRNGKey(7)
    params = arch.init_params(key)
    state = opt.init(params)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, arch.cfg.vocab),
             "labels": jax.random.randint(key, (2, 16), 0, arch.cfg.vocab)}
    step = jax.jit(arch.make_fused_train_step(opt))
    hp = {"lr": jnp.float32(1e-3)}
    for _ in range(2):
        params, state, _, _ = step(params, state, batch, hparams=hp)
    assert int(state.step) == 2

    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    mgr.save(2, (params, state))
    p0, s0 = arch.init_params(key), opt.init(params)
    got_step, (p_r, s_r), _ = mgr.restore(2, template=(p0, s0))
    assert got_step == 2
    assert int(s_r.step) == 2  # the one step scalar survives the round-trip

    p_live, s_live, _, _ = step(params, state, batch, hparams=hp)
    p_rest, s_rest, _, _ = step(p_r, s_r, batch, hparams=hp)
    assert int(s_live.step) == int(s_rest.step) == 3
    for a, b in zip(jax.tree.leaves((p_live, s_live)),
                    jax.tree.leaves((p_rest, s_rest))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

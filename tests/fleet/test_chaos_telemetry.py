"""Telemetry resume semantics under fault injection: kill/resume cycles
must fast-forward the schema-v1 stream to exactly ONE merged stream —
one header, no duplicated and no dropped probe records, step records
bitwise vs the uninterrupted run."""
import json

import numpy as np
import pytest

from _fleet_common import fleet_spec
from repro.fleet import chaos_run
from repro.run import ObservabilitySpec, run
from repro.telemetry import read_stream


@pytest.mark.slow
def test_chaos_resume_merges_one_probe_stream(tmp_path):
    observe = ObservabilitySpec(optimizer_every=2, factored_every=3)
    clean_mp = tmp_path / "clean.jsonl"
    clean = run(fleet_spec(tmp_path / "clean", metrics_path=str(clean_mp),
                           observe=observe),
                log_fn=lambda s: None)

    mp = tmp_path / "chaos.jsonl"
    rep = chaos_run(fleet_spec(tmp_path / "c", metrics_path=str(mp),
                               observe=observe),
                    kill_at=[2, 5], log_fn=lambda s: None)
    assert [k[0] for k in rep.kills] == [2, 5]

    # exactly one header even though the file was rewritten per resume
    lines = [json.loads(l) for l in mp.open() if l.strip()]
    assert sum(1 for r in lines if "schema" in r) == 1
    assert lines[0] == {"schema": 1, "stream": "train"}

    s = read_stream(mp)
    # probe cadence survives the kills: no duplicates, no drops
    assert [r["step"] for r in s.probes("opt_health")] == [0, 2, 4]
    assert [r["step"] for r in s.probes("factored")] == [0, 3]

    # probe payloads are bitwise identical to the uninterrupted run's —
    # the rewind re-recorded the re-executed steps exactly
    cs = read_stream(clean_mp)
    assert s.probes("opt_health") == cs.probes("opt_health")
    assert s.probes("factored") == cs.probes("factored")

    # and the step records are still the full bitwise curve
    steps = s.steps()
    assert [r["step"] for r in steps] == list(range(6))
    np.testing.assert_array_equal(
        np.asarray([r["loss"] for r in steps]),
        np.asarray(clean.history["loss"]))

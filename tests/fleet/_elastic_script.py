"""Elastic-fleet checks that need >1 device — run via subprocess (device
count locks at first jax import, so these cannot share the main pytest
process).  Each case prints a marker the pytest wrapper asserts on."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

import jax
import numpy as np

from repro.data.pipeline import DataConfig
from repro.run import (CheckpointSpec, MeshSpec, ModelSpec, OptSpec,
                       RunSpec, StepSpec, run)

QUIET = lambda s: None  # noqa: E731


def make_spec(d, total=6, shape=None, every=3):
    return RunSpec(model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
                   data=DataConfig(vocab=0, seq_len=32, global_batch=8),
                   opt=OptSpec(name="adalomo", lr=1e-3,
                               schedule="constant"),
                   steps=StepSpec(total=total),
                   mesh=(MeshSpec(kind="multi", shape=shape)
                         if shape else MeshSpec()),
                   checkpoint=CheckpointSpec(dir=str(d), every=every,
                                             resume=True),
                   log_every=0)


def _assert_tree_close(a, b, *, rtol, atol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


def test_elastic_run_matches_single_device():
    """The same RunSpec executed on a (2,2) mesh reproduces the
    single-device run (loss + params to tight tol), with zero
    steady-state recompiles of the sharded step."""
    with tempfile.TemporaryDirectory() as d:
        single = run(make_spec(d + "/a"), log_fn=QUIET)
        elastic = run(make_spec(d + "/b", shape=(2, 2)), log_fn=QUIET)
        np.testing.assert_allclose(np.asarray(single.history["loss"]),
                                   np.asarray(elastic.history["loss"]),
                                   rtol=1e-5, atol=1e-5)
        _assert_tree_close(single.params, elastic.params,
                           rtol=5e-4, atol=1e-5)
        assert elastic.program.cache_size() == 1
    print("ELASTIC_PARITY_OK")


def test_elastic_resume_reshards_opt_state():
    """A checkpoint written single-device resumes onto a (4,2) mesh:
    AdaLomo's factored OptState reshards losslessly (restored state
    equals the single-device state bitwise) and the continued curve
    matches the uninterrupted one to tight tol."""
    from repro.fleet.elastic import mesh_from_spec, program_shardings
    from repro.run.program import build_step_program

    with tempfile.TemporaryDirectory() as d:
        clean = run(make_spec(d + "/clean"), log_fn=QUIET)
        full = np.asarray(clean.history["loss"])

        half = run(make_spec(d + "/e", total=3), log_fn=QUIET)

        # restore straight onto the elastic mesh and check the factored
        # state reshards losslessly before any further step
        spec8 = make_spec(d + "/e", total=6, shape=(4, 2))
        mesh = mesh_from_spec(spec8.mesh)
        program = build_step_program(spec8)
        p_sh, o_sh, _, _ = program_shardings(program, mesh)
        from repro.checkpoint.manager import CheckpointManager
        step, (p8, s8), _ = CheckpointManager(d + "/e").restore(
            template=(half.params, half.opt_state), shardings=(p_sh, o_sh))
        assert step == 3
        _assert_tree_close(half.opt_state, s8, rtol=0, atol=0)  # bitwise
        _assert_tree_close(half.params, p8, rtol=0, atol=0)
        # the factored second-moment vectors really live on the mesh
        shardings = {str(s.spec) for s in
                     jax.tree.leaves(jax.tree.map(lambda x: x.sharding, s8))}
        assert len(shardings) > 1, shardings  # not all replicated

        # resume the run itself on the (4,2) mesh via the spec
        res = run(spec8, log_fn=QUIET)
        assert res.start_step == 3
        np.testing.assert_allclose(np.asarray(res.history["loss"]),
                                   full[3:], rtol=1e-5, atol=1e-5)
        _assert_tree_close(clean.params, res.params, rtol=5e-4, atol=1e-5)
    print("ELASTIC_RESHARD_OK")


def test_same_mesh_resume_is_bitwise():
    """Elastic kill/resume on the SAME mesh has no reduction-order delta:
    the resumed tail is bitwise-identical to the uninterrupted elastic
    run, and a mesh *change* (2,2) → (2,) still matches to tight tol."""
    with tempfile.TemporaryDirectory() as d:
        elastic = run(make_spec(d + "/a", shape=(2, 2)), log_fn=QUIET)
        full = np.asarray(elastic.history["loss"])

        run(make_spec(d + "/b", total=3, shape=(2, 2)), log_fn=QUIET)
        same = run(make_spec(d + "/b", total=6, shape=(2, 2)), log_fn=QUIET)
        assert same.start_step == 3
        np.testing.assert_array_equal(np.asarray(same.history["loss"]),
                                      full[3:])

        # shrink: 4 devices → 2 (lost half the fleet)
        run(make_spec(d + "/c", total=3, shape=(2, 2)), log_fn=QUIET)
        shrunk = run(make_spec(d + "/c", total=6, shape=(2,)), log_fn=QUIET)
        assert shrunk.start_step == 3
        np.testing.assert_allclose(np.asarray(shrunk.history["loss"]),
                                   full[3:], rtol=1e-5, atol=1e-5)
    print("ELASTIC_BITWISE_OK")


if __name__ == "__main__":
    globals()[sys.argv[1]]()

"""Sweep driver: declarative overrides, crash-isolated members, idempotent
re-invocation, one merged ranked report."""
import dataclasses
import json

import pytest

from _fleet_common import fleet_spec
from repro.fleet import (KillAtHook, SimulatedKill, apply_overrides,
                         expand_grid, materialize, member_name, run_sweep)
from repro.run import RunSpec

VARIANTS = [{"opt.lr": 1e-3}, {"opt.lr": 3e-3},
            {"opt.name": "adamw", "opt.lr": 2e-4}]


# ---------------------------------------------------------------------
# Declarative overrides (pure)
# ---------------------------------------------------------------------

def test_expand_grid_deterministic_product():
    got = expand_grid({"opt.lr": [1e-3, 3e-3], "seed": [0, 1]})
    assert got == [{"opt.lr": 1e-3, "seed": 0}, {"opt.lr": 1e-3, "seed": 1},
                   {"opt.lr": 3e-3, "seed": 0}, {"opt.lr": 3e-3, "seed": 1}]


def test_apply_overrides_nested_and_pure():
    base = fleet_spec()
    out = apply_overrides(base, {"opt.lr": 9e-4, "steps.total": 11,
                                 "seed": 7})
    assert (out.opt.lr, out.steps.total, out.seed) == (9e-4, 11, 7)
    # the base spec is frozen and untouched
    assert (base.opt.lr, base.steps.total) == (1e-3, 6)
    # round-trips: an overridden spec is still a plain RunSpec
    assert RunSpec.from_json(out.to_json()) == out


def test_apply_overrides_unknown_field_fails_loudly():
    with pytest.raises(ValueError, match="opt.bogus"):
        apply_overrides(fleet_spec(), {"opt.bogus": 1})
    with pytest.raises(ValueError, match="not a spec node"):
        apply_overrides(fleet_spec(), {"seed.deeper": 1})


def test_member_name_stable_and_safe():
    assert member_name(0, {"opt.lr": 0.001}) == "00_opt.lr=0.001"
    assert member_name(3, {}) == "03_base"
    weird = member_name(1, {"model/arch": "a b"})
    assert "/" not in weird and " " not in weird


def test_materialize_forces_resumable_members(tmp_path):
    members = materialize(fleet_spec(), VARIANTS, tmp_path)
    assert [m.name for m in members] == [
        "00_opt.lr=0.001", "01_opt.lr=0.003",
        "02_opt.lr=0.0002-opt.name=adamw"]
    for m in members:
        ck = m.spec.checkpoint
        assert ck.resume and ck.gc_incomplete and ck.every
        assert str(m.dir) in ck.dir
        assert m.spec.metrics_path == str(m.dir / "metrics.jsonl")
        # spec.json replays to the exact member spec
        replay = RunSpec.from_json((m.dir / "spec.json").read_text())
        assert replay == m.spec


# ---------------------------------------------------------------------
# Execution + report
# ---------------------------------------------------------------------

@pytest.mark.slow
def test_inproc_sweep_report_and_idempotence(tmp_path):
    base = fleet_spec(total=4, every=2)
    report = run_sweep(base, VARIANTS, tmp_path / "sw",
                       log_fn=lambda s: None)

    assert report["n_members"] == 3 and report["n_done"] == 3
    assert report["objective"] == "final_loss"
    rows = {r["name"]: r for r in report["members"]}
    assert set(report["ranking"]) == set(rows)
    # ranking ascending by final loss
    losses = [rows[n]["final_loss"] for n in report["ranking"]]
    assert losses == sorted(losses)
    assert report["best"]["name"] == report["ranking"][0]
    for r in rows.values():
        assert r["status"] == "done"
        assert r["steps_done"] == 4
        assert "final_loss" in r and "best_loss" in r
    # the report is a committed-artifact-shaped JSON on disk
    on_disk = json.loads((tmp_path / "sw" / "report.json").read_text())
    assert on_disk["ranking"] == report["ranking"]
    assert on_disk["base_spec"] == base.to_dict()

    # re-invocation skips everything (DONE markers), same report
    logs = []
    report2 = run_sweep(base, VARIANTS, tmp_path / "sw", log_fn=logs.append)
    assert report2["ranking"] == report["ranking"]
    assert sum("skipping" in l for l in logs) == 3


@pytest.mark.slow
def test_crash_mid_sweep_resumes_only_unfinished(tmp_path):
    """Satellite acceptance: kill a member mid-run, re-invoke the sweep —
    finished members are skipped, the killed one resumes from its last
    complete checkpoint (not from scratch)."""
    # checkpoint.every=2 (no dir: materialize assigns per-member dirs),
    # so the kill at boundary 3 leaves the step-2 save as the newest
    from repro.run import CheckpointSpec
    base = fleet_spec(total=6, checkpoint=CheckpointSpec(every=2))
    sweep_dir = tmp_path / "sw"

    def kill_member_1(member):
        # member 01 dies at step boundary 3 (after its step-2 checkpoint)
        return (KillAtHook(3),) if member.name.startswith("01_") else ()

    # SimulatedKill is a BaseException: it takes down the whole sweep
    # driver, exactly like a process death mid-sweep
    with pytest.raises(SimulatedKill):
        run_sweep(base, VARIANTS, sweep_dir, member_hooks=kill_member_1,
                  log_fn=lambda s: None)

    names = ["00_opt.lr=0.001", "01_opt.lr=0.003",
             "02_opt.lr=0.0002-opt.name=adamw"]
    assert (sweep_dir / names[0] / "DONE.json").exists()
    assert not (sweep_dir / names[1] / "DONE.json").exists()
    assert not (sweep_dir / names[2] / "DONE.json").exists()
    # the killed member left a resumable checkpoint behind
    from repro.checkpoint.manager import CheckpointManager
    assert CheckpointManager(sweep_dir / names[1] / "ckpt").latest_step() == 2

    logs = []
    report = run_sweep(base, VARIANTS, sweep_dir, log_fn=logs.append)
    assert report["n_done"] == 3
    assert sum("skipping" in l for l in logs) == 1          # member 00 only
    assert any("resumed from step 2" in l for l in logs)    # member 01
    # the resumed member's merged metrics stream covers the full curve
    recs = [json.loads(l)
            for l in (sweep_dir / names[1] / "metrics.jsonl").open()
            if l.strip()]
    data = [r for r in recs if "schema" not in r and "event" not in r]
    assert [r["step"] for r in data] == list(range(6))
    # and its history is complete
    hist = json.loads((sweep_dir / names[1] / "history.json").read_text())
    assert len(hist["loss"]) == 6 - 2   # resumed tail


def test_failed_member_is_contained(tmp_path):
    # a member whose spec cannot build fails alone; the sweep finishes
    base = fleet_spec(total=2, every=1)
    report = run_sweep(base, [{"opt.lr": 1e-3},
                              {"opt.name": "no-such-optimizer"}],
                       tmp_path / "sw", log_fn=lambda s: None)
    rows = {r["name"]: r for r in report["members"]}
    statuses = sorted(r["status"] for r in rows.values())
    assert statuses == ["done", "failed"]
    failed = next(r for r in rows.values() if r["status"] == "failed")
    assert (tmp_path / "sw" / failed["name"] / "error.txt").exists()
    assert failed["name"] not in report["ranking"]

"""Elastic restore: resume the same RunSpec on a different device mesh.

Fast cases run in-process on the single default device; everything
needing a real multi-device mesh runs ``_elastic_script.py`` in a
subprocess with 8 virtual devices (device count locks at first jax
import)."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import pytest

from repro.fleet import mesh_from_spec, program_shardings
from repro.run import MeshSpec
from repro.run.spec import parse_mesh_shape

SCRIPT = Path(__file__).parent / "_elastic_script.py"
REPO = Path(__file__).resolve().parents[2]


def _run_case(case: str, marker: str):
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src"),
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), case],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=str(REPO))
    assert marker in proc.stdout, (proc.stdout[-2000:], proc.stderr[-4000:])


# ---------------------------------------------------------------------
# Fast, in-process
# ---------------------------------------------------------------------

def test_mesh_spec_shape_normalization():
    m = MeshSpec(kind="multi", shape=[4, 2])
    assert m.shape == (4, 2) and m.n_devices() == 8
    with pytest.raises(ValueError):
        MeshSpec(shape=(0,))
    with pytest.raises(ValueError):
        MeshSpec(shape=(2, 2, 2, 2))


def test_parse_mesh_shape_forms():
    assert parse_mesh_shape(None) is None
    assert parse_mesh_shape("8") == (8,)
    assert parse_mesh_shape("4x2") == (4, 2)
    assert parse_mesh_shape("2,2,2") == (2, 2, 2)
    with pytest.raises(SystemExit):
        parse_mesh_shape("4x0")
    with pytest.raises(SystemExit):
        parse_mesh_shape("abc")


def test_mesh_from_spec_requires_enough_devices():
    need = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="--virtual-devices"):
        mesh_from_spec(MeshSpec(kind="multi", shape=(need,)))
    with pytest.raises(ValueError, match="shape is required"):
        mesh_from_spec(MeshSpec())


def test_program_shardings_cover_signature():
    # a (1,)-mesh exists on any machine; the shardings must mirror the
    # program's abstract (params, opt_state, batch, hparams) signature
    from repro.run import ModelSpec, OptSpec, RunSpec, StepSpec
    from repro.data.pipeline import DataConfig
    from repro.run.program import build_step_program
    spec = RunSpec(model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
                   data=DataConfig(vocab=0, seq_len=32, global_batch=4),
                   opt=OptSpec(name="adalomo"), steps=StepSpec(total=1))
    program = build_step_program(spec)
    mesh = mesh_from_spec(MeshSpec(kind="multi", shape=(1,)))
    p_sh, o_sh, b_sh, hp_sh = program_shardings(program, mesh)
    p_sds, o_sds, b_sds, hp_sds = program.abstract_args()
    for sh_tree, sds_tree in ((p_sh, p_sds), (o_sh, o_sds),
                              (b_sh, b_sds), (hp_sh, hp_sds)):
        assert (jax.tree.structure(sh_tree) ==
                jax.tree.structure(sds_tree))


# ---------------------------------------------------------------------
# Multi-device, subprocess
# ---------------------------------------------------------------------

@pytest.mark.slow
def test_elastic_run_matches_single_device():
    _run_case("test_elastic_run_matches_single_device",
              "ELASTIC_PARITY_OK")


@pytest.mark.slow
def test_elastic_resume_reshards_opt_state():
    _run_case("test_elastic_resume_reshards_opt_state",
              "ELASTIC_RESHARD_OK")


@pytest.mark.slow
def test_same_mesh_resume_is_bitwise():
    _run_case("test_same_mesh_resume_is_bitwise", "ELASTIC_BITWISE_OK")

"""Preemption safety: SIGTERM/SIGINT → boundary checkpoint → resumable
marker → ``Preempted`` → bitwise resume (DESIGN.md §"Elastic training
fleet").  The subprocess test is the end-to-end acceptance path: a real
SIGTERM against ``repro.launch.train``, exit code 75, then an elastic
resume onto a *different* virtual-device mesh reproducing the
uninterrupted loss curve.
"""
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from _fleet_common import fleet_spec
from repro.checkpoint.manager import CheckpointManager
from repro.fleet import PREEMPTED_EXIT_CODE, Preempted, PreemptionHook
from repro.run import FaultSpec, Hook, run

REPO = Path(__file__).resolve().parents[2]


class SendSignal(Hook):
    """Raise a real signal against our own pid at a step boundary —
    exactly what a cluster scheduler's grace period delivers."""

    def __init__(self, at_step, signum=signal.SIGTERM):
        self.at_step, self.signum = at_step, signum

    def on_step_end(self, ctx, ev):
        if ev.step + 1 == self.at_step:
            os.kill(os.getpid(), self.signum)


def test_sigterm_checkpoints_at_boundary_and_resumes_bitwise(tmp_path):
    # every=5 > kill step: the preemption save is OFF the checkpoint
    # schedule, proving the boundary save is unconditional.
    clean = run(fleet_spec(tmp_path / "clean", every=5),
                log_fn=lambda s: None)
    full = np.asarray(clean.history["loss"])

    spec = fleet_spec(tmp_path / "p", every=5,
                      metrics_path=str(tmp_path / "m.jsonl"))
    # user hooks run after the default pipeline, so a signal at boundary
    # k is observed by PreemptionHook at boundary k+1
    with pytest.raises(Preempted) as ei:
        run(spec, hooks=[SendSignal(2)], log_fn=lambda s: None)
    assert ei.value.step == 3

    mgr = CheckpointManager(tmp_path / "p")
    assert mgr.latest_step() == 3          # off-schedule boundary save
    marker = mgr.read_preempt_marker()
    assert marker == {"step": 3, "resumable": True,
                      "signum": int(signal.SIGTERM)}
    records = [json.loads(l) for l in (tmp_path / "m.jsonl").open()]
    assert {"event": "preempted", "step": 3,
            "signum": int(signal.SIGTERM)} in records

    orig = signal.getsignal(signal.SIGTERM)
    res = run(spec, log_fn=lambda s: None)
    assert res.start_step == 3
    assert mgr.read_preempt_marker() is None   # marker consumed
    np.testing.assert_array_equal(np.asarray(res.history["loss"]), full[3:])
    # original handler restored after the run
    assert signal.getsignal(signal.SIGTERM) == orig


def test_second_signal_escalates(tmp_path):
    hook = PreemptionHook(CheckpointManager(tmp_path))
    orig = signal.getsignal(signal.SIGINT)
    for sig in (signal.SIGTERM, signal.SIGINT):
        hook._originals[sig] = signal.signal(sig, hook._handler)
    try:
        os.kill(os.getpid(), signal.SIGTERM)   # first: sets the flag
        assert hook.requested == signal.SIGTERM
        with pytest.raises(KeyboardInterrupt):
            os.kill(os.getpid(), signal.SIGINT)  # second: escalates
    finally:
        hook._restore()                          # no-op if handler restored
    assert signal.getsignal(signal.SIGINT) == orig


def test_preempt_opt_out_and_no_ckpt(tmp_path):
    # no checkpoint manager → hook never registered
    res = run(fleet_spec(total=1), log_fn=lambda s: None)
    assert not any(isinstance(h, PreemptionHook) for h in res.hooks)
    # fault.preempt=False opts out even with checkpoints
    res = run(fleet_spec(tmp_path, total=1, fault=FaultSpec(preempt=False)),
              log_fn=lambda s: None)
    assert not any(isinstance(h, PreemptionHook) for h in res.hooks)


@pytest.mark.slow
def test_sigterm_then_elastic_resume_subprocess(tmp_path):
    """Acceptance: SIGTERM a real training process mid-run (exit 75,
    resumable marker), then resume it with ``--elastic-from`` onto a
    4x2 virtual-device mesh; the merged metrics stream reproduces the
    uninterrupted single-device loss curve to tight tolerance (bitwise
    before the kill)."""
    spec = fleet_spec(tmp_path / "run", total=40, every=4,
                      metrics_path=str(tmp_path / "m.jsonl"))
    spec_file = tmp_path / "spec.json"
    spec_file.write_text(spec.to_json())

    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    child = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", "--spec",
         str(spec_file)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env, cwd=str(REPO))

    def steps_done():
        try:
            lines = (tmp_path / "m.jsonl").read_text().splitlines()
        except OSError:
            return 0
        n = 0
        for line in lines:
            try:
                n = max(n, json.loads(line).get("step", -1) + 1)
            except ValueError:
                pass
        return n

    deadline = time.time() + 420
    while steps_done() < 3 and time.time() < deadline:
        assert child.poll() is None, \
            f"child exited early:\n{child.stdout.read()[-4000:]}"
        time.sleep(0.1)
    assert steps_done() >= 3, "child never reached step 3"
    child.send_signal(signal.SIGTERM)
    out, _ = child.communicate(timeout=300)
    assert child.returncode == PREEMPTED_EXIT_CODE, out[-4000:]

    mgr = CheckpointManager(tmp_path / "run")
    marker = mgr.read_preempt_marker()
    assert marker and marker["resumable"]
    killed_at = marker["step"]
    assert mgr.latest_step() == killed_at < spec.steps.total

    # resume onto a DIFFERENT mesh: 8 virtual devices, 4x2
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--spec",
         str(spec_file), "--elastic-from", str(tmp_path / "run"),
         "--mesh-shape", "4x2", "--virtual-devices", "8",
         "--history-out", str(tmp_path / "hist.json")],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=str(REPO))
    assert proc.returncode == 0, (proc.stdout[-3000:], proc.stderr[-3000:])
    assert mgr.read_preempt_marker() is None

    # uninterrupted single-device reference
    clean = run(fleet_spec(tmp_path / "clean", total=40, every=4),
                log_fn=lambda s: None)
    full = np.asarray(clean.history["loss"])

    recs = [json.loads(l) for l in (tmp_path / "m.jsonl").open()
            if l.strip()]
    steps = sorted((r for r in recs
                    if "event" not in r and "schema" not in r),
                   key=lambda r: r["step"])
    assert [r["step"] for r in steps] == list(range(40))
    merged = np.asarray([r["loss"] for r in steps])
    # bitwise up to the preemption boundary (same device, same stream)
    np.testing.assert_array_equal(merged[:killed_at], full[:killed_at])
    # tight tolerance across the mesh change (reduction order only)
    np.testing.assert_allclose(merged, full, rtol=1e-4, atol=1e-5)

"""Shared spec factory for the fleet tests (smoke model, tiny batches)."""
from repro.data.pipeline import DataConfig
from repro.run import (CheckpointSpec, ModelSpec, OptSpec, RunSpec,
                       StepSpec)


def fleet_spec(ckpt_dir=None, *, total=6, every=3, metrics_path=None, **kw):
    base = dict(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataConfig(vocab=0, seq_len=32, global_batch=8),
        opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant"),
        steps=StepSpec(total=total),
        metrics_path=metrics_path,
        log_every=0)
    if ckpt_dir is not None:
        base["checkpoint"] = CheckpointSpec(dir=str(ckpt_dir), every=every,
                                            resume=True)
    base.update(kw)
    return RunSpec(**base)

"""Fault-injection harness: kill/resume cycles must reproduce the
uninterrupted run bitwise (the rewind contract, end to end)."""
import json

import numpy as np
import pytest

from _fleet_common import fleet_spec
from repro.fleet import ChaosReport, KillAtHook, SimulatedKill, chaos_run
from repro.run import run


def test_simulated_kill_is_uncatchable_by_recovery():
    # BaseException: neither the runner's transient-failure recovery nor
    # the sweep's crash isolation (`except Exception`) can swallow it —
    # it behaves like a process death.
    assert issubclass(SimulatedKill, BaseException)
    assert not issubclass(SimulatedKill, Exception)


def test_chaos_requires_checkpointing():
    with pytest.raises(ValueError):
        chaos_run(fleet_spec(), kill_at=[2])


@pytest.mark.slow
def test_kill_resume_cycles_are_bitwise(tmp_path):
    clean = run(fleet_spec(tmp_path / "clean"), log_fn=lambda s: None)
    full = np.asarray(clean.history["loss"])

    # two kills — one before the first checkpoint (resume from scratch),
    # one after — plus a wrecked last save (crash mid-write): recovery
    # must fall back to the previous complete checkpoint and still
    # converge to the identical curve.
    rep = chaos_run(fleet_spec(tmp_path / "c",
                               metrics_path=str(tmp_path / "c.jsonl")),
                    kill_at=[2, 5], wreck_last_save=True,
                    log_fn=lambda s: None)
    assert isinstance(rep, ChaosReport)
    assert [k[0] for k in rep.kills] == [2, 5]
    assert all(r < k for k, r in rep.kills)   # resumed strictly earlier

    # the final run's own tail is bitwise
    tail = np.asarray(rep.result.history["loss"])
    np.testing.assert_array_equal(tail, full[rep.result.start_step:])

    # the merged metrics stream (rewritten across every resume) is the
    # full uninterrupted curve, bitwise
    recs = [json.loads(l) for l in (tmp_path / "c.jsonl").open()
            if l.strip()]
    steps = [r for r in recs if "event" not in r and "schema" not in r]
    assert [r["step"] for r in steps] == list(range(6))
    np.testing.assert_array_equal(
        np.asarray([r["loss"] for r in steps]), full)

    # final params identical to the uninterrupted run
    import jax
    for a, b in zip(jax.tree.leaves(clean.params),
                    jax.tree.leaves(rep.result.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kill_that_never_fires_is_an_error(tmp_path):
    with pytest.raises(AssertionError, match="never fired"):
        chaos_run(fleet_spec(tmp_path, total=2), kill_at=[10],
                  log_fn=lambda s: None)


def test_kill_at_hook_raises_at_boundary(tmp_path):
    hook = KillAtHook(2)
    with pytest.raises(SimulatedKill):
        run(fleet_spec(tmp_path, total=4), hooks=[hook],
            log_fn=lambda s: None)

"""Flash custom-VJP (recompute-in-backward) vs direct-attention autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _setup(S=64, B=2, K=2, G=2, dh=16, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, K, G, dh))
    k = jax.random.normal(ks[1], (B, S, K, dh))
    v = jax.random.normal(ks[2], (B, S, K, dh))
    pos = jnp.arange(S, dtype=jnp.int32)
    return q, k, v, pos


@pytest.mark.parametrize("spec,pl", [
    (L.MaskSpec(causal=True), None),
    (L.MaskSpec(causal=True, window=9), None),
    (L.MaskSpec(causal=True, has_prefix=True), np.array([5, 23])),
    (L.MaskSpec(causal=False), None),
])
@pytest.mark.parametrize("tiles", [1, 2, 4])
def test_flash_grads_match_direct(spec, pl, tiles):
    q, k, v, pos = _setup()
    dh = q.shape[-1]
    plj = jnp.asarray(pl) if pl is not None else None

    def f_flash(q, k, v):
        o = L._flash_attention(q, k, v, pos, pos, spec, plj, dh ** -0.5,
                               16, 16, tiles=tiles)
        return jnp.sum(o * jnp.cos(o))

    def f_direct(q, k, v):
        m = L._mask_block(pos, pos, spec, plj)
        m = m[None, None, None] if m.ndim == 2 else m[:, None, None]
        o = L._direct_attention(q, k, v, m, dh ** -0.5)
        return jnp.sum(o * jnp.cos(o))

    v1, g1 = jax.value_and_grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    v2, g2 = jax.value_and_grad(f_direct, argnums=(0, 1, 2))(q, k, v)
    # scalar is a sum over B*S*K*G*dh fp32 terms in different association
    # orders (blockwise online softmax vs direct); 1e-5 sat exactly on the
    # observed prefix-LM error (1.33e-5) — 5e-5 bounds reorder noise
    np.testing.assert_allclose(v1, v2, rtol=5e-5)
    for a, b, nm in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5,
                                   err_msg=f"d{nm}")


def test_flash_non_divisible_blocks():
    """Edge shapes: S not a multiple of the block size."""
    q, k, v, pos = _setup(S=50)
    dh = q.shape[-1]
    spec = L.MaskSpec(causal=True)

    def f(q, k, v, impl):
        if impl == "flash":
            o = L._flash_attention(q, k, v, pos, pos, spec, None,
                                   dh ** -0.5, 16, 16, tiles=1)
        else:
            m = L._mask_block(pos, pos, spec, None)[None, None, None]
            o = L._direct_attention(q, k, v, m, dh ** -0.5)
        return jnp.sum(jnp.tanh(o))

    v1, g1 = jax.value_and_grad(f)(q, k, v, "flash")
    v2, g2 = jax.value_and_grad(f)(q, k, v, "direct")
    np.testing.assert_allclose(v1, v2, rtol=1e-5)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)

"""SSD correctness: chunked algorithm == naive recurrence; decode == train."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_chunked


def _naive_ssd(x, dt, A, Bm, Cm, D):
    """Reference: per-timestep linear recurrence
    s_t = exp(dt_t A) s_{t-1} + dt_t B_t x_t^T;  y_t = C_t s_t + D x_t."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = np.repeat(np.asarray(Bm), rep, axis=2)
    Ch = np.repeat(np.asarray(Cm), rep, axis=2)
    s = np.zeros((B, H, P, N))
    ys = np.zeros((B, S, H, P))
    x = np.asarray(x); dt = np.asarray(dt); A = np.asarray(A)
    for t in range(S):
        dec = np.exp(dt[:, t] * A[None])                  # [B,H]
        s = s * dec[:, :, None, None] + np.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t], Bh[:, t], x[:, t])
        ys[:, t] = np.einsum("bhn,bhpn->bhp", Ch[:, t], s) \
            + x[:, t] * np.asarray(D)[None, :, None]
    return ys, s


@pytest.mark.parametrize("S,chunk", [(16, 4), (20, 8), (8, 8), (31, 8)])
def test_chunked_equals_naive(S, chunk):
    B, H, P, G, N = 2, 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 9), (B, S, G, N)) * 0.5
    D = jnp.ones((H,))
    y_chunk, s_chunk = ssd_chunked(x, dt, A, Bm, Cm, D, chunk,
                                   return_state=True)
    y_ref, s_ref = _naive_ssd(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(y_chunk, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s_chunk, s_ref, rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_prefill():
    from repro.models.registry import get_arch
    from repro.models.mamba2 import make_decode_step, make_prefill_step
    arch = get_arch("mamba2-1.3b", smoke=True)
    cfg = arch.cfg
    key = jax.random.PRNGKey(1)
    params = arch.init_params(key)
    B, S = 2, 11
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    lg_p, cache_p = jax.jit(make_prefill_step(cfg))(params,
                                                    {"tokens": toks})
    decode = jax.jit(make_decode_step(cfg))
    from repro.models.mamba2 import init_state_cache
    cache = init_state_cache(cfg, B)
    lg = None
    for t in range(S):
        lg, cache = decode(params, cache, {"tokens": toks[:, t:t + 1]})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_p),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache["ssm"]),
                               np.asarray(cache_p["ssm"]),
                               rtol=2e-3, atol=2e-3)


def test_hybrid_decode_matches_prefill():
    from repro.models.registry import get_arch
    from repro.models.hybrid import make_decode_step, make_prefill_step
    arch = get_arch("zamba2-1.2b", smoke=True)
    cfg = arch.cfg
    key = jax.random.PRNGKey(2)
    params = arch.init_params(key)
    B, S = 1, 9
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    lg_p, _ = jax.jit(make_prefill_step(cfg, max_len=16))(params,
                                                          {"tokens": toks})
    decode = jax.jit(make_decode_step(cfg))
    cache = arch.init_cache(B, 16)
    lg = None
    for t in range(S):
        lg, cache = decode(params, cache, {"tokens": toks[:, t:t + 1]})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_p),
                               rtol=2e-3, atol=2e-3)

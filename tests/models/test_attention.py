"""Attention-path equivalences: blockwise == direct, SWA gather == masked
direct, decode == last-token of prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _qkv(key, B, S, H, K, dh, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, K, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, K, dh), dtype)
    return q, k, v


@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("S", [24, 65])
def test_blockwise_matches_direct(S, window):
    B, H, K, dh = 2, 4, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, K, dh)
    pos = jnp.arange(S, dtype=jnp.int32)
    spec = L.MaskSpec(causal=True, window=window)
    direct = L.attention(q, k, v, spec=spec, q_pos=pos, kv_pos=pos,
                         force_direct=True)
    blocked = L._block_attention(
        q.reshape(B, S, K, 2, dh), k, v, pos, pos, spec, None, dh ** -0.5,
        q_block=16, kv_block=16).reshape(B, S, H, dh)
    np.testing.assert_allclose(direct, blocked, rtol=2e-5, atol=2e-5)


def test_swa_gather_matches_direct():
    B, S, H, K, dh, W = 1, 96, 4, 4, 8, 16
    q, k, v = _qkv(jax.random.PRNGKey(1), B, S, H, K, dh)
    pos = jnp.arange(S, dtype=jnp.int32)
    spec = L.MaskSpec(causal=True, window=W)
    direct = L.attention(q, k, v, spec=spec, q_pos=pos, kv_pos=pos,
                         force_direct=True)
    swa = L._swa_gather_attention(
        q.reshape(B, S, K, 1, dh), k, v, pos, pos, spec, dh ** -0.5,
        q_block=16).reshape(B, S, H, dh)
    np.testing.assert_allclose(direct, swa, rtol=2e-5, atol=2e-5)


def test_prefix_lm_mask():
    """Prefix positions are bidirectionally visible; suffix stays causal."""
    q_pos = jnp.arange(6, dtype=jnp.int32)
    kv_pos = jnp.arange(6, dtype=jnp.int32)
    spec = L.MaskSpec(causal=True, has_prefix=True)
    m = L._mask_block(q_pos, kv_pos, spec, prefix_len=jnp.array([3]))
    m = np.asarray(m[0])
    assert m[0, 2]  # prefix kv visible to earlier query (bidirectional)
    assert not m[3, 4]  # suffix still causal
    assert m[4, 3]


@pytest.mark.parametrize("arch_id", ["h2o-danube-1.8b", "qwen3-32b",
                                     "deepseek-v3-671b"])
def test_decode_matches_prefill_logits(arch_id):
    """Greedy decode path reproduces teacher-forced forward logits."""
    import dataclasses
    from repro.models.registry import get_arch
    arch = get_arch(arch_id, smoke=True)
    cfg = arch.cfg
    if cfg.moe is not None:
        # capacity dropping is sequence-length dependent; equivalence holds
        # in the no-drop regime (inference-style capacity factor)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(3)
    params = arch.init_params(key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    # full forward logits at the last position
    from repro.core.fused import unfused_loss_fn
    from repro.models.transformer import (_logits, make_fused_spec,
                                          make_prefill_step,
                                          make_decode_step, init_cache)
    prefill = jax.jit(make_prefill_step(cfg))
    lg_prefill, cache = prefill(params, {"tokens": toks})
    # decode token-by-token from an empty cache
    decode = jax.jit(make_decode_step(cfg))
    cache2 = init_cache(cfg, B, S + 4)
    lg = None
    for t in range(S):
        lg, cache2 = decode(params, cache2, {"tokens": toks[:, t:t + 1]})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_prefill),
                               rtol=2e-4, atol=2e-4)


def test_swa_ring_cache_decode():
    """SWA decode with a ring cache (W slots) matches full-cache decode."""
    from repro.models.registry import get_arch
    from repro.models.transformer import make_decode_step, init_cache
    arch = get_arch("h2o-danube-1.8b", smoke=True)  # window=8
    cfg = arch.cfg
    key = jax.random.PRNGKey(4)
    params = arch.init_params(key)
    B, T = 1, 14  # beyond the window
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab)
    decode = jax.jit(make_decode_step(cfg))
    ring = init_cache(cfg, B, max_len=cfg.window)       # W slots only
    assert ring["k"].shape[2] == cfg.window
    big = init_cache(cfg, B, max_len=64)                # effectively unbounded
    lg_r = lg_b = None
    for t in range(T):
        lg_r, ring = decode(params, ring, {"tokens": toks[:, t:t + 1]})
        lg_b, big = decode(params, big, {"tokens": toks[:, t:t + 1]})
    np.testing.assert_allclose(np.asarray(lg_r), np.asarray(lg_b),
                               rtol=2e-4, atol=2e-4)

"""No-cross-segment attention: every kernel path (direct, blockwise,
flash custom-VJP) against a per-document oracle, plus the bitwise
zero-leakage identity — scrubbing every foreign segment's k/v must not
change a single bit of the target segment's output.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

B, S, K, G, dh = 2, 48, 2, 2, 16
H = K * G
ROWS = [[12, 20, 16], [30, 10]]  # row 1 has an 8-slot padding tail


def _meta():
    seg = np.zeros((B, S), np.int32)
    pos = np.zeros((B, S), np.int32)
    for b, lens in enumerate(ROWS):
        o = 0
        for j, n in enumerate(lens):
            seg[b, o:o + n] = j + 1
            pos[b, o:o + n] = np.arange(n)
            o += n
    return jnp.asarray(seg), jnp.asarray(pos)


@pytest.fixture(scope="module")
def qkv():
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, S, H, dh), jnp.float32)
    k = jax.random.normal(kk, (B, S, K, dh), jnp.float32)
    v = jax.random.normal(kv, (B, S, K, dh), jnp.float32)
    return q, k, v


def _oracle(q, k, v, window=None):
    """Per-document direct attention on sliced inputs — no packing."""
    spec = L.MaskSpec(causal=True, window=window)
    out = np.zeros((B, S, H, dh), np.float32)
    for b, lens in enumerate(ROWS):
        o = 0
        for n in lens:
            sl = slice(o, o + n)
            po = jnp.arange(n)
            r = L.attention(q[b:b + 1, sl], k[b:b + 1, sl], v[b:b + 1, sl],
                            spec=spec, q_pos=po, kv_pos=po,
                            force_direct=True)
            out[b, sl] = np.asarray(r[0])
            o += n
    return out


def _real_mask(seg):
    return (np.asarray(seg) > 0)[..., None, None]


@pytest.mark.parametrize("window", [None, 7])
def test_direct_matches_per_document_oracle(qkv, window):
    q, k, v = qkv
    seg, pos = _meta()
    spec = L.MaskSpec(causal=True, window=window, segmented=True)
    o = L.attention(q, k, v, spec=spec, q_pos=pos, kv_pos=pos,
                    q_seg=seg, kv_seg=seg, force_direct=True)
    err = np.abs(np.asarray(o) - _oracle(q, k, v, window)) * _real_mask(seg)
    assert err.max() < 2e-5


def test_block_matches_per_document_oracle(qkv):
    q, k, v = qkv
    seg, pos = _meta()
    spec = L.MaskSpec(causal=True, segmented=True)
    o = L._block_attention(q.reshape(B, S, K, G, dh), k, v, pos, pos, spec,
                           None, dh ** -0.5, q_block=16, kv_block=16,
                           q_seg=seg, kv_seg=seg)
    ob = np.asarray(o).reshape(B, S, H, dh)
    err = np.abs(ob - _oracle(q, k, v)) * _real_mask(seg)
    assert err.max() < 2e-5


def test_direct_zero_leakage_is_bitwise(qkv):
    """Replace every token outside segment 1 with junk k/v: the packed
    layout's whole correctness claim is that segment 1's output is
    *bitwise* unchanged (masked logits underflow to exact zeros in the
    same-shape reduction)."""
    q, k, v = qkv
    seg, pos = _meta()
    spec = L.MaskSpec(causal=True, segmented=True)

    def att(k_, v_):
        return L.attention(q, k_, v_, spec=spec, q_pos=pos, kv_pos=pos,
                           q_seg=seg, kv_seg=seg, force_direct=True)

    tgt = np.asarray(seg) == 1
    keep = jnp.asarray(tgt)[..., None, None]
    o_ref = att(k, v)
    o_scrub = att(jnp.where(keep, k, 7.25), jnp.where(keep, v, -3.5))
    np.testing.assert_array_equal(np.asarray(o_ref)[tgt],
                                  np.asarray(o_scrub)[tgt])


@pytest.mark.parametrize("tiles", [1, 2])
def test_flash_vjp_matches_direct_segmented(qkv, tiles):
    q, k, v = qkv
    seg, pos = _meta()
    spec = L.MaskSpec(causal=True, segmented=True)
    qr = q.reshape(B, S, K, G, dh)
    live = (seg > 0)[:, :, None, None, None]

    def scalar(o):
        o = o * live  # padded slots carry no gradient signal
        return jnp.sum(o * jnp.cos(o))

    def f_flash(q_):
        return scalar(L._flash_attention(
            q_, k, v, pos, pos, spec, None, dh ** -0.5, 16, 16,
            tiles=tiles, q_seg=seg, kv_seg=seg))

    def f_direct(q_):
        o = L.attention(q_.reshape(B, S, H, dh), k, v, spec=spec,
                        q_pos=pos, kv_pos=pos, q_seg=seg, kv_seg=seg,
                        force_direct=True)
        return scalar(o.reshape(B, S, K, G, dh))

    vf, gf = jax.value_and_grad(f_flash)(qr)
    vd, gd = jax.value_and_grad(f_direct)(qr)
    np.testing.assert_allclose(float(vf), float(vd), rtol=5e-5)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gd),
                               rtol=1e-4, atol=1e-5)


def test_flash_kv_grads_match_direct_segmented(qkv):
    q, k, v = qkv
    seg, pos = _meta()
    spec = L.MaskSpec(causal=True, segmented=True)
    qr = q.reshape(B, S, K, G, dh)
    live = (seg > 0)[:, :, None, None, None]

    def f_flash(kv_):
        k_, v_ = kv_
        o = L._flash_attention(qr, k_, v_, pos, pos, spec, None,
                               dh ** -0.5, 16, 16, tiles=2,
                               q_seg=seg, kv_seg=seg)
        return jnp.sum((o * live) ** 2)

    def f_direct(kv_):
        k_, v_ = kv_
        o = L.attention(q, k_, v_, spec=spec, q_pos=pos, kv_pos=pos,
                        q_seg=seg, kv_seg=seg, force_direct=True)
        return jnp.sum((o.reshape(B, S, K, G, dh) * live) ** 2)

    gf = jax.grad(f_flash)((k, v))
    gd = jax.grad(f_direct)((k, v))
    for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gd)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_maskspec_segmented_consistency_asserted(qkv):
    q, k, v = qkv
    seg, pos = _meta()
    with pytest.raises(AssertionError, match="segmented"):
        L.attention(q, k, v, spec=L.MaskSpec(causal=True), q_pos=pos,
                    kv_pos=pos, q_seg=seg, kv_seg=seg)
    with pytest.raises(AssertionError, match="segmented"):
        L.attention(q, k, v, spec=L.MaskSpec(causal=True, segmented=True),
                    q_pos=jnp.arange(S), kv_pos=jnp.arange(S))

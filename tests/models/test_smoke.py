"""Per-arch reduced-config smoke tests (deliverable f): one fused train
step on CPU — output shapes, finite loss, params actually move."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimizers as opt_lib
from repro.models.registry import ARCH_IDS, get_arch


def make_batch(arch, key, B=2, S=16):
    cfg = arch.cfg
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if arch.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.n_frames, cfg.d_model))
    if getattr(cfg, "prefix_lm", False):
        batch["prefix_embed"] = jax.random.normal(
            key, (B, cfg.n_prefix_tokens, cfg.d_model))
        batch["prefix_len"] = jnp.full((B,), cfg.n_prefix_tokens, jnp.int32)
    if getattr(cfg, "mtp", False):
        batch["labels_mtp"] = batch["labels"]
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_one_train_step(arch_id):
    arch = get_arch(arch_id, smoke=True)
    opt = opt_lib.get_opt("adalomo")
    key = jax.random.PRNGKey(0)
    params = arch.init_params(key)
    opt_state = opt.init(params)
    batch = make_batch(arch, key)
    step = arch.make_fused_train_step(opt)
    p2, s2, loss, metrics = jax.jit(
        lambda p, s, b: step(p, s, b, hparams=jnp.float32(1e-3)))(
        params, opt_state, batch)
    assert jnp.isfinite(loss), (arch_id, loss)
    assert float(metrics["ntokens"]) == batch["labels"].size
    # shapes preserved, params moved, everything finite
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(p2)):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert bool(jnp.isfinite(b).all()), jax.tree_util.keystr(kp)
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved
    assert int(s2.step) == 1


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_serve_decode_step(arch_id):
    arch = get_arch(arch_id, smoke=True)
    cfg = arch.cfg
    key = jax.random.PRNGKey(1)
    params = arch.init_params(key)
    B = 2
    if arch.family == "encdec":
        prefill = jax.jit(arch.make_prefill_step(max_decode_len=8))
        _, cache = prefill(params, {
            "frames": jax.random.normal(key, (B, cfg.n_frames,
                                              cfg.d_model))})
    else:
        cache = arch.init_cache(B, 8)
    decode = jax.jit(arch.make_decode_step())
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    logits, cache2 = decode(params, cache, {"tokens": tok})
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache2["cur"]) == int(cache["cur"]) + 1

"""Host-side sentinel policy: monitor bookkeeping (budget, streak,
escalation, quarantine), checkpoint-extra round-trips that rebuild the
device state exactly, and the quarantined data stream."""
import jax
import numpy as np

from repro.data.pipeline import DataConfig
from repro.models.registry import get_arch
from repro.run import ModelSpec, OptSpec, RunSpec, SentinelSpec, StepSpec
from repro.run.data import make_batch_iter
from repro.sentinel import (QUARANTINE_SEED_OFFSET, SentinelMonitor,
                            quarantined_batch_iter, state_from_snapshot)


def _spec(total=8, **kw):
    base = dict(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataConfig(vocab=0, seq_len=32, global_batch=4),
        opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant"),
        steps=StepSpec(total=total),
        log_every=0)
    base.update(kw)
    return RunSpec(**base)


def _verdict(anomaly=0.0, nonfinite=0.0, spike=0.0, trust=0.0, seen=1,
             clean=1, ema=0.5, backoff=0, skipped=0):
    return {"anomaly": anomaly, "nonfinite": nonfinite, "spike": spike,
            "trust": trust, "seen": float(seen), "clean": float(clean),
            "ema": ema, "backoff": float(backoff),
            "skipped": float(skipped)}


def test_monitor_budget_streak_and_escalation():
    m = SentinelMonitor(SentinelSpec(enabled=True,
                                     ladder=("skip", "rollback"),
                                     rollback_after=2, budget=3))
    assert not m.observe(0, _verdict())
    assert m.observe(1, _verdict(anomaly=1.0, nonfinite=1.0))
    assert m.streak == 1 and not m.wants_rollback()
    assert m.observe(2, _verdict(anomaly=1.0, spike=1.0))
    assert m.wants_rollback()

    m.quarantine(1, 3)
    assert m.streak == 0 and m.rollbacks == 1
    assert m.is_quarantined(1) and m.is_quarantined(2)
    assert not m.is_quarantined(3)

    assert not m.exhausted()
    m.observe(3, _verdict(anomaly=1.0, trust=1.0))
    m.observe(4, _verdict(anomaly=1.0, trust=1.0))
    assert m.anomalies == 4 and m.exhausted()


def test_classify_priority_order():
    assert SentinelMonitor.classify(
        _verdict(anomaly=1, nonfinite=1, spike=1)) == "nonfinite"
    assert SentinelMonitor.classify(
        _verdict(anomaly=1, spike=1, trust=1)) == "spike"
    assert SentinelMonitor.classify(_verdict(anomaly=1, trust=1)) == "trust"
    assert SentinelMonitor.classify(_verdict(anomaly=1)) == "unknown"


def test_extra_round_trip_rebuilds_device_state():
    m = SentinelMonitor(SentinelSpec(enabled=True))
    m.observe(5, _verdict(anomaly=1.0, nonfinite=1.0, seen=6, clean=4,
                          ema=0.25, backoff=2, skipped=2))
    m.quarantine(4, 6)
    extra = m.to_extra()

    m2 = SentinelMonitor(SentinelSpec(enabled=True))
    m2.load_extra(extra)
    assert m2.to_extra() == extra
    assert m2.is_quarantined(5)

    sent = state_from_snapshot(extra["state"])
    assert int(sent.seen) == 6 and int(sent.clean) == 4
    assert float(sent.ema) == 0.25
    assert int(sent.backoff) == 2 and int(sent.skipped) == 2


def test_quarantined_iter_substitutes_only_the_range():
    """Outside a quarantined range the stream is bitwise the primary
    stream; inside, it is bitwise the QUARANTINE_SEED_OFFSET stream."""
    spec = _spec()
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    m = SentinelMonitor(SentinelSpec(enabled=True))
    m.quarantine(2, 3)

    q = quarantined_batch_iter(spec, arch, 0, m)
    primary = make_batch_iter(spec, arch, 0)
    alt = next(make_batch_iter(spec, arch, 2,
                               seed_offset=QUARANTINE_SEED_OFFSET))
    for step in range(5):
        got, ref = next(q), next(primary)
        if step == 2:
            for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(alt)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert not all(
                np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)))
        else:
            for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quarantined_iter_respects_start_step():
    spec = _spec()
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    m = SentinelMonitor(SentinelSpec(enabled=True))
    m.quarantine(3, 4)
    # a rewound iterator starting at step 3 yields the replacement batch
    # first, then rejoins the primary stream at step 4
    q = quarantined_batch_iter(spec, arch, 3, m)
    alt = next(make_batch_iter(spec, arch, 3,
                               seed_offset=QUARANTINE_SEED_OFFSET))
    got = next(q)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(alt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ref = make_batch_iter(spec, arch, 4)
    for a, b in zip(jax.tree.leaves(next(q)), jax.tree.leaves(next(ref))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""In-graph sentinel guard: a skipped step is a bitwise no-op on params
AND the full OptState (step counter included), the spike guard + backoff
ladder behave, the trust guard fires, and the guarded+injected step stays
zero-recompile."""
import jax
import numpy as np
import pytest

from repro.data.pipeline import DataConfig
from repro.run import (ModelSpec, ObservabilitySpec, OptSpec, RunSpec,
                       SentinelSpec, StepSpec)
from repro.run.data import make_batch_iter
from repro.run.program import build_step_program
from repro.sentinel import Injection


def _spec(total=8, sentinel=None, **kw):
    base = dict(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataConfig(vocab=0, seq_len=32, global_batch=4),
        opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant"),
        steps=StepSpec(total=total),
        sentinel=sentinel or SentinelSpec(enabled=True),
        log_every=0)
    base.update(kw)
    return RunSpec(**base)


def _drive(program, spec, n):
    """n guarded steps on an undonated program; returns the trajectory
    [(params, opt_state, loss, verdict, sent), ...] with host verdicts."""
    params, opt_state = program.init(spec.seed)
    sent = program.init_sentinel()
    it = make_batch_iter(spec, program.arch)
    out = []
    for step in range(n):
        hp = program.hparams_fn(step + 1)
        params, opt_state, loss, metrics, sent = program.step(
            params, opt_state, next(it), hp, sent)
        out.append((params, opt_state, loss,
                    jax.device_get(metrics["sentinel"]), sent))
    return out


def test_skip_is_bitwise_noop_on_params_and_optstate():
    """The nonfinite guard discards a NaN'd update in-graph: params,
    moments AND the optimizer step counter are bitwise what they were
    before the poisoned step."""
    spec = _spec()
    program = build_step_program(
        spec, donate=False, inject=Injection(kind="nan_grads", at_step=1))
    (p0, s0, _, v0, _), (p1, s1, _, v1, sent1) = _drive(program, spec, 2)

    assert v0["anomaly"] == 0.0
    assert v1["anomaly"] == 1.0 and v1["nonfinite"] == 1.0
    for a, b in zip(jax.tree.leaves((p0, s0)), jax.tree.leaves((p1, s1))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s1.step) == 1          # the skipped step never counted
    assert int(sent1.seen) == 2 and int(sent1.clean) == 1
    assert int(sent1.skipped) == 1


def test_nan_loss_and_inf_grads_trip_the_nonfinite_guard():
    for kind in ("nan_loss", "inf_grads"):
        spec = _spec()
        program = build_step_program(
            spec, donate=False, inject=Injection(kind=kind, at_step=0))
        (_, _, _, v, sent), = _drive(program, spec, 1)
        assert v["nonfinite"] == 1.0, kind
        assert int(sent.skipped) == 1, kind


def test_nan_batch_injector_poisons_float_leaves_only():
    """The LM batch is all-int (nan_batch is a structural no-op there);
    the injector's contract on float leaves is asserted directly."""
    import jax.numpy as jnp
    inj = Injection(kind="nan_batch", at_step=2)
    batch = {"x": jnp.ones((3,), jnp.float32),
             "tok": jnp.ones((3,), jnp.int32)}
    hit = inj.poison_batch(batch, jnp.int32(2))
    assert np.isnan(np.asarray(hit["x"])).all()
    np.testing.assert_array_equal(np.asarray(hit["tok"]), 1)
    miss = inj.poison_batch(batch, jnp.int32(1))   # wrong seen: no fire
    np.testing.assert_array_equal(np.asarray(miss["x"]), 1.0)


def test_spike_guard_arms_after_warmup_and_backoff_scales_lr():
    sspec = SentinelSpec(enabled=True, ladder=("skip", "backoff"),
                         warmup=2, ema_decay=0.5, spike_factor=4.0,
                         backoff_scale=0.25, backoff_window=2)
    spec = _spec(sentinel=sspec)
    program = build_step_program(
        spec, donate=False,
        inject=Injection(kind="spike", at_step=3, scale=1000.0))
    traj = _drive(program, spec, 6)
    verdicts = [v for _, _, _, v, _ in traj]

    assert [v["anomaly"] for v in verdicts] == [0, 0, 0, 1, 0, 0]
    assert verdicts[3]["spike"] == 1.0 and verdicts[3]["nonfinite"] == 0.0
    # backoff: the two steps after the anomaly run at scaled lr, then
    # the window closes
    assert [v["lr_scale"] for v in verdicts] == [1, 1, 1, 1, 0.25, 0.25]
    assert int(traj[-1][4].backoff) == 0
    # the EMA absorbed only clean steps — the spike did not drag the
    # reference toward itself
    assert float(traj[3][4].ema) == float(traj[2][4].ema)


def test_trust_guard_blocks_every_update_when_bound_is_tiny():
    spec = _spec(sentinel=SentinelSpec(enabled=True, trust_max=1e-12))
    program = build_step_program(spec, donate=False)
    traj = _drive(program, spec, 2)
    for _, _, _, v, _ in traj:
        assert v["trust"] == 1.0 and v["anomaly"] == 1.0
        assert v["trust_worst"] > 1e-12
    sent = traj[-1][4]
    assert int(sent.clean) == 0 and int(sent.skipped) == 2


def test_guarded_injected_observed_step_has_one_cache_entry():
    """Guard + injector + optimizer-health probes all fold into ONE jaxpr:
    constant structure, zero steady-state recompiles."""
    spec = _spec(observe=ObservabilitySpec(optimizer_every=1))
    program = build_step_program(
        spec, donate=False, inject=Injection(kind="nan_loss", at_step=2))
    traj = _drive(program, spec, 5)
    assert program.cache_size() == 1
    # probes were computed on the COMMITTED transition: the skipped
    # step's metrics exist (constant structure) every step
    assert all(v["seen"] == i + 1 for i, (_, _, _, v, _) in enumerate(traj))


def test_injection_requires_sentinel():
    spec = _spec(sentinel=SentinelSpec(enabled=False))
    with pytest.raises(ValueError, match="sentinel"):
        build_step_program(spec, inject=Injection(kind="nan_grads"))


def test_sentinel_spec_validates_ladder():
    with pytest.raises(ValueError):
        SentinelSpec(enabled=True, ladder=("backoff",))   # must start skip
    with pytest.raises(ValueError):
        SentinelSpec(enabled=True, ladder=("skip", "skip"))
    with pytest.raises(ValueError):
        SentinelSpec(enabled=True, ema_decay=1.5)

"""Injected-fault acceptance (the PR 10 proof): a run with NaN'd updates
at step k completes under the skip policy with the optimizer state
untouched at k, emits schema-valid ``anomaly`` records, re-runs bitwise,
lands within tight tolerance of the clean run, survives a chaos kill with
its sentinel memory intact, escalates to rollback + quarantine, and fails
loudly when the anomaly budget is exhausted."""
import jax
import numpy as np
import pytest

from repro.data.pipeline import DataConfig
from repro.run import (CheckpointSpec, ModelSpec, OptSpec, RunSpec,
                       SentinelSpec, StepSpec, run)
from repro.sentinel import AnomalyBudgetExceeded, Injection
from repro.telemetry import read_stream

TOTAL = 8
K = 3          # fault step, on the executed-step (seen) clock


def _spec(total=TOTAL, sentinel=None, **kw):
    base = dict(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataConfig(vocab=0, seq_len=32, global_batch=4),
        opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant"),
        steps=StepSpec(total=total),
        sentinel=sentinel or SentinelSpec(enabled=True),
        log_every=0)
    base.update(kw)
    return RunSpec(**base)


def test_injected_nan_run_completes_skips_and_stays_close(tmp_path):
    mp = str(tmp_path / "m.jsonl")
    clean = run(_spec(), log_fn=lambda s: None)
    res = run(_spec(metrics_path=mp),
              inject=Injection(kind="nan_grads", at_step=K),
              log_fn=lambda s: None)

    # completes every step; the poisoned update was discarded, so the
    # optimizer's committed-step counter is exactly one short
    assert res.history["step"] == list(range(TOTAL))
    assert int(res.opt_state.step) == TOTAL - 1
    assert int(clean.opt_state.step) == TOTAL

    # forward passes are untouched through the fault step (the skip
    # preserved pre-fault params bitwise), then stay within tight
    # tolerance of the clean run
    np.testing.assert_array_equal(res.history["loss"][:K + 1],
                                  clean.history["loss"][:K + 1])
    assert np.isfinite(res.history["loss"]).all()
    np.testing.assert_allclose(res.history["loss"][K + 1:],
                               clean.history["loss"][K + 1:], rtol=0.1)

    # schema-valid stream: exactly one anomaly record, reason nonfinite,
    # at the fault step, action skip (read_stream validates every record)
    s = read_stream(mp)
    anoms = s.anomalies()
    assert [(a["anomaly"], a["step"], a["action"]) for a in anoms] == \
        [("nonfinite", K, "skip")]
    assert anoms[0]["count"] == 1
    assert s.anomalies("nonfinite") == anoms      # family filter
    assert [r["step"] for r in s.steps()] == list(range(TOTAL))

    # the guard + injector added zero recompiles
    assert res.program.cache_size() == 1


def test_injected_run_is_bitwise_reproducible(tmp_path):
    def go(i):
        mp = str(tmp_path / f"m{i}.jsonl")
        r = run(_spec(metrics_path=mp),
                inject=Injection(kind="nan_grads", at_step=K),
                log_fn=lambda s: None)
        return r, read_stream(mp)

    r1, s1 = go(1)
    r2, s2 = go(2)
    np.testing.assert_array_equal(r1.history["loss"], r2.history["loss"])
    for a, b in zip(jax.tree.leaves((r1.params, r1.opt_state)),
                    jax.tree.leaves((r2.params, r2.opt_state))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # anomaly records match on every deterministic field (update_norm is
    # NaN at a nonfinite step — NaN != NaN, so compare the keyed fields)
    key = lambda a: (a["anomaly"], a["step"], a["action"], a["count"])
    assert [key(a) for a in s1.anomalies()] == \
        [key(a) for a in s2.anomalies()]


def test_injected_chaos_kill_resumes_bitwise(tmp_path):
    """Kill the injected run after the fault, resume from checkpoint: the
    sentinel's device state rides the checkpoint extra, so the seen-clock
    keeps the fault from re-firing and the final state is bitwise the
    uninterrupted injected run's."""
    from repro.fleet import chaos_run

    inj = Injection(kind="nan_grads", at_step=K)

    def mk(d):
        return _spec(checkpoint=CheckpointSpec(dir=str(d), every=2))

    rep = chaos_run(mk(tmp_path / "a"), kill_at=[5], inject=inj)
    straight = run(mk(tmp_path / "b"), inject=inj, log_fn=lambda s: None)

    assert rep.kills == [(5, 4)]
    for a, b in zip(
            jax.tree.leaves((rep.result.params, rep.result.opt_state)),
            jax.tree.leaves((straight.params, straight.opt_state))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the skip survived the kill/resume cycle: still one uncommitted step
    assert int(rep.result.opt_state.step) == TOTAL - 1


def test_rollback_restores_quarantines_and_completes(tmp_path):
    mp = str(tmp_path / "m.jsonl")
    sspec = SentinelSpec(enabled=True, ladder=("skip", "rollback"),
                         rollback_after=1, budget=8)
    spec = _spec(sentinel=sspec, metrics_path=mp,
                 checkpoint=CheckpointSpec(dir=str(tmp_path / "ck"),
                                           every=2))
    logs = []
    res = run(spec, inject=Injection(kind="nan_grads", at_step=4),
              log_fn=logs.append)

    # checkpoint at step 4 existed when the fault hit step 4: rollback
    # restored it, quarantined [4, 5), and the replay (different seen)
    # sailed through — the run completes with a clean history
    assert any("rolled back to step 4" in m for m in logs)
    assert res.history["step"] == list(range(TOTAL))
    assert np.isfinite(res.history["loss"]).all()

    a, = read_stream(mp).anomalies()
    assert a["anomaly"] == "nonfinite" and a["action"] == "rollback"
    assert a["step"] == 4 and a["anomaly_step"] == 4
    assert a["quarantine"] == [4, 5]


def test_budget_exhaustion_fails_loudly():
    # a tiny trust bound flags every step; budget 2 allows two anomalies,
    # the third must abort — NOT spin through restore cycles
    spec = _spec(sentinel=SentinelSpec(enabled=True, trust_max=1e-12,
                                       budget=2))
    with pytest.raises(AnomalyBudgetExceeded, match="budget"):
        run(spec, log_fn=lambda s: None)


def test_budget_abort_is_recorded(tmp_path):
    mp = str(tmp_path / "m.jsonl")
    spec = _spec(sentinel=SentinelSpec(enabled=True, trust_max=1e-12,
                                       budget=1), metrics_path=mp)
    with pytest.raises(AnomalyBudgetExceeded):
        run(spec, log_fn=lambda s: None)
    anoms = read_stream(mp).anomalies()
    assert anoms[-1]["action"] == "abort" and anoms[-1]["count"] == 2

"""Substrate tests: data pipeline determinism/resume, checkpoint manager
semantics, fault-tolerance primitives."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import (DataConfig, MemmapCorpus, SyntheticLM,
                                 batches, write_corpus)
from repro.train.fault import Heartbeat, StragglerMonitor, retrying


# ---------------- data ----------------

def test_synthetic_deterministic_and_resumable():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4, seed=7)
    a = [b["tokens"] for _, b in zip(range(5), batches(cfg))]
    b = [b["tokens"] for _, b in zip(range(5), batches(cfg))]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    # resume at step 3 reproduces the tail — no iterator state needed
    c = [b["tokens"] for _, b in zip(range(2), batches(cfg, start_step=3))]
    np.testing.assert_array_equal(a[3], c[0])
    np.testing.assert_array_equal(a[4], c[1])


def test_synthetic_has_learnable_structure():
    cfg = DataConfig(vocab=64, seq_len=128, global_batch=8)
    b = SyntheticLM(cfg).batch(0)
    toks = b["tokens"]
    # copy motif: positions [32:64) repeat [0:32) within each 64-period
    np.testing.assert_array_equal(toks[:, 32:64], toks[:, 0:32])


def test_dp_ranks_get_disjoint_streams():
    k = dict(vocab=100, seq_len=16, global_batch=8, seed=1, dp_size=2)
    b0 = SyntheticLM(DataConfig(dp_rank=0, **k)).batch(0)
    b1 = SyntheticLM(DataConfig(dp_rank=1, **k)).batch(0)
    assert b0["tokens"].shape == (4, 16)  # local batch
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_memmap_corpus_roundtrip(tmp_path):
    toks = np.arange(10000, dtype=np.int64) % 50000
    path = tmp_path / "corpus.bin"
    write_corpus(path, toks)
    cfg = DataConfig(vocab=50000, seq_len=64, global_batch=2,
                     source="memmap", path=str(path))
    src = MemmapCorpus(cfg)
    b = src.batch(0)
    assert b["tokens"].shape == (2, 64)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ---------------- checkpoint ----------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "s": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    t = _tree()
    mgr.save(7, t, extra={"note": "x"})
    step, t2, extra = mgr.restore(template=jax.tree.map(jnp.zeros_like, t))
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last_and_atomicity(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2, async_write=False)
    for s in (1, 2, 3):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 3
    assert sorted(mgr._complete_steps()) == [2, 3]
    # a partially-written checkpoint (no _COMPLETE) is invisible
    bad = tmp_path / "step_000000009"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 3


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=True)
    mgr.save(1, _tree())
    mgr.wait()
    assert mgr.latest_step() == 1


# ---------------- fault ----------------

def test_retrying_recovers_then_raises():
    calls = {"n": 0}
    from jax.errors import JaxRuntimeError

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise JaxRuntimeError("transient ICI flap")
        return "ok"

    failures = []
    fn = retrying(flaky, retries=3,
                  on_failure=lambda a, e: failures.append(a))
    assert fn() == "ok"
    assert failures == [0, 1]

    def always():
        raise JaxRuntimeError("dead host")

    with pytest.raises(JaxRuntimeError):
        retrying(always, retries=1)()


def test_heartbeat_detects_stall():
    stalled = threading.Event()
    hb = Heartbeat(timeout_s=0.2, on_stall=stalled.set).start()
    hb.beat()
    time.sleep(0.5)
    assert stalled.is_set() and hb.stalled
    hb.stop()


def test_straggler_monitor():
    mon = StragglerMonitor(factor=3.0)
    assert not mon.observe(0, 1.0)
    assert not mon.observe(1, 1.1)
    assert mon.observe(2, 10.0)   # 10x the EMA → flagged
    assert len(mon.events) == 1

"""Minimal deterministic stand-in for ``hypothesis`` (offline fallback).

The real hypothesis cannot be installed in the air-gapped CI image, but the
property tests only use a tiny slice of its API: ``@given`` with keyword
strategies, ``@settings(max_examples=..., deadline=...)``, and the
``integers`` / ``floats`` / ``booleans`` / ``sampled_from`` strategies.

This shim replays ``max_examples`` pseudo-random draws from a seeded
``np.random.RandomState`` (seed derived from the test name, so runs are
reproducible and independent of collection order).  On failure it re-raises
with the drawn example attached, mirroring hypothesis's falsifying-example
report.  Semantics match hypothesis closely enough for these tests: every
draw is inside the declared bounds and the full example set is deterministic.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    def __init__(self, draw_fn, desc):
        self._draw = draw_fn
        self._desc = desc

    def draw(self, rng: np.random.RandomState):
        return self._draw(rng)

    def __repr__(self):
        return f"st.{self._desc}"


class strategies:
    """Namespace mirror of ``hypothesis.strategies`` (``import ... as st``)."""

    @staticmethod
    def integers(min_value, max_value) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: int(rng.randint(min_value, max_value + 1)),
            f"integers({min_value}, {max_value})")

    @staticmethod
    def floats(min_value, max_value) -> SearchStrategy:
        lo, hi = float(min_value), float(max_value)

        def draw(rng):
            # log-uniform when the range spans orders of magnitude, like
            # hypothesis's biased float generation; plain uniform otherwise.
            if lo > 0 and hi / lo > 1e3:
                return float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
            return float(rng.uniform(lo, hi))

        return SearchStrategy(draw, f"floats({min_value}, {max_value})")

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: bool(rng.randint(2)), "booleans()")

    @staticmethod
    def sampled_from(elements) -> SearchStrategy:
        elements = list(elements)
        return SearchStrategy(
            lambda rng: elements[rng.randint(len(elements))],
            f"sampled_from({elements})")


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording run options; composes with @given either way."""

    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(**strats):
    """Run the test once per drawn example, deterministically seeded."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            opts = (getattr(wrapper, "_stub_settings", None)
                    or getattr(fn, "_stub_settings", None)
                    or {"max_examples": DEFAULT_MAX_EXAMPLES})
            seed = zlib.adler32(fn.__name__.encode()) & 0x7FFFFFFF
            rng = np.random.RandomState(seed)
            for i in range(opts["max_examples"]):
                example = {k: s.draw(rng) for k, s in strats.items()}
                try:
                    fn(*args, **example, **kwargs)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"Falsifying example (stub, draw {i}): "
                        f"{fn.__name__}({example})") from e

        # strategy kwargs are supplied by the draw loop, not pytest fixtures
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        params = [p for name, p in
                  inspect.signature(fn).parameters.items()
                  if name not in strats]
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper

    return deco

"""``repro.telemetry.report``: bitwise reproduction of recorded stream
values, golden-stable text rendering, and the CLI surface (JSON output,
Chrome-trace export)."""
import json
from pathlib import Path

from repro.telemetry import chrome_trace, read_stream
from repro.telemetry.report import main, render_text, summarize

GOLDEN = Path(__file__).parent / "golden"


def _streams():
    return [read_stream(GOLDEN / n)
            for n in ("train.jsonl", "serve.jsonl", "kernel.jsonl")]


def test_summary_values_are_verbatim_stream_values():
    """The acceptance contract: loss / tokens-per-s / pool-utilization in
    the report are the recorded values BITWISE — no re-derivation."""
    train = json.loads((GOLDEN / "train.jsonl").read_text().splitlines()[-1])
    serve_last = json.loads(
        (GOLDEN / "serve.jsonl").read_text().splitlines()[-1])
    s = summarize(_streams())
    assert s["train"]["final_loss"] == train["loss"]
    assert s["train"]["tokens_per_s"]["final"] == train["tokens_per_s"]
    assert s["serve"]["pool_utilization"]["final"] == serve_last["pool_util"]
    # and the text carries them at full repr precision
    text = render_text(s)
    assert repr(train["loss"]) in text
    assert repr(serve_last["pool_util"]) in text


def test_report_text_matches_committed_golden():
    """CI golden check: the rendered report of the committed streams must
    be byte-identical to the committed report.txt.  Regenerate with
    ``python -m repro.telemetry.report tests/telemetry/golden/*.jsonl``
    if you change the renderer on purpose."""
    got = render_text(summarize(_streams()))
    assert got == (GOLDEN / "report.txt").read_text()


def test_summary_sections_and_ranking():
    s = summarize(_streams())
    assert s["schema_versions"] == [1]
    assert s["train"]["steps"] == 4
    assert s["train"]["probes"]["opt_health"]["records"] == 2
    assert s["train"]["events"] == {"straggler": 1}
    assert s["serve"]["samples"] == 3
    assert s["serve"]["queue_depth_max"] == 2
    kn = s["kernels"]
    assert kn["launches"] == 3
    # measured launches first (wall_us desc), analytic rows after
    walls = [r.get("wall_us") for r in kn["ranked"]]
    assert walls[:2] == sorted(walls[:2], reverse=True)
    assert walls[-1] is None


def test_merging_split_streams_equals_one_stream(tmp_path):
    """A run killed and resumed produces one file, but report must also
    merge a stream split across files to the same summary."""
    lines = (GOLDEN / "train.jsonl").read_text().splitlines()
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    a.write_text("\n".join(lines[:4]) + "\n")
    b.write_text(lines[0] + "\n" + "\n".join(lines[4:]) + "\n")
    merged = summarize([read_stream(a), read_stream(b)])
    whole = summarize([read_stream(GOLDEN / "train.jsonl")])
    assert merged["train"] == whole["train"]


def test_cli_json_out_and_chrome_trace(tmp_path, capsys):
    out = tmp_path / "summary.json"
    trace = tmp_path / "trace.json"
    rc = main([str(GOLDEN / "train.jsonl"), str(GOLDEN / "serve.jsonl"),
               "--json", "--out", str(out), "--chrome-trace", str(trace)])
    assert rc == 0
    printed = json.loads(capsys.readouterr().out)
    assert printed == json.loads(out.read_text())
    assert printed["train"]["final_loss"] == 5.230990409851074
    tj = json.loads(trace.read_text())
    assert {e["ph"] for e in tj["traceEvents"]} >= {"X", "i", "M"}


def test_chrome_trace_structure():
    st = read_stream(GOLDEN / "train.jsonl")
    tj = chrome_trace(st)
    evs = tj["traceEvents"]
    steps = [e for e in evs if e["ph"] == "X" and e["name"] == "step"]
    assert len(steps) == 4
    # steps tile the cumulative dt clock in microseconds
    assert steps[1]["ts"] == steps[0]["ts"] + steps[0]["dur"]
    assert steps[0]["dur"] == 2.0e6
    instants = [e for e in evs if e["ph"] == "i"]
    assert {e["name"] for e in instants} == {
        "probe:opt_health", "probe:factored", "event:straggler"}
    sv = chrome_trace(read_stream(GOLDEN / "serve.jsonl"))
    counters = [e for e in sv["traceEvents"] if e["ph"] == "C"]
    assert any(e["name"] == "pool_util" for e in counters)
    kr = chrome_trace(read_stream(GOLDEN / "kernel.jsonl"))
    kx = [e for e in kr["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in kx} == {"adalomo_update",
                                       "paged_decode_attention"}

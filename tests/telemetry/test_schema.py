"""Schema v1 stream contract: header/kind classification, validation
errors, legacy (schema-0) back-compat, truncation tolerance, and the
committed-benchmark validators."""
import json

import pytest

from repro.telemetry import (SCHEMA_VERSION, SchemaError, TelemetryWriter,
                             classify, header_record, iter_data_records,
                             jsonify, parse_records, read_stream,
                             validate_bench, validate_record)


def test_classify_every_kind():
    assert classify({"schema": 1, "stream": "train"}) == "header"
    assert classify({"step": 0, "loss": 1.0}) == "step"
    assert classify({"event": "straggler", "step": 3}) == "event"
    assert classify({"probe": "opt_health", "step": 2}) == "probe"
    assert classify({"gauge": "serve", "t_s": 0.5}) == "gauge"
    assert classify({"kernel": "adalomo_update", "flops": 1.0,
                     "bytes": 2.0}) == "kernel"


def test_validate_rejects_missing_required_fields():
    with pytest.raises(SchemaError, match="missing"):
        validate_record({"probe": "opt_health"})          # no step
    with pytest.raises(SchemaError, match="missing"):
        validate_record({"gauge": "serve"})               # no t_s
    with pytest.raises(SchemaError, match="missing"):
        validate_record({"kernel": "x", "flops": 1.0})    # no bytes
    with pytest.raises(SchemaError, match="without 'step'"):
        validate_record({"loss": 1.0})
    with pytest.raises(SchemaError, match="not an object"):
        validate_record([1, 2, 3])


def test_validate_rejects_future_schema():
    with pytest.raises(SchemaError, match="newer than this reader"):
        validate_record(dict(header_record("train"),
                             schema=SCHEMA_VERSION + 1))


def test_legacy_headerless_stream_is_schema_0(tmp_path):
    p = tmp_path / "legacy.jsonl"
    p.write_text('{"step": 0, "loss": 2.0}\n{"step": 1, "loss": 1.5}\n')
    s = read_stream(p)
    assert s.schema == 0 and s.header is None
    assert [r["step"] for r in s.steps()] == [0, 1]


def test_v1_stream_roundtrip_and_kind_accessors(tmp_path):
    p = tmp_path / "v1.jsonl"
    with TelemetryWriter(p, stream="train", run="t") as w:
        w.write({"step": 0, "loss": 2.0})
        w.probe("opt_health", 0, ratio=0.5)
        w.event("straggler", 0, dt_s=9.0)
        w.gauge("serve", 0.25, pool_util=0.5)
        w.kernel("adalomo_update", flops=10.0, bytes=20.0)
    s = read_stream(p)
    assert s.schema == SCHEMA_VERSION
    assert s.header["stream"] == "train" and s.header["run"] == "t"
    assert len(s.steps()) == 1
    assert s.probes("opt_health")[0]["ratio"] == 0.5
    assert s.probes("nope") == []
    assert s.events()[0]["event"] == "straggler"
    assert s.gauges()[0]["pool_util"] == 0.5
    assert s.kernels()[0]["bytes"] == 20.0


def test_writer_resume_does_not_duplicate_header(tmp_path):
    p = tmp_path / "s.jsonl"
    with TelemetryWriter(p, stream="serve") as w:
        w.gauge("serve", 0.0, pool_util=0.0)
    with TelemetryWriter(p, stream="serve") as w:     # reopen = resume
        w.gauge("serve", 1.0, pool_util=0.5)
    s = read_stream(p)          # strict: duplicate header would raise
    assert len(s.gauges()) == 2


def test_duplicate_header_is_strict_error_lenient_skip():
    lines = ['{"schema": 1, "stream": "a"}', '{"schema": 1, "stream": "b"}']
    with pytest.raises(SchemaError, match="duplicate header"):
        parse_records(lines)
    s = parse_records(lines, strict=False)
    assert s.schema == 1


def test_truncated_tail_strict_vs_lenient(tmp_path):
    p = tmp_path / "t.jsonl"
    p.write_text('{"schema": 1, "stream": "train"}\n'
                 '{"step": 0, "loss": 2.0}\n'
                 '{"step": 1, "lo')            # crash mid-write
    with pytest.raises(SchemaError, match="not valid JSON"):
        read_stream(p)
    s = read_stream(p, strict=False)
    assert [r["step"] for r in s.steps()] == [0]


def test_iter_data_records_skips_headers_and_garbage():
    lines = ['{"schema": 1, "stream": "train"}', '', '{"step": 0}',
             'garbage{', '{"event": "e", "step": 0}', '[1,2]']
    recs = list(iter_data_records(lines))
    assert recs == [{"step": 0}, {"event": "e", "step": 0}]


def test_jsonify_handles_numpy_and_nesting():
    np = pytest.importorskip("numpy")
    out = jsonify({"a": np.float32(1.5), "b": [np.arange(3)],
                   "c": {"d": np.int64(2)}})
    assert out == {"a": 1.5, "b": [[0, 1, 2]], "c": {"d": 2}}
    assert json.dumps(out)      # fully JSON-serializable


def test_validate_bench(tmp_path):
    good = tmp_path / "BENCH_roofline.json"
    good.write_text(json.dumps({
        "backend": "cpu", "peak": {"gflops": 1.0},
        "kernels": [{"kernel": "k", "flops": 1.0, "bytes": 2.0,
                     "wall_us": 3.0}]}))
    assert validate_bench(good)["backend"] == "cpu"

    bad = tmp_path / "BENCH_serve.json"
    bad.write_text(json.dumps({"config": {}, "paged": {}, "legacy": {}}))
    with pytest.raises(SchemaError, match="pool_utilization"):
        validate_bench(bad)

    row = tmp_path / "BENCH_roofline2.json"
    row.write_text(json.dumps({"kernels": [{"kernel": "k"}]}))
    # unknown stem: only the non-empty-object rule applies
    assert validate_bench(row)

    broken = tmp_path / "BENCH_x.json"
    broken.write_text("{not json")
    with pytest.raises(SchemaError, match="not valid JSON"):
        validate_bench(broken)
    empty = tmp_path / "BENCH_y.json"
    empty.write_text("{}")
    with pytest.raises(SchemaError, match="non-empty"):
        validate_bench(empty)

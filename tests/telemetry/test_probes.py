"""Optimizer-health probes: the in-graph reduction math (unit-level) and
the end-to-end contract — probes ride the one bundled per-step transfer,
record at the ObservabilitySpec cadence, and add zero steady-state
recompiles (jit cache stays at one entry)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import optimizers as opt_lib
from repro.core.api import Opt, no_decay_1d
from repro.telemetry.probes import (ObservabilitySpec, effective_lr_hist,
                                    factorization_error, group_ratios,
                                    transition_residual)


def test_observability_spec_validation():
    with pytest.raises(ValueError):
        ObservabilitySpec(optimizer_every=-1)
    with pytest.raises(ValueError):
        ObservabilitySpec(hist_bins=0)
    with pytest.raises(ValueError):
        ObservabilitySpec(hist_range=(2.0, -2.0))
    s = ObservabilitySpec(optimizer_every=4, hist_range=[-6, 0])
    assert s.enabled and s.hist_range == (-6.0, 0.0)
    assert s.resolved_factored_every() == 4
    assert ObservabilitySpec(optimizer_every=4,
                             factored_every=12).resolved_factored_every() == 12
    assert not ObservabilitySpec().enabled


def _tiny_opt():
    rule = opt_lib.get_rule("adalomo")
    return Opt(rule, groups=(no_decay_1d(),))


def test_group_ratios_match_manual_norms():
    opt = _tiny_opt()
    p_old = {"w": jnp.full((4, 4), 2.0), "b": jnp.full((4,), 1.0)}
    p_new = {"w": p_old["w"] + 0.1, "b": p_old["b"] - 0.2}
    r = jax.jit(lambda a, b: group_ratios(a, b, opt))(p_old, p_new)
    assert set(r) == {"default", "no_decay"}
    # ||Δw||/||w|| = (0.1*4)/(2*4), ||Δb||/||b|| = (0.2*2)/(1*2)
    np.testing.assert_allclose(float(r["default"]), 0.4 / 8.0, rtol=1e-6)
    np.testing.assert_allclose(float(r["no_decay"]), 0.4 / 2.0, rtol=1e-6)


def test_group_ratio_zero_init_group_uses_rms_floor():
    opt = _tiny_opt()
    p_old = {"w": jnp.ones((2, 2)), "b": jnp.zeros((4,))}   # zero-init 1-D
    p_new = {"w": p_old["w"], "b": p_old["b"] + 1e-3}
    r = group_ratios(p_old, p_new, opt)
    # floored at eps2*sqrt(n): ratio = (1e-3*2)/(1e-3*2) = 1, not ~1e27
    np.testing.assert_allclose(float(r["no_decay"]), 1.0, rtol=1e-5)


def test_effective_lr_hist_counts_and_stacked_units():
    ospec = ObservabilitySpec(optimizer_every=1, hist_bins=8,
                              hist_range=(-8.0, 0.0))
    p_old = {"stacks": {"w": jnp.ones((3, 4, 4))},    # 3 per-layer units
             "emb": jnp.ones((4, 4))}                 # 1 unit
    p_new = jax.tree.map(lambda x: x * (1.0 - 1e-3), p_old)
    h = effective_lr_hist(p_old, p_new, ospec)
    assert int(h["n_units"]) == 4
    assert int(jnp.sum(h["counts"])) == 4
    assert h["counts"].shape == (8,)
    # every unit moved by exactly rel 1e-3
    np.testing.assert_allclose(float(h["rel_update_mean"]), 1e-3, rtol=1e-4)
    np.testing.assert_allclose(float(h["rel_update_max"]), 1e-3, rtol=1e-4)


def test_transition_residual_zero_for_consistent_rank1_transition():
    # shared column marginal + equal row-marginal mass: the factored EMA
    # recursion commutes with the rank-1 reconstruction exactly
    c = jnp.asarray([1.0, 2.0, 1.0])
    r_old = jnp.asarray([3.0, 1.0])          # sum 4
    R = jnp.asarray([2.0, 2.0])              # sum 4 == sum(r_old)
    beta = 0.5
    r_new = beta * r_old + (1 - beta) * R
    res = transition_residual(r_old, c, r_new, c, beta)
    assert float(res) < 1e-6


def test_transition_residual_positive_for_inconsistent_transition():
    c_old = jnp.asarray([1.0, 2.0, 1.0])
    c_new = jnp.asarray([4.0, 1.0, 3.0])     # column structure rotated
    r_old = jnp.asarray([3.0, 1.0])
    r_new = jnp.asarray([1.0, 3.0])
    assert float(transition_residual(r_old, c_old, r_new, c_new, 0.9)) > 0.01


def test_factorization_error_zero_iff_rank1():
    a = jnp.asarray([1.0, 2.0, 3.0])
    b = jnp.asarray([0.5, 1.5])
    v1 = a[:, None] * b[None, :]             # non-negative rank-1
    assert float(factorization_error(v1)) < 1e-6
    v2 = v1.at[0, 0].add(2.0)                # rank-2 perturbation
    assert float(factorization_error(v2)) > 0.01


def test_run_probes_cadence_and_zero_recompiles(tmp_path):
    """End-to-end: probes are recorded at the spec cadence, values are
    finite, step records stay probe-free, and the step program's jit
    cache holds exactly ONE entry after the whole run — the zero-extra-
    recompiles / zero-extra-host-syncs acceptance gate."""
    from repro.data.pipeline import DataConfig
    from repro.run import (ModelSpec, ObservabilitySpec, OptSpec, RunSpec,
                           StepSpec, build_step_program, run)

    mp = tmp_path / "m.jsonl"
    spec = RunSpec(model=ModelSpec("h2o-danube-1.8b", smoke=True),
                   data=DataConfig(vocab=0, seq_len=32, global_batch=8),
                   opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant"),
                   steps=StepSpec(total=5),
                   observe=ObservabilitySpec(optimizer_every=2,
                                             factored_every=4),
                   metrics_path=str(mp), log_every=0)
    prog = build_step_program(spec)
    run(spec, program=prog, log_fn=lambda s: None)
    assert prog.cache_size() == 1

    recs = [json.loads(l) for l in mp.open()]
    assert recs[0]["schema"] == 1
    steps = [r for r in recs if "schema" not in r and "probe" not in r]
    assert [r["step"] for r in steps] == [0, 1, 2, 3, 4]
    assert all("opt_health" not in r for r in steps)

    oh = [r for r in recs if r.get("probe") == "opt_health"]
    assert [r["step"] for r in oh] == [0, 2, 4]
    for r in oh:
        assert set(r["group_ratio"]) == {"default", "no_decay"}
        assert all(np.isfinite(v) and 0 <= v < 1e3
                   for v in r["group_ratio"].values())
        e = r["eff_lr"]
        assert sum(e["counts"]) == e["n_units"] > 0
        assert np.isfinite(e["rel_update_mean"])

    fr = [r for r in recs if r.get("probe") == "factored"]
    assert [r["step"] for r in fr] == [0, 4]
    payload = {k: v for k, v in fr[0].items() if k not in ("probe", "step")}
    assert any(k.startswith("recon/") for k in payload)
    assert all(np.isfinite(v) and v >= 0 for v in payload.values())


def test_disabled_observe_leaves_program_unwrapped():
    from repro.data.pipeline import DataConfig
    from repro.run import (ModelSpec, OptSpec, RunSpec, StepSpec,
                           build_step_program)
    spec = RunSpec(model=ModelSpec("h2o-danube-1.8b", smoke=True),
                   data=DataConfig(vocab=0, seq_len=32, global_batch=8),
                   opt=OptSpec(name="adalomo"), steps=StepSpec(total=2))
    assert not spec.observe.enabled
    prog = build_step_program(spec)
    # jaxpr-level check: no opt_health in the step's output metrics tree
    out = jax.eval_shape(prog.fn, *prog.abstract_args())
    metrics = out[3]
    assert "opt_health" not in metrics

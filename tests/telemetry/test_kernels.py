"""Kernel roofline counter registry: analytic FLOPs/bytes sanity, the
page-granular KV traffic model, and the config-zoo analytic cases."""
import math

import pytest

from repro.telemetry import (adalomo_update_counters, counters_for,
                             paged_decode_attention_counters, zoo_cases)


def test_adalomo_update_counts_scale_with_elements():
    a = adalomo_update_counters(256, 512)
    assert a.kernel == "adalomo_update"
    assert a.flops == 13.0 * 256 * 512 + 6.0 * (256 + 512)
    assert a.bytes == 4.0 * 256 * 512 * 4 + 4.0 * (256 + 512) * 4
    # stacked [L, m, n] tensors launch L kernels
    s = adalomo_update_counters(256, 512, stacks=3)
    assert s.flops == 3 * a.flops and s.bytes == 3 * a.bytes
    assert a.intensity == pytest.approx(a.flops / a.bytes)


def test_paged_decode_attention_page_granular_bytes():
    base = dict(batch=2, q_heads=8, kv_heads=2, head_dim=64)
    # 100 cached tokens at page_size=16 touch ceil(100/16)=7 pages
    kc = paged_decode_attention_counters(seq_len=100, page_size=16, **base)
    touched = math.ceil(100 / 16)
    kv = 2 * touched * 16 * 2 * 64 * 4 * 2
    qo = 2 * 2 * 8 * 64 * 4
    assert kc.bytes == kv + qo
    # one more token crosses a page boundary -> one more page of traffic
    kc2 = paged_decode_attention_counters(seq_len=113, page_size=16, **base)
    assert kc2.bytes > kc.bytes
    # a fixed block-table grid (today's kernel) reads all pages_per_seq
    kc3 = paged_decode_attention_counters(seq_len=100, page_size=16,
                                          pages_per_seq=32, **base)
    assert kc3.bytes > kc.bytes
    # FLOPs don't depend on paging at all
    assert kc3.flops == kc.flops == 2 * 8 * (4.0 * 100 * 64 + 5.0 * 100)


def test_gqa_shares_kv_pages_across_query_heads():
    lo = paged_decode_attention_counters(batch=1, q_heads=32, kv_heads=8,
                                         head_dim=64, seq_len=256)
    hi = paged_decode_attention_counters(batch=1, q_heads=32, kv_heads=32,
                                         head_dim=64, seq_len=256)
    assert lo.flops == hi.flops          # every q head attends fully
    assert lo.bytes < hi.bytes           # but shares 4x fewer KV pages


def test_counters_for_registry_dispatch():
    kc = counters_for("adalomo_update", m=8, n=8)
    assert kc.kernel == "adalomo_update"
    with pytest.raises(KeyError, match="no roofline counters"):
        counters_for("unknown_kernel", m=1)


def test_record_is_a_valid_kernel_stream_record():
    from repro.telemetry import validate_record
    rec = counters_for("adalomo_update", m=8, n=8).record(wall_us=1.5)
    assert validate_record(rec) == "kernel"
    assert rec["wall_us"] == 1.5 and rec["shape"]["m"] == 8


def test_zoo_cases_cover_decode_and_update():
    cases = zoo_cases()
    kernels = {k for k, _, _ in cases}
    assert kernels == {"paged_decode_attention", "adalomo_update"}
    for kernel, shape, cell in cases:
        kc = counters_for(kernel, **shape)
        assert kc.flops > 0 and kc.bytes > 0, cell

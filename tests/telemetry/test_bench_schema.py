"""CI schema-validation of the committed BENCH_*.json baselines — runs
in the fast tier AND as a standalone stage in scripts/ci.sh (`python -m
repro.telemetry.schema benchmarks`)."""
from pathlib import Path

from repro.telemetry import validate_bench, validate_bench_dir
from repro.telemetry.schema import main

BENCH_DIR = Path(__file__).resolve().parents[2] / "benchmarks"


def test_all_committed_benchmarks_validate():
    names = validate_bench_dir(BENCH_DIR)
    assert names, "no committed BENCH_*.json found"
    # the Telemetry-v1 deliverable: the kernel roofline baseline exists
    assert "BENCH_roofline.json" in names
    assert "BENCH_serve.json" in names


def test_roofline_baseline_contents():
    payload = validate_bench(BENCH_DIR / "BENCH_roofline.json")
    assert payload["peak"]["gflops"] > 0
    kernels = {r["kernel"] for r in payload["kernels"]}
    assert kernels == {"adalomo_update", "paged_decode_attention"}
    for row in payload["kernels"]:
        assert row["flops"] > 0 and row["bytes"] > 0 and row["wall_us"] > 0
        assert 0 < row["frac_of_peak"] <= 1.0
    # analytic config-zoo rows ride along, clearly marked
    assert all(r.get("analytic") for r in payload["analytic"])


def test_serve_baseline_has_pool_utilization():
    payload = validate_bench(BENCH_DIR / "BENCH_serve.json")
    pu = payload["pool_utilization"]
    assert 0 <= pu["mean"] <= pu["max"] <= 1.0
    assert pu["samples"] > 0


def test_schema_cli_entry(capsys):
    assert main([str(BENCH_DIR)]) == 0
    out = capsys.readouterr().out
    assert "BENCH_roofline.json" in out

"""Checkpoint-manager crash robustness: discovery must never see a
partially-written step, and GC can reclaim crash orphans."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree():
    return {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros((3,))}


def _make_partial(ckpt_dir, step, *, with_manifest=True):
    """Simulate a crash mid-write: a step dir without _COMPLETE."""
    d = ckpt_dir / f"step_{step:09d}"
    d.mkdir(parents=True)
    np.save(d / "arr_00000.npy", np.zeros((2, 3), np.float32))
    if with_manifest:
        (d / "manifest.json").write_text(json.dumps(
            {"step": step, "n_leaves": 1,
             "leaves": [{"file": "arr_00000.npy", "shape": [2, 3],
                         "dtype": "float32"}], "extra": {}}))
    return d


def test_crash_mid_write_restores_previous_complete_step(tmp_path):
    """Regression: a crash between the leaf writes and the _COMPLETE
    marker must leave the previous complete step as the restore target —
    the partial dir is invisible to discovery and to restore()."""
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    tree = _tree()
    mgr.save(3, tree)
    assert mgr.latest_step() == 3
    # crash during the *next* save: step 6 dir exists, no _COMPLETE
    _make_partial(mgr.dir, 6)
    (mgr.dir / "_tmp_step_000000009").mkdir()  # orphaned staging dir

    assert mgr.latest_step() == 3, "partial step leaked into discovery"
    step, got, _ = mgr.restore(template=tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))


def test_gc_incomplete_removes_only_orphans(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    tree = _tree()
    mgr.save(3, tree)
    mgr.save(6, tree)
    partial = _make_partial(mgr.dir, 9)
    staging = mgr.dir / "_tmp_step_000000012"
    staging.mkdir()

    removed = mgr.gc_incomplete()
    assert sorted(removed) == ["_tmp_step_000000012", "step_000000009"]
    assert not partial.exists() and not staging.exists()
    # complete steps untouched, restore unaffected
    assert sorted(mgr._complete_steps()) == [3, 6]
    step, got, _ = mgr.restore(template=tree)
    assert step == 6


def test_gc_incomplete_at_construction(tmp_path):
    d = tmp_path / "ck"
    mgr = CheckpointManager(d, async_write=False)
    mgr.save(2, _tree())
    _make_partial(d, 5, with_manifest=False)
    mgr2 = CheckpointManager(d, gc_incomplete=True)
    assert not (d / "step_000000005").exists()
    assert mgr2.latest_step() == 2


def test_restore_with_no_complete_steps_raises(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    _make_partial(mgr.dir, 4)
    assert mgr.latest_step() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore(template=_tree())

"""Checkpoint-manager crash robustness: discovery must never see a
partially-written step, and GC can reclaim crash orphans."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree():
    return {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros((3,))}


def _make_partial(ckpt_dir, step, *, with_manifest=True):
    """Simulate a crash mid-write: a step dir without _COMPLETE."""
    d = ckpt_dir / f"step_{step:09d}"
    d.mkdir(parents=True)
    np.save(d / "arr_00000.npy", np.zeros((2, 3), np.float32))
    if with_manifest:
        (d / "manifest.json").write_text(json.dumps(
            {"step": step, "n_leaves": 1,
             "leaves": [{"file": "arr_00000.npy", "shape": [2, 3],
                         "dtype": "float32"}], "extra": {}}))
    return d


def test_crash_mid_write_restores_previous_complete_step(tmp_path):
    """Regression: a crash between the leaf writes and the _COMPLETE
    marker must leave the previous complete step as the restore target —
    the partial dir is invisible to discovery and to restore()."""
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    tree = _tree()
    mgr.save(3, tree)
    assert mgr.latest_step() == 3
    # crash during the *next* save: step 6 dir exists, no _COMPLETE
    _make_partial(mgr.dir, 6)
    (mgr.dir / "_tmp_step_000000009").mkdir()  # orphaned staging dir

    assert mgr.latest_step() == 3, "partial step leaked into discovery"
    step, got, _ = mgr.restore(template=tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))


def test_gc_incomplete_removes_only_orphans(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    tree = _tree()
    mgr.save(3, tree)
    mgr.save(6, tree)
    partial = _make_partial(mgr.dir, 9)
    staging = mgr.dir / "_tmp_step_000000012"
    staging.mkdir()

    removed = mgr.gc_incomplete()
    assert sorted(removed) == ["_tmp_step_000000012", "step_000000009"]
    assert not partial.exists() and not staging.exists()
    # complete steps untouched, restore unaffected
    assert sorted(mgr._complete_steps()) == [3, 6]
    step, got, _ = mgr.restore(template=tree)
    assert step == 6


def test_gc_incomplete_at_construction(tmp_path):
    d = tmp_path / "ck"
    mgr = CheckpointManager(d, async_write=False)
    mgr.save(2, _tree())
    _make_partial(d, 5, with_manifest=False)
    mgr2 = CheckpointManager(d, gc_incomplete=True)
    assert not (d / "step_000000005").exists()
    assert mgr2.latest_step() == 2


def test_restore_with_no_complete_steps_raises(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    _make_partial(mgr.dir, 4)
    assert mgr.latest_step() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore(template=_tree())


# -------------------------------------------------------------- corruption
# A _COMPLETE marker proves the *writer* finished; it says nothing about
# what the disk did to the bytes afterwards.  restore() validates every
# leaf (existence, size vs the manifest's nbytes, np.load, shape/dtype)
# and falls back to the previous complete step, flagging the damaged dir.

def _leaf_files(d):
    return sorted(d.glob("arr_*.npy"))


def test_truncated_leaf_falls_back_and_flags(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    tree = _tree()
    mgr.save(3, tree)
    mgr.save(6, tree)
    bad = mgr.dir / "step_000000006"
    leaf = _leaf_files(bad)[0]
    leaf.write_bytes(leaf.read_bytes()[:-16])   # lost the tail on disk

    step, got, _ = mgr.restore(template=tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))
    # flagged: discovery skips it from now on, gc reclaims it
    assert (bad / CheckpointManager.DAMAGED_MARKER).exists()
    assert mgr.latest_step() == 3
    assert "step_000000006" in mgr.gc_incomplete()
    assert not bad.exists()


def test_garbled_leaf_explicit_step_raises_latest_falls_back(tmp_path):
    from repro.checkpoint.manager import CorruptCheckpoint
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    tree = _tree()
    mgr.save(2, tree)
    mgr.save(4, tree)
    leaf = _leaf_files(mgr.dir / "step_000000004")[0]
    data = bytearray(leaf.read_bytes())
    data[:6] = b"GARBLE"                # same size, unreadable npy header
    leaf.write_bytes(bytes(data))

    # an explicitly requested step never falls back silently
    with pytest.raises(CorruptCheckpoint):
        mgr.restore(step=4, template=tree)
    step, got, _ = mgr.restore(template=tree)
    assert step == 2


def test_every_checkpoint_damaged_raises(tmp_path):
    from repro.checkpoint.manager import CorruptCheckpoint
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    tree = _tree()
    mgr.save(5, tree)
    _leaf_files(mgr.dir / "step_000000005")[0].unlink()
    with pytest.raises(CorruptCheckpoint, match="damaged"):
        mgr.restore(template=tree)


def test_manifest_without_nbytes_still_restores(tmp_path):
    """Pre-v10 manifests carry no nbytes — the size check is skipped,
    not failed (back-compat with existing checkpoint dirs)."""
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    tree = _tree()
    mgr.save(7, tree)
    mpath = mgr.dir / "step_000000007" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    for leaf in manifest["leaves"]:
        leaf.pop("nbytes")
    mpath.write_text(json.dumps(manifest))
    step, got, _ = mgr.restore(template=tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))

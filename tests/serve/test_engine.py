"""Continuous-batching engine behaviour: mid-flight admission produces
the same tokens as solo runs, EOS recycles pages, the fixed-shape decode
chunk never recompiles after warmup, and the fixed legacy engine still
serves."""
import jax
import numpy as np
import pytest

from benchmarks.common import tiny_llama
from repro.serve.engine import (Engine, PagedEngine, PagedServeConfig,
                                ServeConfig)

PROMPTS = [[5, 17, 23, 9], [101, 44], [7] * 6, [3, 4, 5, 6, 7, 8, 9, 10, 11],
           [42] * 14]


@pytest.fixture(scope="module")
def setup():
    arch = tiny_llama(layers=2, d=64)
    params = arch.init_params(jax.random.PRNGKey(0))
    return arch, params


def _cfg(**kw):
    base = dict(page_size=8, num_pages=32, max_batch=3, max_pages_per_seq=8,
                chunk=4, max_new_tokens=8, bucket_min=8)
    base.update(kw)
    return PagedServeConfig(**base)


def _solo(arch, params, prompt, **kw):
    return PagedEngine(arch, params, _cfg(**kw)).generate([prompt])[0]


def test_midflight_admission_matches_solo(setup):
    """Requests that join the running batch between chunks must produce
    exactly the tokens they'd produce served alone (greedy)."""
    arch, params = setup
    solos = [_solo(arch, params, p) for p in PROMPTS]
    eng = PagedEngine(arch, params, _cfg())
    rids = [eng.submit(p) for p in PROMPTS[:2]]
    eng.step()                         # batch is mid-flight...
    eng.step()
    rids += [eng.submit(p) for p in PROMPTS[2:]]   # ...now others join
    eng.run()
    for solo, rid in zip(solos, rids):
        assert eng.requests[rid].out == solo, rid


def test_preemption_matches_solo(setup):
    """A pool too small for all admitted sequences forces preemption; the
    re-prefill over prompt+generated must reproduce the same stream."""
    arch, params = setup
    kw = dict(page_size=4, num_pages=14, max_pages_per_seq=16,
              max_new_tokens=24)
    big = dict(kw, num_pages=64)
    prompts = PROMPTS[:3]
    solos = [_solo(arch, params, p, **big) for p in prompts]
    eng = PagedEngine(arch, params, _cfg(**kw))
    outs = eng.generate(prompts)
    assert sum(r.n_preempted for r in eng.requests.values()) > 0, \
        "pool was large enough that preemption never happened"
    assert outs == solos


def test_eos_frees_pages_back_to_allocator(setup):
    arch, params = setup
    # discover what the model greedily emits, then make token #2 the EOS
    eng0 = PagedEngine(arch, params, _cfg())
    probe = eng0.generate([PROMPTS[0]])[0]
    eos = probe[2]
    eng = PagedEngine(arch, params, _cfg(eos_id=eos))
    n_free_before = eng.allocator.n_free
    out = eng.generate([PROMPTS[0]])[0]
    assert out == probe[:3] and out[-1] == eos    # stopped at EOS
    assert not eng.scheduler.has_work()
    assert eng.allocator.n_free == n_free_before  # every page recycled
    # and the freed pages are immediately reusable by a new request
    out2 = eng.generate([PROMPTS[1]])[0]
    assert len(out2) > 0
    assert eng.allocator.n_free == n_free_before


def test_zero_decode_recompiles_after_warmup(setup):
    """A mixed-length (16-256 token prompts) continuous-batching workload
    must add zero decode executables after warmup: the decode chunk is one
    fixed-shape program, prefill a bounded pow-2 bucket set."""
    arch, params = setup
    rng = np.random.RandomState(0)
    lens = [16, 40, 100, 256, 23, 180]
    prompts = [list(rng.randint(1, 250, size=n).astype(int)) for n in lens]
    eng = PagedEngine(arch, params, PagedServeConfig(
        page_size=32, num_pages=41, max_batch=3, max_pages_per_seq=9,
        chunk=2, max_new_tokens=4, bucket_min=16))
    eng.warmup([min(lens), max(lens)])   # covers buckets 16..256
    assert eng.decode_compile_count() == 1
    prefill_compiles = eng.prefill_compile_count()
    rids = [eng.submit(p) for p in prompts[:3]]
    eng.step()
    rids += [eng.submit(p) for p in prompts[3:]]   # join mid-flight
    eng.run()
    assert all(len(eng.requests[r].out) == 4 for r in rids)
    assert eng.decode_compile_count() == 1, "decode step recompiled"
    # prefill compiles stay within the warmed pow-2 bucket set
    assert eng.prefill_compile_count() == prefill_compiles
    # another mixed round: still the same executables
    eng.generate([prompts[1][:17], prompts[3][:77]])
    assert eng.decode_compile_count() == 1
    assert eng.prefill_compile_count() == prefill_compiles


def test_pages_conserved_across_rounds(setup):
    arch, params = setup
    eng = PagedEngine(arch, params, _cfg())
    total = eng.allocator.n_free
    for round_prompts in (PROMPTS[:3], PROMPTS[3:], PROMPTS[1:4]):
        eng.generate(round_prompts)
        assert eng.allocator.n_free == total   # no leaked pages


def test_engine_kernel_path_matches_jnp(setup):
    """End-to-end with the paged Pallas kernel (interpret mode) instead of
    the jnp gather path: same tokens."""
    arch, params = setup
    kw = dict(page_size=8, num_pages=32, max_batch=2, max_pages_per_seq=4,
              chunk=2, max_new_tokens=4, bucket_min=8)
    ref = PagedEngine(arch, params, PagedServeConfig(**kw))
    krn = PagedEngine(arch, params,
                      PagedServeConfig(**kw, use_kernel=True,
                                       interpret=True))
    prompts = [[5, 17, 23, 9], [7, 7]]
    assert ref.generate(prompts) == krn.generate(prompts)


def test_swa_arch_midflight_matches_solo():
    """Sliding-window arch (danube smoke, window=8): the paged decode mask
    must reproduce solo generations for ragged prompts too."""
    from repro.models.registry import get_arch
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    assert arch.supports_paged_serving() and arch.cfg.window == 8
    params = arch.init_params(jax.random.PRNGKey(1))
    prompts = [[5, 17, 23, 9, 2, 11, 3], [101, 44], [7] * 12]
    solos = [_solo(arch, params, p, max_new_tokens=10) for p in prompts]
    eng = PagedEngine(arch, params, _cfg(max_new_tokens=10))
    rids = [eng.submit(prompts[0])]
    eng.step()
    rids += [eng.submit(p) for p in prompts[1:]]
    eng.run()
    assert [eng.requests[r].out for r in rids] == solos


def test_max_new_tokens_zero_and_oversize_rejection(setup):
    arch, params = setup
    eng = PagedEngine(arch, params, _cfg())
    assert eng.generate([[1, 2, 3]], max_new_tokens=0) == [[]]
    assert eng.allocator.n_free == eng.scfg.num_pages - 1
    with pytest.raises(ValueError):
        eng.submit([1] * 100)          # exceeds per-seq/pool capacity


def test_legacy_engine_single_transfer_decode(setup):
    """The fixed legacy engine: emits max_new tokens per row, stops at
    EOS, and keeps finished rows frozen rather than re-sampling them."""
    arch, params = setup
    eng = Engine(arch, params, ServeConfig(max_new_tokens=6))
    outs = eng.generate([[1, 2, 3], [4, 5, 6]])
    assert all(len(o) == 6 for o in outs)
    eos = outs[0][1]                   # make the 2nd emitted token EOS
    eng2 = Engine(arch, params, ServeConfig(max_new_tokens=6, eos_id=eos))
    outs2 = eng2.generate([[1, 2, 3], [4, 5, 6]])
    assert outs2[0] == outs[0][:2] and outs2[0][-1] == eos
    for o in outs2:
        assert len(o) <= 6

"""Request TTL in the paged engine: a request whose deadline passes is
evicted at the next chunk boundary — pages back in the pool, partial
output kept and frozen, ``timed_out`` counted in the gauges — while
untimed requests run to completion.  Driven by an injected fake clock, so
nothing sleeps."""
import jax
import pytest

from benchmarks.common import tiny_llama
from repro.serve.engine import PagedEngine, PagedServeConfig
from repro.serve.scheduler import TIMED_OUT
from repro.telemetry import read_stream


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def setup():
    arch = tiny_llama(layers=2, d=64)
    params = arch.init_params(jax.random.PRNGKey(0))
    return arch, params


def _cfg(**kw):
    base = dict(page_size=8, num_pages=32, max_batch=3, max_pages_per_seq=8,
                chunk=4, max_new_tokens=8, bucket_min=8)
    base.update(kw)
    return PagedServeConfig(**base)


def test_expired_request_is_evicted_at_chunk_boundary(setup, tmp_path):
    arch, params = setup
    clock = FakeClock()
    path = tmp_path / "g.jsonl"
    eng = PagedEngine(arch, params, _cfg(telemetry_path=str(path)),
                      clock=clock)
    free0 = eng.allocator.n_free

    ra = eng.submit([5, 17, 23, 9], ttl_s=10.0)
    rb = eng.submit([7, 8, 9])                  # no TTL: must finish
    a, b = eng.requests[ra], eng.requests[rb]
    assert a.deadline_s == 10.0 and b.deadline_s is None

    eng.step()                                  # both admitted, one chunk
    assert a.status == "running" and len(a.out) > 0

    clock.t = 11.0                              # past A's deadline
    eng.step()
    assert a.status == TIMED_OUT
    assert a.pages == [] and a.slot is None     # pool got its pages back
    partial = list(a.out)
    assert partial                              # partial output kept

    eng.run()                                   # B unaffected by the TTL
    assert b.status == "finished" and len(b.out) == 8
    assert a.out == partial                     # ...and A's out is frozen
    assert eng.allocator.n_free == free0        # every page reclaimed
    assert eng.scheduler.counters["timed_out"] == 1
    assert eng.scheduler.counters["finished"] == 1

    # the lifetime counter reaches the gauge stream
    gauges = read_stream(path).gauges()
    assert gauges[-1]["timed_out"] == 1 and gauges[-1]["running"] == 0


def test_default_ttl_expires_running_and_queued(setup):
    """scfg.ttl_s stamps every submit; a queued request that never got a
    slot times out too (dropped with empty output) and run() terminates."""
    arch, params = setup
    clock = FakeClock()
    eng = PagedEngine(arch, params, _cfg(max_batch=1, ttl_s=5.0),
                      clock=clock)
    ra = eng.submit([1, 2, 3, 4])
    rb = eng.submit([9, 9, 9])                  # only one slot: stays queued
    eng.step()
    a, b = eng.requests[ra], eng.requests[rb]
    assert a.status == "running" and b.status == "queued"

    clock.t = 6.0
    eng.run()
    assert a.status == TIMED_OUT and a.out      # evicted mid-flight
    assert b.status == TIMED_OUT and b.out == []   # never ran at all
    assert eng.scheduler.counters["timed_out"] == 2
    assert not eng.scheduler.has_work()

"""Page allocator + block-table unit tests (pure host-side, no jit)."""
import numpy as np
import pytest

from repro.serve.paging import (OutOfPages, PageAllocator,
                                build_block_tables, pages_for)
from repro.serve.scheduler import Request, Scheduler


def test_pages_for():
    assert pages_for(0, 16) == 0
    assert pages_for(1, 16) == 1
    assert pages_for(16, 16) == 1
    assert pages_for(17, 16) == 2


def test_allocator_reserves_scratch_page():
    a = PageAllocator(num_pages=8, page_size=16)
    got = a.alloc(7)
    assert sorted(got) == list(range(1, 8))   # page 0 never handed out
    with pytest.raises(OutOfPages):
        a.alloc(1)
    a.free(got)
    assert a.n_free == 7


def test_alloc_is_atomic():
    a = PageAllocator(num_pages=4, page_size=8)
    a.alloc(2)
    before = a.n_free
    with pytest.raises(OutOfPages):
        a.alloc(2)
    assert a.n_free == before   # failed alloc takes nothing


def test_double_free_asserts():
    a = PageAllocator(num_pages=4, page_size=8)
    pages = a.alloc(1)
    a.free(pages)
    with pytest.raises(AssertionError):
        a.free(pages)


def test_block_tables_pad_with_scratch():
    t = build_block_tables([[3, 1], [], [2]], max_pages_per_seq=4)
    np.testing.assert_array_equal(
        t, np.array([[3, 1, 0, 0], [0, 0, 0, 0], [2, 0, 0, 0]], np.int32))


def test_scheduler_admission_gated_on_pages():
    a = PageAllocator(num_pages=4, page_size=8)   # 3 usable pages
    s = Scheduler(n_slots=2, allocator=a, max_pages_per_seq=3)
    s.submit(Request(rid=0, prompt=[1] * 16, max_new_tokens=4))   # 2 pages
    s.submit(Request(rid=1, prompt=[1] * 16, max_new_tokens=4))   # 2 pages
    r0 = s.admit_next()
    assert r0 is not None and r0.rid == 0 and len(r0.pages) == 2
    assert s.admit_next() is None          # 1 page free < 2 needed
    s.finish(r0)
    assert a.n_free == 3
    r1 = s.admit_next()
    assert r1 is not None and r1.rid == 1


def test_scheduler_preempt_requeues_at_front():
    a = PageAllocator(num_pages=6, page_size=8)
    s = Scheduler(n_slots=2, allocator=a, max_pages_per_seq=5)
    s.submit(Request(rid=0, prompt=[1] * 8, max_new_tokens=30))
    s.submit(Request(rid=1, prompt=[1] * 8, max_new_tokens=30))
    old, young = s.admit_next(), s.admit_next()
    young.out = [7, 8]
    victim = s.preempt_latest()
    assert victim is young and victim.pages == [] and victim.slot is None
    assert s.queue[0] is young             # front of the queue
    assert young.tokens == [1] * 8 + [7, 8]   # re-prefill covers generated
    with pytest.raises(ValueError):           # exceeds per-seq capacity
        s.submit(Request(rid=2, prompt=[1] * 30, max_new_tokens=30))


def test_submit_rejects_request_larger_than_pool():
    """A request that can never fit the pool must be rejected up front —
    otherwise admission spins forever (run() livelock)."""
    a = PageAllocator(num_pages=4, page_size=8)       # 3 usable pages
    s = Scheduler(n_slots=2, allocator=a, max_pages_per_seq=8)
    with pytest.raises(ValueError, match="pool"):
        s.submit(Request(rid=0, prompt=[1] * 30, max_new_tokens=2))

"""Paged decode attention vs the dense oracle, for ragged sequence
lengths and shuffled page assignments (kernel in interpret mode + jnp
gather path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.decode_attention import (
    paged_decode_attention_pallas)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)

CASES = [
    # B, H, K, dh, page_size, P, window, seq_lens
    (3, 8, 2, 64, 8, 4, None, (19, 9, 25)),
    (2, 4, 4, 32, 16, 2, None, (1, 32)),
    (4, 8, 8, 64, 4, 8, 6, (30, 3, 17, 8)),
    (1, 16, 4, 128, 8, 3, None, (24,)),
]


def _scatter_setup(key, B, H, K, dh, ps, P, seq_lens):
    """Dense per-seq caches + the same data scattered into shuffled pages."""
    rng = np.random.RandomState(int(jax.random.randint(key, (), 0, 1 << 30)))
    W = P * ps
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    kc = np.array(jax.random.normal(ks[1], (B, W, K, dh), jnp.float32))
    vc = np.array(jax.random.normal(ks[2], (B, W, K, dh), jnp.float32))
    # zero out positions past seq_len so garbage can't hide a masking bug
    for b, n in enumerate(seq_lens):
        kc[b, n:] = 0.0
        vc[b, n:] = 0.0
    N = 1 + B * P                      # page 0 = scratch
    perm = rng.permutation(np.arange(1, N))
    bt = perm.reshape(B, P).astype(np.int32)
    k_pages = rng.normal(size=(N, ps, K, dh)).astype(np.float32)  # garbage
    v_pages = rng.normal(size=(N, ps, K, dh)).astype(np.float32)
    for b in range(B):
        for p in range(P):
            k_pages[bt[b, p]] = kc[b, p * ps:(p + 1) * ps]
            v_pages[bt[b, p]] = vc[b, p * ps:(p + 1) * ps]
    return (q, jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(k_pages),
            jnp.asarray(v_pages), jnp.asarray(bt),
            jnp.asarray(seq_lens, dtype=jnp.int32))


@pytest.mark.parametrize("B,H,K,dh,ps,P,window,seq_lens", CASES)
def test_paged_matches_dense_oracle(B, H, K, dh, ps, P, window, seq_lens):
    key = jax.random.PRNGKey(B * 31 + P)
    q, kc, vc, kp, vp, bt, sl = _scatter_setup(key, B, H, K, dh, ps, P,
                                               seq_lens)
    W = P * ps
    pos = jnp.arange(W, dtype=jnp.int32)
    kv_pos = jnp.where(pos[None] < sl[:, None], pos[None], -1)
    dense = decode_attention_ref(q, kc, vc, kv_pos=kv_pos,
                                 q_pos=sl - 1, window=window)
    paged_jnp = paged_decode_attention_ref(q, kp, vp, bt, sl, window=window)
    paged_krn = paged_decode_attention_pallas(q, kp, vp, bt, sl,
                                              window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(paged_jnp), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(paged_krn), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_paged_ignores_scratch_garbage():
    """Unallocated block-table tail entries point at scratch page 0; junk
    there must never leak into the output."""
    B, H, K, dh, ps, P = 2, 4, 2, 32, 8, 4
    key = jax.random.PRNGKey(7)
    seq_lens = (5, 11)
    q, kc, vc, kp, vp, bt, sl = _scatter_setup(key, B, H, K, dh, ps, P,
                                               seq_lens)
    out1 = paged_decode_attention_ref(q, kp, vp, bt, sl)
    kp2 = kp.at[0].set(1e9)
    vp2 = vp.at[0].set(-1e9)
    bt2 = bt.at[:, 2:].set(0)          # tail -> scratch (lens fit 2 pages)
    out2 = paged_decode_attention_ref(q, kp2, vp2, bt2, sl)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)

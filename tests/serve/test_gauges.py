"""Serve-engine telemetry gauges: a PagedEngine given a telemetry path
emits a schema-v1 gauge stream whose pool/queue/counter values match the
engine's own bookkeeping, sampled at chunk boundaries before finished
sequences retire."""
import jax
import pytest

from benchmarks.common import tiny_llama
from repro.serve.engine import PagedEngine, PagedServeConfig
from repro.telemetry import read_stream

PROMPTS = [[5, 17, 23, 9], [101, 44], [7] * 6, [3, 4, 5, 6, 7, 8, 9, 10]]


@pytest.fixture(scope="module")
def setup():
    arch = tiny_llama(layers=2, d=64)
    params = arch.init_params(jax.random.PRNGKey(0))
    return arch, params


def _cfg(**kw):
    base = dict(page_size=8, num_pages=32, max_batch=3, max_pages_per_seq=8,
                chunk=4, max_new_tokens=8, bucket_min=8)
    base.update(kw)
    return PagedServeConfig(**base)


def test_engine_without_path_has_no_telemetry(setup):
    arch, params = setup
    eng = PagedEngine(arch, params, _cfg())
    assert eng.telemetry is None
    eng.generate([PROMPTS[0]])         # and the plain path still serves


def test_gauge_stream_matches_engine_bookkeeping(setup, tmp_path):
    arch, params = setup
    path = tmp_path / "gauges.jsonl"
    eng = PagedEngine(arch, params, _cfg(telemetry_path=str(path)))
    outs = eng.generate(PROMPTS)
    assert all(len(o) == 8 for o in outs)

    s = read_stream(path)
    assert s.header == {"schema": 1, "stream": "serve"}
    gauges = s.gauges()
    assert gauges, "no gauge records emitted"
    for g in gauges:
        assert 0.0 <= g["pool_util"] <= 1.0
        assert 0.0 <= g["block_table_occupancy"] <= 1.0
        assert g["queue_depth"] >= 0 and g["running"] >= 0
        assert g["t_s"] >= 0.0
    # sampled before _collect retires sequences: some chunk must have
    # seen real pool pressure even though every request finishes quickly
    assert max(g["pool_util"] for g in gauges) > 0.0
    # cumulative counters are monotone and end at the scheduler's truth
    for key in ("admitted", "finished", "chunks"):
        vals = [g[key] for g in gauges]
        assert vals == sorted(vals)
    last = gauges[-1]
    assert last["admitted"] == eng.scheduler.counters["admitted"] == 4
    assert last["finished"] == eng.scheduler.counters["finished"] == 4
    assert last["prefill_s"] > 0.0 and last["decode_s"] > 0.0
    assert last["chunks"] == eng.telemetry.chunks


def test_run_emits_forced_final_drain_sample(setup, tmp_path):
    """run() forces one last sample so the stream always closes on the
    drained state (pool empty, queue empty) regardless of cadence."""
    arch, params = setup
    path = tmp_path / "gauges.jsonl"
    eng = PagedEngine(arch, params, _cfg(telemetry_path=str(path),
                                         telemetry_every=1000))
    eng.generate(PROMPTS[:2])
    gauges = read_stream(path).gauges()
    assert gauges, "forced drain sample missing"
    assert gauges[-1]["running"] == 0 and gauges[-1]["queue_depth"] == 0
    assert gauges[-1]["pool_util"] == 0.0


def test_telemetry_every_thins_samples(setup, tmp_path):
    arch, params = setup
    p1, p2 = tmp_path / "every1.jsonl", tmp_path / "every2.jsonl"
    for path, every in ((p1, 1), (p2, 2)):
        eng = PagedEngine(arch, params, _cfg(telemetry_path=str(path),
                                             telemetry_every=every))
        eng.generate(PROMPTS)
    dense = read_stream(p1).gauges()
    thin = read_stream(p2).gauges()
    assert len(thin) < len(dense)
    # thinned stream still carries the forced drain sample
    assert thin[-1]["running"] == 0


def test_preemption_counters_reach_the_stream(setup, tmp_path):
    """Under a pool too small for the admitted set, the preempt/evict
    counters must show up in the gauges (same workload as the engine
    preemption test)."""
    arch, params = setup
    path = tmp_path / "gauges.jsonl"
    eng = PagedEngine(arch, params, _cfg(
        page_size=4, num_pages=14, max_pages_per_seq=16, max_new_tokens=24,
        telemetry_path=str(path)))
    eng.generate(PROMPTS[:3])
    last = read_stream(path).gauges()[-1]
    assert last["preempted"] > 0
    assert last["evicted_pages"] > 0
    assert last["preempted"] == eng.scheduler.counters["preempted"]

"""Flash-decoding Pallas kernel vs oracle: shape/dtype/window sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic shim (tests/_compat)
    from hypothesis_stub import given, settings, strategies as st

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas)
from repro.kernels.decode_attention.ref import decode_attention_ref

CASES = [
    # B, W, H, K, dh, window, cur
    (2, 128, 8, 2, 64, None, 100),
    (1, 300, 4, 4, 128, None, 250),
    (3, 512, 16, 4, 64, 64, 400),
    (2, 64, 8, 8, 32, None, 10),
    (1, 1024, 32, 8, 128, 256, 900),
]


def _mk(key, B, W, H, K, dh, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, dh), dtype)
    kc = jax.random.normal(ks[1], (B, W, K, dh), dtype)
    vc = jax.random.normal(ks[2], (B, W, K, dh), dtype)
    return q, kc, vc


@pytest.mark.parametrize("B,W,H,K,dh,window,cur", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_oracle(B, W, H, K, dh, window, cur, dtype):
    q, kc, vc = _mk(jax.random.PRNGKey(B * W), B, W, H, K, dh, dtype)
    pos = jnp.where(jnp.arange(W) <= cur, jnp.arange(W), -1)
    out_k = decode_attention_pallas(q, kc, vc, pos, float(cur),
                                    window=window, kv_block=128,
                                    interpret=True)
    out_r = decode_attention_ref(
        q, kc, vc, kv_pos=jnp.broadcast_to(pos[None], (B, W)),
        q_pos=jnp.full((B,), cur, jnp.int32), window=window)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=12, deadline=None)
@given(W=st.integers(16, 400), K=st.sampled_from([1, 2, 4]),
       G=st.sampled_from([1, 2, 4]), dh=st.sampled_from([32, 64]),
       kv_block=st.sampled_from([32, 128]))
def test_property_ragged_cache(W, K, G, dh, kv_block):
    """Partially-filled ring caches with arbitrary W vs block sizes."""
    B, H = 2, K * G
    cur = max(W // 2, 1)
    q, kc, vc = _mk(jax.random.PRNGKey(W * K), B, W, H, K, dh, jnp.float32)
    pos = jnp.where(jnp.arange(W) <= cur, jnp.arange(W), -1)
    out_k = decode_attention_pallas(q, kc, vc, pos, float(cur),
                                    kv_block=kv_block, interpret=True)
    out_r = decode_attention_ref(
        q, kc, vc, kv_pos=jnp.broadcast_to(pos[None], (B, W)),
        q_pos=jnp.full((B,), cur, jnp.int32))
    np.testing.assert_allclose(out_k, out_r, rtol=2e-5, atol=2e-5)

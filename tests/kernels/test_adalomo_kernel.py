"""Pallas AdaLomo kernel vs the pure-jnp oracle (interpret mode on CPU):
shape × dtype sweeps + hypothesis edge shapes + rule drop-in."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic shim (tests/_compat)
    from hypothesis_stub import given, settings, strategies as st

from repro.core.adalomo import AdaLomoConfig
from repro.kernels.adalomo_update.ops import adalomo_update, make_kernel_rule
from repro.kernels.adalomo_update.ref import adalomo_update_ref

SHAPES = [(64, 128), (256, 512), (300, 700), (128, 130), (1000, 96),
          (16, 4096)]


def _mk(key, m, n, pdtype, gdtype, step):
    ks = jax.random.split(key, 4)
    p = (jax.random.normal(ks[0], (m, n), jnp.float32) * 0.1).astype(pdtype)
    g = (jax.random.normal(ks[1], (m, n), jnp.float32) * 0.3).astype(gdtype)
    r = jax.random.uniform(ks[2], (m,), jnp.float32) * (step > 1) * 1e-2
    c = jax.random.uniform(ks[3], (n,), jnp.float32) * (step > 1) * 1e-2
    return p, g, r, c


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("pdtype,gdtype", [(jnp.float32, jnp.float32),
                                           (jnp.bfloat16, jnp.bfloat16),
                                           (jnp.float32, jnp.bfloat16)])
def test_kernel_matches_oracle(shape, pdtype, gdtype):
    m, n = shape
    key = jax.random.PRNGKey(m * 7 + n)
    for step in (1.0, 5.0):
        p, g, r, c = _mk(key, m, n, pdtype, gdtype, step)
        cfg = AdaLomoConfig()
        pk, rk, ck = adalomo_update(p, g, r, c, 5e-4, step, cfg=cfg,
                                    interpret=True, block=(128, 256))
        pr, rr, cr = adalomo_update_ref(p, g, r, c, lr=5e-4, step=step,
                                        cfg=cfg)
        tol = 1e-5 if pdtype == jnp.float32 else 5e-3
        np.testing.assert_allclose(
            np.asarray(pk, np.float32), np.asarray(pr, np.float32),
            rtol=tol, atol=tol)
        # r/c: blockwise vs single-pass reduction order → ~1e-5 relative
        np.testing.assert_allclose(rk, rr, rtol=3e-5, atol=1e-5)
        np.testing.assert_allclose(ck, cr, rtol=3e-5, atol=1e-5)


def test_stacked_vmap_path():
    key = jax.random.PRNGKey(0)
    L, m, n = 3, 96, 160
    p = jax.random.normal(key, (L, m, n)) * 0.1
    g = jax.random.normal(jax.random.fold_in(key, 1), (L, m, n))
    r = jnp.zeros((L, m))
    c = jnp.zeros((L, n))
    pk, rk, ck = adalomo_update(p, g, r, c, 1e-3, 1.0, interpret=True,
                                block=(64, 128))
    for i in range(L):
        pr, rr, cr = adalomo_update_ref(p[i], g[i], r[i], c[i], lr=1e-3,
                                        step=1.0)
        np.testing.assert_allclose(pk[i], pr, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(rk[i], rr, rtol=1e-5, atol=1e-6)


def test_literal_mode_and_weight_decay():
    key = jax.random.PRNGKey(5)
    p, g, r, c = _mk(key, 64, 128, jnp.float32, jnp.float32, 2.0)
    for cfg in (AdaLomoConfig(literal_div_v=True),
                AdaLomoConfig(weight_decay=0.1)):
        pk, rk, ck = adalomo_update(p, g, r, c, 1e-3, 2.0, cfg=cfg,
                                    interpret=True, block=(64, 128))
        pr, rr, cr = adalomo_update_ref(p, g, r, c, lr=1e-3, step=2.0,
                                        cfg=cfg)
        np.testing.assert_allclose(pk, pr, rtol=2e-5, atol=2e-6)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 200), n=st.integers(8, 300),
       bm=st.sampled_from([32, 64, 128]), bn=st.sampled_from([64, 128]))
def test_property_block_edges(m, n, bm, bn):
    """Any (shape, block) combination — incl. non-divisible edges — matches
    the oracle."""
    key = jax.random.PRNGKey(m * 1000 + n)
    p, g, r, c = _mk(key, m, n, jnp.float32, jnp.float32, 3.0)
    pk, rk, ck = adalomo_update(p, g, r, c, 1e-3, 3.0, interpret=True,
                                block=(bm, bn))
    pr, rr, cr = adalomo_update_ref(p, g, r, c, lr=1e-3, step=3.0)
    np.testing.assert_allclose(pk, pr, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(rk, rr, rtol=2e-5, atol=2e-7)
    np.testing.assert_allclose(ck, cr, rtol=2e-5, atol=2e-7)


def test_kernel_rule_drop_in_trains():
    """make_kernel_rule() slots into the fused engine and reproduces the
    pure-jnp rule's trajectory."""
    from repro.core import optimizers as opt_lib
    from repro.core.fused import init_fused_opt_state
    from repro.models.registry import get_arch
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = arch.init_params(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, arch.cfg.vocab),
             "labels": jax.random.randint(key, (2, 16), 0, arch.cfg.vocab)}
    results = []
    for rule in (opt_lib.get_rule("adalomo"),
                 make_kernel_rule(interpret=True)):
        opt_state = init_fused_opt_state(rule, params)
        step = arch.make_fused_train_step(rule)
        p, s = params, opt_state
        for _ in range(2):
            p, s, loss, _ = jax.jit(
                lambda pp, ss, bb: step(pp, ss, bb, lr=jnp.float32(1e-3))
            )(p, s, batch)
        results.append((float(loss), p))
    assert abs(results[0][0] - results[1][0]) < 1e-4
    for a, b in zip(jax.tree.leaves(results[0][1]),
                    jax.tree.leaves(results[1][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)

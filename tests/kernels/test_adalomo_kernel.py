"""Pallas AdaLomo kernel vs the pure-jnp oracle (interpret mode on CPU):
shape × dtype sweeps + hypothesis edge shapes + backend-dispatch drop-in."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline CI: deterministic shim (tests/_compat)
    from hypothesis_stub import given, settings, strategies as st

from repro.core.adalomo import AdaLomoConfig
from repro.kernels.adalomo_update.ops import adalomo_update
from repro.kernels.adalomo_update.ref import adalomo_update_ref

SHAPES = [(64, 128), (256, 512), (300, 700), (128, 130), (1000, 96),
          (16, 4096)]


def _mk(key, m, n, pdtype, gdtype, step):
    ks = jax.random.split(key, 4)
    p = (jax.random.normal(ks[0], (m, n), jnp.float32) * 0.1).astype(pdtype)
    g = (jax.random.normal(ks[1], (m, n), jnp.float32) * 0.3).astype(gdtype)
    r = jax.random.uniform(ks[2], (m,), jnp.float32) * (step > 1) * 1e-2
    c = jax.random.uniform(ks[3], (n,), jnp.float32) * (step > 1) * 1e-2
    return p, g, r, c


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("pdtype,gdtype", [(jnp.float32, jnp.float32),
                                           (jnp.bfloat16, jnp.bfloat16),
                                           (jnp.float32, jnp.bfloat16)])
def test_kernel_matches_oracle(shape, pdtype, gdtype):
    m, n = shape
    key = jax.random.PRNGKey(m * 7 + n)
    for step in (1.0, 5.0):
        p, g, r, c = _mk(key, m, n, pdtype, gdtype, step)
        pk, rk, ck = adalomo_update(p, g, r, c, 5e-4, step,
                                    interpret=True, block=(128, 256))
        pr, rr, cr = adalomo_update_ref(p, g, r, c, lr=5e-4, step=step)
        tol = 1e-5 if pdtype == jnp.float32 else 5e-3
        np.testing.assert_allclose(
            np.asarray(pk, np.float32), np.asarray(pr, np.float32),
            rtol=tol, atol=tol)
        # r/c: blockwise vs single-pass reduction order → ~1e-5 relative
        np.testing.assert_allclose(rk, rr, rtol=3e-5, atol=1e-5)
        np.testing.assert_allclose(ck, cr, rtol=3e-5, atol=1e-5)


def test_stacked_vmap_path():
    key = jax.random.PRNGKey(0)
    L, m, n = 3, 96, 160
    p = jax.random.normal(key, (L, m, n)) * 0.1
    g = jax.random.normal(jax.random.fold_in(key, 1), (L, m, n))
    r = jnp.zeros((L, m))
    c = jnp.zeros((L, n))
    pk, rk, ck = adalomo_update(p, g, r, c, 1e-3, 1.0, interpret=True,
                                block=(64, 128))
    for i in range(L):
        pr, rr, cr = adalomo_update_ref(p[i], g[i], r[i], c[i], lr=1e-3,
                                        step=1.0)
        np.testing.assert_allclose(pk[i], pr, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(rk[i], rr, rtol=1e-5, atol=1e-6)


def test_literal_mode_matches_oracle():
    key = jax.random.PRNGKey(5)
    p, g, r, c = _mk(key, 64, 128, jnp.float32, jnp.float32, 2.0)
    cfg = AdaLomoConfig(literal_div_v=True)
    pk, rk, ck = adalomo_update(p, g, r, c, 1e-3, 2.0, cfg=cfg,
                                interpret=True, block=(64, 128))
    pr, rr, cr = adalomo_update_ref(p, g, r, c, lr=1e-3, step=2.0, cfg=cfg)
    np.testing.assert_allclose(pk, pr, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("lr,wd", [(1e-3, 0.1), (0.1, 0.5)])
def test_weight_decay_parity(lr, wd):
    """Kernel == oracle with weight_decay > 0 at *tight* tolerance.

    Regression for the pre-v2 divergence: the kernel used to pre-scale θ by
    (1 - lr·wd) before accumulating Σθ², so its RMS(θ) trust scale came
    from the decayed θ while the oracle's came from the un-decayed θ.  At
    lr=0.1, wd=0.5 that is a 5% scale error — far outside this tolerance.
    """
    key = jax.random.PRNGKey(6)
    p, g, r, c = _mk(key, 96, 160, jnp.float32, jnp.float32, 2.0)
    pk, rk, ck = adalomo_update(p, g, r, c, lr, 2.0, 0.999, wd, 1.0,
                                interpret=True, block=(64, 128))
    pr, rr, cr = adalomo_update_ref(p, g, r, c, lr=lr, step=2.0,
                                    weight_decay=wd)
    np.testing.assert_allclose(pk, pr, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(rk, rr, rtol=2e-5, atol=2e-7)
    np.testing.assert_allclose(ck, cr, rtol=2e-5, atol=2e-7)


def test_dynamic_hparams_are_traced_operands():
    """lr/β/wd/clip are kernel operands, not compile-time constants:
    changing them between calls must not recompile the jitted wrapper."""
    key = jax.random.PRNGKey(7)
    p, g, r, c = _mk(key, 64, 128, jnp.float32, jnp.float32, 2.0)

    @jax.jit
    def step(p, g, r, c, lr, beta, wd, clip):
        return adalomo_update(p, g, r, c, lr, 2.0, beta, wd, clip,
                              interpret=True, block=(64, 128))

    outs = [step(p, g, r, c, jnp.float32(lr), jnp.float32(b),
                 jnp.float32(w), jnp.float32(cl))
            for lr, b, w, cl in [(1e-3, 0.999, 0.0, 1.0),
                                 (5e-4, 0.99, 0.1, 2.0),
                                 (1e-2, 0.9, 0.3, 0.5)]]
    assert step._cache_size() == 1, "hparam change recompiled the kernel"
    # and each matches the oracle at its own hparams
    for (pk, _, _), (lr, b, w, cl) in zip(
            outs, [(1e-3, 0.999, 0.0, 1.0), (5e-4, 0.99, 0.1, 2.0),
                   (1e-2, 0.9, 0.3, 0.5)]):
        pr, _, _ = adalomo_update_ref(p, g, r, c, lr=lr, step=2.0, beta=b,
                                      weight_decay=w, clip=cl)
        np.testing.assert_allclose(pk, pr, rtol=2e-5, atol=2e-6)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 200), n=st.integers(8, 300),
       bm=st.sampled_from([32, 64, 128]), bn=st.sampled_from([64, 128]))
def test_property_block_edges(m, n, bm, bn):
    """Any (shape, block) combination — incl. non-divisible edges — matches
    the oracle."""
    key = jax.random.PRNGKey(m * 1000 + n)
    p, g, r, c = _mk(key, m, n, jnp.float32, jnp.float32, 3.0)
    pk, rk, ck = adalomo_update(p, g, r, c, 1e-3, 3.0, interpret=True,
                                block=(bm, bn))
    pr, rr, cr = adalomo_update_ref(p, g, r, c, lr=1e-3, step=3.0)
    np.testing.assert_allclose(pk, pr, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(rk, rr, rtol=2e-5, atol=2e-7)
    np.testing.assert_allclose(ck, cr, rtol=2e-5, atol=2e-7)


def test_pallas_backend_drop_in_trains():
    """get_rule('adalomo', backend='pallas') is the same rule — it slots
    into the fused engine over the same OptState and reproduces the jnp
    backend's trajectory (the kernel is a dispatch, not a second rule)."""
    from repro.core import optimizers as opt_lib
    from repro.models.registry import get_arch
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = arch.init_params(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, arch.cfg.vocab),
             "labels": jax.random.randint(key, (2, 16), 0, arch.cfg.vocab)}
    results = []
    state_trees = []
    for opt in (opt_lib.get_opt("adalomo", backend="jnp"),
                opt_lib.get_opt("adalomo", backend="pallas",
                                interpret=True, block=(128, 256))):
        opt_state = opt.init(params)
        step = arch.make_fused_train_step(opt)
        p, s = params, opt_state
        for _ in range(2):
            p, s, loss, _ = jax.jit(
                lambda pp, ss, bb: step(pp, ss, bb,
                                        hparams=jnp.float32(1e-3))
            )(p, s, batch)
        results.append((float(loss), p))
        state_trees.append(s)
    assert abs(results[0][0] - results[1][0]) < 1e-4
    for a, b in zip(jax.tree.leaves(results[0][1]),
                    jax.tree.leaves(results[1][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)
    # one state layout: identical treedefs across backends
    assert (jax.tree.structure(state_trees[0])
            == jax.tree.structure(state_trees[1]))

"""Test-suite bootstrap: make the offline hypothesis shim importable.

The CI image has no network, so ``hypothesis`` may be absent.  Property
tests import it via ``try: from hypothesis import ...`` with a fallback to
``hypothesis_stub`` — this conftest puts ``tests/_compat`` on sys.path so
that fallback resolves regardless of how pytest was invoked.
"""
import os
import sys

_COMPAT = os.path.join(os.path.dirname(__file__), "_compat")
if _COMPAT not in sys.path:
    sys.path.insert(0, _COMPAT)

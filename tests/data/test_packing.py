"""Segment packing: bucket boundaries, first-fit placement, the exactly-
once token-conservation guarantee, layout invariants (positions restart,
labels never cross segments, loss_mask), and the packed stream's
stateless-given-step rewind contract.
"""
import numpy as np
import pytest

from repro.data.pipeline import (DataConfig, PackedBatch, SyntheticLM,
                                 batches, bucket_boundaries, pack_documents,
                                 padded_batch_from_docs)


# ---------------------------------------------------------------------
# bucket boundaries (t2t idiom)
# ---------------------------------------------------------------------

def test_bucket_boundaries_monotone_and_bounded():
    bb = bucket_boundaries(512)
    assert all(b2 > b1 for b1, b2 in zip(bb, bb[1:]))
    assert bb[0] == 8 and bb[-1] < 512
    # multiplicative growth: each boundary is max(x+1, int(1.1 x))
    for b1, b2 in zip(bb, bb[1:]):
        assert b2 == max(b1 + 1, int(b1 * 1.1))


def test_bucket_boundaries_degenerate():
    assert bucket_boundaries(8) == [8]
    assert bucket_boundaries(4) == [4]


# ---------------------------------------------------------------------
# pack_documents
# ---------------------------------------------------------------------

def _docs(lengths, base=0):
    """Documents with globally-unique tokens: doc i's slots are a
    contiguous integer range, so conservation is checkable by value."""
    out, off = [], base
    for n in lengths:
        out.append(np.arange(off, off + n + 1, dtype=np.int32))
        off += n + 1
    return out


def test_tokens_conserved_exactly_once():
    docs = _docs([12, 20, 9, 31, 5, 17])
    pb, used = pack_documents(docs, n_rows=2, seq_len=48)
    assert used == [0, 1, 2, 3, 4, 5]
    got = sorted(pb.tokens[pb.segment_ids > 0].tolist())
    want = sorted(t for d in docs for t in d[:-1].tolist())
    assert got == want  # every input token placed exactly once
    # pad slots are inert: label -1, loss_mask False
    assert (pb.labels[pb.segment_ids == 0] == -1).all()
    assert not pb.loss_mask[pb.segment_ids == 0].any()


def test_layout_invariants_per_segment():
    docs = _docs([12, 20, 9, 31, 5, 17])
    pb, _ = pack_documents(docs, n_rows=2, seq_len=48)
    for r in range(pb.tokens.shape[0]):
        for s in range(1, pb.segment_ids[r].max() + 1):
            sl = pb.segment_ids[r] == s
            n = int(sl.sum())
            # positions restart at 0 within every segment
            assert pb.positions[r][sl].tolist() == list(range(n))
            toks = pb.tokens[r][sl]
            labs = pb.labels[r][sl]
            # labels are the doc's own next tokens — the per-document
            # shift happened before packing, so no label crosses into a
            # neighbouring segment
            assert (labs[:-1] == toks[1:]).all()
            assert labs[-1] == toks[-1] + 1  # unique-range docs
            assert pb.loss_mask[r][sl].all()


def test_first_fit_overflows_to_next_row():
    # 40 + 20 can't share a 48-slot row: first-fit must split them
    docs = _docs([40, 20])
    pb, used = pack_documents(docs, n_rows=2, seq_len=48)
    assert used == [0, 1]
    rows_used = {int(r) for r in range(2) if (pb.segment_ids[r] > 0).any()}
    assert rows_used == {0, 1}
    assert pb.segment_ids.max() == 1  # one doc per row here


def test_nonfitting_docs_dropped_deterministically():
    docs = _docs([40, 40, 40])  # only two rows of 48 slots
    pb, used = pack_documents(docs, n_rows=2, seq_len=48)
    assert len(used) == 2
    pb2, used2 = pack_documents(docs, n_rows=2, seq_len=48)
    assert used == used2
    np.testing.assert_array_equal(pb.tokens, pb2.tokens)


def test_pack_documents_raises():
    with pytest.raises(ValueError, match="exceeds row seq_len"):
        pack_documents(_docs([49]), n_rows=1, seq_len=48)
    with pytest.raises(ValueError, match=">= 2 tokens"):
        pack_documents([np.array([7], np.int32)], n_rows=1, seq_len=48)


def test_padding_efficiency_property():
    docs = _docs([30, 10])
    pb, _ = pack_documents(docs, n_rows=1, seq_len=48)
    assert pb.padding_efficiency == pytest.approx(40 / 48)


# ---------------------------------------------------------------------
# the packed stream
# ---------------------------------------------------------------------

def _cfg(**kw):
    base = dict(vocab=128, seq_len=64, global_batch=4, packing=True)
    base.update(kw)
    return DataConfig(**base)


def test_packed_batch_matches_train_specs():
    from repro.models.registry import get_arch
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    cfg = _cfg(vocab=arch.cfg.vocab)
    b = SyntheticLM(cfg).packed_batch(0)
    specs = arch.train_batch_specs(cfg.global_batch, cfg.seq_len,
                                   packed=True)
    assert set(b) == set(specs)
    for k_, sds in specs.items():
        assert b[k_].shape == sds.shape, k_
        assert b[k_].dtype == sds.dtype, k_


def test_packed_stream_stateless_given_step():
    cfg = _cfg()
    it0 = batches(cfg, 0)
    for _ in range(2):
        next(it0)
    third = next(it0)
    first = next(batches(cfg, 2))
    for k_ in third:
        np.testing.assert_array_equal(third[k_], first[k_])


def test_packing_flag_dispatches_stream():
    b_packed = next(batches(_cfg(), 0))
    b_padded = next(batches(_cfg(packing=False), 0))
    assert "segment_ids" in b_packed and "segment_ids" not in b_padded
    assert set(b_padded) == {"tokens", "labels"}


def test_packed_beats_padded_efficiency():
    """The point of the layout: first-fit packing recovers most of the
    padding tax a one-doc-per-row layout pays on ragged docs."""
    cfg = _cfg()
    src = SyntheticLM(cfg)
    b = src.packed_batch(0)
    packed_eff = (b["segment_ids"] > 0).mean()
    docs = src.docs(0)[:cfg.global_batch]
    pad = padded_batch_from_docs(docs, cfg.global_batch, cfg.seq_len)
    padded_eff = (pad["labels"] >= 0).mean()
    assert packed_eff > padded_eff
    assert packed_eff > 0.85


def test_padded_batch_from_docs_layout():
    docs = _docs([12, 30])
    b = padded_batch_from_docs(docs, n_rows=2, seq_len=48)
    assert set(b) == {"tokens", "labels"}
    assert b["tokens"].shape == (2, 48)
    np.testing.assert_array_equal(b["tokens"][0][:12], docs[0][:-1])
    np.testing.assert_array_equal(b["labels"][0][:12], docs[0][1:])
    assert (b["labels"][0][12:] == -1).all()


def test_memmap_corpus_packed(tmp_path):
    from repro.data.pipeline import MemmapCorpus
    data = np.arange(4096, dtype=np.int32) % 128
    path = tmp_path / "corpus.bin"
    data.tofile(path)
    cfg = _cfg(path=str(path))
    src = MemmapCorpus(cfg)
    b = src.packed_batch(0)
    assert b["tokens"].shape == (4, 64)
    assert (b["segment_ids"] > 0).mean() > 0.5
    # stateless too
    b2 = src.packed_batch(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])

"""Liveness events in the metrics stream: heartbeat stalls and straggler
steps annotate ``{"event": ...}`` records into the MetricsHook JSONL, so
one file per run carries throughput *and* liveness (consumed by the
sweep report's per-member event counts)."""
import json
import time
import types

from repro.run import (HeartbeatHook, MetricsHook, StepEvent,
                       StragglerHook, find_metrics_hook)


def _ctx(metrics, extra_hooks=()):
    return types.SimpleNamespace(
        spec=types.SimpleNamespace(data=None),
        start_step=0, log=lambda s: None,
        hooks=(metrics,) + tuple(extra_hooks))


def _ev(step, dt, loss=1.0):
    return StepEvent(step=step, loss=loss, metrics={}, hparams={"lr": 1e-3},
                     dt=dt)


def _records(path):
    return [r for r in (json.loads(l) for l in open(path) if l.strip())
            if "schema" not in r]           # skip the stream header


def test_find_metrics_hook():
    m = MetricsHook("/tmp/unused.jsonl")
    assert find_metrics_hook((object(), m)) is m
    assert find_metrics_hook(()) is None


def test_annotate_interleaves_event_records(tmp_path):
    path = tmp_path / "m.jsonl"
    m = MetricsHook(path)
    ctx = _ctx(m)
    m.on_run_start(ctx)
    m.on_step_end(ctx, _ev(0, dt=0.1))
    m.annotate("custom", 0, detail="x")
    m.on_step_end(ctx, _ev(1, dt=0.1))
    m.on_exit(ctx)
    recs = _records(path)
    assert [r.get("event") for r in recs] == [None, "custom", None]
    assert recs[1] == {"event": "custom", "step": 0, "detail": "x"}


def test_straggler_step_annotates_metrics(tmp_path):
    path = tmp_path / "m.jsonl"
    m = MetricsHook(path)
    s = StragglerHook()
    ctx = _ctx(m, (s,))
    m.on_run_start(ctx)
    for step, dt in enumerate([0.1, 0.1, 0.1]):
        ev = _ev(step, dt)
        m.on_step_end(ctx, ev)
        s.on_step_end(ctx, ev)
    slow = _ev(3, dt=10.0)              # >3x the EMA: flagged
    m.on_step_end(ctx, slow)
    s.on_step_end(ctx, slow)
    m.on_exit(ctx)
    events = [r for r in _records(path) if "event" in r]
    assert len(events) == 1
    e = events[0]
    assert e["event"] == "straggler" and e["step"] == 3
    assert e["dt_s"] == 10.0 and e["ema_s"] > 0


def test_heartbeat_stall_annotates_metrics(tmp_path):
    path = tmp_path / "m.jsonl"
    m = MetricsHook(path)
    h = HeartbeatHook(timeout_s=0.05)
    ctx = _ctx(m, (h,))
    m.on_run_start(ctx)
    h.on_run_start(ctx)
    ev = _ev(0, dt=0.01)
    m.on_step_end(ctx, ev)
    h.on_step_end(ctx, ev)
    try:
        deadline = time.time() + 5.0
        while time.time() < deadline:     # the watchdog fires off-thread
            with m._lock:
                if any("event" in r for r in m.records):
                    break
            time.sleep(0.01)
    finally:
        h.on_exit(ctx)
        m.on_exit(ctx)
    events = [r for r in _records(path) if "event" in r]
    assert events and events[0]["event"] == "heartbeat_stall"
    assert events[0]["step"] == 0
    assert events[0]["timeout_s"] == 0.05

"""Hook pipeline + run() driver: event protocol, ordering, default
pipeline assembly, checkpoint/resume through the one entrypoint, and the
acceptance guarantee that hooks + schedulable hparams cause **zero
steady-state recompiles** of the jitted step.
"""
import numpy as np
import pytest

from repro.data.pipeline import DataConfig
from repro.run import (CheckpointSpec, CheckpointHook, EvalSpec, FaultSpec,
                       HeartbeatHook, HistoryHook, Hook, LoggingHook,
                       ModelSpec, OptSpec, RunSpec, StepSpec, StragglerHook,
                       run)


def _spec(total=3, **kw):
    base = dict(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataConfig(vocab=0, seq_len=32, global_batch=4),
        opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant"),
        steps=StepSpec(total=total),
        log_every=0)
    base.update(kw)
    return RunSpec(**base)


class Recorder(Hook):
    def __init__(self):
        self.events = []

    def on_run_start(self, ctx):
        self.events.append(("run_start", ctx.start_step))

    def on_step_end(self, ctx, ev):
        self.events.append(("step_end", ev.step))

    def on_eval(self, ctx, step, metrics):
        self.events.append(("eval", step))

    def on_exit(self, ctx):
        self.events.append(("exit", None))


# ---------------------------------------------------------------------
# Event protocol
# ---------------------------------------------------------------------

def test_event_sequence_and_payload():
    rec = Recorder()
    res = run(_spec(total=3), hooks=(rec,), log_fn=lambda s: None)
    assert rec.events == [("run_start", 0), ("step_end", 0),
                          ("step_end", 1), ("step_end", 2), ("exit", None)]
    assert res.history["step"] == [0, 1, 2]
    assert len(res.history["loss"]) == 3
    assert np.isfinite(res.history["loss"]).all()
    # constant schedule recorded through the hook
    assert res.history["lr"] == [pytest.approx(1e-3)] * 3


def test_eval_event_broadcast_to_all_hooks():
    rec = Recorder()
    res = run(_spec(total=4, eval=EvalSpec(every=2, n_batches=1)),
              hooks=(rec,), log_fn=lambda s: None)
    assert ("eval", 1) in rec.events and ("eval", 3) in rec.events
    assert res.history["eval_step"] == [1, 3]
    assert len(res.history["eval_loss"]) == 2


def test_on_exit_runs_even_when_a_step_raises():
    rec = Recorder()

    def bad_iter():
        yield {"tokens": np.zeros((4, 32), np.int32),
               "labels": np.zeros((4, 32), np.int32)}
        raise RuntimeError("data source died")

    with pytest.raises(RuntimeError, match="data source died"):
        run(_spec(total=3, fault=FaultSpec(retries=0)),
            batch_iter=bad_iter(), hooks=(rec,), log_fn=lambda s: None)
    assert rec.events[-1] == ("exit", None)
    assert ("step_end", 0) in rec.events


def test_on_exit_runs_when_on_run_start_raises():
    rec = Recorder()

    class Bomb(Hook):
        def on_run_start(self, ctx):
            raise RuntimeError("bad hook")

    with pytest.raises(RuntimeError, match="bad hook"):
        run(_spec(total=2), hooks=(rec, Bomb()), log_fn=lambda s: None)
    # rec started before the bomb, and still saw the exit event
    assert rec.events == [("run_start", 0), ("exit", None)]


def _flaky_program(spec, fail_on_call):
    """A StepProgram whose step raises a transient device error on the
    N-th call — after the real (donating) computation already consumed
    its input buffers, like a real late-step failure."""
    from jax.errors import JaxRuntimeError
    from repro.run import build_step_program
    prog = build_step_program(spec)
    real = prog.step
    calls = {"n": 0}

    def step(params, opt_state, batch, hp):
        out = real(params, opt_state, batch, hp)
        calls["n"] += 1
        if calls["n"] == fail_on_call:
            raise JaxRuntimeError("injected ICI flap")
        return out

    prog.step = step
    return prog


def test_transient_failure_recovers_from_checkpoint(tmp_path):
    """A transient device error mid-run restores the latest complete
    checkpoint, rewinds the stateless data stream, and finishes with the
    exact state AND history of an uninterrupted run (donated buffers make
    a blind same-args retry impossible — recovery goes through the
    checkpoint; on_recover truncates re-executed history entries)."""
    # fail on call 6 = step 5, two steps past the step-3 checkpoint, so
    # recovery re-executes steps 3 and 4 — the history-duplication case
    spec = _spec(total=7, eval=EvalSpec(every=2, n_batches=1),
                 checkpoint=CheckpointSpec(dir=str(tmp_path / "c"),
                                           every=3))
    logs = []
    res = run(spec, program=_flaky_program(spec, 6), log_fn=logs.append)
    assert any("restored step 3" in m for m in logs)
    assert int(res.opt_state.step) == 7

    clean = run(_spec(total=7, eval=EvalSpec(every=2, n_batches=1)),
                log_fn=lambda s: None)
    import jax
    for a, b in zip(jax.tree.leaves((res.params, res.opt_state)),
                    jax.tree.leaves((clean.params, clean.opt_state))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # history is the uninterrupted record: no duplicated steps, and the
    # rewound eval stream reproduces the clean eval curve exactly
    assert res.history["step"] == clean.history["step"] == list(range(7))
    np.testing.assert_allclose(res.history["loss"], clean.history["loss"])
    assert res.history["eval_step"] == clean.history["eval_step"]
    np.testing.assert_allclose(res.history["eval_loss"],
                               clean.history["eval_loss"])


def test_eval_stream_deterministic_across_resume(tmp_path):
    """The default eval stream fast-forwards on checkpoint resume: a
    resumed run's eval curve equals the uninterrupted run's tail."""
    ck = str(tmp_path / "ck")
    clean = run(_spec(total=6, eval=EvalSpec(every=2, n_batches=2)),
                log_fn=lambda s: None)
    run(_spec(total=4, eval=EvalSpec(every=2, n_batches=2),
              checkpoint=CheckpointSpec(dir=ck, every=4)),
        log_fn=lambda s: None)
    res = run(_spec(total=6, eval=EvalSpec(every=2, n_batches=2),
                    checkpoint=CheckpointSpec(dir=ck, every=4,
                                              resume=True)),
              log_fn=lambda s: None)
    assert res.start_step == 4
    assert res.history["eval_step"] == [5]
    np.testing.assert_allclose(res.history["eval_loss"],
                               clean.history["eval_loss"][2:])


def test_transient_failure_without_checkpoint_raises():
    from jax.errors import JaxRuntimeError
    from repro.run import build_step_program
    spec = _spec(total=3)
    prog = build_step_program(spec)

    def step(params, opt_state, batch, hp):
        raise JaxRuntimeError("no checkpoint to recover from")

    prog.step = step
    with pytest.raises(JaxRuntimeError):
        run(spec, program=prog, log_fn=lambda s: None)


# ---------------------------------------------------------------------
# Default pipeline assembly
# ---------------------------------------------------------------------

def test_default_pipeline_order_and_replacement():
    mine = StragglerHook()
    res = run(_spec(total=1,
                    fault=FaultSpec(heartbeat_timeout_s=60.0),
                    log_every=5),
              hooks=(mine,), log_fn=lambda s: None)
    kinds = [type(h).__name__ for h in res.hooks]
    # measurement before side effects; user instance replaces the default
    assert kinds == ["HeartbeatHook", "HistoryHook", "LoggingHook",
                    "StragglerHook"]
    assert res.find_hook(StragglerHook) is mine
    assert len(mine.monitor.events) == 0  # observed, no stragglers flagged
    hb = res.find_hook(HeartbeatHook)
    assert hb.heartbeat is not None and not hb.heartbeat.stalled


def test_checkpoint_hook_and_resume_through_run(tmp_path):
    ck = str(tmp_path / "ck")
    spec = _spec(total=4, checkpoint=CheckpointSpec(dir=ck, every=2))
    res = run(spec, log_fn=lambda s: None)
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(ck)
    assert mgr.latest_step() == 4

    # a second run with resume=True and a longer horizon continues at 4
    spec2 = _spec(total=6, checkpoint=CheckpointSpec(dir=ck, every=2,
                                                     resume=True))
    res2 = run(spec2, log_fn=lambda s: None)
    assert res2.start_step == 4
    assert res2.history["step"] == [4, 5]
    # ...and the resumed trajectory equals the uninterrupted one
    res_full = run(_spec(total=6), log_fn=lambda s: None)
    np.testing.assert_allclose(res2.history["loss"],
                               res_full.history["loss"][4:], rtol=1e-5)


# ---------------------------------------------------------------------
# Acceptance: zero steady-state recompiles with the full pipeline
# ---------------------------------------------------------------------

def test_full_hook_pipeline_zero_recompiles(tmp_path):
    """6 steps with cosine-scheduled hparams + history + logging + eval +
    checkpoint + heartbeat hooks: the jitted step compiles exactly once.
    Hooks are host-side observers — they can never retrace the program."""
    spec = RunSpec(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataConfig(vocab=0, seq_len=32, global_batch=4),
        opt=OptSpec(name="adalomo", lr=1e-3, schedule="cosine",
                    hparams={"weight_decay": 0.01}),
        steps=StepSpec(total=6),
        checkpoint=CheckpointSpec(dir=str(tmp_path / "ck"), every=2),
        eval=EvalSpec(every=3, n_batches=1),
        fault=FaultSpec(heartbeat_timeout_s=60.0),
        log_every=2)
    res = run(spec, log_fn=lambda s: None)
    assert res.program.cache_size() == 1, \
        "hook pipeline / hparam schedule recompiled the train step"
    # the lr actually changed every step (schedule ran as data)
    assert len(set(res.history["lr"])) == len(res.history["lr"])
    assert res.find_hook(CheckpointHook) is not None
    assert res.find_hook(HistoryHook) is not None


def test_microbatched_run_zero_recompiles():
    spec = _spec(total=4, steps=StepSpec(total=4, microbatches=2),
                 data=DataConfig(vocab=0, seq_len=32, global_batch=4))
    res = run(spec, log_fn=lambda s: None)
    assert res.program.cache_size() == 1
    assert int(res.opt_state.step) == 8  # k sequential updates per step


def test_history_matches_trainer_shim():
    """The Trainer compat shim and bare run() produce identical curves —
    the migration is semantics-preserving."""
    import jax
    from repro.data.pipeline import batches
    from repro.models.registry import get_arch
    from repro.train.loop import TrainConfig, Trainer
    arch = get_arch("h2o-danube-1.8b", smoke=True)
    spec = _spec(total=3)
    res = run(spec, log_fn=lambda s: None)

    tcfg = TrainConfig(optimizer="adalomo", lr=1e-3, total_steps=3,
                       schedule="constant", log_every=0)
    tr = Trainer(arch, tcfg, log_fn=lambda s: None)
    params, state = tr.init(0)
    dcfg = DataConfig(vocab=arch.cfg.vocab, seq_len=32, global_batch=4)
    out = tr.fit(params, state, batches(dcfg))
    np.testing.assert_allclose(out["history"]["loss"],
                               res.history["loss"], rtol=1e-6)

"""MetricsHook × schema v1: the stream opens with a header, probe
records obey the ObservabilitySpec cadence and the rewind contract, and
— the back-compat guarantee — old unversioned JSONL files still parse
through the reader and every find_metrics_hook consumer path."""
import json
import types

from repro.run import MetricsHook, ObservabilitySpec, StepEvent
from repro.telemetry import iter_data_records, read_stream


def _ctx(metrics, start_step=0, observe=None):
    return types.SimpleNamespace(
        spec=types.SimpleNamespace(data=None, observe=observe),
        start_step=start_step, log=lambda s: None, hooks=(metrics,))


def _ev(step, health=None, loss=1.0):
    metrics = {} if health is None else {"opt_health": health}
    return StepEvent(step=step, loss=loss, metrics=metrics,
                     hparams={"lr": 1e-3}, dt=0.1)


def _health(x=0.5):
    return {"group_ratio": {"default": x}, "eff_lr": {"n_units": 1},
            "factored": {"recon/w": x / 2}}


def test_stream_opens_with_v1_header(tmp_path):
    p = tmp_path / "m.jsonl"
    m = MetricsHook(p)
    ctx = _ctx(m)
    m.on_run_start(ctx)
    m.on_step_end(ctx, _ev(0))
    m.on_exit(ctx)
    lines = [json.loads(l) for l in p.open()]
    assert lines[0] == {"schema": 1, "stream": "train"}
    assert m.records == lines[1:]            # header never in records
    s = read_stream(p)
    assert s.schema == 1 and len(s.steps()) == 1


def test_legacy_unversioned_stream_still_parses(tmp_path):
    """Pre-v1 files (no header) must read cleanly — schema 0."""
    p = tmp_path / "old.jsonl"
    p.write_text('{"step": 0, "loss": 2.0, "tokens_per_s": 10.0}\n'
                 '{"event": "straggler", "step": 1, "dt_s": 9.0}\n'
                 '{"step": 1, "loss": 1.5, "tokens_per_s": 11.0}\n')
    s = read_stream(p)
    assert s.schema == 0 and s.header is None
    assert [r["step"] for r in s.steps()] == [0, 1]
    assert len(s.events("straggler")) == 1
    # the consumer surface sweep._member_stats uses — identical records
    recs = list(iter_data_records(p.read_text().splitlines()))
    assert len(recs) == 3


def test_resume_from_legacy_file_upgrades_to_v1(tmp_path):
    """A resumed run over a pre-v1 metrics file keeps the old records and
    rewrites the stream WITH a header — write-side upgrade, read-side
    back-compat."""
    p = tmp_path / "m.jsonl"
    p.write_text('{"step": 0, "loss": 2.0}\n{"step": 1, "loss": 1.9}\n'
                 '{"step": 2, "loss": 1.8}\n')
    m = MetricsHook(p)
    ctx = _ctx(m, start_step=2)
    m.on_run_start(ctx)                      # keeps steps < 2
    m.on_step_end(ctx, _ev(2, loss=1.7))
    m.on_exit(ctx)
    lines = [json.loads(l) for l in p.open()]
    assert lines[0]["schema"] == 1
    data = lines[1:]
    assert [r["step"] for r in data] == [0, 1, 2]
    assert data[2]["loss"] == 1.7            # re-executed tail replaced


def test_resume_from_v1_file_keeps_single_header(tmp_path):
    p = tmp_path / "m.jsonl"
    m = MetricsHook(p)
    ctx = _ctx(m)
    m.on_run_start(ctx)
    m.on_step_end(ctx, _ev(0))
    m.on_step_end(ctx, _ev(1))
    m.on_exit(ctx)

    m2 = MetricsHook(p)
    ctx2 = _ctx(m2, start_step=1)
    m2.on_run_start(ctx2)
    m2.on_step_end(ctx2, _ev(1))
    m2.on_exit(ctx2)
    lines = [json.loads(l) for l in p.open()]
    assert sum(1 for r in lines if "schema" in r) == 1
    assert [r["step"] for r in lines[1:]] == [0, 1]


def test_probe_records_cadence_and_rewind(tmp_path):
    p = tmp_path / "m.jsonl"
    m = MetricsHook(p)
    ctx = _ctx(m, observe=ObservabilitySpec(optimizer_every=2,
                                            factored_every=4))
    m.on_run_start(ctx)
    for step in range(6):
        m.on_step_end(ctx, _ev(step, health=_health(0.1 * (step + 1))))
    s = read_stream(p)
    assert [r["step"] for r in s.probes("opt_health")] == [0, 2, 4]
    assert [r["step"] for r in s.probes("factored")] == [0, 4]

    # fault rewind to step 3: probe records at/after 3 are dropped too,
    # then re-recorded identically by the re-executed steps
    m.on_recover(ctx, 3)
    for step in range(3, 6):
        m.on_step_end(ctx, _ev(step, health=_health(0.1 * (step + 1))))
    m.on_exit(ctx)
    s2 = read_stream(p)
    assert [r["step"] for r in s2.probes("opt_health")] == [0, 2, 4]
    assert [r["step"] for r in s2.steps()] == list(range(6))
    assert s2.probes("opt_health")[-1]["group_ratio"]["default"] == 0.5


def test_probes_not_recorded_when_observe_disabled(tmp_path):
    p = tmp_path / "m.jsonl"
    m = MetricsHook(p)
    ctx = _ctx(m, observe=None)              # e.g. a hand-built ctx
    m.on_run_start(ctx)
    m.on_step_end(ctx, _ev(0, health=_health()))
    m.on_exit(ctx)
    assert read_stream(p).probes() == []

"""StepProgram contract: one builder owns the fused/unfused ×
microbatch matrix, exposes the abstract jit signature (dry-run lowers the
identical program), and microbatching is equivalence-tested against
explicit steps — pinning the scan path.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig
from repro.models.registry import get_arch
from repro.run import (ModelSpec, OptSpec, RunSpec, StepSpec,
                       build_step_program)

REPO = Path(__file__).resolve().parents[2]


@pytest.fixture(scope="module")
def arch():
    return get_arch("h2o-danube-1.8b", smoke=True)


def _spec(arch, *, batch, microbatches=1, fused=None, optimizer="adalomo",
          seq=32):
    return RunSpec(
        model=ModelSpec(arch=arch.arch_id, smoke=True),
        data=DataConfig(vocab=arch.cfg.vocab, seq_len=seq,
                        global_batch=batch),
        opt=OptSpec(name=optimizer, lr=1e-3, schedule="constant"),
        steps=StepSpec(total=4, microbatches=microbatches, fused=fused),
        log_every=0)


def _batch(arch, key, b, s=32):
    return {"tokens": jax.random.randint(key, (b, s), 0, arch.cfg.vocab),
            "labels": jax.random.randint(key, (b, s), 0, arch.cfg.vocab)}


# ---------------------------------------------------------------------
# Microbatching equivalence (satellite: pin the scan path)
# ---------------------------------------------------------------------

def test_fused_microbatch_equals_explicit_sequential_steps_bitwise(arch):
    """The fused path at microbatches=k on a k·b batch does *sequential
    per-microbatch updates* (LOMO semantics): it must equal k explicit
    single-microbatch steps on the k chunks — bitwise, since it is the
    same math in the same order."""
    k, b = 2, 2
    prog_k = build_step_program(_spec(arch, batch=k * b, microbatches=k))
    prog_1 = build_step_program(_spec(arch, batch=b, microbatches=1))
    hp = prog_k.hparams_fn(1)  # constant schedule: same hp every step

    big = _batch(arch, jax.random.PRNGKey(1), k * b)
    chunks = [jax.tree.map(lambda x: x[i * b:(i + 1) * b], big)
              for i in range(k)]

    p_scan, s_scan = prog_k.init(0)
    p_scan, s_scan, loss_scan, _ = prog_k.step(p_scan, s_scan, big, hp)

    p_seq, s_seq = prog_1.init(0)
    losses = []
    for c in chunks:
        p_seq, s_seq, loss, _ = prog_1.step(p_seq, s_seq, c, hp)
        losses.append(loss)

    assert int(s_scan.step) == int(s_seq.step) == k
    for a, b_ in zip(jax.tree.leaves((p_scan, s_scan)),
                     jax.tree.leaves((p_seq, s_seq))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
    np.testing.assert_allclose(
        float(loss_scan), float(np.mean([float(x) for x in losses])),
        rtol=1e-6)


def test_unfused_microbatch_accumulation_matches_full_batch(arch):
    """The unfused path at microbatches=k accumulates gradients — one
    update from the mean gradient, which must match the full-batch
    gradient step to tight tolerance (fp reassociation only)."""
    k, b = 2, 2
    prog_k = build_step_program(
        _spec(arch, batch=k * b, microbatches=k, optimizer="adamw",
              fused=False))
    prog_full = build_step_program(
        _spec(arch, batch=k * b, microbatches=1, optimizer="adamw",
              fused=False))
    hp = prog_k.hparams_fn(1)
    big = _batch(arch, jax.random.PRNGKey(2), k * b)

    p_k, s_k = prog_k.init(0)
    p_k, s_k, loss_k, _ = prog_k.step(p_k, s_k, big, hp)
    p_f, s_f = prog_full.init(0)
    p_f, s_f, loss_f, _ = prog_full.step(p_f, s_f, big, hp)

    assert int(s_k.step) == int(s_f.step) == 1
    np.testing.assert_allclose(float(loss_k), float(loss_f), rtol=1e-5)
    for a, b_ in zip(jax.tree.leaves(p_k), jax.tree.leaves(p_f)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=1e-6)


def test_microbatch_divisibility_error_is_clear(arch):
    # spec.data is None here, so the check fires at trace time instead
    # of RunSpec construction — with the same clear message.
    prog = build_step_program(
        RunSpec(model=ModelSpec(arch=arch.arch_id, smoke=True),
                data=None,
                opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant"),
                steps=StepSpec(total=2, microbatches=3), log_every=0),
        arch)
    bad = _batch(arch, jax.random.PRNGKey(0), 4)
    p, s = prog.init(0)
    with pytest.raises(ValueError, match="not divisible by microbatches"):
        prog.step(p, s, bad, prog.hparams_fn(1))


# ---------------------------------------------------------------------
# Abstract signature: dryrun lowers what train executes
# ---------------------------------------------------------------------

def test_abstract_args_match_concrete_signature(arch):
    spec = _spec(arch, batch=4)
    prog = build_step_program(spec)
    p_sds, o_sds, b_sds, hp_sds = prog.abstract_args()
    p, s = prog.init(0)
    assert jax.tree.structure(p_sds) == jax.tree.structure(p)
    assert all(a.shape == b_.shape and a.dtype == b_.dtype
               for a, b_ in zip(jax.tree.leaves(p_sds), jax.tree.leaves(p)))
    assert jax.tree.structure(o_sds) == jax.tree.structure(s)
    batch = _batch(arch, jax.random.PRNGKey(0), 4)
    assert {k: (v.shape, v.dtype) for k, v in b_sds.items()} == \
        {k: (v.shape, v.dtype) for k, v in batch.items()}
    assert jax.tree.structure(hp_sds) == \
        jax.tree.structure(prog.hparams_fn(1))


def test_lower_on_abstract_args_then_train_no_retrace(arch):
    """Lowering the program (what dryrun does) and then training on
    concrete arrays of the same shapes uses ONE compiled entry — the
    dry-run artifact is the training program, not a variant."""
    spec = _spec(arch, batch=4)
    prog = build_step_program(spec)
    lowered = prog.lower()
    assert len(lowered.as_text()) > 0
    p, s = prog.init(0)
    batch = _batch(arch, jax.random.PRNGKey(0), 4)
    for i in range(3):
        p, s, loss, _ = prog.step(p, s, batch, prog.hparams_fn(i + 1))
    assert prog.cache_size() == 1, \
        "training re-traced a program dryrun had already lowered"


def test_train_batch_specs_agree_with_input_specs(arch):
    """The registry's dry-run input_specs and the run layer's train batch
    signature are the same function — the drift risk the Run API removes."""
    from repro.configs.shapes import SHAPES
    for shape_name in arch.supported_cells():
        sh = SHAPES[shape_name]
        if sh.kind != "train":
            continue
        via_registry = arch.input_specs(shape_name)
        via_run = arch.train_batch_specs(sh.global_batch, sh.seq_len)
        assert via_registry == via_run


@pytest.mark.slow
def test_dryrun_build_cell_lowers_via_step_program():
    """End-to-end: launch/dryrun's train cell builds through
    build_step_program and lowers under shardings (8 virtual devices;
    subprocess because the device count locks at first jax import)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import build_cell
mesh = make_test_mesh(8)
fn, args, in_sh, out_sh, donate, meta = build_cell(
    "h2o-danube-1.8b", "train_4k", mesh)
assert meta["kind"] == "train"
assert fn.__qualname__.startswith("build_step_program"), fn.__qualname__
assert isinstance(args[3], dict) and "lr" in args[3]
with mesh:
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    jfn.lower(*args)
print("DRYRUN_PROGRAM_OK")
"""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=600,
                          env=env, cwd=str(REPO))
    assert "DRYRUN_PROGRAM_OK" in proc.stdout, (proc.stdout[-2000:],
                                                proc.stderr[-4000:])

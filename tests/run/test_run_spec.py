"""RunSpec contract: serializable, CLI-parseable, validated."""
import dataclasses

import pytest

from repro.data.pipeline import DataConfig
from repro.launch.train import parse_virtual_devices
from repro.run import (CheckpointSpec, EvalSpec, FaultSpec, MeshSpec,
                       ModelSpec, OptSpec, RunSpec, StepSpec)


def _spec(**kw):
    base = dict(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataConfig(vocab=256, seq_len=64, global_batch=8, seed=3),
        opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant",
                    kwargs={"backend": "jnp"},
                    hparams={"weight_decay": 0.1}),
        steps=StepSpec(total=7, microbatches=2),
        mesh=MeshSpec(kind="single", optimized=False),
        checkpoint=CheckpointSpec(dir="/tmp/x", every=3, resume=True),
        eval=EvalSpec(every=2, n_batches=2),
        fault=FaultSpec(heartbeat_timeout_s=5.0, retries=1),
        log_every=0, seed=11)
    base.update(kw)
    return RunSpec(**base)


# ---------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------

def test_json_round_trip_is_lossless():
    spec = _spec()
    again = RunSpec.from_json(spec.to_json())
    assert again == spec
    # and through an indent/whitespace variation
    assert RunSpec.from_json(spec.to_json(indent=2)) == spec


def test_json_round_trip_with_none_data():
    spec = _spec(data=None)
    assert RunSpec.from_json(spec.to_json()) == spec
    assert spec.to_dict()["data"] is None


def test_nested_dataclasses_rehydrate_with_types():
    again = RunSpec.from_json(_spec().to_json())
    assert isinstance(again.model, ModelSpec)
    assert isinstance(again.data, DataConfig)
    assert isinstance(again.opt, OptSpec)
    assert again.opt.kwargs == {"backend": "jnp"}
    assert again.data.local_batch == 8


# ---------------------------------------------------------------------
# Validation / resolution
# ---------------------------------------------------------------------

def test_bad_schedule_and_mesh_kind_rejected():
    with pytest.raises(ValueError, match="schedule"):
        OptSpec(schedule="linear")
    with pytest.raises(ValueError, match="mesh kind"):
        MeshSpec(kind="torus")
    with pytest.raises(ValueError, match="microbatches"):
        StepSpec(microbatches=0)


def test_microbatch_divisibility_checked():
    with pytest.raises(ValueError, match="not divisible"):
        _spec(steps=StepSpec(total=3, microbatches=3))


def test_lr_and_fused_resolution():
    assert OptSpec(name="adalomo").resolved_lr() == 5e-4
    assert OptSpec(name="adamw").resolved_lr() == 2e-5
    assert OptSpec(name="adamw", lr=0.5).resolved_lr() == 0.5
    assert StepSpec().resolved_fused("adalomo") is True
    assert StepSpec().resolved_fused("adamw") is False
    assert StepSpec(fused=False).resolved_fused("adalomo") is False


def test_specs_are_frozen():
    spec = _spec()
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.model.arch = "other"


# ---------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------

def test_from_cli_basic():
    spec = RunSpec.from_cli(
        ["--arch", "h2o-danube-1.8b", "--smoke", "--steps", "5",
         "--optimizer", "adamw", "--weight-decay", "0.1", "--unfused",
         "--batch", "4", "--seq", "32", "--microbatches", "2",
         "--ckpt-dir", "/tmp/ck", "--ckpt-every", "2", "--resume",
         "--schedule", "constant", "--seed", "9"])
    assert spec.model == ModelSpec(arch="h2o-danube-1.8b", smoke=True)
    assert spec.opt.name == "adamw"
    assert spec.opt.hparams == {"weight_decay": 0.1}
    assert spec.opt.schedule == "constant"
    assert spec.steps == StepSpec(total=5, microbatches=2, fused=False)
    assert spec.checkpoint.dir == "/tmp/ck"
    assert spec.checkpoint.resume is True
    assert spec.data.global_batch == 4 and spec.data.seq_len == 32
    assert spec.data.vocab == 0      # resolved from the arch at run()
    assert spec.seed == 9


def test_from_cli_requires_arch():
    with pytest.raises(SystemExit):
        RunSpec.from_cli(["--steps", "3"])


def test_from_cli_round_trips_through_json():
    spec = RunSpec.from_cli(["--arch", "qwen3-32b", "--steps", "2"])
    assert RunSpec.from_json(spec.to_json()) == spec


# ---------------------------------------------------------------------
# --virtual-devices pre-argparse extraction (launch/train.py satellite)
# ---------------------------------------------------------------------

def test_virtual_devices_both_forms():
    assert parse_virtual_devices(["--virtual-devices", "8"]) == 8
    assert parse_virtual_devices(["--virtual-devices=8"]) == 8
    assert parse_virtual_devices(
        ["--arch", "x", "--virtual-devices=16", "--steps", "2"]) == 16
    assert parse_virtual_devices(["--arch", "x"]) is None


def test_virtual_devices_errors_cleanly():
    with pytest.raises(SystemExit, match="requires a value"):
        parse_virtual_devices(["--virtual-devices"])
    with pytest.raises(SystemExit, match="requires a value"):
        parse_virtual_devices(["--virtual-devices", "--arch"])
    for bad in ("abc", "0", "-3", ""):
        with pytest.raises(SystemExit, match="integer"):
            parse_virtual_devices([f"--virtual-devices={bad}"])

"""Packed batches through the Run API: per-document loss equivalence
(bitwise zero-leakage on the direct path, tight-tol vs one-doc-per-row),
the packed stream's fault-recovery rewind (bitwise resume), the packed
program's jit signature, and build-time rejection of families whose
batches carry structure packing would break.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, pack_documents
from repro.models.registry import get_arch
from repro.run import (CheckpointSpec, EvalSpec, MetricsHook, ModelSpec,
                       OptSpec, RunSpec, StepSpec, build_step_program, run)

SEQ = 24


def _spec(total=3, **kw):
    base = dict(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataConfig(vocab=0, seq_len=32, global_batch=4, packing=True),
        opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant"),
        steps=StepSpec(total=total),
        log_every=0)
    base.update(kw)
    return RunSpec(**base)


def _docs(lengths):
    out, off = [], 0
    for n in lengths:
        out.append(np.arange(off, off + n + 1, dtype=np.int32))
        off += n + 1
    return out


def _placements(pb, docs, used):
    """(row, segment_id) of every used doc, located by its unique tokens."""
    out = {}
    for i in used:
        first = docs[i][0]
        r, c = np.argwhere((pb.tokens == first) & (pb.segment_ids > 0))[0]
        out[i] = (int(r), int(pb.segment_ids[r, c]))
    return out


@pytest.fixture(scope="module")
def arch():
    return get_arch("h2o-danube-1.8b", smoke=True)


@pytest.fixture(scope="module")
def packed_case(arch):
    docs = _docs([10, 14, 8])
    pb, used = pack_documents(docs, n_rows=2, seq_len=SEQ)
    assert used == [0, 1, 2]
    params = arch.init_params(jax.random.PRNGKey(0))
    loss_fn = jax.jit(arch.make_loss_fn())
    return docs, pb, _placements(pb, docs, used), params, loss_fn


def _doc_loss(loss_fn, params, pb, row, seg_id):
    """Loss restricted to one packed document via label masking."""
    b = {k: jnp.asarray(v) for k, v in pb.as_dict().items()}
    keep = (pb.segment_ids == seg_id)
    keep[np.arange(pb.tokens.shape[0]) != row] = False
    b["labels"] = jnp.where(jnp.asarray(keep), b["labels"], -1)
    loss, metrics = loss_fn(params, b)
    return float(loss), float(metrics["ntokens"])


def test_per_document_loss_bitwise_under_foreign_scrub(packed_case):
    """Direct path, same shapes: replacing every *other* document's
    tokens with junk leaves each document's loss bitwise identical —
    the end-to-end no-cross-segment guarantee at the model level."""
    docs, pb, places, params, loss_fn = packed_case
    for i, (row, seg_id) in places.items():
        ref, ntok = _doc_loss(loss_fn, params, pb, row, seg_id)
        assert ntok == len(docs[i]) - 1
        scrub = pb.as_dict()
        keep = (pb.segment_ids == seg_id) & \
            (np.arange(pb.tokens.shape[0])[:, None] == row)
        scrub["tokens"] = np.where(keep, pb.tokens, 1)
        pb2 = pb.__class__(tokens=scrub["tokens"], labels=pb.labels,
                           segment_ids=pb.segment_ids,
                           positions=pb.positions, loss_mask=pb.loss_mask)
        got, _ = _doc_loss(loss_fn, params, pb2, row, seg_id)
        assert got == ref, f"doc {i}: cross-segment leakage into the loss"


def test_per_document_loss_matches_one_doc_per_row(packed_case):
    """Each packed document's loss equals the same doc alone in its own
    row (the unpacked layout), to float tolerance — the reduction tree
    shifts with the in-row offset, so bitwise is only guaranteed for
    identical layouts (previous test)."""
    docs, pb, places, params, loss_fn = packed_case
    for i, (row, seg_id) in places.items():
        packed_loss, ntok = _doc_loss(loss_fn, params, pb, row, seg_id)
        solo, used = pack_documents([docs[i]], n_rows=1, seq_len=SEQ)
        assert used == [0]
        solo_loss, solo_ntok = _doc_loss(loss_fn, params, solo, 0, 1)
        assert solo_ntok == ntok
        np.testing.assert_allclose(packed_loss, solo_loss, rtol=1e-5)


def test_packed_abstract_args_match_concrete(arch):
    from repro.run.data import make_batch_iter
    spec = _spec()
    prog = build_step_program(spec, arch)
    batch_sds = prog.abstract_args()[2]
    concrete = next(make_batch_iter(spec, arch, 0))
    assert {k: (v.shape, np.dtype(v.dtype)) for k, v in batch_sds.items()} \
        == {k: (v.shape, np.dtype(v.dtype)) for k, v in concrete.items()}


def test_packed_lower_then_train_zero_recompiles():
    spec = _spec(total=3)
    prog = build_step_program(spec)
    prog.lower()
    res = run(spec, program=prog, log_fn=lambda s: None)
    assert prog.cache_size() == 1
    assert np.isfinite(res.history["loss"]).all()


@pytest.mark.parametrize("arch_id", ["paligemma-3b", "mamba2-1.3b"])
def test_unsupported_family_raises_at_build_time(arch_id):
    spec = _spec(model=ModelSpec(arch=arch_id, smoke=True))
    with pytest.raises(ValueError, match="packing is not supported"):
        build_step_program(spec)


def _flaky_program(spec, fail_on_call):
    """A StepProgram whose step raises a transient device error on the
    N-th call, after the donating computation already consumed its input
    buffers (same idiom as tests/run/test_hooks.py)."""
    from jax.errors import JaxRuntimeError
    prog = build_step_program(spec)
    real = prog.step
    calls = {"n": 0}

    def step(params, opt_state, batch, hp):
        out = real(params, opt_state, batch, hp)
        calls["n"] += 1
        if calls["n"] == fail_on_call:
            raise JaxRuntimeError("injected ICI flap")
        return out

    prog.step = step
    return prog


def test_packed_run_recovers_bitwise_after_fault(tmp_path):
    """A transient failure mid-packed-run restores the checkpoint,
    rewinds the packed stream, and finishes with bitwise the state and
    history of an uninterrupted packed run; the MetricsHook JSONL also
    reads as the uninterrupted record."""
    mp = str(tmp_path / "metrics.jsonl")
    spec = _spec(total=7, eval=EvalSpec(every=2, n_batches=1),
                 checkpoint=CheckpointSpec(dir=str(tmp_path / "c"),
                                           every=3),
                 metrics_path=mp)
    logs = []
    res = run(spec, program=_flaky_program(spec, 6), log_fn=logs.append)
    assert any("restored step 3" in m for m in logs)

    clean = run(_spec(total=7, eval=EvalSpec(every=2, n_batches=1)),
                log_fn=lambda s: None)
    for a, b in zip(jax.tree.leaves((res.params, res.opt_state)),
                    jax.tree.leaves((clean.params, clean.opt_state))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res.history["step"] == clean.history["step"] == list(range(7))
    np.testing.assert_allclose(res.history["loss"], clean.history["loss"])

    lines = [json.loads(line) for line in open(mp)]
    assert lines[0] == {"schema": 1, "stream": "train"}   # versioned stream
    # the transient failure leaves one recover event in the stream
    assert [r for r in lines if r.get("event") == "recover"]
    recs = [r for r in lines if "schema" not in r and "event" not in r]
    assert [r["step"] for r in recs] == list(range(7))
    assert all(0 < r["padding_efficiency"] <= 1.0 for r in recs)
    assert all(r["tokens_per_s"] > 0 for r in recs)


def test_metrics_hook_every_and_default_pipeline(tmp_path):
    mp = str(tmp_path / "m.jsonl")
    res = run(_spec(total=4, metrics_path=mp), log_fn=lambda s: None)
    assert res.find_hook(MetricsHook) is not None
    recs = [json.loads(line) for line in open(mp)]
    recs = [r for r in recs if "schema" not in r]
    assert [r["step"] for r in recs] == [0, 1, 2, 3]
    assert {"loss", "lr", "dt_s", "ntokens", "tokens_per_s",
            "padding_efficiency"} <= set(recs[0])

"""ProfilerHook: a jax profiler trace for a configurable step window,
stamped with the RunSpec that produced it."""
import json
import types

import pytest

from repro.data.pipeline import DataConfig
from repro.run import (CheckpointSpec, ModelSpec, OptSpec, ProfileSpec,
                       ProfilerHook, RunSpec, StepSpec, run)


def _spec(total=4, **kw):
    base = dict(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataConfig(vocab=0, seq_len=32, global_batch=4),
        opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant"),
        steps=StepSpec(total=total),
        log_every=0)
    base.update(kw)
    return RunSpec(**base)


def test_profile_spec_roundtrip():
    spec = _spec(profile=ProfileSpec(dir="/tmp/prof", start=2, steps=3))
    back = RunSpec.from_json(spec.to_json())
    assert back.profile == spec.profile
    assert back == spec


def test_profiler_traces_window_and_stamps_spec(tmp_path):
    spec = _spec(profile=ProfileSpec(dir=str(tmp_path / "prof"),
                                     start=1, steps=2))
    res = run(spec, log_fn=lambda s: None)

    hook = res.find_hook(ProfilerHook)
    assert hook is not None
    # registered by the default pipeline, before HistoryHook
    kinds = [type(h).__name__ for h in res.hooks]
    assert kinds.index("ProfilerHook") < kinds.index("HistoryHook")
    # window executed and closed
    assert hook.done and not hook.active

    prof = tmp_path / "prof"
    # RunSpec sidecar: the trace is attributable to its exact spec
    sidecar = json.loads((prof / "profile.runspec.json").read_text())
    assert RunSpec.from_dict(sidecar) == spec
    # the trace itself landed (plugins/... tensorboard layout)
    produced = [p for p in prof.iterdir()
                if p.name != "profile.runspec.json"]
    assert produced, list(prof.iterdir())
    # tracing must not add steady-state recompiles
    assert res.program.cache_size() == 1


def test_profiler_skips_window_already_executed(tmp_path):
    hook = ProfilerHook(tmp_path / "prof", start=1, steps=2)
    spec = _spec()
    ctx = types.SimpleNamespace(spec=spec, start_step=3,
                                log=lambda s: None)
    hook.on_run_start(ctx)
    assert hook.done and not hook.active
    # step events after a skipped window never (re)start a trace
    hook.on_step_end(ctx, types.SimpleNamespace(step=3))
    assert not hook.active


def test_profiler_user_instance_replaces_default(tmp_path):
    mine = ProfilerHook(tmp_path / "mine", start=1, steps=1)
    spec = _spec(profile=ProfileSpec(dir=str(tmp_path / "default")))
    res = run(spec, hooks=(mine,), log_fn=lambda s: None)
    profilers = [h for h in res.hooks if isinstance(h, ProfilerHook)]
    assert profilers == [mine]
    assert not (tmp_path / "default").exists()


def test_profiler_absent_without_profile_dir(tmp_path):
    res = run(_spec(total=1,
                    checkpoint=CheckpointSpec(dir=str(tmp_path), every=1)),
              log_fn=lambda s: None)
    assert res.find_hook(ProfilerHook) is None

"""Bounded transient-failure retry: deterministic exponential backoff
(base * 2^(attempt-1), capped), zero sleeps on the zero-backoff default,
and attempt-indexed ``recover`` events in the metrics stream."""
import json

import numpy as np
import pytest
from jax.errors import JaxRuntimeError

from repro.data.pipeline import DataConfig
from repro.run import (CheckpointSpec, FaultSpec, ModelSpec, OptSpec,
                       RunSpec, StepSpec, build_step_program, run)


def _spec(tmp_path, total=7, fault=None, **kw):
    base = dict(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataConfig(vocab=0, seq_len=32, global_batch=4),
        opt=OptSpec(name="adalomo", lr=1e-3, schedule="constant"),
        steps=StepSpec(total=total),
        checkpoint=CheckpointSpec(dir=str(tmp_path / "ck"), every=2),
        metrics_path=str(tmp_path / "m.jsonl"),
        fault=fault or FaultSpec(),
        log_every=0)
    base.update(kw)
    return RunSpec(**base)


def _flaky_program(spec, fail_on_calls):
    """A StepProgram whose step raises a transient device error on each
    call number in ``fail_on_calls`` (same idiom as test_packed_run)."""
    prog = build_step_program(spec)
    real = prog.step
    calls = {"n": 0}

    def step(params, opt_state, batch, hp):
        out = real(params, opt_state, batch, hp)
        calls["n"] += 1
        if calls["n"] in fail_on_calls:
            raise JaxRuntimeError("injected ICI flap")
        return out

    prog.step = step
    return prog


@pytest.fixture
def sleeps(monkeypatch):
    """Capture every runner backoff sleep instead of actually waiting."""
    import repro.run.runner as runner_mod
    rec = []
    monkeypatch.setattr(runner_mod.time, "sleep", rec.append)
    return rec


def test_backoff_schedule_doubles_and_caps(tmp_path, sleeps):
    """Two consecutive transient failures: attempt 1 waits the base,
    attempt 2 doubles but hits the cap; both recover events carry their
    attempt index, the failed step, and the actual backoff."""
    spec = _spec(tmp_path, fault=FaultSpec(retries=3, retry_backoff_s=0.05,
                                           retry_backoff_max_s=0.08))
    # ckpt labeled 2 saved after step 1; call 4 = step 3, call 5 = the
    # replayed step 2 right after the first restore
    res = run(spec, program=_flaky_program(spec, {4, 5}),
              log_fn=lambda s: None)

    assert sleeps == [0.05, 0.08]
    assert res.history["step"] == list(range(7))
    assert np.isfinite(res.history["loss"]).all()

    lines = [json.loads(line) for line in open(spec.metrics_path)]
    recov = [r for r in lines if r.get("event") == "recover"]
    assert [(r["attempt"], r["failed_step"], r["step"]) for r in recov] == \
        [(1, 3, 2), (2, 2, 2)]
    assert [r["backoff_s"] for r in recov] == [0.05, 0.08]


def test_default_backoff_never_sleeps(tmp_path, sleeps):
    spec = _spec(tmp_path)          # FaultSpec() default: retry_backoff_s=0
    run(spec, program=_flaky_program(spec, {4}), log_fn=lambda s: None)
    assert sleeps == []
    lines = [json.loads(line) for line in open(spec.metrics_path)]
    recov = [r for r in lines if r.get("event") == "recover"]
    assert [(r["attempt"], r["backoff_s"]) for r in recov] == [(1, 0.0)]


def test_retries_are_bounded(tmp_path, sleeps):
    """retries=2 means the third failure propagates — no infinite
    restore loop against a persistent fault."""
    spec = _spec(tmp_path, fault=FaultSpec(retries=2, retry_backoff_s=0.01))
    with pytest.raises(JaxRuntimeError, match="injected ICI flap"):
        run(spec, program=_flaky_program(spec, {4, 5, 6}),
            log_fn=lambda s: None)
    assert sleeps == [0.01, 0.02]   # the exhausted attempt never waits

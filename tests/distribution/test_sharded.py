"""Multi-device distribution tests (subprocess: device count locks at
first jax import, so each case runs in its own interpreter)."""
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = Path(__file__).parent / "_dist_script.py"
REPO = Path(__file__).resolve().parents[2]


def _run(case: str, marker: str):
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), case],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd=str(REPO))
    assert marker in proc.stdout, (proc.stdout[-2000:], proc.stderr[-4000:])


def test_sharded_step_matches_single_device():
    _run("test_sharded_step_matches_single_device", "SHARDED_MATCH_OK")


def test_elastic_restore_across_meshes():
    _run("test_elastic_restore", "ELASTIC_OK")


def test_multipod_mesh_compiles():
    _run("test_multipod_mesh_compiles", "MULTIPOD_OK")

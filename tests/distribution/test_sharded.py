"""Multi-device distribution tests (subprocess: device count locks at
first jax import, so each case runs in its own interpreter)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = Path(__file__).parent / "_dist_script.py"
REPO = Path(__file__).resolve().parents[2]


def _run(case: str, marker: str):
    # Inherit the parent environment (JAX_PLATFORMS in particular: without
    # it the child probes for TPU/GPU plugins and can stall for minutes
    # before falling back to CPU) and force the host-platform override.
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src"),
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), case],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=str(REPO))
    assert marker in proc.stdout, (proc.stdout[-2000:], proc.stderr[-4000:])


@pytest.mark.slow
def test_sharded_step_matches_single_device():
    _run("test_sharded_step_matches_single_device", "SHARDED_MATCH_OK")


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    _run("test_elastic_restore", "ELASTIC_OK")


@pytest.mark.slow
def test_multipod_mesh_compiles():
    _run("test_multipod_mesh_compiles", "MULTIPOD_OK")

"""Distribution checks that need >1 device — run via subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (device count locks at
first jax import, so these cannot share the main pytest process)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np


def make_bits(arch_id="h2o-danube-1.8b"):
    from repro.core import optimizers as opt_lib
    from repro.models.registry import get_arch
    arch = get_arch(arch_id, smoke=True)
    opt = opt_lib.get_opt("adalomo")
    key = jax.random.PRNGKey(0)
    params = arch.init_params(key)
    opt_state = opt.init(params)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, arch.cfg.vocab),
             "labels": jax.random.randint(key, (8, 32), 0, arch.cfg.vocab)}
    return arch, opt, params, opt_state, batch


def test_sharded_step_matches_single_device():
    """pjit-sharded fused train step == single-device result."""
    from repro.launch.mesh import make_test_mesh
    from repro.sharding import rules as R
    arch, opt, params, opt_state, batch = make_bits()
    step = arch.make_fused_train_step(opt)
    fn = lambda p, s, b: step(p, s, b, hparams=jnp.float32(1e-3))  # noqa: E731

    p1, s1, loss1, _ = jax.jit(fn)(params, opt_state, batch)

    mesh = make_test_mesh(8)
    axes = R.MeshAxes(mesh)
    p_sh = R.to_shardings(R.param_pspecs(params, axes), mesh)
    o_sh = R.to_shardings(
        R.opt_pspecs(opt_state, params, R.param_pspecs(params, axes), axes),
        mesh)
    b_sh = R.to_shardings(R.batch_pspecs(batch, axes), mesh)
    with mesh:
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(opt_state, o_sh)
        batch_s = jax.device_put(batch, b_sh)
        p2, s2, loss2, _ = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh))(
            params_s, opt_s, batch_s)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-5)
    print("SHARDED_MATCH_OK")


def test_elastic_restore():
    """Checkpoint saved from an 8-device mesh restores onto a 4-device mesh
    (simulated pod loss) and onto a single device."""
    import tempfile
    from repro.checkpoint.manager import CheckpointManager
    from repro.launch.mesh import _mk
    from repro.sharding import rules as R
    arch, opt, params, opt_state, batch = make_bits()
    mesh8 = _mk((4, 2), ("data", "model"))
    axes8 = R.MeshAxes(mesh8)
    p_specs = R.param_pspecs(params, axes8)
    p_sh8 = R.to_shardings(p_specs, mesh8)
    params8 = jax.device_put(params, p_sh8)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_write=False)
        mgr.save(5, params8)
        # restore onto a *different* mesh: 4 devices
        devs = np.array(jax.devices()[:4]).reshape(2, 2)
        mesh4 = jax.sharding.Mesh(devs, ("data", "model"))
        p_sh4 = R.to_shardings(R.param_pspecs(params, R.MeshAxes(mesh4)),
                               mesh4)
        step, p4, _ = mgr.restore(template=params, shardings=p_sh4)
        assert step == 5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=0)
        # and onto a single device (no shardings)
        _, p1, _ = mgr.restore(template=params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    print("ELASTIC_OK")


def test_multipod_mesh_compiles():
    """Tiny multi-pod mesh (2,2,2): the pod axis shards the batch."""
    from repro.launch.mesh import make_test_mesh
    from repro.sharding import rules as R
    arch, opt, params, opt_state, batch = make_bits()
    step = arch.make_fused_train_step(opt)
    fn = lambda p, s, b: step(p, s, b, hparams=jnp.float32(1e-3))  # noqa: E731
    mesh = make_test_mesh(8, multi_pod=True)
    axes = R.MeshAxes(mesh)
    assert axes.batch == ("pod", "data")
    p_sh = R.to_shardings(R.param_pspecs(params, axes), mesh)
    o_sh = R.to_shardings(R.opt_pspecs(
        opt_state, params, R.param_pspecs(params, axes), axes), mesh)
    b_sh = R.to_shardings(R.batch_pspecs(batch, axes), mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh)).lower(
            params, opt_state, batch).compile()
    assert compiled is not None
    print("MULTIPOD_OK")


if __name__ == "__main__":
    name = sys.argv[1]
    globals()[name]()

"""Golden tests: every rule fires on its positive fixture and stays
quiet on the negative one (the fixture pair is the rule's contract)."""
from pathlib import Path

import pytest

from repro.analysis import analyze_module
from repro.analysis.rules import ALL_RULES, RULES_BY_ID

pytestmark = pytest.mark.analysis

FIXTURES = Path(__file__).parent / "fixtures"


def run_rule(rule_id: str, fixture: str):
    path = FIXTURES / fixture
    # is_test=False: fixtures live under tests/ but model production code
    return analyze_module(str(path), path.read_text(),
                          rules=[RULES_BY_ID[rule_id]], is_test=False)


@pytest.mark.parametrize("rule_id", sorted(RULES_BY_ID))
def test_rule_fires_on_positive_fixture(rule_id):
    findings = run_rule(rule_id, f"{rule_id.lower()}_pos.py")
    assert findings, f"{rule_id} found nothing in its positive fixture"
    assert all(f.rule == rule_id for f in findings)


@pytest.mark.parametrize("rule_id", sorted(RULES_BY_ID))
def test_rule_quiet_on_negative_fixture(rule_id):
    findings = run_rule(rule_id, f"{rule_id.lower()}_neg.py")
    assert findings == [], (
        f"{rule_id} false positives: "
        + "; ".join(f.format() for f in findings))


def test_rule_ids_are_unique_and_stable():
    assert sorted(RULES_BY_ID) == ["R1", "R2", "R3", "R4", "R5", "R6", "R7"]
    assert len(ALL_RULES) == len(RULES_BY_ID)


# ------------------------------------------------------------- specifics


def test_r1_distinguishes_value_from_shape_branch():
    pos = run_rule("R1", "r1_pos.py")
    assert any("branch" in f.message for f in pos)
    assert any("int()" in f.message for f in pos)
    assert any("static" in f.message for f in pos)   # unhashable literal


def test_r2_covers_all_three_hot_contexts():
    pos = run_rule("R2", "r2_pos.py")
    contexts = {f.context for f in pos}
    assert "CollectHook.on_step_end" in contexts      # hook path
    assert "step" in contexts                         # traced step
    assert "ToyEngine.step" in contexts               # decode loop


def test_r3_reports_the_read_site():
    pos = run_rule("R3", "r3_pos.py")
    assert {f.context for f in pos} == {"loop", "Trainer.run"}
    assert all("donated" in f.message for f in pos)


def test_r4_three_violation_kinds():
    pos = run_rule("R4", "r4_pos.py")
    msgs = " | ".join(f.message for f in pos)
    assert "floor division" in msgs
    assert "interpret=True" in msgs
    assert "SMEM" in msgs


def test_r4_interpret_allowed_in_test_files():
    path = FIXTURES / "r4_pos.py"
    findings = analyze_module(str(path), path.read_text(),
                              rules=[RULES_BY_ID["R4"]], is_test=True)
    assert not any("interpret" in f.message for f in findings)


def test_r5_all_impurity_kinds():
    pos = run_rule("R5", "r5_pos.py")
    msgs = " | ".join(f.message for f in pos)
    assert "time.time" in msgs
    assert "numpy.random" in msgs
    assert "random.random" in msgs
    assert "global" in msgs.lower()


def test_r6_names_the_drifted_fields():
    pos = run_rule("R6", "r6_pos.py")
    msgs = " | ".join(f.message for f in pos)
    assert "`data`" in msgs and "from_dict" in msgs
    assert "`new_knob`" in msgs and "to_dict" in msgs
    assert "from_cli_args" in msgs


def test_r7_flags_bare_and_swallowing_broad_handlers():
    pos = run_rule("R7", "r7_pos.py")
    msgs = " | ".join(f.message for f in pos)
    assert "bare `except:`" in msgs
    # all four swallowing shapes: pass, ..., docstring body, continue —
    # including tuple and attribute-qualified forms of Exception
    assert sum("swallows" in f.message for f in pos) == 4
    assert len(pos) == 5


def test_r7_skipped_in_test_files():
    path = FIXTURES / "r7_pos.py"
    findings = analyze_module(str(path), path.read_text(),
                              rules=[RULES_BY_ID["R7"]], is_test=True)
    assert findings == []

"""R3 negative fixture: same-statement rebind is the safe idiom."""
import jax

step = jax.jit(lambda s, b: (s + b, s.sum()), donate_argnums=(0,))


def loop(state, batches):
    losses = []
    for b in batches:
        state, loss = step(state, b)    # rebinds the donated name
        losses.append(loss)
    return state, losses


class Trainer:
    def __init__(self):
        self._step = jax.jit(lambda s, b: s + b, donate_argnums=(0,))

    def run(self, batch):
        self._state = self._step(self._state, batch)   # same-stmt rebind
        return self._state

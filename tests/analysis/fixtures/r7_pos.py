"""R7 positive fixture: bare except and silently-swallowing broad
handlers, each of which erases the transient/anomalous/fatal failure
classification."""
import builtins


def bare_except(path):
    try:
        return open(path).read()
    except:                        # noqa: E722 — the violation under test
        return None


def swallow_pass(fn):
    try:
        fn()
    except Exception:
        pass


def swallow_ellipsis(fn):
    try:
        fn()
    except (ValueError, Exception):
        ...


def swallow_qualified(fn):
    try:
        fn()
    except builtins.BaseException:
        """nothing to see here"""


def swallow_continue(items):
    for it in items:
        try:
            it()
        except Exception:
            continue

"""R1 negative fixture: static shape/metadata branching is fine."""
import jax
import jax.numpy as jnp


@jax.jit
def branch_on_shape(x):
    if x.ndim > 1:                  # static metadata — trace-time Python
        x = x.reshape(-1)
    if len(x.shape) == 1:
        pass
    return jnp.where(x > 0, x, -x)  # value select stays on device


@jax.jit
def identity_test(x, mask=None):
    out = x * mask if mask is not None else x   # identity test is static
    return out


def _fn(x, cfg):
    return x * len(cfg)


jitted = jax.jit(_fn, static_argnums=(1,))


def caller(x):
    return jitted(x, (1, 2, 3))     # hashable tuple static arg

"""R3 positive fixture: use-after-donation."""
import jax

step = jax.jit(lambda s, b: (s + b, s.sum()), donate_argnums=(0,))


def loop(state, batches):
    for b in batches:
        new_state, loss = step(state, b)
        check = state.mean()        # R3: donated buffer read after call
        state = new_state
    return state, check


class Trainer:
    def __init__(self):
        self._step = jax.jit(lambda s, b: s + b, donate_argnums=(0,))

    def run(self, batch):
        out = self._step(self._state, batch)
        stale = self._state + 1     # R3: self._state was donated
        self._state = out
        return stale

"""R7 negative fixture: narrow handlers, and broad handlers that act
(log, re-raise, recover) — all legal."""
import logging

log = logging.getLogger(__name__)


def narrow_pass(path):
    # narrow best-effort cleanup: allowed even with a pass body
    try:
        return open(path).read()
    except OSError:
        return None


def narrow_swallow(d, k):
    try:
        del d[k]
    except KeyError:
        pass


def broad_but_logged(fn):
    try:
        fn()
    except Exception as e:
        log.warning("fn failed: %s", e)


def broad_but_reraised(fn):
    try:
        fn()
    except Exception as e:
        raise RuntimeError("wrapped") from e


def broad_but_recovers(fn, fallback):
    try:
        return fn()
    except Exception:
        return fallback()

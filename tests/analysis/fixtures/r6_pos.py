"""R6 positive fixture: RunSpec fields drifting out of the
(de)serializers."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class DataSpec:
    path: str = ""


@dataclasses.dataclass(frozen=True)
class RunSpec:
    steps: int = 0
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    new_knob: float = 0.0

    def to_dict(self):
        # hand-rolled and missing new_knob  -> R6
        return {"steps": self.steps, "data": {"path": self.data.path}}

    @classmethod
    def from_dict(cls, d):
        # nested `data` never re-hydrated   -> R6
        return cls(**dict(d))


def from_cli_args(args):
    # new_knob unreachable from the CLI     -> R6
    return RunSpec(steps=args.steps, data=DataSpec(path=args.data))

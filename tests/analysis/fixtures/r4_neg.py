"""R4 negative fixture: asserted grids, flag-threaded interpret, scalar
SMEM."""
import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[0]


def launch(x, block=128, interpret=False):
    m, n = x.shape
    assert m % block == 0 and n % block == 0
    grid = (m // block, n // block)
    scal = jnp.array([2.0], jnp.float32)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((block, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.SMEM((2,), jnp.float32)],
        interpret=interpret,            # threaded flag, not a literal
    )(scal, x)

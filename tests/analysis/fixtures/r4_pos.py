"""R4 positive fixture: Pallas hygiene violations."""
import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def launch_truncating(x, block=128):
    m, n = x.shape
    grid = (m // block, n // block)     # R4: floordiv, no assert
    return pl.pallas_call(
        _kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
    )(x)


def launch_debug(x):
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,                 # R4: interpreter left on
    )(x)


def launch_matrix_smem(x):
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.SMEM((8, 128), jnp.float32)],  # R4: tile
    )(x)

"""R2 negative fixture: host-side coercions of host values are fine."""
import jax
import jax.numpy as jnp


class CollectHook:
    def __init__(self):
        self.losses = []

    def on_step_end(self, ctx, ev):
        self.losses.append(ev.loss)                 # host scalar, no sync
        frac = float(len(self.losses)) / 10.0       # host int — fine
        del frac


@jax.jit
def step(x):
    return jnp.sum(x)                               # stays on device


def driver(xs):
    # float() outside any hot context is not R2's business
    return [float(x) for x in xs]

"""R5 positive fixture: impurity baked into traced code."""
import time
import random
import jax
import numpy as np

_CALLS = 0


@jax.jit
def stamped_step(x):
    t0 = time.time()                    # R5: wall clock freezes at trace
    return x * t0


@jax.jit
def noisy_step(x):
    return x + np.random.rand()         # R5: host RNG, one sample ever


@jax.jit
def jittered(x):
    return x * random.random()          # R5: stdlib RNG


@jax.jit
def counted(x):
    global _CALLS                       # R5: global mutation
    _CALLS += 1
    return x

"""R6 negative fixture: every field round-trips."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class DataSpec:
    path: str = ""


@dataclasses.dataclass(frozen=True)
class RunSpec:
    steps: int = 0
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    new_knob: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        d = dict(d)
        d["data"] = DataSpec(**d.get("data", {}))
        return cls(**d)


def from_cli_args(args):
    return RunSpec(steps=args.steps,
                   data=DataSpec(path=args.data),
                   new_knob=args.new_knob)

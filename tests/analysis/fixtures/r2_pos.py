"""R2 positive fixture: host syncs in hot paths."""
import jax
import numpy as np


class CollectHook:
    def __init__(self):
        self.losses = []

    def on_step_end(self, ctx, ev):
        self.losses.append(float(ev.loss))          # R2: sync in hook


@jax.jit
def step(x):
    return float(jax.numpy.sum(x))                  # R2: sync in traced


@jax.jit
def to_host(x):
    return np.asarray(jax.numpy.exp(x))             # R2: implicit transfer


class ToyEngine:
    def step(self):
        return self._state.item()                   # R2: per-token sync

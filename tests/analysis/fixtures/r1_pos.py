"""R1 positive fixture: recompile hazards inside traced code."""
import jax
import jax.numpy as jnp


@jax.jit
def branch_on_value(x):
    if x > 0:                       # R1: Python branch on traced value
        return x
    return -x


@jax.jit
def format_value(x):
    return f"loss={x}"              # R1: f-string on traced value


@jax.jit
def concretize(x):
    return jnp.zeros(int(x.sum()))  # R1: int() on traced value


def _fn(x, cfg):
    return x * len(cfg)


jitted = jax.jit(_fn, static_argnums=(1,))


def caller(x):
    return jitted(x, [1, 2, 3])     # R1: unhashable literal static arg

"""R5 negative fixture: clocks/RNG on the host side, keys on device."""
import time
import jax
import jax.numpy as jnp


@jax.jit
def pure_step(x, key):
    noise = jax.random.normal(key, x.shape)     # explicit key — pure
    return x + noise


def timed_driver(x, key):
    t0 = time.time()                            # host code: fine
    out = pure_step(x, key)
    return out, time.time() - t0

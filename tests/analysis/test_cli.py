"""CLI behaviour: exit codes, formats, rule filtering, baseline flow."""
import json
from pathlib import Path

import pytest

from repro.analysis.lint import main

pytestmark = pytest.mark.analysis

FIXTURES = Path(__file__).parent / "fixtures"

_DIRTY = (
    "import jax\n"
    "@jax.jit\n"
    "def f(x):\n"
    "    return float(x)\n"
)
_CLEAN = (
    "import jax.numpy as jnp\n"
    "def f(x):\n"
    "    return jnp.sum(x)\n"
)


def test_clean_file_exits_zero(tmp_path, capsys):
    p = tmp_path / "ok.py"
    p.write_text(_CLEAN)
    assert main([str(p), "--no-baseline"]) == 0
    assert "clean" in capsys.readouterr().out


def test_finding_exits_one_with_location(tmp_path, capsys):
    p = tmp_path / "bad.py"
    p.write_text(_DIRTY)
    assert main([str(p), "--no-baseline"]) == 1
    out = capsys.readouterr().out
    assert "bad.py:4" in out and "R2" in out


def test_json_format(tmp_path, capsys):
    p = tmp_path / "bad.py"
    p.write_text(_DIRTY)
    assert main([str(p), "--no-baseline", "--format", "json"]) == 1
    data = json.loads(capsys.readouterr().out)
    assert data["findings"][0]["rule"] == "R2"
    assert data["findings"][0]["line"] == 4
    assert data["stale_baseline"] == []


def test_rules_filter(tmp_path):
    p = tmp_path / "bad.py"
    p.write_text(_DIRTY)
    assert main([str(p), "--no-baseline", "--rules", "R4"]) == 0
    assert main([str(p), "--no-baseline", "--rules", "R2"]) == 1


def test_unknown_rule_and_missing_path_are_usage_errors(tmp_path):
    assert main([str(tmp_path / "nope.py"), "--no-baseline"]) == 2
    p = tmp_path / "ok.py"
    p.write_text(_CLEAN)
    assert main([str(p), "--no-baseline", "--rules", "R99"]) == 2


def test_baseline_roundtrip(tmp_path, capsys):
    p = tmp_path / "bad.py"
    p.write_text(_DIRTY)
    bl = tmp_path / "bl.json"
    # write, justify, re-run clean; then fix the code -> entry is stale
    assert main([str(p), "--baseline", str(bl),
                 "--write-baseline"]) == 0
    data = json.loads(bl.read_text())
    data["entries"][0]["justification"] = "known, tracked elsewhere"
    bl.write_text(json.dumps(data))
    capsys.readouterr()
    assert main([str(p), "--baseline", str(bl)]) == 0
    p.write_text(_CLEAN)
    assert main([str(p), "--baseline", str(bl)]) == 1
    assert "stale" in capsys.readouterr().out


def test_todo_justification_rejected(tmp_path, capsys):
    p = tmp_path / "bad.py"
    p.write_text(_DIRTY)
    bl = tmp_path / "bl.json"
    assert main([str(p), "--baseline", str(bl),
                 "--write-baseline"]) == 0
    # un-edited TODO justification is accepted by load (non-empty), but
    # the dialect is: humans must replace it.  Blank it -> hard error.
    data = json.loads(bl.read_text())
    data["entries"][0]["justification"] = ""
    bl.write_text(json.dumps(data))
    capsys.readouterr()
    assert main([str(p), "--baseline", str(bl)]) == 2
    assert "justification" in capsys.readouterr().err


def test_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("R1", "R2", "R3", "R4", "R5", "R6"):
        assert rid in out


def test_fixture_directory_smoke():
    """The whole fixture corpus parses and lints without crashing."""
    from repro.analysis.lint import lint_paths
    findings = lint_paths([str(FIXTURES)])
    assert {f.rule for f in findings} >= {"R1", "R2", "R3", "R5", "R6"}

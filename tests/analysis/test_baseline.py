"""Baseline semantics + the no-silent-drift regression: the committed
baseline must exactly match a fresh run over the linted tree."""
import json
from pathlib import Path

import pytest

from repro.analysis import baseline
from repro.analysis.core import Finding

pytestmark = pytest.mark.analysis

REPO = Path(__file__).resolve().parents[2]


def mk_finding(rule="R2", path="src/repro/x.py", context="f",
               line_text="float(x)", line=10):
    return Finding(rule=rule, path=path, line=line, col=0,
                   message="m", context=context, line_text=line_text)


def mk_entry(**kw):
    base = dict(rule="R2", path="src/repro/x.py", context="f",
                line_text="float(x)", justification="because")
    base.update(kw)
    return baseline.BaselineEntry(**base)


def test_entry_matches_ignoring_line_number():
    assert mk_entry().matches(mk_finding(line=10))
    assert mk_entry().matches(mk_finding(line=999))


def test_entry_suffix_path_matching():
    assert mk_entry(path="repro/x.py").matches(mk_finding())
    assert mk_entry(path="x.py").matches(mk_finding())
    assert not mk_entry(path="y.py").matches(mk_finding())
    # suffix is component-wise, not substring
    assert not mk_entry(path="o/x.py").matches(mk_finding())


def test_apply_splits_new_and_stale():
    new, stale = baseline.apply(
        [mk_finding(), mk_finding(context="g")], [mk_entry()])
    assert [f.context for f in new] == ["g"]
    assert stale == []
    new, stale = baseline.apply([], [mk_entry()])
    assert new == [] and len(stale) == 1


def test_load_rejects_missing_justification(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"version": 1, "entries": [
        {"rule": "R2", "path": "x.py", "context": "f",
         "line_text": "float(x)", "justification": "   "}]}))
    with pytest.raises(baseline.BaselineError, match="justification"):
        baseline.load(p)


def test_load_rejects_bad_schema(tmp_path):
    p = tmp_path / "b.json"
    p.write_text("[]")
    with pytest.raises(baseline.BaselineError):
        baseline.load(p)
    p.write_text("not json")
    with pytest.raises(baseline.BaselineError):
        baseline.load(p)


def test_save_stamps_todo_justifications(tmp_path):
    p = tmp_path / "b.json"
    baseline.save(p, [mk_finding()])
    data = json.loads(p.read_text())
    assert data["entries"][0]["justification"].startswith("TODO")


def test_committed_baseline_matches_fresh_run():
    """No silent drift: linting the tree exactly reproduces the committed
    baseline — no new findings, no stale entries."""
    from repro.analysis.lint import lint_paths
    findings = lint_paths([str(REPO / "src"), str(REPO / "benchmarks")])
    entries = baseline.load(REPO / baseline.BASELINE_NAME)
    new, stale = baseline.apply(findings, entries)
    assert new == [], "un-baselined findings:\n" + "\n".join(
        f.format() for f in new)
    assert stale == [], "stale baseline entries: " + repr(stale)

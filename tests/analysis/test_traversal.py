"""Traversal-engine edge cases: alias resolution, decorated/nested jitted
functions, builder-convention tracing, taint escapes, suppressions."""
import pytest

from repro.analysis import analyze_module
from repro.analysis.core import ModuleModel
from repro.analysis.rules import RULES_BY_ID

pytestmark = pytest.mark.analysis


def findings(source: str, rule_id: str):
    return analyze_module("mod.py", source,
                          rules=[RULES_BY_ID[rule_id]], is_test=False)


# ------------------------------------------------------------ alias forms


def test_from_import_alias_resolves():
    src = (
        "import jax\n"
        "from jax import numpy as foo\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return float(foo.sum(x))\n"
    )
    assert len(findings(src, "R2")) == 1


def test_jit_itself_aliased():
    src = (
        "from jax import jit as J\n"
        "@J\n"
        "def f(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    assert len(findings(src, "R1")) == 1


def test_numpy_alias_in_traced_code():
    src = (
        "import jax\n"
        "import numpy as np2\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np2.asarray(x)\n"
    )
    assert len(findings(src, "R2")) == 1


# ----------------------------------------------- decorated / nested forms


def test_functools_partial_jit_decorator_with_static_argnames():
    src = (
        "import functools\n"
        "import jax\n"
        "@functools.partial(jax.jit, static_argnames=('k',))\n"
        "def f(x, k):\n"
        "    if k > 2:\n"          # static arg: fine
        "        return x\n"
        "    if x > 0:\n"          # traced arg: R1
        "        return -x\n"
        "    return x\n"
    )
    out = findings(src, "R1")
    assert len(out) == 1
    assert out[0].line == 7


def test_nested_def_inside_jitted_function_is_traced():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def outer(x):\n"
        "    def inner(y):\n"
        "        return float(y)\n"
        "    return inner(x)\n"
    )
    out = findings(src, "R2")
    assert [f.context for f in out] == ["outer.inner"]


def test_make_builder_closure_is_traced():
    src = (
        "import time\n"
        "def make_step(cfg):\n"
        "    def step(params, batch):\n"
        "        return params, time.time()\n"
        "    return step\n"
    )
    out = findings(src, "R5")
    assert [f.context for f in out] == ["make_step.step"]


def test_locally_called_helper_inherits_tracedness():
    src = (
        "import jax\n"
        "import time\n"
        "def helper(v):\n"
        "    return v * time.time()\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return helper(x)\n"
    )
    out = findings(src, "R5")
    assert [f.context for f in out] == ["helper"]


def test_propagated_callee_params_not_assumed_traced():
    # helper is called from traced code but with a static Python int —
    # float() on it is NOT a sync, and the engine must know that.
    src = (
        "import jax\n"
        "def helper(x, n):\n"
        "    return x / float(n)\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    m, n = x.shape\n"
        "    return helper(x, m * n)\n"
    )
    assert findings(src, "R2") == []


# ------------------------------------------------------------ suppression


_VIOLATION = (
    "import jax\n"
    "@jax.jit\n"
    "def f(x):\n"
    "    return float(x){comment}\n"
)


def test_same_line_suppression():
    src = _VIOLATION.format(comment="  # repro-lint: disable=R2")
    assert findings(src, "R2") == []


def test_line_above_suppression():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    # repro-lint: disable=R2 — proving the comment form works\n"
        "    return float(x)\n"
    )
    assert findings(src, "R2") == []


def test_wrong_rule_id_does_not_suppress():
    src = _VIOLATION.format(comment="  # repro-lint: disable=R5")
    assert len(findings(src, "R2")) == 1


def test_multi_rule_suppression_list():
    src = _VIOLATION.format(comment="  # repro-lint: disable=R1, R2")
    assert findings(src, "R2") == []


# ------------------------------------------------------------------ taint


def test_shape_metadata_escapes_taint():
    model = ModuleModel("m.py", (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    s = x.shape\n"
        "    return s\n"
    ))
    f = [fn for fn in model.funcs if fn.name == "f"][0]
    assert f.traced and f.params_traced


def test_shadowed_redefinition_both_seeded():
    # the program.py `one_step` / noqa: F811 pattern: both defs seeded
    model = ModuleModel("m.py", (
        "import jax\n"
        "def one(a):\n"
        "    return a\n"
        "def one(a):  # noqa: F811\n"
        "    return a + 1\n"
        "g = jax.jit(one)\n"
    ))
    assert sum(1 for fn in model.funcs
               if fn.name == "one" and fn.traced) == 2


def test_self_method_tracing_through_jit_member():
    src = (
        "import jax\n"
        "import time\n"
        "class Engine:\n"
        "    def __init__(self):\n"
        "        self._chunk = jax.jit(self._make_fn())\n"
        "    def _make_fn(self):\n"
        "        def chunk(state):\n"
        "            return state * time.time()\n"
        "        return chunk\n"
    )
    out = findings(src, "R5")
    assert [f.context for f in out] == ["Engine._make_fn.chunk"]

"""Segment packing: real-token throughput and padding efficiency,
packed vs padded, on two config-zoo shapes (smoke sizes, CPU).

Both arms consume the *same* ragged document stream
(``SyntheticLM.docs`` — bucket-sampled lengths, t2t boundaries):

  padded — one document per row, tail slots are -1-label padding
           (the pre-packing layout: efficiency = mean doc len / seq);
  packed — greedy first-fit into rows with segment ids + restarting
           positions (``DataConfig.packing=True``, the default stream).

The signal is tokens/s of *real* (loss-bearing) tokens — the step's
``ntokens`` metric over wall dt, first step (compile) excluded — and
padding efficiency (real tokens / slot tokens).  Packing wins on both
because the padded arm burns identical FLOPs on dead slots.

Writes ``benchmarks/BENCH_packing.json`` (committed artifact).
"""
from __future__ import annotations

from benchmarks.common import fmt_row, write_bench_json
from repro.data.pipeline import DataConfig, SyntheticLM, padded_batch_from_docs
from repro.models.registry import get_arch
from repro.run import Hook, ModelSpec, OptSpec, RunSpec, StepSpec
from repro.run import run as run_training

ARCHS = ("h2o-danube-1.8b", "qwen3-32b")
STEPS, BATCH, SEQ = 4, 4, 128


class _Collect(Hook):
    """Per-step (dt, real-token count) capture."""

    def __init__(self):
        self.dts: list = []
        self.ntoks: list = []

    def on_step_end(self, ctx, ev) -> None:
        self.dts.append(ev.dt)
        self.ntoks.append(ev.metrics["ntokens"])


def _spec(arch, *, packed: bool) -> RunSpec:
    return RunSpec(
        model=ModelSpec(arch=arch.arch_id, smoke=True),
        data=DataConfig(vocab=arch.cfg.vocab, seq_len=SEQ,
                        global_batch=BATCH, packing=packed),
        opt=OptSpec(name="adalomo", schedule="constant"),
        steps=StepSpec(total=STEPS, fused=True),
        log_every=0)


def _padded_iter(spec: RunSpec):
    """The padded arm: same ragged docs, one per row, tail padded."""
    src = SyntheticLM(spec.data)
    step = 0
    while True:
        docs = src.docs(step)[:spec.data.global_batch]
        yield padded_batch_from_docs(docs, spec.data.global_batch,
                                     spec.data.seq_len)
        step += 1


def _measure(arch_id: str, *, packed: bool) -> dict:
    arch = get_arch(arch_id, smoke=True)
    spec = _spec(arch, packed=packed)
    col = _Collect()
    kw = {} if packed else {"batch_iter": _padded_iter(_spec(arch, packed=True))}
    run_training(spec, arch=arch, hooks=(col,), log_fn=lambda s: None, **kw)
    dts, ntoks = col.dts[1:], col.ntoks[1:]  # drop compile step
    slot = BATCH * SEQ
    return {
        "tokens_per_s": round(sum(ntoks) / sum(dts), 1),
        "padding_efficiency": round(sum(ntoks) / (slot * len(ntoks)), 4),
        "steps_measured": len(dts),
    }


def run(fast: bool = True) -> list:
    rows = []
    payload = {"batch": BATCH, "seq_len": SEQ, "steps": STEPS,
               "note": "real-token throughput, first (compile) step "
                       "excluded; both arms share one ragged doc stream",
               "cells": {}}
    for arch_id in ARCHS:
        packed = _measure(arch_id, packed=True)
        padded = _measure(arch_id, packed=False)
        speedup = packed["tokens_per_s"] / max(padded["tokens_per_s"], 1e-9)
        payload["cells"][arch_id] = {
            "packed": packed, "padded": padded,
            "real_token_speedup": round(speedup, 2),
        }
        rows.append(fmt_row(
            f"packing/{arch_id}", 0.0,
            f"packed_tps={packed['tokens_per_s']};"
            f"padded_tps={padded['tokens_per_s']};"
            f"packed_eff={packed['padding_efficiency']};"
            f"padded_eff={padded['padding_efficiency']};"
            f"speedup={speedup:.2f}"))
    out = write_bench_json("packing", payload)
    rows.append(fmt_row("packing/artifact", 0.0, str(out)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Benchmark driver — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (deliverable d).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig4,...]
"""
import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "table1_memory",        # Table 1: memory model
    "fig1_moment_ablation", # Figure 1 + Appendix A Figure 6
    "table2_instruction",   # Table 2/5: instruction-tuning comparison
    "fig23_further_pretrain",  # Figures 2/3: further pre-training
    "fig4_scratch_pretrain",   # Figure 4 / Table 7: from-scratch
    "fig5_profile",         # Figure 5 / Table 8: memory + throughput
    "appb_gradnorm",        # Appendix B: ± gradient normalization
    "roofline",             # §Roofline from the dry-run artifacts
    "serve_throughput",     # paged continuous batching vs static batching
    "packing_efficiency",   # segment packing: packed vs padded tokens/s
    "step_time",            # step-time baseline on two config-zoo shapes
    "fleet_sweep",          # sweep driver demo: 3-variant ranked report
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer runs (more steps)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    args = ap.parse_args(argv)
    mods = MODULES if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            for row in mod.run(fast=not args.full):
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Paper Figure 5 / Table 8: memory footprint and throughput by optimizer.

Memory: compiled peak (temp+args) per method from HLO memory_analysis —
the apples-to-apples analogue of the paper's pynvml numbers.
Throughput: tokens/sec on CPU for the tiny proxy (relative ordering is the
signal: LoRA > LOMO ≈ AdamW > AdaLomo, all same order of magnitude)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import fmt_row, run_spec, tiny_llama
from repro.run import build_step_program

B, S = 8, 256


def _measure(arch, rule_name, fused):
    spec = run_spec(arch, rule_name, steps=8, batch=B, seq=S, lr=1e-3,
                    fused=fused)
    program = build_step_program(spec, arch)
    params, opt_state = program.init(0)
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, arch.cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, arch.cfg.vocab)}
    hp = program.hparams_fn(1)
    compiled = program.lower().compile()
    ma = compiled.memory_analysis()
    peak = ma.temp_size_in_bytes + ma.argument_size_in_bytes
    # throughput (post-warmup)
    p, s = params, opt_state
    p, s, *_ = program.step(p, s, batch, hp)
    jax.block_until_ready(jax.tree.leaves(p)[0])
    t0 = time.time()
    n = 8
    for _ in range(n):
        p, s, loss, m = program.step(p, s, batch, hp)
    jax.block_until_ready(loss)
    dt = (time.time() - t0) / n
    return {"peak_MB": peak / 1e6, "tgs": B * S / dt, "us": dt * 1e6}


def run(fast: bool = True) -> list:
    arch = tiny_llama(layers=6, d=256)
    rows = []
    res = {}
    for name, rule_name, fused in [
            ("AdamW", "adamw", False), ("Adafactor", "adafactor", False),
            ("LOMO", "lomo", True), ("AdaLomo", "adalomo", True)]:
        r = _measure(arch, rule_name, fused)
        res[name] = r
        rows.append(fmt_row(f"fig5/{name}", r["us"],
                            f"peak_MB={r['peak_MB']:.1f};tgs={r['tgs']:.0f}"))
    ok = (res["AdaLomo"]["peak_MB"] <= res["AdamW"]["peak_MB"]
          and res["AdaLomo"]["tgs"] > 0.3 * res["AdamW"]["tgs"])
    rows.append(fmt_row(
        "fig5/claim", 0.0,
        f"adalomo_mem_vs_adamw={res['AdaLomo']['peak_MB']/res['AdamW']['peak_MB']:.2f};"
        f"adalomo_tgs_vs_adamw={res['AdaLomo']['tgs']/res['AdamW']['tgs']:.2f};"
        f"ok={bool(ok)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

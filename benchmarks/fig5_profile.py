"""Paper Figure 5 / Table 8: memory footprint and throughput by optimizer.

Memory: compiled peak (temp+args) per method from HLO memory_analysis —
the apples-to-apples analogue of the paper's pynvml numbers.
Throughput: tokens/sec on CPU for the tiny proxy (relative ordering is the
signal: LoRA > LOMO ≈ AdamW > AdaLomo, all same order of magnitude)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, tiny_llama
from repro.core import optimizers as opt_lib

B, S = 8, 256


def _measure(arch, rule_name, fused):
    opt = opt_lib.get_opt(rule_name)
    key = jax.random.PRNGKey(0)
    params = arch.init_params(key)
    opt_state = opt.init(params)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, arch.cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, arch.cfg.vocab)}
    hp = {"lr": jnp.float32(1e-3)}
    if fused:
        step = arch.make_fused_train_step(opt)
        fn = lambda p, s, b: step(p, s, b, hparams=hp)  # noqa: E731
    else:
        loss_fn = arch.make_loss_fn()

        def fn(p, s, b):
            (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
            p2, s2 = opt.step(p, g, s, hp)
            return p2, s2, loss, m

    jf = jax.jit(fn, donate_argnums=(0, 1))
    compiled = jf.lower(params, opt_state, batch).compile()
    ma = compiled.memory_analysis()
    peak = ma.temp_size_in_bytes + ma.argument_size_in_bytes
    # throughput (post-warmup)
    p, s = params, opt_state
    p, s, *_ = jf(p, s, batch)
    jax.block_until_ready(jax.tree.leaves(p)[0])
    t0 = time.time()
    n = 8
    for _ in range(n):
        p, s, loss, m = jf(p, s, batch)
    jax.block_until_ready(loss)
    dt = (time.time() - t0) / n
    return {"peak_MB": peak / 1e6, "tgs": B * S / dt, "us": dt * 1e6}


def run(fast: bool = True) -> list:
    arch = tiny_llama(layers=6, d=256)
    rows = []
    res = {}
    for name, rule_name, fused in [
            ("AdamW", "adamw", False), ("Adafactor", "adafactor", False),
            ("LOMO", "lomo", True), ("AdaLomo", "adalomo", True)]:
        r = _measure(arch, rule_name, fused)
        res[name] = r
        rows.append(fmt_row(f"fig5/{name}", r["us"],
                            f"peak_MB={r['peak_MB']:.1f};tgs={r['tgs']:.0f}"))
    ok = (res["AdaLomo"]["peak_MB"] <= res["AdamW"]["peak_MB"]
          and res["AdaLomo"]["tgs"] > 0.3 * res["AdamW"]["tgs"])
    rows.append(fmt_row(
        "fig5/claim", 0.0,
        f"adalomo_mem_vs_adamw={res['AdaLomo']['peak_MB']/res['AdamW']['peak_MB']:.2f};"
        f"adalomo_tgs_vs_adamw={res['AdaLomo']['tgs']/res['AdamW']['tgs']:.2f};"
        f"ok={bool(ok)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

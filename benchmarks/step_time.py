"""Step-time baseline: wall time per fused AdaLomo train step on two
config-zoo shapes (smoke sizes, CPU).

The repo has convergence and memory baselines but — until this module —
no committed *step-time* number, so a perf regression in the step (a new
hook, a layout change, an optimizer edit) only showed up anecdotally.
This is the reference point: per-arch compile time, median/mean step
wall time and real-token throughput, measured through the stock
``run()`` loop with the default hook pipeline (the number users actually
get, not a hookless best case).

Writes ``benchmarks/BENCH_step_time.json`` (committed artifact; regenerate
with ``PYTHONPATH=src python -m benchmarks.run --only step_time``).
"""
from __future__ import annotations

import statistics

from benchmarks.common import fmt_row, write_bench_json
from repro.data.pipeline import DataConfig
from repro.models.registry import get_arch
from repro.run import Hook, ModelSpec, OptSpec, RunSpec, StepSpec
from repro.run import run as run_training

ARCHS = ("h2o-danube-1.8b", "qwen3-32b")
BATCH, SEQ = 8, 128


class _Collect(Hook):
    def __init__(self):
        self.dts: list = []
        self.ntoks: list = []

    def on_step_end(self, ctx, ev) -> None:
        self.dts.append(ev.dt)
        self.ntoks.append(ev.metrics["ntokens"])


def _spec(arch, steps: int) -> RunSpec:
    return RunSpec(
        model=ModelSpec(arch=arch.arch_id, smoke=True),
        data=DataConfig(vocab=arch.cfg.vocab, seq_len=SEQ,
                        global_batch=BATCH),
        opt=OptSpec(name="adalomo", schedule="constant"),
        steps=StepSpec(total=steps, fused=True),
        log_every=0)


def _measure(arch_id: str, steps: int) -> dict:
    arch = get_arch(arch_id, smoke=True)
    col = _Collect()
    res = run_training(_spec(arch, steps), arch=arch, hooks=(col,),
                       log_fn=lambda s: None)
    warm = col.dts[1:]                      # step 0 = compile + run
    return {
        "compile_s": round(col.dts[0], 3),
        "median_step_ms": round(statistics.median(warm) * 1e3, 2),
        "mean_step_ms": round(statistics.mean(warm) * 1e3, 2),
        "tokens_per_s": round(sum(col.ntoks[1:]) / sum(warm), 1),
        "steps_measured": len(warm),
        "cache_size": res.program.cache_size(),   # must stay 1
    }


def run(fast: bool = True) -> list:
    steps = 8 if fast else 32
    cells, rows = {}, []
    for arch_id in ARCHS:
        cell = _measure(arch_id, steps)
        cells[arch_id] = cell
        rows.append(fmt_row(f"step_time/{arch_id}",
                            cell["median_step_ms"] * 1e3,
                            f"{cell['tokens_per_s']}tok/s"))
    write_bench_json("step_time", {
        "batch": BATCH, "seq": SEQ, "optimizer": "adalomo",
        "fused": True, "cells": cells,
    })
    return rows

"""Serving throughput: continuous batching (paged KV) vs legacy static
batching, under a mixed-length Poisson-arrival workload.

Requests arrive as a Poisson process with prompt lengths drawn uniformly
from [min_len, max_len].  The paged engine admits them mid-flight between
fixed-shape decode chunks (zero steady-state recompiles); the legacy
engine groups arrivals into static right-padded batches and pays a
prefill re-jit for every distinct padded length — exactly the behaviour
this benchmark exists to show.

Writes ``benchmarks/artifacts/serve_throughput.json`` with tokens/sec for
both engines plus compile/preemption counters, the serve-gauge telemetry
stream ``benchmarks/artifacts/serve_gauges.jsonl`` (page-pool / queue /
time-split samples at every chunk boundary), and the committed
``benchmarks/BENCH_serve.json`` baseline (tokens/s + p50/p99 request
latency + pool utilization on the Poisson workload).

  PYTHONPATH=src python -m benchmarks.serve_throughput [--full]
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import tiny_llama, write_bench_json
from repro.serve.engine import (Engine, PagedEngine, PagedServeConfig,
                                ServeConfig)
from repro.serve.scheduler import FINISHED
from repro.telemetry import read_stream

ART = Path(__file__).parent / "artifacts"


def make_workload(n_requests: int, min_len: int, max_len: int,
                  rate_per_s: float, seed: int = 0):
    """[(arrival_time_s, prompt), ...] sorted by arrival."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=n_requests)
    arrivals = np.cumsum(gaps)
    lens = rng.randint(min_len, max_len + 1, size=n_requests)
    prompts = [list(rng.randint(1, 250, size=n).astype(int)) for n in lens]
    return list(zip(arrivals.tolist(), prompts))


def _latency_stats(latencies_s: list) -> dict:
    lat = np.asarray(latencies_s, dtype=np.float64)
    return {"n": int(lat.size),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "mean_ms": float(lat.mean() * 1e3)}


def _drain_paged(engine: PagedEngine, workload, max_new: int) -> dict:
    t0 = time.time()
    pending = list(workload)
    arrival: dict = {}    # rid -> scheduled arrival (s since t0)
    done_at: dict = {}    # rid -> completion (s since t0)
    while pending or engine.scheduler.has_work():
        now = time.time() - t0
        while pending and pending[0][0] <= now:
            at, prompt = pending.pop(0)
            rid = engine.submit(prompt, max_new)
            arrival[rid] = at
        if engine.scheduler.has_work():
            engine.step()
            now = time.time() - t0
            for rid, req in engine.requests.items():
                if req.status == FINISHED and rid not in done_at:
                    done_at[rid] = now
        elif pending:
            time.sleep(min(0.01, pending[0][0] - now))
    wall = time.time() - t0
    n_tok = sum(len(r.out) for r in engine.requests.values())
    return {"wall_s": wall, "new_tokens": n_tok,
            "tokens_per_sec": n_tok / wall,
            "latency": _latency_stats(
                [done_at[r] - arrival[r] for r in done_at]),
            "decode_compiles": engine.decode_compile_count(),
            "prefill_compiles": engine.prefill_compile_count(),
            "preemptions": sum(r.n_preempted
                               for r in engine.requests.values())}


def _drain_legacy(engine: Engine, workload, batch: int) -> dict:
    t0 = time.time()
    pending = list(workload)
    n_tok = 0
    n_batches = 0
    lats: list = []
    while pending:
        now = time.time() - t0
        arrived = [p for p in pending if p[0] <= now]
        if len(arrived) < min(batch, len(pending)):
            time.sleep(0.005)
            continue
        take, pending = pending[:batch], pending[batch:]
        outs = engine.generate([p for _, p in take])
        done = time.time() - t0
        # batch-synchronous: every request completes when the batch does
        lats.extend(done - at for at, _ in take)
        n_tok += sum(len(o) for o in outs)
        n_batches += 1
    wall = time.time() - t0
    return {"wall_s": wall, "new_tokens": n_tok,
            "tokens_per_sec": n_tok / wall,
            "latency": _latency_stats(lats), "batches": n_batches}


def run(fast: bool = True):
    """CSV rows for benchmarks.run; also writes the JSON artifact."""
    if fast:
        n_req, min_len, max_len, max_new, rate = 8, 8, 48, 8, 50.0
        layers, d = 2, 64
    else:
        n_req, min_len, max_len, max_new, rate = 32, 16, 256, 32, 20.0
        layers, d = 4, 128
    arch = tiny_llama(layers=layers, d=d)
    params = arch.init_params(jax.random.PRNGKey(0))
    workload = make_workload(n_req, min_len, max_len, rate)

    ps = 16
    ART.mkdir(exist_ok=True)
    gauge_stream = ART / "serve_gauges.jsonl"
    if gauge_stream.exists():
        gauge_stream.unlink()          # regenerate, don't append forever
    pcfg = PagedServeConfig(
        page_size=ps, max_batch=4, chunk=8, max_new_tokens=max_new,
        max_pages_per_seq=-(-(max_len + max_new) // ps),
        num_pages=2 + 4 * -(-(max_len + max_new) // ps),
        eos_id=-1, telemetry_path=str(gauge_stream))
    paged = PagedEngine(arch, params, pcfg)
    # warmup compiles the bounded shape set: pow2 buckets + the chunk
    paged.warmup([min_len, max_len])
    res_paged = _drain_paged(paged, workload, max_new)

    legacy = Engine(arch, params,
                    ServeConfig(max_new_tokens=max_new, eos_id=-1))
    # legacy warms one shape; every other padded length re-jits (that is
    # its documented serving behaviour, and part of the measured cost)
    legacy.generate([[1] * max_len] * 4)
    res_legacy = _drain_legacy(legacy, workload, batch=4)

    # fold the gauge stream (page-pool pressure over the run) into the
    # committed baseline — the utilization the throughput was bought at
    gauges = read_stream(gauge_stream).gauges()
    util = [g["pool_util"] for g in gauges]
    pool_utilization = {
        "final": util[-1] if util else 0.0,
        "max": max(util, default=0.0),
        "mean": float(np.mean(util)) if util else 0.0,
        "samples": len(util),
        "prefill_s": gauges[-1]["prefill_s"] if gauges else 0.0,
        "decode_s": gauges[-1]["decode_s"] if gauges else 0.0,
    }

    out = {"config": {"n_requests": n_req, "prompt_len": [min_len, max_len],
                      "max_new_tokens": max_new, "rate_per_s": rate,
                      "arch": f"tiny-llama L{layers} d{d}",
                      "backend": jax.default_backend()},
           "paged": res_paged, "legacy": res_legacy,
           "pool_utilization": pool_utilization,
           "speedup": res_paged["tokens_per_sec"]
           / res_legacy["tokens_per_sec"]}
    (ART / "serve_throughput.json").write_text(json.dumps(out, indent=2))
    # committed baseline: the ROADMAP "serve tokens/s" gap
    write_bench_json("serve", out)

    yield (f"serve/paged,{1e6 / res_paged['tokens_per_sec']:.1f},"
           f"{res_paged['tokens_per_sec']:.1f} tok/s "
           f"({res_paged['decode_compiles']} decode compiles)")
    yield (f"serve/legacy,{1e6 / res_legacy['tokens_per_sec']:.1f},"
           f"{res_legacy['tokens_per_sec']:.1f} tok/s")
    yield f"serve/speedup,0.0,{out['speedup']:.2f}x"
    yield (f"serve/pool_util,0.0,max={pool_utilization['max']:.3f};"
           f"mean={pool_utilization['mean']:.3f};"
           f"samples={pool_utilization['samples']}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for row in run(fast=not args.full):
        print(row)

"""Paper Table 2/5 proxy: instruction-tuning comparison across optimizers.

Offline stand-in for the five-benchmark GPT-4-judged evaluation: fine-tune
on a held-in structured task and compare held-out loss/accuracy.  The
paper's claim to reproduce: AdaLomo ≈ AdamW ≈ Adafactor > LOMO."""
from __future__ import annotations

from benchmarks.common import fmt_row, tiny_llama, train_curve

OPTS = ["adalomo", "adamw", "adafactor", "lomo"]


def run(fast: bool = True) -> list:
    steps = 60 if fast else 240
    arch = tiny_llama()
    rows, finals = [], {}
    for opt in OPTS:
        out = train_curve(arch, opt, steps=steps, eval_every=0)
        # held-out eval
        from repro.data.pipeline import DataConfig, batches
        import jax, jax.numpy as jnp
        loss_fn = jax.jit(arch.make_loss_fn())
        ev = batches(DataConfig(vocab=arch.cfg.vocab, seq_len=128,
                                global_batch=8, seed=1234))
        tot = acc = 0.0
        for _ in range(4):
            b = jax.tree.map(jnp.asarray, next(ev))
            l, m = loss_fn(out["params"], b)
            tot += float(l) / 4
            acc += float(m["accuracy"]) / 4
        finals[opt] = (tot, acc)
        rows.append(fmt_row(f"table2/{opt}", out["us_per_step"],
                            f"eval_loss={tot:.4f};eval_acc={acc:.4f}"))
    # one-sided: AdaLomo at least matches AdamW (doing *better* is a pass)
    # and is not worse than LOMO (Table 2's ordering)
    ok = (finals["adalomo"][0] < finals["lomo"][0] + 0.05
          and finals["adalomo"][0] < finals["adamw"][0] + 0.2)
    rows.append(fmt_row(
        "table2/claim", 0.0,
        f"adalomo_matches_adamw_and_beats_lomo={bool(ok)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Paper Appendix B: AdaLomo ± global gradient normalization.

Claims: (1) convergence is unaffected — grouped update normalization
already stabilizes; (2) the grad-norm variant costs a second backward pass
(≈2× backward FLOPs), which we verify structurally from the jaxpr/HLO."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, run_spec, tiny_llama
from repro.data.pipeline import batches
from repro.run import build_step_program
from repro.run.data import resolved_data


def run(fast: bool = True) -> list:
    steps = 40 if fast else 160
    arch = tiny_llama()
    rows = []
    finals, flops = {}, {}
    # clip=5.0: at proxy scale early grad norms exceed 1.0 by far, so the
    # paper's 1.0 threshold would act as an lr schedule rather than a
    # safety clip; 5.0 binds only on spikes — matching the paper's regime.
    for name, gn in [("no_gradnorm", None), ("gradnorm", 5.0)]:
        # constant schedule: the pre-Run-API benchmark trained at a fixed
        # 2e-3, and hp below is (correctly) reused for every step
        spec = run_spec(arch, "adalomo", steps=steps, lr=2e-3,
                        schedule="constant")
        program = build_step_program(spec, arch, global_grad_norm=gn)
        params, opt_state = program.init(0)
        compiled = program.lower().compile()
        from repro.launch.hlo_analysis import analyze
        flops[name] = analyze(compiled.as_text())["flops"]
        it = batches(resolved_data(spec, arch))
        p, s = params, opt_state
        hp = program.hparams_fn(1)
        loss = None
        for _ in range(steps):
            b = jax.tree.map(jnp.asarray, next(it))
            p, s, loss, m = program.step(p, s, b, hp)
        finals[name] = float(loss)
        rows.append(fmt_row(f"appb/{name}", 0.0,
                            f"final_loss={finals[name]:.4f};"
                            f"hlo_flops={flops[name]:.3e}"))
    ratio = flops["gradnorm"] / flops["no_gradnorm"]
    gap = abs(finals["gradnorm"] - finals["no_gradnorm"])
    rows.append(fmt_row(
        "appb/claim", 0.0,
        f"flops_ratio_2pass={ratio:.2f};loss_gap={gap:.4f};"
        f"convergence_unaffected={bool(gap < 0.15)};"
        f"second_pass_costly={bool(ratio > 1.5)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

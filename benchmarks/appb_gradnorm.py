"""Paper Appendix B: AdaLomo ± global gradient normalization.

Claims: (1) convergence is unaffected — grouped update normalization
already stabilizes; (2) the grad-norm variant costs a second backward pass
(≈2× backward FLOPs), which we verify structurally from the jaxpr/HLO."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, tiny_llama
from repro.core import optimizers as opt_lib
from repro.core.fused import fused_train_step
from repro.data.pipeline import DataConfig, batches
from repro.models.transformer import make_fused_spec


def run(fast: bool = True) -> list:
    steps = 40 if fast else 160
    arch = tiny_llama()
    spec = make_fused_spec(arch.cfg)
    opt = opt_lib.get_opt("adalomo")
    rows = []
    finals, flops = {}, {}
    # clip=5.0: at proxy scale early grad norms exceed 1.0 by far, so the
    # paper's 1.0 threshold would act as an lr schedule rather than a
    # safety clip; 5.0 binds only on spikes — matching the paper's regime.
    for name, gn in [("no_gradnorm", None), ("gradnorm", 5.0)]:
        key = jax.random.PRNGKey(0)
        params = arch.init_params(key)
        opt_state = opt.init(params)

        def fn(p, s, b, _gn=gn):
            return fused_train_step(spec, opt, p, s, b,
                                    hparams=jnp.float32(2e-3),
                                    global_grad_norm=_gn)

        jf = jax.jit(fn, donate_argnums=(0, 1))
        dcfg = DataConfig(vocab=arch.cfg.vocab, seq_len=128, global_batch=8)
        it = batches(dcfg)
        compiled = jf.lower(params, opt_state,
                            jax.tree.map(jnp.asarray, next(it))).compile()
        from repro.launch.hlo_analysis import analyze
        flops[name] = analyze(compiled.as_text())["flops"]
        p, s = params, opt_state
        loss = None
        for _ in range(steps):
            b = jax.tree.map(jnp.asarray, next(it))
            p, s, loss, m = jf(p, s, b)
        finals[name] = float(loss)
        rows.append(fmt_row(f"appb/{name}", 0.0,
                            f"final_loss={finals[name]:.4f};"
                            f"hlo_flops={flops[name]:.3e}"))
    ratio = flops["gradnorm"] / flops["no_gradnorm"]
    gap = abs(finals["gradnorm"] - finals["no_gradnorm"])
    rows.append(fmt_row(
        "appb/claim", 0.0,
        f"flops_ratio_2pass={ratio:.2f};loss_gap={gap:.4f};"
        f"convergence_unaffected={bool(gap < 0.15)};"
        f"second_pass_costly={bool(ratio > 1.5)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

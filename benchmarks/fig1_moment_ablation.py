"""Paper Figure 1 + Appendix A (Fig 6): which Adam moment matters?

LM proxy: fine-tune the tiny-llama on structured synthetic data with
Adam / SGD / SGD+momentum / SGD+variance; the second-moment-only variant
must track Adam, first-order methods must lag (the observation AdaLomo is
built on).  Plus the 2-D two-well trajectory endpoints (Fig 6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row, tiny_llama, train_curve
from repro.core import optimizers as opt_lib

OPTS = ["adamw", "sgd", "sgd_momentum", "sgd_variance"]


def _two_well():
    def f(xy):
        x, y = xy[0], xy[1]
        return (x ** 2 + y ** 2
                - 2 * jnp.exp(-5 * ((x - 1) ** 2 + y ** 2))
                - 3 * jnp.exp(-5 * ((x + 1) ** 2 + y ** 2)))

    res = {}
    for name, lr in [("sgd", 0.02), ("sgd_momentum", 0.02),
                     ("sgd_variance", 0.02), ("adamw", 0.02),
                     ("adalomo", 0.05)]:
        opt = opt_lib.get_opt(name)
        p = jnp.array([0.5, 1.0])
        s = opt.init(p)
        g_fn = jax.jit(jax.grad(f))
        for _ in range(600):
            p, s = opt.step(p, g_fn(p), s, jnp.float32(lr))
        res[name] = ("global" if float(p[0]) < 0 else "local",
                     float(f(p)))
    return res


def run(fast: bool = True) -> list:
    steps = 50 if fast else 200
    arch = tiny_llama()
    rows = []
    finals = {}
    for opt in OPTS:
        out = train_curve(arch, opt, steps=steps, fused=False)
        finals[opt] = out["history"]["loss"][-1]
        rows.append(fmt_row(f"fig1/{opt}", out["us_per_step"],
                            f"final_loss={finals[opt]:.4f}"))
    gap_v = finals["sgd_variance"] - finals["adamw"]
    gap_m = finals["sgd_momentum"] - finals["adamw"]
    rows.append(fmt_row(
        "fig1/claim", 0.0,
        f"variance_gap_to_adam={gap_v:.4f};momentum_gap_to_adam={gap_m:.4f};"
        f"variance_closer={bool(gap_v < gap_m)}"))
    for name, (well, fv) in _two_well().items():
        rows.append(fmt_row(f"fig6/{name}", 0.0,
                            f"well={well};f={fv:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

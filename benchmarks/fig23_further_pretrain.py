"""Paper Figures 2/3 proxy: further pre-training on a domain-shifted
corpus (different token distribution + different structure seed), AdaLomo
vs AdamW; loss curves should overlap and the validation ppl match."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, tiny_llama, train_curve
from repro.data.pipeline import DataConfig, batches
from repro.train.loop import TrainConfig, Trainer


def run(fast: bool = True) -> list:
    steps = 60 if fast else 300
    arch = tiny_llama()
    # stage 1: "pre-train" briefly on domain A
    base = train_curve(arch, "adamw", steps=steps // 2, data_seed=0)
    rows = []
    finals = {}
    for opt in ("adalomo", "adamw"):
        # stage 2: further pre-train on domain B (shifted distribution).
        # paper lr ratio (Table 6): AdaLomo ≈ 30× AdamW's
        tcfg = TrainConfig(optimizer=opt,
                           lr=2e-2 if opt == "adalomo" else 1e-3,
                           total_steps=steps,
                           fused=opt == "adalomo", log_every=0)
        trainer = Trainer(arch, tcfg, log_fn=lambda s: None)
        opt_state = trainer.opt.init(base["params"])
        dcfg = DataConfig(vocab=arch.cfg.vocab, seq_len=128, global_batch=8,
                          seed=4242)  # domain shift
        out = trainer.fit(jax.tree.map(jnp.copy, base["params"]), opt_state,
                          batches(dcfg))
        h = out["history"]
        finals[opt] = h["loss"][-1]
        rows.append(fmt_row(
            f"fig23/{opt}", 0.0,
            f"start_loss={h['loss'][0]:.4f};final_loss={h['loss'][-1]:.4f};"
            f"ppl={float(jnp.exp(h['loss'][-1])):.2f}"))
    gap = abs(finals["adalomo"] - finals["adamw"])
    rows.append(fmt_row(
        "fig23/claim", 0.0,
        f"curves_overlap_gap={gap:.4f};ok={bool(gap < 0.5)} "
        f"(60-step CPU-proxy horizon; paper parity is at convergence)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

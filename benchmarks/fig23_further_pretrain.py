"""Paper Figures 2/3 proxy: further pre-training on a domain-shifted
corpus (different token distribution + different structure seed), AdaLomo
vs AdamW; loss curves should overlap and the validation ppl match."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, run_spec, tiny_llama, train_curve
from repro.run import run as run_api


def run(fast: bool = True) -> list:
    steps = 60 if fast else 300
    arch = tiny_llama()
    # stage 1: "pre-train" briefly on domain A
    base = train_curve(arch, "adamw", steps=steps // 2, data_seed=0)
    rows = []
    finals = {}
    for opt in ("adalomo", "adamw"):
        # stage 2: further pre-train on domain B (shifted distribution),
        # warm-started from the stage-1 params via the Run API's params
        # override.  Paper lr ratio (Table 6): AdaLomo ≈ 30× AdamW's.
        spec = run_spec(arch, opt, steps=steps,
                        lr=2e-2 if opt == "adalomo" else 1e-3,
                        fused=opt == "adalomo",
                        data_seed=4242)  # domain shift
        out = run_api(spec, arch=arch,
                      params=jax.tree.map(jnp.copy, base["params"]),
                      log_fn=lambda s: None)
        h = out.history
        finals[opt] = h["loss"][-1]
        rows.append(fmt_row(
            f"fig23/{opt}", 0.0,
            f"start_loss={h['loss'][0]:.4f};final_loss={h['loss'][-1]:.4f};"
            f"ppl={float(jnp.exp(h['loss'][-1])):.2f}"))
    gap = abs(finals["adalomo"] - finals["adamw"])
    rows.append(fmt_row(
        "fig23/claim", 0.0,
        f"curves_overlap_gap={gap:.4f};ok={bool(gap < 0.5)} "
        f"(60-step CPU-proxy horizon; paper parity is at convergence)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

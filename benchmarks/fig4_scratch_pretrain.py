"""Paper Figure 4 / Table 7: from-scratch pre-training (LLaMA-family) —
SGD vs Adafactor vs AdamW vs AdaLomo.  Claim: AdamW ≈ Adafactor ≈ AdaLomo,
all well above SGD."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import fmt_row, tiny_llama, train_curve

OPTS = ["sgd", "adafactor", "adamw", "adalomo"]


def run(fast: bool = True) -> list:
    steps = 80 if fast else 400
    arch = tiny_llama(layers=4, d=128)
    rows, finals = [], {}
    for opt in OPTS:
        out = train_curve(arch, opt, steps=steps, seed=0)
        h = out["history"]
        finals[opt] = h["loss"][-1]
        rows.append(fmt_row(
            f"fig4/{opt}", out["us_per_step"],
            f"final_loss={h['loss'][-1]:.4f};"
            f"final_acc={h['accuracy'][-1]:.4f};"
            f"ppl={float(jnp.exp(h['loss'][-1])):.2f}"))
    adaptive = [finals[o] for o in ("adafactor", "adamw", "adalomo")]
    # paper Fig. 4 qualitative claim at proxy horizon: every adaptive
    # method (incl. AdaLomo) out-trains SGD; spread reported informationally
    ok = finals["sgd"] > max(adaptive) - 0.05
    rows.append(fmt_row("fig4/claim", 0.0,
                        f"all_adaptive_beat_sgd={bool(ok)};"
                        f"adaptive_spread={max(adaptive)-min(adaptive):.4f};"
                        f"sgd_gap={finals['sgd']-max(adaptive):.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Fleet sweep demo: one base RunSpec fanned across three optimizer
variants, merged into the ranked report the sweep driver ships.

This is the committed example of the ``repro.fleet.sweep`` artifact
(DESIGN.md §"Elastic training fleet" documents the schema): three
members — AdaLomo at two learning rates plus an AdamW ablation — run to
completion on the tiny proxy model, and ``report.json`` merges their
HistoryHook/MetricsHook outputs ranked by final loss.

Writes ``benchmarks/BENCH_sweep.json`` (committed artifact; regenerate
with ``PYTHONPATH=src python -m benchmarks.run --only fleet_sweep``).
"""
from __future__ import annotations

import tempfile

from benchmarks.common import LRS, fmt_row, tiny_llama, write_bench_json
from repro.fleet import run_sweep
from repro.run import ModelSpec, OptSpec, RunSpec, StepSpec
from repro.data.pipeline import DataConfig

VARIANTS = [
    {"opt.lr": LRS["adalomo"]},
    {"opt.lr": LRS["adalomo"] / 3},
    {"opt.name": "adamw", "opt.lr": LRS["adamw"]},
]


def _base(arch, steps: int) -> RunSpec:
    return RunSpec(
        model=ModelSpec(arch=arch.arch_id),
        data=DataConfig(vocab=arch.cfg.vocab, seq_len=128, global_batch=8),
        opt=OptSpec(name="adalomo", schedule="cosine"),
        steps=StepSpec(total=steps),
        log_every=0)


def run(fast: bool = True) -> list:
    arch = tiny_llama()
    steps = 12 if fast else 60
    with tempfile.TemporaryDirectory() as d:
        report = run_sweep(_base(arch, steps), VARIANTS, d,
                           run_kwargs={"arch": arch},
                           log_fn=lambda s: None)
    # the committed artifact is the report itself, minus the base spec
    # blob (redundant with the per-member overrides for review purposes)
    slim = {k: v for k, v in report.items() if k != "base_spec"}
    write_bench_json("sweep", {"arch": "tiny-llama", "steps": steps,
                               "report": slim})
    rows = []
    for rank, name in enumerate(report["ranking"], 1):
        row = next(r for r in report["members"] if r["name"] == name)
        rows.append(fmt_row(f"fleet_sweep/{name}",
                            row.get("mean_tokens_per_s", 0.0) or 0.0,
                            f"rank{rank}_loss{row['final_loss']:.3f}"))
    return rows

"""Emit the EXPERIMENTS.md §Dry-run/§Roofline markdown tables from the
dry-run artifacts.  PYTHONPATH=src python -m benchmarks.make_report"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ART = Path(__file__).parent / "artifacts"


def fmt_table(tag: str, mesh: str) -> str:
    from repro.launch.dryrun import roofline_terms
    rows = []
    for p in sorted((ART / tag).glob(f"*__{mesh}.json")):
        d = json.loads(p.read_text())
        t = roofline_terms(d)
        ma = d["memory_analysis"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['collective_s_raw']:.3f} | "
            f"{t['dominant'].replace('_s','')} | {t['useful_ratio']:.3f} | "
            f"{t['roofline_fraction']:.4f} | "
            f"{ma.get('temp_size_in_bytes',0)/1e9:.1f} | "
            f"{ma.get('argument_size_in_bytes',0)/1e9:.2f} | "
            f"{d['compile_s']:.0f} |")
    head = ("| arch | shape | compute_s | memory_s | coll_s | coll_s_raw | "
            "dom | useful | frac | temp_GB | args_GB | compile_s |\n"
            "|---|---|---|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def main():
    for tag in ("dryrun_baseline", "dryrun"):
        for mesh in ("single", "multi"):
            n = len(list((ART / tag).glob(f"*__{mesh}.json")))
            if not n:
                continue
            print(f"\n### {tag} × {mesh} ({n} cells)\n")
            print(fmt_table(tag, mesh))


if __name__ == "__main__":
    main()

"""Shared benchmark helpers: small-scale training comparisons on CPU,
driven through the Run API (``RunSpec`` + ``run()``)."""
from __future__ import annotations

import json
from pathlib import Path

import jax.numpy as jnp

from repro.data.pipeline import DataConfig
from repro.models.registry import Arch
from repro.models.transformer import LMConfig
from repro.run import (EvalSpec, ModelSpec, OptSpec, RunSpec, StepSpec,
                       TimingHook, run)


def tiny_llama(vocab=256, layers=4, d=128) -> Arch:
    """~1.5M-param llama-architecture model: the CPU-scale stand-in for the
    paper's LLaMA runs (same family as the 1.1B from-scratch config)."""
    return Arch(
        arch_id="tiny-llama", family="transformer",
        cfg=LMConfig(name="tiny-llama", n_layers=layers, d_model=d,
                     n_heads=4, n_kv_heads=2, d_ff=d * 3, vocab=vocab,
                     dtype=jnp.float32))


# Paper LRs (Table 3/6/7) rescaled for the tiny proxy model; the paper's
# AdaLomo/AdamW lr ratio is 25-50x, and the grouped-norm trust ratio makes
# AdaLomo tolerant of large lr (tests/core/test_adalomo.py).
LRS = {"adalomo": 1e-2, "adafactor": 1e-2, "adamw": 2e-3, "lomo": 3e-2,
       "sgd": 3e-2, "sgd_momentum": 3e-2, "sgd_variance": 2e-3}


def run_spec(arch: Arch, optimizer: str, *, steps=60, batch=8, seq=128,
             lr=None, fused=None, data_seed=0, eval_every=0,
             hparams=None, seed=0, schedule="cosine") -> RunSpec:
    """The benchmark-standard RunSpec for one (arch × optimizer) curve."""
    return RunSpec(
        model=ModelSpec(arch=arch.arch_id),
        data=DataConfig(vocab=arch.cfg.vocab, seq_len=seq,
                        global_batch=batch, seed=data_seed),
        opt=OptSpec(name=optimizer, lr=lr if lr is not None
                    else LRS[optimizer], schedule=schedule,
                    hparams=hparams or {}),
        steps=StepSpec(total=steps, fused=fused),
        eval=EvalSpec(every=eval_every),
        log_every=0,
        seed=seed)


def train_curve(arch: Arch, optimizer: str, *, steps=60, batch=8, seq=128,
                lr=None, fused=None, seed=0, data_seed=0,
                eval_every=0, hparams=None) -> dict:
    """Train via ``run()`` and return {'history', 'us_per_step', 'params'}.

    ``hparams``: extra dynamic hyperparameters (Opt v2), e.g.
    ``{"weight_decay": 0.01}`` — 1-D params auto-group to no-decay."""
    spec = run_spec(arch, optimizer, steps=steps, batch=batch, seq=seq,
                    lr=lr, fused=fused, data_seed=data_seed,
                    eval_every=eval_every, hparams=hparams, seed=seed)
    # eval (when enabled) uses run()'s default held-out stream: the same
    # data seed offset the old hand-built iterator used, but resumable.
    timing = TimingHook()
    res = run(spec, arch=arch, hooks=(timing,), log_fn=lambda s: None)
    return {"history": res.history,
            "us_per_step": timing.us_per_step,
            "params": res.params}


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


BENCH_DIR = Path(__file__).resolve().parent


def write_bench_json(name: str, payload: dict) -> Path:
    """Write a committed benchmark artifact ``benchmarks/BENCH_{name}.json``.

    These are checked in (unlike ``benchmarks/artifacts/``) so a reviewer
    can diff measured numbers without re-running the benchmark."""
    out = BENCH_DIR / f"BENCH_{name}.json"
    out.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    return out

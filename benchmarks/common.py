"""Shared benchmark helpers: small-scale training comparisons on CPU."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, batches
from repro.models.registry import Arch, get_arch
from repro.models.transformer import LMConfig
from repro.train.loop import TrainConfig, Trainer


def tiny_llama(vocab=256, layers=4, d=128) -> Arch:
    """~1.5M-param llama-architecture model: the CPU-scale stand-in for the
    paper's LLaMA runs (same family as the 1.1B from-scratch config)."""
    return Arch(
        arch_id="tiny-llama", family="transformer",
        cfg=LMConfig(name="tiny-llama", n_layers=layers, d_model=d,
                     n_heads=4, n_kv_heads=2, d_ff=d * 3, vocab=vocab,
                     dtype=jnp.float32))


# Paper LRs (Table 3/6/7) rescaled for the tiny proxy model; the paper's
# AdaLomo/AdamW lr ratio is 25-50x, and the grouped-norm trust ratio makes
# AdaLomo tolerant of large lr (tests/core/test_adalomo.py).
LRS = {"adalomo": 1e-2, "adafactor": 1e-2, "adamw": 2e-3, "lomo": 3e-2,
       "sgd": 3e-2, "sgd_momentum": 3e-2, "sgd_variance": 2e-3}


def train_curve(arch: Arch, optimizer: str, *, steps=60, batch=8, seq=128,
                lr=None, fused=None, seed=0, data_seed=0,
                eval_every=0, hparams=None) -> dict:
    """Train and return {'history', 'us_per_step'}.

    ``hparams``: extra dynamic hyperparameters (Opt v2), e.g.
    ``{"weight_decay": 0.01}`` — 1-D params auto-group to no-decay."""
    fused = fused if fused is not None else optimizer in (
        "adalomo", "lomo", "sgd")
    tcfg = TrainConfig(optimizer=optimizer, lr=lr or LRS[optimizer],
                       total_steps=steps, fused=fused, log_every=0,
                       eval_every=eval_every, hparams=hparams or {})
    trainer = Trainer(arch, tcfg, log_fn=lambda s: None)
    params, opt_state = trainer.init(seed)
    dcfg = DataConfig(vocab=arch.cfg.vocab, seq_len=seq, global_batch=batch,
                      seed=data_seed)
    ev = batches(DataConfig(vocab=arch.cfg.vocab, seq_len=seq,
                            global_batch=batch, seed=data_seed + 999))
    t0 = time.time()
    out = trainer.fit(params, opt_state, batches(dcfg),
                      eval_iter=ev if eval_every else None)
    wall = time.time() - t0
    return {"history": out["history"],
            "us_per_step": wall / steps * 1e6,
            "params": out["params"]}


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"

"""Roofline report (deliverable g): the dry-run three-term table per
(arch × shape × mesh), plus the *measured* kernel roofline driven through
the telemetry counter registry (``repro.telemetry.kernels``).

``run()`` emits both, writes the committed ``BENCH_roofline.json``
(per-kernel FLOPs / bytes / achieved-vs-peak on CPU smoke shapes, plus
analytic config-zoo rows), and appends a ``kernel``-kind telemetry stream
under ``benchmarks/artifacts/telemetry/`` for ``repro.telemetry.report``.
Also used to regenerate EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, write_bench_json
from repro.telemetry import TelemetryWriter, counters_for, zoo_cases

ARTIFACT_DIR = Path(__file__).parent / "artifacts" / "dryrun"
TELEMETRY_DIR = Path(__file__).parent / "artifacts" / "telemetry"


# --------------------------------------------------------------------------
# Dry-run cells (analytic, from committed lowering artifacts)
# --------------------------------------------------------------------------

def load_cells(mesh: str = "single") -> list:
    cells = []
    for p in sorted(ARTIFACT_DIR.glob(f"*__{mesh}.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def cell_terms(d: dict) -> dict:
    from repro.launch.dryrun import roofline_terms
    return roofline_terms(d)


def table(mesh: str = "single") -> list:
    rows = []
    for d in load_cells(mesh):
        t = cell_terms(d)
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "model_flops": t["model_flops"],
            "useful_ratio": t["useful_ratio"],
            "roofline_fraction": t["roofline_fraction"],
            "temp_gb": d["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9,
            "args_gb": d["memory_analysis"].get(
                "argument_size_in_bytes", 0) / 1e9,
        })
    return rows


# --------------------------------------------------------------------------
# Measured kernel roofline (telemetry counter registry)
# --------------------------------------------------------------------------

def _best_of(fn, reps: int = 5) -> float:
    """Best wall seconds over ``reps`` post-warmup calls (compile excluded)."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate_peak(n: int = 512, reps: int = 5) -> dict:
    """Achievable-FLOPs anchor for this backend: best-of matmul GFLOP/s.

    Not the datasheet peak — the same-process, same-allocator rate a
    dense f32 [n,n]@[n,n] reaches, which is the honest denominator for
    "fraction of peak" on whatever machine regenerated this file.
    """
    a = jnp.ones((n, n), jnp.float32)
    b = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda x, y: x @ y)
    best = _best_of(lambda: f(a, b), reps)
    flops = 2.0 * n ** 3
    return {"probe": f"matmul{n}", "gflops": flops / best / 1e9,
            "wall_us": best * 1e6}


def _smoke_cases(fast: bool = True) -> list:
    """(kernel, shape-kwargs, thunk-builder) triples at CPU smoke scale."""
    interpret = jax.default_backend() != "tpu"   # threaded, not hardcoded

    def adalomo_case(m, n):
        from repro.kernels.adalomo_update.ops import adalomo_update
        key = jax.random.PRNGKey(0)
        p = jax.random.normal(key, (m, n), jnp.float32)
        g = jax.random.normal(jax.random.fold_in(key, 1), (m, n),
                              jnp.float32) * 1e-2
        r, c = jnp.ones((m,), jnp.float32), jnp.ones((n,), jnp.float32)

        def thunk():
            return adalomo_update(p, g, r, c, 1e-3, 2,
                                  interpret=interpret)

        impl = "pallas" if not interpret else "pallas_interpret"
        return ("adalomo_update", {"m": m, "n": n}, thunk, impl)

    def paged_case(batch, q_heads, kv_heads, head_dim, seq_len, page_size,
                   pages_per_seq):
        from repro.kernels.decode_attention.ops import paged_decode_attention
        key = jax.random.PRNGKey(2)
        num_pages = batch * pages_per_seq + 1
        q = jax.random.normal(key, (batch, 1, q_heads, head_dim),
                              jnp.float32)
        kp = jax.random.normal(jax.random.fold_in(key, 1),
                               (num_pages, page_size, kv_heads, head_dim),
                               jnp.float32)
        vp = jax.random.normal(jax.random.fold_in(key, 2), kp.shape,
                               jnp.float32)
        tables = (1 + jnp.arange(batch * pages_per_seq, dtype=jnp.int32)
                  ).reshape(batch, pages_per_seq)
        lens = jnp.full((batch,), seq_len, jnp.int32)
        fn = jax.jit(lambda q, kp, vp, bt, sl: paged_decode_attention(
            q, kp, vp, bt, sl, interpret=interpret))

        def thunk():
            return fn(q, kp, vp, tables, lens)

        impl = ("pallas" if jax.default_backend() == "tpu" else "jnp_ref")
        return ("paged_decode_attention",
                {"batch": batch, "q_heads": q_heads, "kv_heads": kv_heads,
                 "head_dim": head_dim, "seq_len": seq_len,
                 "page_size": page_size, "pages_per_seq": pages_per_seq},
                thunk, impl)

    cases = [adalomo_case(256, 512),
             paged_case(4, 8, 4, 64, 120, 16, 8)]
    if not fast:
        cases += [adalomo_case(1024, 1024),
                  paged_case(8, 16, 4, 64, 1000, 16, 64)]
    return cases


def measure_kernels(fast: bool = True, telemetry_path=None) -> dict:
    """Time the smoke cases through the public auto-dispatch entry points
    and pair each with its analytic counters; optionally append the rows
    to a ``kernel`` telemetry stream."""
    peak = calibrate_peak()
    writer = (TelemetryWriter(telemetry_path, stream="kernel",
                              backend=jax.default_backend())
              if telemetry_path else None)
    rows = []
    for kernel, shape, thunk, impl in _smoke_cases(fast):
        kc = counters_for(kernel, **shape)
        wall_s = _best_of(thunk, reps=3 if fast else 5)
        gflops = kc.flops / wall_s / 1e9
        row = kc.record(wall_us=wall_s * 1e6, impl=impl, gflops=gflops,
                        frac_of_peak=gflops / peak["gflops"])
        rows.append(row)
        if writer is not None:
            writer.write(row)
    if writer is not None:
        writer.close()
    analytic = [counters_for(k, **shape).record(cell=cell, analytic=True)
                for k, shape, cell in zoo_cases()]
    return {"backend": jax.default_backend(), "peak": peak,
            "kernels": rows, "analytic": analytic}


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

def run(fast: bool = True) -> list:
    rows = []
    for mesh in ("single", "multi"):
        for r in table(mesh):
            rows.append(fmt_row(
                f"roofline/{r['arch']}/{r['shape']}/{mesh}", 0.0,
                f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
                f"collective_s={r['collective_s']:.4f};dom={r['dominant']};"
                f"useful={r['useful_ratio']:.3f};"
                f"frac={r['roofline_fraction']:.3f}"))
    TELEMETRY_DIR.mkdir(parents=True, exist_ok=True)
    stream = TELEMETRY_DIR / "kernels.jsonl"
    if stream.exists():
        stream.unlink()                 # regenerate, don't append forever
    out = measure_kernels(fast, telemetry_path=stream)
    for r in out["kernels"]:
        rows.append(fmt_row(
            f"roofline/kernel/{r['kernel']}/{r['impl']}", r["wall_us"],
            f"gflops={r['gflops']:.2f};frac={r['frac_of_peak']:.4f};"
            f"intensity={r['intensity']:.2f}"))
    write_bench_json("roofline", out)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Roofline report (deliverable g): reads the dry-run artifacts and emits
the three-term table per (arch × shape × mesh).  Also used to regenerate
EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import fmt_row

ARTIFACT_DIR = Path(__file__).parent / "artifacts" / "dryrun"


def load_cells(mesh: str = "single") -> list:
    cells = []
    for p in sorted(ARTIFACT_DIR.glob(f"*__{mesh}.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def cell_terms(d: dict) -> dict:
    from repro.launch.dryrun import roofline_terms
    return roofline_terms(d)


def table(mesh: str = "single") -> list:
    rows = []
    for d in load_cells(mesh):
        t = cell_terms(d)
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "model_flops": t["model_flops"],
            "useful_ratio": t["useful_ratio"],
            "roofline_fraction": t["roofline_fraction"],
            "temp_gb": d["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9,
            "args_gb": d["memory_analysis"].get(
                "argument_size_in_bytes", 0) / 1e9,
        })
    return rows


def run(fast: bool = True) -> list:
    rows = []
    for mesh in ("single", "multi"):
        for r in table(mesh):
            rows.append(fmt_row(
                f"roofline/{r['arch']}/{r['shape']}/{mesh}", 0.0,
                f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
                f"collective_s={r['collective_s']:.4f};dom={r['dominant']};"
                f"useful={r['useful_ratio']:.3f};"
                f"frac={r['roofline_fraction']:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Paper Table 1: training-state memory by method (mixed precision).

Two views:
  * analytic bytes (params + grads + optimizer state) per method for each
    assigned arch's full config — the paper's 16M vs ~2M accounting;
  * structural check from compiled HLO: fused vs unfused temp memory on the
    smoke config (the O(1)-gradient claim, measured not asserted).
"""
from __future__ import annotations

import jax

from repro.core import optimizers as opt_lib
from benchmarks.common import fmt_row, tiny_llama


def analytic_rows(arch_ids=("h2o-danube-1.8b", "qwen3-32b",
                            "deepseek-v3-671b")) -> list:
    from repro.models.registry import get_arch
    rows = []
    for aid in arch_ids:
        arch = get_arch(aid)
        params = jax.eval_shape(
            lambda a=arch: a.init_params(jax.random.PRNGKey(0)))
        leaves = jax.tree.leaves(params)
        param_b = sum(x.size * 2 for x in leaves)  # bf16 weights
        n = sum(x.size for x in leaves)
        for method, rule_name, grad_b, extra in [
                ("AdamW", "adamw", param_b, 2 * n * 4),      # fp32 m+v
                ("Adafactor", "adafactor", param_b, None),
                ("LOMO", "lomo", 0, 0),
                ("AdaLomo", "adalomo", 0, None)]:
            rule = opt_lib.get_rule(rule_name)
            state_b = extra if extra is not None else sum(
                rule.state_bytes(x) for x in leaves)
            total = param_b + grad_b + state_b
            rows.append((aid, method, param_b, grad_b, state_b, total))
    return rows


def structural_check() -> dict:
    """Compiled temp bytes: fused-AdaLomo vs unfused-AdamW on one model.
    Each variant is the Run API's own StepProgram, lowered on its abstract
    signature — the same program the launcher would train."""
    from benchmarks.common import run_spec
    from repro.run import build_step_program
    arch = tiny_llama(layers=6, d=256)
    out = {}
    for name, rule_name, fused in [("adalomo_fused", "adalomo", True),
                                   ("adamw_unfused", "adamw", False),
                                   ("lomo_fused", "lomo", True)]:
        spec = run_spec(arch, rule_name, steps=1, batch=8, seq=256,
                        lr=1e-3, fused=fused)
        c = build_step_program(spec, arch).lower().compile()
        ma = c.memory_analysis()
        out[name] = {"temp": int(ma.temp_size_in_bytes),
                     "args": int(ma.argument_size_in_bytes)}
    return out


def run(fast: bool = True) -> list:
    rows = []
    for aid, method, pb, gb, sb, tot in analytic_rows():
        rows.append(fmt_row(
            f"table1/{aid}/{method}", 0.0,
            f"param_GB={pb/1e9:.2f};grad_GB={gb/1e9:.2f};"
            f"state_GB={sb/1e9:.2f};total_GB={tot/1e9:.2f}"))
    sc = structural_check()
    base = sc["adamw_unfused"]["temp"]
    for name, d in sc.items():
        rows.append(fmt_row(
            f"table1/structural/{name}", 0.0,
            f"temp_MB={d['temp']/1e6:.1f};args_MB={d['args']/1e6:.1f};"
            f"temp_vs_adamw={d['temp']/base:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Paper Table 1: training-state memory by method (mixed precision).

Two views:
  * analytic bytes (params + grads + optimizer state) per method for each
    assigned arch's full config — the paper's 16M vs ~2M accounting;
  * structural check from compiled HLO: fused vs unfused temp memory on the
    smoke config (the O(1)-gradient claim, measured not asserted).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import optimizers as opt_lib
from benchmarks.common import fmt_row, tiny_llama


def analytic_rows(arch_ids=("h2o-danube-1.8b", "qwen3-32b",
                            "deepseek-v3-671b")) -> list:
    from repro.models.registry import get_arch
    rows = []
    for aid in arch_ids:
        arch = get_arch(aid)
        params = jax.eval_shape(
            lambda a=arch: a.init_params(jax.random.PRNGKey(0)))
        leaves = jax.tree.leaves(params)
        param_b = sum(x.size * 2 for x in leaves)  # bf16 weights
        n = sum(x.size for x in leaves)
        for method, rule_name, grad_b, extra in [
                ("AdamW", "adamw", param_b, 2 * n * 4),      # fp32 m+v
                ("Adafactor", "adafactor", param_b, None),
                ("LOMO", "lomo", 0, 0),
                ("AdaLomo", "adalomo", 0, None)]:
            rule = opt_lib.get_rule(rule_name)
            state_b = extra if extra is not None else sum(
                rule.state_bytes(x) for x in leaves)
            total = param_b + grad_b + state_b
            rows.append((aid, method, param_b, grad_b, state_b, total))
    return rows


def structural_check() -> dict:
    """Compiled temp bytes: fused-AdaLomo vs unfused-AdamW on one model."""
    arch = tiny_llama(layers=6, d=256)
    key = jax.random.PRNGKey(0)
    params = arch.init_params(key)
    batch = {"tokens": jnp.zeros((8, 256), jnp.int32),
             "labels": jnp.zeros((8, 256), jnp.int32)}
    hp = {"lr": jnp.float32(1e-3)}
    out = {}
    for name, rule_name, fused in [("adalomo_fused", "adalomo", True),
                                   ("adamw_unfused", "adamw", False),
                                   ("lomo_fused", "lomo", True)]:
        opt = opt_lib.get_opt(rule_name)
        opt_state = opt.init(params)
        if fused:
            step = arch.make_fused_train_step(opt)
            fn = lambda p, s, b: step(p, s, b, hparams=hp)  # noqa: E731
        else:
            loss_fn = arch.make_loss_fn()

            def fn(p, s, b, _loss_fn=loss_fn, _opt=opt):
                (loss, m), g = jax.value_and_grad(_loss_fn, has_aux=True)(
                    p, b)
                p2, s2 = _opt.step(p, g, s, hp)
                return p2, s2, loss, m

        c = jax.jit(fn, donate_argnums=(0, 1)).lower(
            params, opt_state, batch).compile()
        ma = c.memory_analysis()
        out[name] = {"temp": int(ma.temp_size_in_bytes),
                     "args": int(ma.argument_size_in_bytes)}
    return out


def run(fast: bool = True) -> list:
    rows = []
    for aid, method, pb, gb, sb, tot in analytic_rows():
        rows.append(fmt_row(
            f"table1/{aid}/{method}", 0.0,
            f"param_GB={pb/1e9:.2f};grad_GB={gb/1e9:.2f};"
            f"state_GB={sb/1e9:.2f};total_GB={tot/1e9:.2f}"))
    sc = structural_check()
    base = sc["adamw_unfused"]["temp"]
    for name, d in sc.items():
        rows.append(fmt_row(
            f"table1/structural/{name}", 0.0,
            f"temp_MB={d['temp']/1e6:.1f};args_MB={d['args']/1e6:.1f};"
            f"temp_vs_adamw={d['temp']/base:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Quickstart: AdaLomo in 30 lines — fused backward, factored state.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import optimizers as opt
from repro.core.fused import init_fused_opt_state
from repro.models.registry import get_arch

# 1. pick an architecture (any of the 10 assigned ids; smoke = CPU-sized)
arch = get_arch("h2o-danube-1.8b", smoke=True)

# 2. AdaLomo rule: factored second moment + grouped update normalization
rule = opt.adalomo()

# 3. init params and the O(m+n)-per-matrix optimizer state
params = arch.init_params(jax.random.PRNGKey(0))
opt_state = init_fused_opt_state(rule, params)
state_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(opt_state["moments"]))
param_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
print(f"params: {param_bytes/1e6:.1f} MB, optimizer state: "
      f"{state_bytes/1e6:.2f} MB ({state_bytes/param_bytes:.1%})")

# 4. the fused train step: backward pass and update are one scan —
#    gradients of at most one layer are ever alive (LOMO's trick, XLA-style)
step = jax.jit(arch.make_fused_train_step(rule), donate_argnums=(0, 1))

batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                 arch.cfg.vocab),
    "labels": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                 arch.cfg.vocab),
}
for i in range(10):
    params, opt_state, loss, metrics = step(params, opt_state, batch,
                                            lr=jnp.float32(1e-3))
    print(f"step {i}: loss={float(loss):.4f} "
          f"acc={float(metrics['accuracy']):.3f}")

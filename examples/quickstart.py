"""Quickstart: AdaLomo in 30 lines — fused backward, factored state.

Opt v2 idiom ("hyperparameters as arguments, state as data", DESIGN.md):
build an ``Opt`` from a rule + param groups, ``opt.init(params)`` gives a
serializable ``OptState(step, moments)`` pytree, and every train step takes
an ``hparams`` dict — so lr/β/weight-decay schedules and per-group
overrides are plain data, changed per step with zero recompiles.

Steps 1-4 drive the pieces by hand; step 5 is the same thing as one
declarative ``RunSpec`` through the Run API (DESIGN.md §"Run API v1") —
what ``launch/train.py``, the benchmarks, and the dry-run all build on.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import optimizers as opt_lib
from repro.models.registry import get_arch

# 1. pick an architecture (any of the 10 assigned ids; smoke = CPU-sized)
arch = get_arch("h2o-danube-1.8b", smoke=True)

# 2. AdaLomo: factored second moment + grouped update normalization.
#    One rule, every path: the same Opt drives the fused backward engine,
#    the unfused opt.step, and (backend="pallas") the TPU kernel.
#    no_decay_1d() labels norm scales/biases into a weight_decay=0 group.
opt = opt_lib.get_opt("adalomo", groups=(opt_lib.no_decay_1d(),))

# 3. init params and the O(m+n)-per-matrix optimizer state
params = arch.init_params(jax.random.PRNGKey(0))
opt_state = opt.init(params)
state_bytes = opt.state_bytes(params)
param_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
print(f"params: {param_bytes/1e6:.1f} MB, optimizer state: "
      f"{state_bytes/1e6:.2f} MB ({state_bytes/param_bytes:.1%})")

# 4. the fused train step: backward pass and update are one scan —
#    gradients of at most one layer are ever alive (LOMO's trick, XLA-style)
step = jax.jit(arch.make_fused_train_step(opt), donate_argnums=(0, 1))

batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                 arch.cfg.vocab),
    "labels": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                 arch.cfg.vocab),
}
for i in range(10):
    # hparams are data: this decayed lr never triggers a recompile
    hp = {"lr": jnp.float32(1e-3 * (1.0 - i / 20)),
          "weight_decay": jnp.float32(0.01)}
    params, opt_state, loss, metrics = step(params, opt_state, batch,
                                            hparams=hp)
    print(f"step {int(opt_state.step)}: loss={float(loss):.4f} "
          f"acc={float(metrics['accuracy']):.3f}")

# 5. the same run, declaratively (Run API v1): one serializable RunSpec,
#    one entrypoint — run() builds the identical fused step program
#    (which launch/dryrun.py can lower without training), wires the hook
#    pipeline (history/logging/eval/checkpoint), and drives the loop.
from repro.data.pipeline import DataConfig  # noqa: E402
from repro.run import ModelSpec, OptSpec, RunSpec, StepSpec, run  # noqa: E402

spec = RunSpec(model=ModelSpec("h2o-danube-1.8b", smoke=True),
               data=DataConfig(vocab=arch.cfg.vocab, seq_len=64,
                               global_batch=4),
               opt=OptSpec(name="adalomo", lr=1e-3,
                           hparams={"weight_decay": 0.01}),
               steps=StepSpec(total=5), log_every=0)
print("RunSpec round-trips:", RunSpec.from_json(spec.to_json()) == spec)
result = run(spec, log_fn=lambda s: None)
print(f"run(): final loss {result.history['loss'][-1]:.4f} in "
      f"{len(result.history['step'])} steps")

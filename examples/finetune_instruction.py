"""Instruction-tuning example (paper §4.1): compares AdaLomo vs AdamW vs
LOMO on a fine-tuning task and prints the final held-out metrics —
the offline analogue of Table 2.

  PYTHONPATH=src python examples/finetune_instruction.py [--steps 60]
"""
import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import tiny_llama, train_curve
from repro.data.pipeline import DataConfig, batches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    arch = tiny_llama()
    print(f"{'optimizer':<12} {'eval_loss':>9} {'eval_acc':>9} "
          f"{'us/step':>9}")
    for opt in ("adalomo", "adamw", "lomo"):
        # AdamW gets the paper-standard decoupled decay; Opt v2 groups
        # exempt 1-D params (norm scales/biases) automatically.
        hp = {"weight_decay": 0.01} if opt == "adamw" else None
        out = train_curve(arch, opt, steps=args.steps, hparams=hp)
        loss_fn = jax.jit(arch.make_loss_fn())
        ev = batches(DataConfig(vocab=arch.cfg.vocab, seq_len=128,
                                global_batch=8, seed=1234))
        tot = acc = 0.0
        for _ in range(4):
            b = jax.tree.map(jnp.asarray, next(ev))
            loss, m = loss_fn(out["params"], b)
            tot += float(loss) / 4
            acc += float(m["accuracy"]) / 4
        print(f"{opt:<12} {tot:9.4f} {acc:9.4f} "
              f"{out['us_per_step']:9.0f}")


if __name__ == "__main__":
    main()

"""End-to-end pre-training driver (deliverable b): trains a ~100M-param
LLaMA-architecture model from scratch with AdaLomo for a few hundred steps
on the synthetic corpus, with checkpointing and eval — the CPU-scale
version of the paper's §4.3 / Figure 4 run, expressed as one RunSpec.

  PYTHONPATH=src python examples/pretrain.py [--steps 300] [--optimizer adamw]

(~100M params is heavy for 1 CPU core; --small switches to a 10M model.)
"""
import argparse

from repro.data.pipeline import DataConfig
from repro.models.registry import Arch
from repro.models.transformer import LMConfig
from repro.run import (CheckpointSpec, EvalSpec, FaultSpec, ModelSpec,
                       OptSpec, RunSpec, StepSpec, StragglerHook, run)


def model_100m() -> Arch:
    import jax.numpy as jnp
    return Arch(arch_id="llama-100m", family="transformer",
                cfg=LMConfig(name="llama-100m", n_layers=12, d_model=768,
                             n_heads=12, n_kv_heads=4, d_ff=2048,
                             vocab=32000, dtype=jnp.float32))


def model_10m() -> Arch:
    import jax.numpy as jnp
    return Arch(arch_id="llama-10m", family="transformer",
                cfg=LMConfig(name="llama-10m", n_layers=6, d_model=256,
                             n_heads=8, n_kv_heads=4, d_ff=768, vocab=8192,
                             dtype=jnp.float32))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--optimizer", default="adalomo")
    ap.add_argument("--weight-decay", type=float, default=None,
                    help="dynamic hparam (Opt v2); 1-D params auto-group "
                         "to no-decay")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_pretrain_ckpt")
    args = ap.parse_args()

    arch = model_10m() if args.small else model_100m()
    n = arch.cfg.param_count()
    print(f"model: {arch.arch_id} ({n/1e6:.1f}M params), "
          f"optimizer: {args.optimizer}")
    lrs = {"adalomo": 1e-3, "adamw": 3e-4, "adafactor": 1e-3, "sgd": 1e-2,
           "lomo": 1e-2}
    hparams = ({} if args.weight_decay is None
               else {"weight_decay": args.weight_decay})
    spec = RunSpec(
        model=ModelSpec(arch=arch.arch_id),
        data=DataConfig(vocab=arch.cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch, seed=0),
        opt=OptSpec(name=args.optimizer, lr=lrs[args.optimizer],
                    hparams=hparams),
        steps=StepSpec(total=args.steps),
        checkpoint=CheckpointSpec(dir=args.ckpt_dir, every=100,
                                  keep_last=2),
        eval=EvalSpec(every=max(args.steps // 5, 1)),
        fault=FaultSpec(heartbeat_timeout_s=600),
        log_every=10)
    res = run(spec, arch=arch)
    h = res.history
    straggler = res.find_hook(StragglerHook)
    print(f"loss {h['loss'][0]:.3f} -> {h['loss'][-1]:.3f}; "
          f"stragglers observed: {len(straggler.monitor.events)}")


if __name__ == "__main__":
    main()

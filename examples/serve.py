"""Continuous-batching serving example: paged KV + mid-flight admission.

Trains a tiny model briefly so generations aren't pure noise, then serves
mixed-length prompts through the PagedEngine — two requests start, two
more join the running batch between decode chunks (continuous batching),
and the block-table allocator recycles pages as sequences finish.  The
legacy static-batch Engine result is printed for contrast.

  PYTHONPATH=src python examples/serve.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import tiny_llama, train_curve
from repro.serve.engine import (Engine, PagedEngine, PagedServeConfig,
                                ServeConfig)


def main():
    arch = tiny_llama()
    print("fitting a tiny model so generations follow the bigram data...")
    out = train_curve(arch, "adalomo", steps=80)
    params = out["params"]

    scfg = PagedServeConfig(page_size=8, num_pages=64, max_batch=4,
                            max_pages_per_seq=8, chunk=4,
                            max_new_tokens=12, temperature=0.0)
    engine = PagedEngine(arch, params, scfg)
    prompts = [[5, 17, 23, 9], [101, 44], [7, 7, 7, 7, 7, 7],
               [3, 1, 4, 1, 5, 9, 2, 6]]
    # continuous batching: two requests up front ...
    rids = [engine.submit(p) for p in prompts[:2]]
    engine.step()
    # ... two more join the running batch mid-flight
    rids += [engine.submit(p) for p in prompts[2:]]
    engine.run()
    for p, rid in zip(prompts, rids):
        print(f"prompt {p} -> {engine.output(rid)}")
    print(f"decode-step compiles: {engine.decode_compile_count()} "
          f"(fixed-shape chunk, compiled once)")
    print(f"pages free after serving: {engine.allocator.n_free}")

    legacy = Engine(arch, params, ServeConfig(max_new_tokens=12))
    print("legacy static batch:", legacy.generate(prompts))


if __name__ == "__main__":
    main()

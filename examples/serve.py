"""Batched serving example: prefill + KV-cache decode through the Engine.

Trains a tiny model briefly so generations aren't pure noise, then serves
a batch of prompts (greedy).  The decode step is the same function the
multi-pod dry-run lowers for decode_32k / long_500k.

  PYTHONPATH=src python examples/serve.py
"""
import jax

from benchmarks.common import tiny_llama, train_curve
from repro.serve.engine import Engine, ServeConfig


def main():
    arch = tiny_llama()
    print("fitting a tiny model so generations follow the bigram data...")
    out = train_curve(arch, "adalomo", steps=80)
    engine = Engine(arch, out["params"],
                    ServeConfig(max_new_tokens=12, temperature=0.0))
    prompts = [[5, 17, 23, 9], [101, 44], [7, 7, 7, 7, 7, 7]]
    completions = engine.generate(prompts)
    for p, c in zip(prompts, completions):
        print(f"prompt {p} -> {c}")


if __name__ == "__main__":
    main()

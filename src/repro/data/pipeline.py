"""Deterministic, resumable token pipeline.

Two sources:
  * ``SyntheticLM`` — a structured synthetic language (Zipfian unigrams +
    deterministic bigram structure + copy motifs) so that optimizers have a
    real signal to fit (losses drop well below the unigram entropy), used by
    every benchmark in this offline container;
  * ``MemmapCorpus`` — production path: a binary uint16/uint32 token file
    (the standard "packed .bin" layout) read with np.memmap, sharded by
    data-parallel rank.

Both are *stateless* given (step, rank): resume after preemption needs only
the step counter from the checkpoint — no iterator state to persist.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"          # "synthetic" | "memmap"
    path: Optional[str] = None         # for memmap
    dp_rank: int = 0
    dp_size: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.dp_size == 0
        return self.global_batch // self.dp_size


class SyntheticLM:
    """Zipf unigrams + rotation bigrams + periodic copy spans.

    A next-token predictor can reach substantially below unigram entropy by
    learning (a) the bigram rotation and (b) the copy structure — enough
    signal to separate SGD from adaptive optimizers (paper Fig. 1/4).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        V = cfg.vocab
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.rot = rng.permutation(V)          # deterministic bigram map
        self.copy_period = 64

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.dp_rank)
        B, S = cfg.local_batch, cfg.seq_len
        base = rng.choice(cfg.vocab, size=(B, S + 1), p=self.probs)
        # bigram structure: with p=0.5 the next token is rot[prev]
        use_rot = rng.random((B, S)) < 0.5
        for t in range(1, S + 1):
            sel = use_rot[:, t - 1]
            base[sel, t] = self.rot[base[sel, t - 1]]
        # copy motif: second half of each period repeats the first half
        half = self.copy_period // 2
        for start in range(0, S + 1 - self.copy_period, self.copy_period):
            base[:, start + half:start + self.copy_period] = \
                base[:, start:start + half]
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


class MemmapCorpus:
    """Packed binary token corpus; rank-sharded strided reads."""

    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        assert cfg.path, "memmap source requires path"
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.n_seqs = (len(self.data) - 1) // cfg.seq_len

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        B, S = cfg.local_batch, cfg.seq_len
        rng = np.random.default_rng(cfg.seed + step)
        # deterministic shuffled order, strided by dp rank
        order = rng.permutation(self.n_seqs)
        idx = order[(np.arange(B) + step * cfg.global_batch
                     + cfg.dp_rank * B) % self.n_seqs]
        toks = np.stack([self.data[i * S:i * S + S + 1] for i in idx])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_source(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticLM(cfg)
    if cfg.source == "memmap":
        return MemmapCorpus(cfg)
    raise ValueError(cfg.source)


def batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    src = make_source(cfg)
    step = start_step
    while True:
        yield src.batch(step)
        step += 1


def write_corpus(path: str | Path, tokens: np.ndarray):
    """Write a packed binary corpus (production format, used in tests)."""
    tokens = np.asarray(tokens)
    dtype = np.uint16 if tokens.max() < 2 ** 16 else np.uint32
    tokens.astype(dtype).tofile(str(path))
    return dtype

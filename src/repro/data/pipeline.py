"""Deterministic, resumable token pipeline.

Two sources:
  * ``SyntheticLM`` — a structured synthetic language (Zipfian unigrams +
    deterministic bigram structure + copy motifs) so that optimizers have a
    real signal to fit (losses drop well below the unigram entropy), used by
    every benchmark in this offline container;
  * ``MemmapCorpus`` — production path: a binary uint16/uint32 token file
    (the standard "packed .bin" layout) read with np.memmap, sharded by
    data-parallel rank.

Both are *stateless* given (step, rank): resume after preemption needs only
the step counter from the checkpoint — no iterator state to persist.

With ``packing=True`` both sources emit **segment-packed** batches instead
of one-document-per-row: ragged documents (length-bucketed draws) are
greedy first-fit packed into fixed ``(B, S)`` rows (:class:`PackedBatch` —
tokens, segment_ids, positions, loss_mask), so ragged corpora stop paying
the padding tax while the batch shape — and therefore the jitted step —
stays constant.  Packed batches keep the same stateless-given-step
contract: ``packed_batch(step)`` is a pure function of (cfg, step, rank),
so fault recovery rewinds packed streams exactly like padded ones.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"          # "synthetic" | "memmap"
    path: Optional[str] = None         # for memmap
    dp_rank: int = 0
    dp_size: int = 1
    # Segment-packed ragged batching (DESIGN.md "Packed sequence layout").
    packing: bool = False
    min_doc_len: int = 16              # shortest sampled document (slots)

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.dp_size == 0
        return self.global_batch // self.dp_size


# --------------------------------------------------------------------------
# Segment packing: ragged documents -> fixed-shape (B, S) rows
# --------------------------------------------------------------------------

def bucket_boundaries(max_length: int, min_length: int = 8,
                      length_bucket_step: float = 1.1) -> list[int]:
    """Geometric length-bucket boundaries (tensor2tensor ``data_reader``
    idiom): ``[min_length, ...]`` increasing by ``length_bucket_step`` up
    to (exclusive) ``max_length``."""
    assert length_bucket_step > 1.0
    if min_length >= max_length:
        return [max_length]
    boundaries, x = [], min_length
    while x < max_length:
        boundaries.append(x)
        x = max(x + 1, int(x * length_bucket_step))
    return boundaries


@dataclasses.dataclass
class PackedBatch:
    """The packed-segment layout every layer consumes natively.

    A *document* is a 1-D token array of length ``n+1``; it occupies ``n``
    row slots with inputs ``doc[:-1]`` and labels ``doc[1:]`` (the label
    shift happens per document, *before* packing — so a label can never
    point across a segment boundary).  Per slot:

      ``segment_ids``  1..n_segments within the row, 0 = padding;
      ``positions``    restart at 0 at each segment start (RoPE restarts);
      ``labels``       next token within the segment, -1 where invalid;
      ``loss_mask``    True exactly where labels are real targets.
    """

    tokens: np.ndarray        # (B, S) int32
    labels: np.ndarray        # (B, S) int32, -1 = ignored
    segment_ids: np.ndarray   # (B, S) int32, 0 = padding
    positions: np.ndarray     # (B, S) int32, per-segment
    loss_mask: np.ndarray     # (B, S) bool

    def as_dict(self) -> dict:
        return {"tokens": self.tokens, "labels": self.labels,
                "segment_ids": self.segment_ids,
                "positions": self.positions, "loss_mask": self.loss_mask}

    @property
    def padding_efficiency(self) -> float:
        """Real tokens / slot tokens — the padding-tax metric."""
        return float((self.segment_ids > 0).sum()) / self.segment_ids.size


def pack_documents(docs: Sequence[np.ndarray], n_rows: int, seq_len: int,
                   *, boundaries: Optional[Sequence[int]] = None
                   ) -> tuple[PackedBatch, list[int]]:
    """Greedy first-fit packing of ragged documents into fixed-shape rows.

    Documents are visited longest-bucket-first (first-fit-decreasing at
    bucket granularity, arrival order within a bucket — the fixed-row-shape
    analogue of tensor2tensor's ``bucket_boundaries`` batching scheme) and
    placed into the first row with room; documents that fit nowhere are
    dropped (deterministically).  Returns ``(batch, used)`` where ``used``
    is the sorted list of packed document indices — every used document's
    tokens appear exactly once.
    """
    slots = [len(d) - 1 for d in docs]
    for n in slots:
        if n < 1:
            raise ValueError("documents need >= 2 tokens (input + label)")
        if n > seq_len:
            raise ValueError(f"document with {n} slots exceeds row "
                             f"seq_len={seq_len}; split upstream")
    if boundaries is None:
        boundaries = bucket_boundaries(seq_len)
    bidx = np.searchsorted(np.asarray(boundaries), np.asarray(
        slots, np.int64), side="right") if slots else np.zeros(0, np.int64)
    order = sorted(range(len(docs)), key=lambda i: (-int(bidx[i]), i))

    tokens = np.zeros((n_rows, seq_len), np.int32)
    labels = np.full((n_rows, seq_len), -1, np.int32)
    segment_ids = np.zeros((n_rows, seq_len), np.int32)
    positions = np.zeros((n_rows, seq_len), np.int32)
    fill = [0] * n_rows
    nseg = [0] * n_rows
    used = []
    for i in order:
        n = slots[i]
        for r in range(n_rows):
            if fill[r] + n > seq_len:
                continue
            a = fill[r]
            d = np.asarray(docs[i], np.int32)
            tokens[r, a:a + n] = d[:-1]
            labels[r, a:a + n] = d[1:]
            nseg[r] += 1
            segment_ids[r, a:a + n] = nseg[r]
            positions[r, a:a + n] = np.arange(n, dtype=np.int32)
            fill[r] += n
            used.append(i)
            break
    loss_mask = (labels >= 0) & (segment_ids > 0)
    return (PackedBatch(tokens, labels, segment_ids, positions, loss_mask),
            sorted(used))


def padded_batch_from_docs(docs: Sequence[np.ndarray], n_rows: int,
                           seq_len: int) -> dict:
    """The padded baseline for the same ragged documents: one document per
    row, right-padded — what the packing benchmark compares against."""
    tokens = np.zeros((n_rows, seq_len), np.int32)
    labels = np.full((n_rows, seq_len), -1, np.int32)
    for r, d in enumerate(docs[:n_rows]):
        d = np.asarray(d, np.int32)
        n = min(len(d) - 1, seq_len)
        tokens[r, :n] = d[:n]
        labels[r, :n] = d[1:n + 1]
    return {"tokens": tokens, "labels": labels}


def _sample_doc_lengths(rng, boundaries: Sequence[int], seq_len: int,
                        slot_budget: int) -> list[int]:
    """Length-bucketed ragged draws until the slot budget (+1 row of
    slack for first-fit to drop) is covered; bounded candidate count."""
    lengths, total = [], 0
    cap = 4 * max(slot_budget // max(boundaries[0], 1), 1)
    while total < slot_budget + seq_len and len(lengths) < cap:
        b = int(rng.integers(len(boundaries)))
        lo = boundaries[b]
        hi = boundaries[b + 1] if b + 1 < len(boundaries) else seq_len
        n = min(int(rng.integers(lo, max(hi, lo) + 1)), seq_len)
        lengths.append(n)
        total += n
    return lengths


class SyntheticLM:
    """Zipf unigrams + rotation bigrams + periodic copy spans.

    A next-token predictor can reach substantially below unigram entropy by
    learning (a) the bigram rotation and (b) the copy structure — enough
    signal to separate SGD from adaptive optimizers (paper Fig. 1/4).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        V = cfg.vocab
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.rot = rng.permutation(V)          # deterministic bigram map
        self.copy_period = 64

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.dp_rank)
        B, S = cfg.local_batch, cfg.seq_len
        base = rng.choice(cfg.vocab, size=(B, S + 1), p=self.probs)
        # bigram structure: with p=0.5 the next token is rot[prev]
        use_rot = rng.random((B, S)) < 0.5
        for t in range(1, S + 1):
            sel = use_rot[:, t - 1]
            base[sel, t] = self.rot[base[sel, t - 1]]
        # copy motif: second half of each period repeats the first half
        half = self.copy_period // 2
        for start in range(0, S + 1 - self.copy_period, self.copy_period):
            base[:, start + half:start + self.copy_period] = \
                base[:, start:start + half]
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def _doc(self, rng, n: int) -> np.ndarray:
        """One document of n+1 tokens with the same unigram/bigram/copy
        structure as :meth:`batch`, but ragged."""
        base = rng.choice(self.cfg.vocab, size=n + 1, p=self.probs)
        use_rot = rng.random(n) < 0.5
        for t in range(1, n + 1):
            if use_rot[t - 1]:
                base[t] = self.rot[base[t - 1]]
        half = self.copy_period // 2
        for start in range(0, n + 1 - self.copy_period, self.copy_period):
            base[start + half:start + self.copy_period] = \
                base[start:start + half]
        return base.astype(np.int32)

    def docs(self, step: int) -> list[np.ndarray]:
        """Ragged documents for one packed batch; pure in (cfg, step,
        rank).  A distinct rng stream from :meth:`batch` — enabling
        packing must not perturb the padded stream."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.dp_rank + 0x5E6)
        bounds = bucket_boundaries(cfg.seq_len, min_length=cfg.min_doc_len)
        lengths = _sample_doc_lengths(rng, bounds, cfg.seq_len,
                                      cfg.local_batch * cfg.seq_len)
        return [self._doc(rng, n) for n in lengths]

    def packed_batch(self, step: int) -> dict:
        cfg = self.cfg
        packed, _ = pack_documents(self.docs(step), cfg.local_batch,
                                   cfg.seq_len)
        return packed.as_dict()


class MemmapCorpus:
    """Packed binary token corpus; rank-sharded strided reads."""

    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        assert cfg.path, "memmap source requires path"
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.n_seqs = (len(self.data) - 1) // cfg.seq_len

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        B, S = cfg.local_batch, cfg.seq_len
        rng = np.random.default_rng(cfg.seed + step)
        # deterministic shuffled order, strided by dp rank
        order = rng.permutation(self.n_seqs)
        idx = order[(np.arange(B) + step * cfg.global_batch
                     + cfg.dp_rank * B) % self.n_seqs]
        toks = np.stack([self.data[i * S:i * S + S + 1] for i in idx])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def docs(self, step: int) -> list[np.ndarray]:
        """Ragged documents drawn at rank-keyed random offsets; pure in
        (cfg, step, rank), so packed streams rewind like padded ones."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.dp_rank + 0x5E6)
        bounds = bucket_boundaries(cfg.seq_len, min_length=cfg.min_doc_len)
        lengths = _sample_doc_lengths(rng, bounds, cfg.seq_len,
                                      cfg.local_batch * cfg.seq_len)
        n_tok = len(self.data)
        out = []
        for n in lengths:
            n = min(n, n_tok - 1)
            off = int(rng.integers(0, max(n_tok - n - 1, 1)))
            out.append(np.asarray(self.data[off:off + n + 1],
                                  dtype=np.int64).astype(np.int32))
        return out

    def packed_batch(self, step: int) -> dict:
        cfg = self.cfg
        packed, _ = pack_documents(self.docs(step), cfg.local_batch,
                                   cfg.seq_len)
        return packed.as_dict()


def make_source(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticLM(cfg)
    if cfg.source == "memmap":
        return MemmapCorpus(cfg)
    raise ValueError(cfg.source)


def batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    src = make_source(cfg)
    step = start_step
    while True:
        yield src.packed_batch(step) if cfg.packing else src.batch(step)
        step += 1


def write_corpus(path: str | Path, tokens: np.ndarray):
    """Write a packed binary corpus (production format, used in tests)."""
    tokens = np.asarray(tokens)
    dtype = np.uint16 if tokens.max() < 2 ** 16 else np.uint32
    tokens.astype(dtype).tofile(str(path))
    return dtype

"""Tree-level optimizer API (the unfused path) built on per-tensor rules.

``Optimizer`` applies a :class:`~repro.core.optimizers.TensorRule` across a
parameter pytree — the conventional "materialize all grads, then step"
approach that AdamW/Adafactor baselines use, and the contrast point for the
fused engine in ``core/fused.py``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.optimizers import TensorRule

Array = jax.Array


class OptState(NamedTuple):
    step: Array            # scalar int32, 1-based after first update
    moments: Any           # pytree matching params, of per-tensor rule states


class Optimizer:
    """Wraps a per-tensor rule into a whole-pytree optimizer."""

    def __init__(self, rule: TensorRule):
        self.rule = rule

    @property
    def name(self) -> str:
        return self.rule.name

    def init(self, params) -> OptState:
        moments = jax.tree.map(self.rule.init, params)
        return OptState(step=jnp.zeros((), jnp.int32), moments=moments)

    def apply_gradients(self, params, grads, state: OptState, *, lr
                        ) -> tuple[Any, OptState]:
        """θ, s ← rule(θ, g, s) for every tensor. lr may be a scalar array."""
        step = state.step + 1
        stepf = step.astype(jnp.float32)

        def upd(p, g, s):
            return self.rule.update(p, g, s, lr=lr, step=stepf)

        out = jax.tree.map(upd, params, grads, state.moments,
                           is_leaf=lambda x: x is None)
        # Split the (param, state) tuples back into two trees.
        treedef = jax.tree.structure(params)
        flat = treedef.flatten_up_to(out)
        new_params = treedef.unflatten([t[0] for t in flat])
        new_moments = treedef.unflatten([t[1] for t in flat])
        return new_params, OptState(step=step, moments=new_moments)

    def state_bytes(self, params) -> int:
        return sum(self.rule.state_bytes(p) for p in jax.tree.leaves(params))

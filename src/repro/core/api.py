"""Opt v2 — one composable, introspectable optimizer API.

The contract ("hyperparameters as arguments, state as data", DESIGN.md):

    opt   = Opt(rule, groups=(GroupSpec(...), ...))
    state = opt.init(params)                         # OptState: a pytree
    new_p, new_state = opt.step(params, grads, state, hparams)

* **Hyperparameters are call-time data.**  ``hparams`` is a plain dict of
  scalars — ``{"lr": ..., "beta": ..., "weight_decay": ..., ...}`` — passed
  on every step.  Values may be traced arrays, so schedules (lr, β, decay
  warmup) never trigger a recompile; the dict's *structure* is the only
  thing baked into the jaxpr.  A bare scalar is shorthand for
  ``{"lr": scalar}``.  Per-group overrides ride along under a ``"groups"``
  key: ``{"lr": 1e-3, "groups": {"embed": {"lr": 1e-4}}}``.

* **State is data.**  ``OptState(step, moments)`` holds one global step
  scalar and a moments pytree mirroring ``params`` — no closures, no
  hidden Python state, directly serializable by ``checkpoint/manager.py``
  and shardable by ``sharding/rules.py``.  The same layout is produced and
  consumed by the fused backward engine (``core/fused.py``), the unfused
  ``Opt.step`` path, and the Pallas kernel backend.

* **Param groups are path labels.**  A :class:`GroupSpec` maps leaves to a
  group by regex on the leaf's path string or by predicate on its
  :class:`LeafInfo`; each group carries default hparam overrides (e.g.
  ``weight_decay=0`` for norm scales and biases — the paper's grouped
  treatment) and an optional ``factored`` state mask.

Layout convention: a top-level ``"stacks"`` key marks scan-over-layers
parameter stacks ``[L, ...]`` (see ``core/fused.py``); their optimizer
state is initialized per layer slice (vmapped), so factorization and the
grouped-RMS axes see the per-layer tensor shape.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Callable, Mapping, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Array = jax.Array

# Top-level pytree key marking [L, ...] layer stacks (core/fused.py layout).
STACKS_KEY = "stacks"


# --------------------------------------------------------------------------
# Per-tensor rules: pure init/update with hyperparameters as data
# --------------------------------------------------------------------------

class UpdateRule(NamedTuple):
    """A per-tensor optimizer rule, v2.

    ``init(param, factored=None) -> state`` — per-tensor state (a pytree).
    ``update(param, grad, state, hp, step) -> (new_param, new_state)`` —
    one step; ``hp`` is a fully-resolved dict containing every key in
    ``hparams``; ``step`` is the 1-based global step as float32.
    ``hparams`` declares the accepted dynamic hyperparameters and their
    defaults — the introspection surface for schedules and group overrides.
    """

    name: str
    init: Callable[..., Any]
    update: Callable[..., tuple[Array, Any]]
    hparams: dict
    # Analytic per-tensor optimizer-state bytes (Table-1 benchmark).
    state_bytes: Callable[[Array], int]


def make_rule(name: str, init_fn, update_fn, hparams: Mapping[str, Any]
              ) -> UpdateRule:
    """Assemble an :class:`UpdateRule`, deriving ``state_bytes`` from init."""

    def state_bytes(param: Array) -> int:
        st = jax.eval_shape(lambda p: init_fn(p), param)
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(st))

    return UpdateRule(name=name, init=init_fn, update=update_fn,
                      hparams=dict(hparams), state_bytes=state_bytes)


class OptState(NamedTuple):
    """Whole-tree optimizer state: ONE step scalar + per-tensor moments."""

    step: Array            # scalar int32, 1-based after first update
    moments: Any           # pytree matching params, of per-tensor states


# --------------------------------------------------------------------------
# Path-based param-group labeling
# --------------------------------------------------------------------------

def _key_name(k) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def path_str(key_path) -> str:
    """'outer/embed' / 'stacks/blocks/w_qkv' — the string GroupSpec regexes
    match against."""
    return "/".join(_key_name(k) for k in key_path)


@dataclasses.dataclass(frozen=True)
class LeafInfo:
    """What a group predicate gets to see about one parameter leaf."""

    path: str
    shape: tuple
    stacked: bool    # leading dim is a layer-stack axis ("stacks" subtree)

    @property
    def tensor_shape(self) -> tuple:
        """Shape of the per-tensor unit the rule sees (stack dim stripped)."""
        return self.shape[1:] if self.stacked else self.shape

    @property
    def tensor_ndim(self) -> int:
        return len(self.tensor_shape)


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """One param group: match rule + hparam overrides + state masks.

    ``match`` is a regex (``re.search`` on the leaf's path string) or a
    predicate ``f(LeafInfo) -> bool``.  The first matching GroupSpec wins;
    unmatched leaves belong to the default group (base hparams).
    ``hparams`` are static default overrides (validated against the rule's
    accepted set); call-time overrides via ``hparams["groups"][name]`` take
    precedence.  ``factored=False`` forces unfactored second-moment state
    for rules with factored state (a per-group state-layout mask).
    """

    name: str
    match: Union[str, Callable[[LeafInfo], bool]]
    hparams: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    factored: Optional[bool] = None

    def matches(self, info: LeafInfo) -> bool:
        if callable(self.match):
            return bool(self.match(info))
        return re.search(self.match, info.path) is not None


def no_decay_1d(name: str = "no_decay") -> GroupSpec:
    """The table-stakes AdamW grouping: no weight decay on 1-D tensors
    (norm scales, biases) — per-tensor ndim, so a [L, d] stacked norm
    scale counts as 1-D."""
    return GroupSpec(name, match=lambda i: i.tensor_ndim <= 1,
                     hparams={"weight_decay": 0.0})


def _leaf_info(key_path, leaf) -> LeafInfo:
    p = path_str(key_path)
    parts = p.split("/") if p else []
    stacked = (len(parts) >= 1 and parts[0] == STACKS_KEY
               and getattr(leaf, "ndim", 0) >= 1)
    return LeafInfo(path=p, shape=tuple(leaf.shape), stacked=stacked)


def _check_hparam_keys(rule: UpdateRule, d: Mapping, what: str) -> None:
    unknown = sorted(set(d) - set(rule.hparams))
    if unknown:
        raise KeyError(
            f"rule {rule.name!r} does not accept {what} {unknown}; "
            f"accepted hyperparameters: {sorted(rule.hparams)}")


# --------------------------------------------------------------------------
# The optimizer object
# --------------------------------------------------------------------------

class Opt:
    """A per-tensor rule + param groups = a whole-pytree optimizer.

    One instance drives the unfused path (:meth:`step`), the fused
    backward engine (``core/fused.py`` consumes ``rule``/``labels``/
    ``resolve``), and — through the rule's backend dispatch — the Pallas
    kernel, all over the same :class:`OptState` layout.
    """

    def __init__(self, rule: UpdateRule, groups: tuple = ()):
        names = [g.name for g in groups]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate group names: {names}")
        for g in groups:
            _check_hparam_keys(rule, g.hparams, f"group {g.name!r} hparams")
        self.rule = rule
        self.groups = tuple(groups)

    @property
    def name(self) -> str:
        return self.rule.name

    # ---------------- labeling & hparam resolution ----------------
    def _flat_infos(self, params):
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        infos = [_leaf_info(kp, leaf) for kp, leaf in flat]
        labels = []
        for info in infos:
            idx = 0
            for i, g in enumerate(self.groups):
                if g.matches(info):
                    idx = i + 1
                    break
            labels.append(idx)
        return flat, treedef, infos, labels

    def labels(self, params):
        """Pytree of group indices (0 = default, i+1 = groups[i]) matching
        ``params`` — the introspectable label assignment."""
        _, treedef, _, labels = self._flat_infos(params)
        return jax.tree_util.tree_unflatten(treedef, labels)

    def resolve(self, hparams=None) -> tuple:
        """Resolved per-group hparam dicts, indexed by label.

        Merge order (later wins): rule defaults < call-time base <
        GroupSpec static overrides < call-time ``hparams["groups"][name]``.
        Unknown keys raise a KeyError naming the accepted set.
        """
        if hparams is None:
            hparams = {}
        if not isinstance(hparams, Mapping):
            hparams = {"lr": hparams}
        user = dict(hparams)
        group_over = dict(user.pop("groups", None) or {})
        _check_hparam_keys(self.rule, user, "hparams")
        known = {g.name for g in self.groups}
        unknown_groups = sorted(set(group_over) - known)
        if unknown_groups:
            raise KeyError(f"unknown group overrides {unknown_groups}; "
                           f"groups: {sorted(known)}")
        base = {**self.rule.hparams, **user}
        out = [base]
        for g in self.groups:
            over = dict(group_over.get(g.name, {}))
            _check_hparam_keys(self.rule, over,
                               f"group {g.name!r} call-time hparams")
            out.append({**base, **g.hparams, **over})
        return tuple(out)

    def _group_of(self, label: int) -> Optional[GroupSpec]:
        return None if label == 0 else self.groups[label - 1]

    # ---------------- init / step ----------------
    def init(self, params) -> OptState:
        """Per-tensor state for every leaf; ``stacks`` leaves vmapped so
        state[i] == rule.init(param[i]) (factorization and grouped-RMS axes
        see the per-layer shape)."""
        flat, treedef, infos, labels = self._flat_infos(params)
        moments = []
        for (kp, leaf), info, lab in zip(flat, infos, labels):
            g = self._group_of(lab)
            factored = g.factored if g is not None else None
            if info.stacked:
                st = jax.vmap(
                    lambda p: self.rule.init(p, factored=factored))(leaf)
            else:
                st = self.rule.init(leaf, factored=factored)
            moments.append(st)
        return OptState(step=jnp.zeros((), jnp.int32),
                        moments=jax.tree_util.tree_unflatten(treedef,
                                                             moments))

    def step(self, params, grads, state: OptState, hparams=None
             ) -> tuple[Any, OptState]:
        """One unfused optimizer step: θ, s ← rule(θ, g, s, hp) per tensor,
        vmapping over the layer dim of ``stacks`` leaves so the math is
        identical to the fused path."""
        hp = self.resolve(hparams)
        flat, treedef, infos, labels = self._flat_infos(params)
        g_flat = treedef.flatten_up_to(grads)
        s_flat = treedef.flatten_up_to(state.moments)
        new_step = state.step + 1
        stepf = new_step.astype(jnp.float32)
        new_p, new_s = [], []
        for (kp, p), g, s, info, lab in zip(flat, g_flat, s_flat, infos,
                                            labels):
            d = hp[lab]
            if info.stacked:
                p2, s2 = jax.vmap(
                    lambda pi, gi, si: self.rule.update(pi, gi, si, d,
                                                        stepf))(p, g, s)
            else:
                p2, s2 = self.rule.update(p, g, s, d, stepf)
            new_p.append(p2)
            new_s.append(s2)
        return (jax.tree_util.tree_unflatten(treedef, new_p),
                OptState(step=new_step,
                         moments=jax.tree_util.tree_unflatten(treedef,
                                                              new_s)))

    # ---------------- introspection ----------------
    def state_bytes(self, params) -> int:
        """Analytic optimizer-state footprint, honoring group state masks
        (Table-1 accounting)."""
        st = jax.eval_shape(self.init, params)
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(st.moments))

    def describe(self, params) -> dict:
        """Per-group accounting: leaf paths, param counts, hparam defaults."""
        flat, _, infos, labels = self._flat_infos(params)
        hp = self.resolve()
        out = {}
        for lab, name in enumerate(
                ["default"] + [g.name for g in self.groups]):
            leaves = [info for info, l_ in zip(infos, labels) if l_ == lab]
            out[name] = {
                "paths": [i.path for i in leaves],
                "n_params": sum(math.prod(i.shape) for i in leaves),
                "hparams": {k: float(v) for k, v in hp[lab].items()},
            }
        return out

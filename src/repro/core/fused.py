"""Fused backward-and-update engine: LOMO's mechanism, TPU/XLA-native.

The paper's LOMO/AdaLomo fuses the optimizer step into the backward pass so
that no more than ~one layer's gradients are ever resident (O(1) gradient
memory in depth).  PyTorch does this with eager autograd hooks; XLA has no
hooks, so we express the same dataflow *structurally*:

  * models are scan-over-layers with stacked ``[L, ...]`` parameter pytrees;
  * the forward pass is a ``lax.scan`` that saves each layer's *input*
    (residual) — nothing else;
  * the backward pass is a **reverse ``lax.scan``** whose body
      1. re-runs one layer's forward under ``jax.vjp`` (per-layer remat),
      2. obtains that layer's parameter gradients,
      3. applies the optimizer rule to that layer *immediately*,
      4. carries only the activation gradient (and small shared-param
         gradient accumulators) to the next iteration.

  The parameter gradient of layer ℓ is born and dies inside one scan
  iteration — the direct analogue of LOMO's "gradients of only two
  consecutive parameters are live".  With (params, opt_state) donated at the
  jit boundary, XLA updates buffers in place.

Grouped update normalization (paper §3.2) is what makes this a *single*
backward pass: the trust-ratio normalization in the rule needs only the
layer-local tensors, never a global gradient norm.  ``global_grad_norm``
mode below reproduces LOMO's two-pass alternative for the Appendix-B
benchmark.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.api import Opt, OptState, UpdateRule

Array = jax.Array


# --------------------------------------------------------------------------
# Per-tensor rule application across an arbitrary (layer) pytree
# --------------------------------------------------------------------------

def apply_rule_tree(rule: UpdateRule, params, grads, states, labels, hp,
                    step):
    """Apply ``rule`` leaf-wise with per-group hyperparameters.

    ``states`` has one rule-state per param leaf; ``labels`` is an int
    pytree matching ``params`` (group index per leaf, from ``Opt.labels``);
    ``hp`` is the tuple of resolved per-group hparam dicts from
    ``Opt.resolve`` — labels are static, hparam values may be traced.
    """
    treedef = jax.tree.structure(params)
    p_flat = treedef.flatten_up_to(params)
    g_flat = treedef.flatten_up_to(grads)
    s_flat = treedef.flatten_up_to(states)
    l_flat = treedef.flatten_up_to(labels)
    new_p, new_s = [], []
    for p, g, s, lab in zip(p_flat, g_flat, s_flat, l_flat):
        np_, ns_ = rule.update(p, g, s, hp[lab], step)
        new_p.append(np_)
        new_s.append(ns_)
    return treedef.unflatten(new_p), treedef.unflatten(new_s)


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def _tree_zeros_like(t):
    return jax.tree.map(jnp.zeros_like, t)


# --------------------------------------------------------------------------
# Scanned-stack forward/backward with inline updates
# --------------------------------------------------------------------------

class StackResiduals(NamedTuple):
    """What the forward scan saves: one input activation per layer."""

    saved_x: Any          # [L, ...] stacked layer inputs
    x_out: Any            # final activation


def stack_forward(
    body: Callable,
    stacked_params,
    ctx,
    x,
    xs_aux=None,
    *,
    residual_constraint: Optional[Callable[[Any], Any]] = None,
) -> StackResiduals:
    """Forward ``lax.scan`` over a layer stack, saving layer inputs.

    ``body(layer_params, ctx, x, aux) -> x`` is one layer's forward.
    ``ctx`` is a pytree visible to every layer (shared weights, encoder
    output, rope tables...).  ``xs_aux`` optionally supplies per-layer
    non-learned scan inputs (e.g. layer indices).
    ``residual_constraint`` applies a sharding constraint to each saved
    residual (sequence-sharding keeps activation memory on-chip at scale).
    """
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    if xs_aux is None:
        xs_aux = jnp.arange(L, dtype=jnp.int32)

    def fwd(carry_x, xs):
        layer_p, aux = xs
        saved = carry_x
        if residual_constraint is not None:
            saved = residual_constraint(saved)
        y = body(layer_p, ctx, carry_x, aux)
        return y, saved

    x_out, saved_x = jax.lax.scan(fwd, x, (stacked_params, xs_aux))
    return StackResiduals(saved_x=saved_x, x_out=x_out)


def stack_backward_update(
    body: Callable,
    rule: UpdateRule,
    stacked_params,
    stacked_states,
    ctx,
    residuals: StackResiduals,
    dx_out,
    xs_aux=None,
    *,
    labels,
    hp,
    step,
    grad_constraint: Optional[Callable[[Any], Any]] = None,
):
    """Reverse scan: per-layer VJP + immediate optimizer update.

    Returns ``(dx_in, d_ctx, new_stacked_params, new_stacked_states)``.
    ``d_ctx`` is the accumulated gradient w.r.t. ``ctx`` (shared weights /
    cross-attended activations), summed over layers in the scan carry.

    ``grad_constraint`` (perf, §Perf H2): constrains each layer gradient to
    the parameter's sharding *before* the update consumes it.  Under pjit
    this turns the full-tensor fp32 all-reduce of dW (the ZeRO-2 sin) into
    a bf16 reduce-scatter; the factored-moment row/col sums then reduce the
    scattered shard with only O(m+n) cross-shard traffic.
    """
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    if xs_aux is None:
        xs_aux = jnp.arange(L, dtype=jnp.int32)

    # fp32 accumulators for ctx grads (shared params are few; activations
    # accumulate in their own dtype to bound memory).
    d_ctx0 = _tree_zeros_like(ctx)

    def bwd(carry, xs):
        dx, d_ctx = carry
        layer_p, layer_s, x_in, aux = xs
        # Per-layer remat: re-run the layer forward under vjp.
        _, vjp = jax.vjp(lambda p, c, xi: body(p, c, xi, aux),
                         layer_p, ctx, x_in)
        g_layer, g_ctx, dx_in = vjp(dx)
        if grad_constraint is not None:
            g_layer = grad_constraint(g_layer)
        # >>> the LOMO moment: this layer's grads are consumed *here* <<<
        new_p, new_s = apply_rule_tree(rule, layer_p, g_layer, layer_s,
                                       labels, hp, step)
        return (dx_in, _tree_add(d_ctx, g_ctx)), (new_p, new_s)

    (dx_in, d_ctx), (new_params, new_states) = jax.lax.scan(
        bwd, (dx_out, d_ctx0),
        (stacked_params, stacked_states, residuals.saved_x, xs_aux),
        reverse=True)
    return dx_in, d_ctx, new_params, new_states


def stack_grads(
    body: Callable,
    stacked_params,
    ctx,
    residuals: StackResiduals,
    dx_out,
    xs_aux=None,
):
    """Backward scan that only *collects* grads (no update) — used by the
    two-pass global-grad-norm mode and by fused-vs-unfused equivalence tests."""
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    if xs_aux is None:
        xs_aux = jnp.arange(L, dtype=jnp.int32)
    d_ctx0 = _tree_zeros_like(ctx)

    def bwd(carry, xs):
        dx, d_ctx = carry
        layer_p, x_in, aux = xs
        _, vjp = jax.vjp(lambda p, c, xi: body(p, c, xi, aux),
                         layer_p, ctx, x_in)
        g_layer, g_ctx, dx_in = vjp(dx)
        return (dx_in, _tree_add(d_ctx, g_ctx)), g_layer

    (dx_in, d_ctx), g_stack = jax.lax.scan(
        bwd, (dx_out, d_ctx0),
        (stacked_params, residuals.saved_x, xs_aux), reverse=True)
    return dx_in, d_ctx, g_stack


# --------------------------------------------------------------------------
# Whole-model fused train step for the standard decoder-LM layout.
# Models with extra streams (enc-dec, hybrid) wire the helpers themselves.
# --------------------------------------------------------------------------

class FusedSpec(NamedTuple):
    """Scan structure of a model, as consumed by :func:`fused_train_step`.

    params layout: ``{"outer": pytree, "shared": pytree, "stacks": {name: [L,...]}}``
      * ``outer``  — prologue/epilogue parameters (embeddings, final norm, head)
      * ``shared`` — parameters used by *every* layer (zamba2's shared block);
        grads accumulate across layers, updated once per step
      * ``stacks`` — ordered stacked layer pytrees

    functions:
      * ``prologue(outer, batch) -> x0``
      * ``bodies[name](layer_params, ctx, x, aux) -> x`` with
        ``ctx = (shared, pro_ctx)`` where ``pro_ctx`` is any activation
        context the prologue wants visible to all layers (rope tables, masks)
      * ``epilogue(outer, x, batch) -> (loss, metrics)``
      * ``pro_ctx(outer, batch) -> pytree`` (non-learned context; default ())
    """

    prologue: Callable
    bodies: dict
    epilogue: Callable
    pro_ctx: Callable = lambda outer, batch: ()


def fused_train_step(
    spec: FusedSpec,
    opt: Opt,
    params,
    opt_state: OptState,
    batch,
    *,
    hparams=None,
    residual_constraint=None,
    global_grad_norm: Optional[float] = None,
    grad_constraint=None,
):
    """One fused LOMO/AdaLomo training step.

    ``opt_state`` is the v2 :class:`OptState` from ``opt.init(params)`` —
    the same single layout as the unfused ``Opt.step`` path.  ``hparams``
    is the call-time hyperparameter pytree (``Opt.resolve`` semantics:
    dict of scalars, optional per-group overrides, bare scalar = lr);
    its values may be traced, so lr/β/decay schedules never recompile.
    Returns ``(new_params, new_opt_state, loss, metrics)``.

    When ``global_grad_norm`` is set, runs LOMO's two-pass variant: pass 1
    computes the global gradient norm (grads discarded layer-by-layer), pass 2
    re-runs backward applying the clipped update — reproducing the paper's
    §2.1 "two backward passes" cost for the Appendix-B comparison.
    """
    rule = opt.rule
    hp = opt.resolve(hparams)
    labels = opt.labels(params)
    step = opt_state.step + 1
    stepf = step.astype(jnp.float32)
    moments = opt_state.moments
    outer, shared, stacks = params["outer"], params["shared"], params["stacks"]

    # ---- forward ----
    x0, pro_vjp = jax.vjp(lambda o: spec.prologue(o, batch), outer)
    ctx_act = spec.pro_ctx(outer, batch)
    residuals: dict[str, StackResiduals] = {}
    x = x0
    for name, stacked in stacks.items():
        res = stack_forward(spec.bodies[name], stacked, (shared, ctx_act), x,
                            residual_constraint=residual_constraint)
        residuals[name] = res
        x = res.x_out
    loss, epi_vjp, metrics = jax.vjp(
        lambda o, xx: spec.epilogue(o, xx, batch), outer, x, has_aux=True)

    # ---- backward + inline update ----
    g_outer_epi, dx = epi_vjp(jnp.ones_like(loss))

    def _sqsum(tree):
        leaves = jax.tree.leaves(tree)
        if not leaves:
            return jnp.float32(0.0)
        return sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)

    if global_grad_norm is not None:
        # LOMO's two-pass mode (paper §2.1): pass 1 walks the entire backward
        # graph just to obtain the global grad norm; grads of each layer are
        # discarded as soon as their squared sum is accumulated.
        sq = jnp.float32(0.0)
        dxn = dx
        d_shared_n = _tree_zeros_like(shared)
        for name in reversed(list(stacks.keys())):
            dxn, (d_sh, _), g_stack = stack_grads(
                spec.bodies[name], stacks[name], (shared, ctx_act),
                residuals[name], dxn)
            d_shared_n = _tree_add(d_shared_n, d_sh)
            sq = sq + _sqsum(g_stack)
        (g_outer_pro_n,) = pro_vjp(dxn)
        sq = sq + _sqsum(_tree_add(g_outer_epi, g_outer_pro_n))
        sq = sq + _sqsum(d_shared_n)
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, global_grad_norm / (gnorm + 1e-6))
        # Fold the clip into every group's lr — hparams stay data.
        hp = tuple({**d, "lr": d["lr"] * scale} for d in hp)

    new_stacks, new_stack_m = {}, {}
    d_shared = _tree_zeros_like(shared)
    for name in reversed(list(stacks.keys())):
        gc = grad_constraint(name) if grad_constraint is not None else None
        dx, (d_sh, _), new_p, new_s = stack_backward_update(
            spec.bodies[name], rule, stacks[name], moments["stacks"][name],
            (shared, ctx_act), residuals[name], dx,
            labels=labels["stacks"][name], hp=hp, step=stepf,
            grad_constraint=gc)
        new_stacks[name] = new_p
        new_stack_m[name] = new_s
        d_shared = _tree_add(d_shared, d_sh)

    (g_outer_pro,) = pro_vjp(dx)
    g_outer = _tree_add(g_outer_epi, g_outer_pro)
    new_outer, new_outer_m = apply_rule_tree(
        rule, outer, g_outer, moments["outer"], labels["outer"], hp, stepf)
    new_shared, new_shared_m = apply_rule_tree(
        rule, shared, d_shared, moments["shared"], labels["shared"], hp,
        stepf)

    new_params = {"outer": new_outer, "shared": new_shared,
                  "stacks": new_stacks}
    new_opt = OptState(
        step=step,
        moments={"outer": new_outer_m, "shared": new_shared_m,
                 "stacks": new_stack_m})
    return new_params, new_opt, loss, metrics


def unfused_loss_fn(spec: FusedSpec, params, batch):
    """The same model as one differentiable function — for jax.grad-based
    baselines (AdamW/Adafactor) and fused-vs-unfused equivalence tests."""
    outer, shared, stacks = params["outer"], params["shared"], params["stacks"]
    x = spec.prologue(outer, batch)
    ctx_act = spec.pro_ctx(outer, batch)
    for name, stacked in stacks.items():
        body = spec.bodies[name]

        def fwd(carry_x, xs):
            layer_p, aux = xs
            return body(layer_p, (shared, ctx_act), carry_x, aux), None

        L = jax.tree.leaves(stacked)[0].shape[0]
        x, _ = jax.lax.scan(fwd, x, (stacked, jnp.arange(L, dtype=jnp.int32)))
    loss, metrics = spec.epilogue(outer, x, batch)
    return loss, metrics

"""AdaLomo: low-memory optimization with adaptive learning rate.

Implements the paper's Algorithm 1 as pure per-tensor functions so the same
math is usable from three call-sites:

  * the fused-backward engine (``core/fused.py``) — applied per layer slice
    inside the reverse scan (the paper's LOMO-style fused update);
  * the tree-level optax-like API (``core/api.py``) — the unfused baseline;
  * the Pallas kernel (``kernels/adalomo_update``) — whose ``ref.py`` oracle
    is literally :func:`compute_update` below.

State per m×n parameter is the non-negative-matrix-factorized second moment
(r ∈ R^m, c ∈ R^n), per paper Eq. (5)-(7):

    r_t = β r_{t-1} + (1-β) rowsum(g²)
    c_t = β c_{t-1} + (1-β) colsum(g²)
    v_t = outer(r_t, c_t) / sum(r_t)

followed by the grouped update normalization of Alg. 1 line 11:

    u  = g / (sqrt(v̂) + ε)           # see DESIGN.md on the line-10 typo
    û  = u / max(1, RMS(u)/d) * max(ε₂, RMS(θ))
    θ ← θ - α û

1-D parameters (norm scales, biases) keep the unfactored v (already O(m)).
Leading dimensions beyond the trailing matrix dims (stacked layers ``[L,m,n]``,
experts ``[E,m,n]``) are treated as independent parameter groups: statistics
and RMS reductions are over the trailing matrix dims only, so behaviour is
identical whether a layer stack is updated as one array or slice-by-slice.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdaLomoConfig:
    """*Structural* configuration of AdaLomo (paper §3.1 / Alg. 1).

    Only knobs that change state layout, numerics structure, or algorithm
    shape live here.  The *dynamic* hyperparameters — lr, β, weight decay,
    clip threshold d — are call-time arguments (see DEFAULT_HPARAMS and the
    Opt v2 contract in ``core/api.py``), so they can be scheduled per step
    and overridden per param group with zero recompiles.
    """

    eps_div: float = 1e-8          # ε added to sqrt(v̂) in the division
    eps_stat: float = 1e-30        # tiny floor inside the statistics
    eps_rms: float = 1e-3          # ε₂: floor of the parameter-scale term
    min_dim_size_to_factor: int = 16
    factored: bool = True
    bias_correction: bool = True
    # Faithfulness switch: Alg.1 line 10 literally reads u = g / v (no sqrt).
    # Dimensionally inconsistent with Eq.(2)/(4); off by default (DESIGN.md).
    literal_div_v: bool = False
    # dtype for the factored statistics; fp32 regardless of param dtype.
    state_dtype: Any = jnp.float32


# Dynamic hyperparameters (Opt v2): accepted keys and paper defaults.
#   beta — single decay coefficient β for r and c (paper Eq. 6/7)
#   clip — d in  max(1, RMS(u)/d)  (Alg. 1 line 11)
#   weight_decay — decoupled, paper default: none
DEFAULT_HPARAMS = {"lr": 1e-3, "beta": 0.999, "weight_decay": 0.0,
                   "clip": 1.0}


class FactoredState(NamedTuple):
    """Second-moment state for one tensor: (r, c) if factored else v."""

    r: Optional[Array]
    c: Optional[Array]
    v: Optional[Array]


def _should_factor(shape: tuple[int, ...], cfg: AdaLomoConfig) -> bool:
    if not cfg.factored or len(shape) < 2:
        return False
    m, n = shape[-2], shape[-1]
    return min(m, n) >= cfg.min_dim_size_to_factor


def init_state(param: Array, cfg: AdaLomoConfig) -> FactoredState:
    """O(m+n) state for an m×n tensor; O(m) unfactored state otherwise."""
    shape = tuple(param.shape)
    dt = cfg.state_dtype
    if _should_factor(shape, cfg):
        r = jnp.zeros(shape[:-1], dtype=dt)            # (..., m)
        c = jnp.zeros(shape[:-2] + shape[-1:], dtype=dt)  # (..., n)
        return FactoredState(r=r, c=c, v=None)
    return FactoredState(r=None, c=None, v=jnp.zeros(shape, dtype=dt))


def state_bytes(param: Array, cfg: AdaLomoConfig) -> int:
    """Analytic optimizer-state footprint (for the Table-1 benchmark)."""
    st = jax.eval_shape(lambda p: init_state(p, cfg), param)
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(st))


def _matrix_axes(ndim: int) -> tuple[int, ...]:
    """Axes forming 'the parameter matrix' — trailing two (or one if 1-D)."""
    return (-1,) if ndim < 2 else (-2, -1)


def _rms(x: Array, axes: tuple[int, ...]) -> Array:
    return jnp.sqrt(jnp.mean(jnp.square(x), axis=axes, keepdims=True))


def update_moment(
    grad: Array, state: FactoredState, *, beta, cfg: AdaLomoConfig
) -> FactoredState:
    """EMA update of the (possibly factored) second moment. Paper Eq.(6)(7)."""
    g2 = jnp.square(grad.astype(cfg.state_dtype)) + cfg.eps_stat
    b = beta
    if state.v is not None:
        return FactoredState(r=None, c=None, v=b * state.v + (1.0 - b) * g2)
    r = b * state.r + (1.0 - b) * jnp.sum(g2, axis=-1)
    c = b * state.c + (1.0 - b) * jnp.sum(g2, axis=-2)
    return FactoredState(r=r, c=c, v=None)


def reconstruct_v(state: FactoredState, cfg: AdaLomoConfig) -> Array:
    """v = outer(r, c) / sum(r) — rank-1 NMF reconstruction, paper Eq.(5)."""
    if state.v is not None:
        return state.v
    denom = jnp.sum(state.r, axis=-1, keepdims=True)  # (..., 1)
    # (..., m, 1) * (..., 1, n) / (..., 1, 1)
    return (state.r[..., :, None] * state.c[..., None, :]) / jnp.maximum(
        denom[..., None], cfg.eps_stat
    )


def compute_update(
    param: Array,
    grad: Array,
    state: FactoredState,
    *,
    step: Array,
    beta=DEFAULT_HPARAMS["beta"],
    clip=DEFAULT_HPARAMS["clip"],
    cfg: AdaLomoConfig,
) -> tuple[Array, FactoredState]:
    """Return (û, new_state): the grouped-normalized update of Alg. 1.

    ``step`` is the 1-based global step (scalar, for bias correction).
    ``beta``/``clip`` may be traced scalars (scheduled per call).
    û is in fp32; the caller applies ``θ ← θ - lr·û`` (and weight decay).
    """
    new_state = update_moment(grad, state, beta=beta, cfg=cfg)
    v = reconstruct_v(new_state, cfg)
    if cfg.bias_correction:
        correction = 1.0 - jnp.asarray(beta, cfg.state_dtype) \
            ** step.astype(cfg.state_dtype)
        v_hat = v / jnp.maximum(correction, cfg.eps_stat)
    else:
        v_hat = v
    g32 = grad.astype(cfg.state_dtype)
    if cfg.literal_div_v:  # Alg.1 line 10 verbatim (see DESIGN.md)
        u = g32 / (v_hat + cfg.eps_div)
    else:
        u = g32 / (jnp.sqrt(v_hat) + cfg.eps_div)
    axes = _matrix_axes(u.ndim)
    # Grouped update normalization (Alg.1 line 11): per-matrix trust ratio.
    rms_u = _rms(u, axes)
    u = u / jnp.maximum(1.0, rms_u / clip)
    p32 = param.astype(cfg.state_dtype)
    scale = jnp.maximum(cfg.eps_rms, _rms(p32, axes))
    u = u * scale
    return u, new_state


def update_tensor(
    param: Array,
    grad: Array,
    state: FactoredState,
    *,
    lr: Array,
    step: Array,
    beta=DEFAULT_HPARAMS["beta"],
    weight_decay=DEFAULT_HPARAMS["weight_decay"],
    clip=DEFAULT_HPARAMS["clip"],
    cfg: AdaLomoConfig,
) -> tuple[Array, FactoredState]:
    """One AdaLomo step for a single tensor: θ ← θ - α·û (Alg.1 line 12).

    Decoupled weight decay pre-scales θ, but the RMS(θ) trust scale inside
    ``compute_update`` is taken from the *un-decayed* θ (the Pallas kernel
    matches this — see tests/kernels parity with weight_decay > 0).
    Applied unconditionally: with weight_decay == 0 the factor is exactly
    1.0, so the no-decay path is bitwise unchanged.
    """
    u, new_state = compute_update(param, grad, state, step=step, beta=beta,
                                  clip=clip, cfg=cfg)
    p32 = param.astype(cfg.state_dtype)
    p32 = p32 * (1.0 - lr * weight_decay)
    new_param = (p32 - lr * u).astype(param.dtype)
    return new_param, new_state

"""Optimizer rules (Opt v2): AdaLomo + the baselines the paper compares to.

Every optimizer is an :class:`repro.core.api.UpdateRule`:

    rule.init(param, factored=None)          -> state
    rule.update(param, grad, state, hp, step) -> (new_param, new_state)

where ``hp`` is a resolved dict of *dynamic* hyperparameters (each rule
declares its accepted set + defaults in ``rule.hparams``) and ``step`` is
the 1-based global step as float32.  Wrap a rule in
:class:`repro.core.api.Opt` for whole-pytree init/step with param-group
labeling; the same rule runs (i) unfused via ``Opt.step``, (ii) fused into
the backward scan (``core/fused.py``), and — for AdaLomo — (iii) on the
Pallas TPU kernel via ``backend="pallas"``.  LOMO is literally ``sgd()``
under the fused engine; the paper's §2.2 ablations are ``sgd_momentum()``
(Eq. 3) and ``sgd_variance()`` (Eq. 4).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import adalomo as _adalomo
from repro.core.api import (GroupSpec, Opt, UpdateRule, make_rule,
                            no_decay_1d)

__all__ = ["adalomo", "sgd", "sgd_momentum", "sgd_variance", "adamw",
           "adafactor", "REGISTRY", "get_rule", "get_opt", "Opt",
           "GroupSpec", "UpdateRule", "no_decay_1d"]

Array = jax.Array


# --------------------------------------------------------------------------
# AdaLomo — one rule, two backends (pure jnp / Pallas kernel)
# --------------------------------------------------------------------------

_BACKENDS = ("auto", "jnp", "pallas")


def _resolve_backend(backend: str) -> str:
    if backend not in _BACKENDS:
        raise ValueError(f"backend {backend!r} not in {_BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


def adalomo(cfg: Optional[_adalomo.AdaLomoConfig] = None, *,
            backend: str = "auto", interpret: bool = False,
            block: Optional[tuple] = None,
            lr: float = _adalomo.DEFAULT_HPARAMS["lr"],
            beta: float = _adalomo.DEFAULT_HPARAMS["beta"],
            weight_decay: float = _adalomo.DEFAULT_HPARAMS["weight_decay"],
            clip: float = _adalomo.DEFAULT_HPARAMS["clip"]) -> UpdateRule:
    """AdaLomo (paper Alg. 1) with backend dispatch.

    ``backend="pallas"`` routes factored ≥2-D tensors through the fused
    Pallas kernel (``kernels/adalomo_update``); 1-D/unfactored tensors and
    ``backend="jnp"`` use the pure-jnp path — same math, same state.
    ``"auto"`` picks pallas on TPU, jnp elsewhere.  ``interpret=True``
    runs the kernel in interpreter mode (CPU validation).
    ``lr``/``beta``/``weight_decay``/``clip`` set the rule's *default*
    dynamic hparams; call-time values override them without recompiling.
    """
    cfg = cfg or _adalomo.AdaLomoConfig()
    use_pallas = _resolve_backend(backend) == "pallas"
    if use_pallas:
        from repro.kernels.adalomo_update.ops import adalomo_update
        from repro.kernels.adalomo_update.adalomo_update import DEFAULT_BLOCK
        kblock = tuple(block) if block is not None else DEFAULT_BLOCK

    def init_fn(param, *, factored=None):
        c = cfg if factored is None else dataclasses.replace(
            cfg, factored=factored)
        return _adalomo.init_state(param, c)

    def update_fn(param, grad, state, hp, step):
        if use_pallas and state.v is None and param.ndim >= 2:
            new_p, nr, nc = adalomo_update(
                param, grad, state.r, state.c, hp["lr"], step, hp["beta"],
                hp["weight_decay"], hp["clip"], cfg=cfg, block=kblock,
                interpret=interpret)
            return new_p, _adalomo.FactoredState(r=nr, c=nc, v=None)
        return _adalomo.update_tensor(
            param, grad, state, lr=hp["lr"], step=step, beta=hp["beta"],
            weight_decay=hp["weight_decay"], clip=hp["clip"], cfg=cfg)

    return make_rule("adalomo", init_fn, update_fn,
                     hparams=dict(lr=lr, beta=beta,
                                  weight_decay=weight_decay, clip=clip))


# --------------------------------------------------------------------------
# SGD family (paper Eq. 1, 3, 4) — LOMO is fused sgd()
# --------------------------------------------------------------------------

def sgd(*, lr: float = 1e-3) -> UpdateRule:
    """Plain SGD — the LOMO update rule (paper Eq. 1)."""

    def init_fn(param, *, factored=None):
        del factored
        return ()

    def update_fn(param, grad, state, hp, step):
        del step
        p32 = param.astype(jnp.float32)
        new_param = (p32 - hp["lr"] * grad.astype(jnp.float32)).astype(
            param.dtype)
        return new_param, state

    return make_rule("sgd", init_fn, update_fn, hparams=dict(lr=lr))


class MomentumState(NamedTuple):
    m: Array


def sgd_momentum(*, lr: float = 1e-3, beta1: float = 0.9,
                 bias_correction: bool = True) -> UpdateRule:
    """First-moment-only ablation (paper Eq. 3)."""

    def init_fn(param, *, factored=None):
        del factored
        return MomentumState(m=jnp.zeros(param.shape, jnp.float32))

    def update_fn(param, grad, state, hp, step):
        b1 = hp["beta1"]
        g32 = grad.astype(jnp.float32)
        m = b1 * state.m + (1.0 - b1) * g32
        m_hat = m / (1.0 - b1 ** step) if bias_correction else m
        p32 = param.astype(jnp.float32)
        return ((p32 - hp["lr"] * m_hat).astype(param.dtype),
                MomentumState(m=m))

    return make_rule("sgd_momentum", init_fn, update_fn,
                     hparams=dict(lr=lr, beta1=beta1))


class VarianceState(NamedTuple):
    v: Array


def sgd_variance(*, lr: float = 1e-3, beta2: float = 0.999,
                 eps: float = 1e-8,
                 bias_correction: bool = True) -> UpdateRule:
    """Second-moment-only ablation (paper Eq. 4) — the 'SGD with variance'
    curve in Fig. 1/6 that motivates AdaLomo."""

    def init_fn(param, *, factored=None):
        del factored
        return VarianceState(v=jnp.zeros(param.shape, jnp.float32))

    def update_fn(param, grad, state, hp, step):
        b2 = hp["beta2"]
        g32 = grad.astype(jnp.float32)
        v = b2 * state.v + (1.0 - b2) * jnp.square(g32)
        v_hat = v / (1.0 - b2 ** step) if bias_correction else v
        p32 = param.astype(jnp.float32)
        upd = g32 / (jnp.sqrt(v_hat) + hp["eps"])
        return ((p32 - hp["lr"] * upd).astype(param.dtype),
                VarianceState(v=v))

    return make_rule("sgd_variance", init_fn, update_fn,
                     hparams=dict(lr=lr, beta2=beta2, eps=eps))


# --------------------------------------------------------------------------
# AdamW (paper Eq. 2 + decoupled weight decay) — the de-facto baseline
# --------------------------------------------------------------------------

class AdamState(NamedTuple):
    m: Array
    v: Array


def adamw(*, lr: float = 1e-3, beta1: float = 0.9, beta2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> UpdateRule:
    def init_fn(param, *, factored=None):
        del factored
        return AdamState(m=jnp.zeros(param.shape, jnp.float32),
                         v=jnp.zeros(param.shape, jnp.float32))

    def update_fn(param, grad, state, hp, step):
        b1, b2 = hp["beta1"], hp["beta2"]
        g32 = grad.astype(jnp.float32)
        m = b1 * state.m + (1.0 - b1) * g32
        v = b2 * state.v + (1.0 - b2) * jnp.square(g32)
        m_hat = m / (1.0 - b1 ** step)
        v_hat = v / (1.0 - b2 ** step)
        p32 = param.astype(jnp.float32)
        p32 = p32 * (1.0 - hp["lr"] * hp["weight_decay"])
        upd = m_hat / (jnp.sqrt(v_hat) + hp["eps"])
        return ((p32 - hp["lr"] * upd).astype(param.dtype),
                AdamState(m=m, v=v))

    return make_rule("adamw", init_fn, update_fn,
                     hparams=dict(lr=lr, beta1=beta1, beta2=beta2, eps=eps,
                                  weight_decay=weight_decay))


# --------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018) — the factored-moment baseline.
# AdaLomo's Table-1 claim: same-quality factored state, but grads are O(1)
# because the update happens inside the backward pass.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    """Structural config; decay_rate/clip/weight_decay are dynamic hparams."""

    eps_stat: float = 1e-30
    eps_rms: float = 1e-3
    min_dim_size_to_factor: int = 16
    factored: bool = True
    relative_step_scale: bool = True  # multiply update by max(eps2, RMS(θ))


def adafactor(cfg: Optional[AdafactorConfig] = None, *, lr: float = 1e-3,
              decay_rate: float = 0.8, clip: float = 1.0,
              weight_decay: float = 0.0) -> UpdateRule:
    cfg = cfg or AdafactorConfig()
    # Reuse AdaLomo's factored-state container/init with matching thresholds.
    al_cfg = _adalomo.AdaLomoConfig(
        min_dim_size_to_factor=cfg.min_dim_size_to_factor,
        factored=cfg.factored, eps_stat=cfg.eps_stat)

    def init_fn(param, *, factored=None):
        c = al_cfg if factored is None else dataclasses.replace(
            al_cfg, factored=factored)
        return _adalomo.init_state(param, c)

    def update_fn(param, grad, state, hp, step):
        g32 = grad.astype(jnp.float32)
        g2 = jnp.square(g32) + cfg.eps_stat
        beta2t = 1.0 - step ** (-hp["decay_rate"])
        if state.v is not None:
            v = beta2t * state.v + (1.0 - beta2t) * g2
            new_state = _adalomo.FactoredState(r=None, c=None, v=v)
        else:
            r = beta2t * state.r + (1.0 - beta2t) * jnp.mean(g2, axis=-1)
            c = beta2t * state.c + (1.0 - beta2t) * jnp.mean(g2, axis=-2)
            new_state = _adalomo.FactoredState(r=r, c=c, v=None)
        v_hat = _adalomo.reconstruct_v(new_state, al_cfg)
        u = g32 * jax.lax.rsqrt(v_hat + cfg.eps_stat)
        axes = _adalomo._matrix_axes(u.ndim)
        rms_u = _adalomo._rms(u, axes)
        u = u / jnp.maximum(1.0, rms_u / hp["clip"])
        if cfg.relative_step_scale:
            u = u * jnp.maximum(cfg.eps_rms,
                                _adalomo._rms(param.astype(jnp.float32),
                                              axes))
        p32 = param.astype(jnp.float32)
        p32 = p32 * (1.0 - hp["lr"] * hp["weight_decay"])
        return (p32 - hp["lr"] * u).astype(param.dtype), new_state

    return make_rule("adafactor", init_fn, update_fn,
                     hparams=dict(lr=lr, decay_rate=decay_rate, clip=clip,
                                  weight_decay=weight_decay))


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

REGISTRY: dict[str, Callable[..., UpdateRule]] = {
    "adalomo": adalomo,
    "lomo": sgd,       # LOMO == fused SGD
    "sgd": sgd,
    "sgd_momentum": sgd_momentum,
    "sgd_variance": sgd_variance,
    "adamw": adamw,
    "adafactor": adafactor,
}


def _accepted_kwargs(factory) -> set[str]:
    sig = inspect.signature(factory)
    return {p.name for p in sig.parameters.values()
            if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)}


def get_rule(name: str, **kwargs) -> UpdateRule:
    """Build a rule by registry name; unknown kwargs raise a KeyError
    naming the kwargs this rule accepts (not a bare TypeError)."""
    if name not in REGISTRY:
        raise KeyError(f"unknown optimizer {name!r}; have {sorted(REGISTRY)}")
    factory = REGISTRY[name]
    accepted = _accepted_kwargs(factory)
    unknown = sorted(set(kwargs) - accepted)
    if unknown:
        raise KeyError(
            f"optimizer {name!r} does not accept {unknown}; accepted "
            f"kwargs: {sorted(accepted)} (dynamic hyperparameters can also "
            f"be passed per step via the hparams argument)")
    return factory(**kwargs)


def get_opt(name: str, *, groups: tuple = (), **kwargs) -> Opt:
    """``Opt(get_rule(name, **kwargs), groups)`` — the one-stop constructor."""
    return Opt(get_rule(name, **kwargs), groups=groups)

"""Baseline optimizers the paper compares against, as per-tensor rules.

Every optimizer here (and AdaLomo in ``adalomo.py``) is exposed through the
same ``TensorRule`` interface:

    rule.init(param)                          -> state
    rule.update(param, grad, state, lr, step) -> (new_param, new_state)

so that any rule can run (i) unfused via the tree-level API or (ii) fused
into the backward scan (``core/fused.py``).  LOMO is literally
``sgd()`` under the fused engine; the paper's §2.2 ablations are
``sgd_momentum()`` (Eq. 3) and ``sgd_variance()`` (Eq. 4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import adalomo as _adalomo

Array = jax.Array


class TensorRule(NamedTuple):
    """A per-tensor optimizer: pure init and update functions."""

    name: str
    init: Callable[[Array], Any]
    update: Callable[..., tuple[Array, Any]]  # (p, g, s, *, lr, step)
    # Analytic per-tensor optimizer-state bytes (Table-1 benchmark).
    state_bytes: Callable[[Array], int]


def _bytes_of(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _rule_from_fns(name, init_fn, update_fn) -> TensorRule:
    def state_bytes(param: Array) -> int:
        st = jax.eval_shape(init_fn, param)
        return _bytes_of(st)

    return TensorRule(name=name, init=init_fn, update=update_fn,
                      state_bytes=state_bytes)


# --------------------------------------------------------------------------
# AdaLomo (re-exported as a rule)
# --------------------------------------------------------------------------

def adalomo(cfg: Optional[_adalomo.AdaLomoConfig] = None) -> TensorRule:
    cfg = cfg or _adalomo.AdaLomoConfig()

    def init_fn(param):
        return _adalomo.init_state(param, cfg)

    def update_fn(param, grad, state, *, lr, step):
        return _adalomo.update_tensor(param, grad, state, lr=lr, step=step,
                                      cfg=cfg)

    return _rule_from_fns("adalomo", init_fn, update_fn)


# --------------------------------------------------------------------------
# SGD family (paper Eq. 1, 3, 4) — LOMO is fused sgd()
# --------------------------------------------------------------------------

def sgd() -> TensorRule:
    """Plain SGD — the LOMO update rule (paper Eq. 1)."""

    def init_fn(param):
        return ()

    def update_fn(param, grad, state, *, lr, step):
        del step
        p32 = param.astype(jnp.float32)
        new_param = (p32 - lr * grad.astype(jnp.float32)).astype(param.dtype)
        return new_param, state

    return _rule_from_fns("sgd", init_fn, update_fn)


class MomentumState(NamedTuple):
    m: Array


def sgd_momentum(beta1: float = 0.9, bias_correction: bool = True
                 ) -> TensorRule:
    """First-moment-only ablation (paper Eq. 3)."""

    def init_fn(param):
        return MomentumState(m=jnp.zeros(param.shape, jnp.float32))

    def update_fn(param, grad, state, *, lr, step):
        g32 = grad.astype(jnp.float32)
        m = beta1 * state.m + (1.0 - beta1) * g32
        m_hat = m / (1.0 - beta1 ** step) if bias_correction else m
        p32 = param.astype(jnp.float32)
        return (p32 - lr * m_hat).astype(param.dtype), MomentumState(m=m)

    return _rule_from_fns("sgd_momentum", init_fn, update_fn)


class VarianceState(NamedTuple):
    v: Array


def sgd_variance(beta2: float = 0.999, eps: float = 1e-8,
                 bias_correction: bool = True) -> TensorRule:
    """Second-moment-only ablation (paper Eq. 4) — the 'SGD with variance'
    curve in Fig. 1/6 that motivates AdaLomo."""

    def init_fn(param):
        return VarianceState(v=jnp.zeros(param.shape, jnp.float32))

    def update_fn(param, grad, state, *, lr, step):
        g32 = grad.astype(jnp.float32)
        v = beta2 * state.v + (1.0 - beta2) * jnp.square(g32)
        v_hat = v / (1.0 - beta2 ** step) if bias_correction else v
        p32 = param.astype(jnp.float32)
        upd = g32 / (jnp.sqrt(v_hat) + eps)
        return (p32 - lr * upd).astype(param.dtype), VarianceState(v=v)

    return _rule_from_fns("sgd_variance", init_fn, update_fn)


# --------------------------------------------------------------------------
# AdamW (paper Eq. 2 + decoupled weight decay) — the de-facto baseline
# --------------------------------------------------------------------------

class AdamState(NamedTuple):
    m: Array
    v: Array


def adamw(beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> TensorRule:
    def init_fn(param):
        return AdamState(m=jnp.zeros(param.shape, jnp.float32),
                         v=jnp.zeros(param.shape, jnp.float32))

    def update_fn(param, grad, state, *, lr, step):
        g32 = grad.astype(jnp.float32)
        m = beta1 * state.m + (1.0 - beta1) * g32
        v = beta2 * state.v + (1.0 - beta2) * jnp.square(g32)
        m_hat = m / (1.0 - beta1 ** step)
        v_hat = v / (1.0 - beta2 ** step)
        p32 = param.astype(jnp.float32)
        if weight_decay:
            p32 = p32 * (1.0 - lr * weight_decay)
        upd = m_hat / (jnp.sqrt(v_hat) + eps)
        return (p32 - lr * upd).astype(param.dtype), AdamState(m=m, v=v)

    return _rule_from_fns("adamw", init_fn, update_fn)


# --------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018) — the factored-moment baseline.
# AdaLomo's Table-1 claim: same-quality factored state, but grads are O(1)
# because the update happens inside the backward pass.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    decay_rate: float = 0.8        # β2_t = 1 - t^{-decay_rate}
    eps_stat: float = 1e-30
    eps_rms: float = 1e-3
    clip_threshold: float = 1.0
    min_dim_size_to_factor: int = 16
    factored: bool = True
    relative_step_scale: bool = True  # multiply update by max(eps2, RMS(θ))


def adafactor(cfg: Optional[AdafactorConfig] = None) -> TensorRule:
    cfg = cfg or AdafactorConfig()
    # Reuse AdaLomo's factored-state container/init with matching thresholds.
    al_cfg = _adalomo.AdaLomoConfig(
        min_dim_size_to_factor=cfg.min_dim_size_to_factor,
        factored=cfg.factored, eps_stat=cfg.eps_stat)

    def init_fn(param):
        return _adalomo.init_state(param, al_cfg)

    def update_fn(param, grad, state, *, lr, step):
        g32 = grad.astype(jnp.float32)
        g2 = jnp.square(g32) + cfg.eps_stat
        beta2t = 1.0 - step.astype(jnp.float32) ** (-cfg.decay_rate)
        if state.v is not None:
            v = beta2t * state.v + (1.0 - beta2t) * g2
            new_state = _adalomo.FactoredState(r=None, c=None, v=v)
        else:
            r = beta2t * state.r + (1.0 - beta2t) * jnp.mean(g2, axis=-1)
            c = beta2t * state.c + (1.0 - beta2t) * jnp.mean(g2, axis=-2)
            new_state = _adalomo.FactoredState(r=r, c=c, v=None)
        v_hat = _adalomo.reconstruct_v(new_state, al_cfg)
        u = g32 * jax.lax.rsqrt(v_hat + cfg.eps_stat)
        axes = _adalomo._matrix_axes(u.ndim)
        rms_u = _adalomo._rms(u, axes)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        if cfg.relative_step_scale:
            u = u * jnp.maximum(cfg.eps_rms,
                                _adalomo._rms(param.astype(jnp.float32), axes))
        p32 = param.astype(jnp.float32)
        return (p32 - lr * u).astype(param.dtype), new_state

    return _rule_from_fns("adafactor", init_fn, update_fn)


REGISTRY: dict[str, Callable[..., TensorRule]] = {
    "adalomo": adalomo,
    "lomo": sgd,       # LOMO == fused SGD
    "sgd": sgd,
    "sgd_momentum": sgd_momentum,
    "sgd_variance": sgd_variance,
    "adamw": adamw,
    "adafactor": adafactor,
}


def get_rule(name: str, **kwargs) -> TensorRule:
    if name not in REGISTRY:
        raise KeyError(f"unknown optimizer {name!r}; have {list(REGISTRY)}")
    return REGISTRY[name](**kwargs)

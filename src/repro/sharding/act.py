"""Activation-sharding policy: FSDP-SP discipline for pjit.

Without intra-layer constraints, XLA SPMD propagation picks pathological
strategies — the qwen3 baseline HLO double-gathers each MLP weight to a
fully-replicated fp32 copy per use (EXPERIMENTS.md §Perf H1), and a
Megatron-TP constraint set makes it worse (H2-refuted: per-layer fp32
(B,S,d) all-reduce/all-gather pairs).  The scheme that wins on this
hardware model is **FSDP + sequence parallelism**:

  * the residual stream (and every [B,S,*] activation) stays
    *sequence-sharded* over the model axis: P(dp, tp, …) — layer dots
    contract unsharded dims, so no partial-sum all-reduces exist at all;
  * layer weights are all-gathered **transiently, in bf16** per layer
    (see rules.make_param_constraint) — classic ZeRO-3;
  * attention runs sequence-tiled: every device owns S/tp query rows
    against a replicated K/V (gathered once per layer, the only
    activation collective).

Models call :func:`shard_act(x, kind)` at canonical points; a no-op unless
a policy is installed, so model code stays mesh-agnostic.

Kinds:
  hidden — residual stream [B,S,D]      → P(dp, tp, None)   (seq-sharded)
  ffn    — MLP hidden [B,S,F]           → P(dp, tp, None)
  heads  — q tensor [B,S,H,dh]          → P(dp, tp, None, None)
  kv_full— k/v for attention [B,S,K,dh] → P(dp, None, None, None)
  vocab  — logits [B,S,V] or [B,V]      → P(dp, None, tp) / P(dp, tp)
  experts— MoE buffers [B,E,C,D]        → P(dp, tp, None, None)  (EP)
"""
from __future__ import annotations

import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY: contextvars.ContextVar = contextvars.ContextVar(
    "act_sharding_policy", default=None)


class ActPolicy:
    def __init__(self, mesh: Mesh, axes):
        """axes: repro.sharding.rules.MeshAxes"""
        self.mesh = mesh
        self.axes = axes
        self.dp = axes.batch if len(axes.batch) > 1 else (
            axes.batch[0] if axes.batch else None)
        self.tp = axes.tp[0] if axes.tp else None
        self.dp_size = axes.size(axes.batch)
        self.tp_size = axes.size(axes.tp)

    def _ok(self, dim: int, size: int) -> bool:
        return size > 1 and dim % size == 0 and dim > 1

    def spec(self, x, kind: str) -> Optional[P]:
        nd = x.ndim
        s: list = [None] * nd
        if nd >= 1 and self._ok(x.shape[0], self.dp_size):
            s[0] = self.dp
        if self.tp is None:
            return P(*s)
        if kind in ("hidden", "ffn", "heads") and nd >= 2:
            if self._ok(x.shape[1], self.tp_size):
                s[1] = self.tp           # sequence parallelism
        elif kind == "q_tiled" and nd >= 2:
            if x.shape[1] == self.tp_size:
                s[1] = self.tp           # tile dim == tp axis
        elif kind == "kv_full":
            pass                          # replicated over tp by design
        elif kind == "vocab" and nd >= 2:
            if self._ok(x.shape[-1], self.tp_size):
                s[-1] = self.tp
        elif kind == "experts" and nd >= 2:
            if self._ok(x.shape[1], self.tp_size):
                s[1] = self.tp
        return P(*s)


def install(policy: Optional[ActPolicy]):
    """Install (or clear with None) the process-wide policy."""
    _POLICY.set(policy)


def current_policy() -> Optional[ActPolicy]:
    return _POLICY.get()


class use_policy:
    def __init__(self, policy: Optional[ActPolicy]):
        self.policy = policy

    def __enter__(self):
        self.tok = _POLICY.set(self.policy)
        return self.policy

    def __exit__(self, *exc):
        _POLICY.reset(self.tok)


def shard_act(x, kind: str):
    """Constrain activation sharding; identity when no policy installed."""
    pol = _POLICY.get()
    if pol is None or not hasattr(x, "ndim"):
        return x
    spec = pol.spec(x, kind)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pol.mesh, spec))


def seq_tiles(seq_len: int) -> int:
    """Number of sequence tiles the attention q-scan should expose so the
    scan axis stays *unsharded* while the tile axis carries the tp
    sharding (layers._block_attention)."""
    pol = _POLICY.get()
    if pol is None or pol.tp is None:
        return 1
    return pol.tp_size if seq_len % pol.tp_size == 0 else 1

"""Partition rules: param/optimizer/activation shardings for pjit.

Logical axes:
  * ``dp`` — data parallel + ZeRO-3/FSDP param sharding.  Resolves to
    ``('data',)`` on the single-pod mesh and ``('pod','data')`` multi-pod
    for the *batch*; parameters are FSDP-sharded over ``'data'`` only
    (gathered within a pod; replicated across pods — all-gathering weights
    over the inter-pod DCI every layer would dominate the step).
  * ``tp`` — tensor/expert parallel, resolves to ``('model',)``.

Rules are (regex over the param path, dim-role template) pairs; every rule
is shape-guarded: an axis is applied to a dim only if the dim is divisible
by the mesh axis size (e.g. whisper's vocab 51865 falls back to replicated
instead of failing).  Optimizer state shardings are derived from the param
spec by shape-suffix matching, so AdaLomo's factored (r, c) vectors land on
the same devices as the rows/columns they describe.
"""
from __future__ import annotations

import math
import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


class MeshAxes:
    """Resolved logical→physical axis names for a given mesh."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        names = mesh.axis_names
        self.batch = tuple(n for n in ("pod", "data") if n in names)
        self.fsdp = ("data",) if "data" in names else ()
        self.tp = ("model",) if "model" in names else ()

    def size(self, axes: tuple[str, ...]) -> int:
        return math.prod(self.mesh.shape[a] for a in axes) if axes else 1


# Dim-role templates per param-name pattern.  Roles:
#   'fsdp' → shard over data axis (ZeRO-3);  'tp' → tensor/expert parallel;
#   None → replicated;  'stack' → leading layer/stack dim (never sharded).
# Matched against the '/'-joined tree path, most-specific first.
_PARAM_RULES: list[tuple[str, tuple[Optional[str], ...]]] = [
    # --- MoE expert weights [E, d, f] / [E, f, d]: EP over tp, FSDP inner
    (r"moe/w_(gate|up)$", ("tp", "fsdp", None)),
    (r"moe/w_down$", ("tp", None, "fsdp")),
    (r"moe/router$", ("fsdp", None)),
    (r"moe/shared_mlp/w_(gate|up)$", ("fsdp", "tp")),
    (r"moe/shared_mlp/w_down$", ("tp", "fsdp")),
    # --- attention projections
    (r"attn/w[qkv]$", ("fsdp", "tp")),
    (r"attn/wo$", ("tp", "fsdp")),
    (r"attn/w_dq$", ("fsdp", "tp")),
    (r"attn/w_uq$", ("tp", None)),        # q_lora sharded out of w_dq
    (r"attn/w_dkv$", ("fsdp", None)),     # latent stays replicated (512)
    (r"attn/w_kr$", ("fsdp", None)),
    (r"attn/w_u[kv]$", (None, "tp")),     # per-head up-proj over tp
    (r"(self_attn|cross_attn)/w[qkv]$", ("fsdp", "tp")),
    (r"(self_attn|cross_attn)/wo$", ("tp", "fsdp")),
    # --- dense MLP
    (r"mlp/w_(gate|up)$", ("fsdp", "tp")),
    (r"mlp/w_down$", ("tp", "fsdp")),
    # --- zamba2 shared block + lora
    (r"^shared/w[qkv]$", ("fsdp", "tp")),
    (r"^shared/wo$", ("tp", "fsdp")),
    (r"^shared/w_(gate|up)$", ("fsdp", "tp")),
    (r"^shared/w_down$", ("tp", "fsdp")),
    (r"lora_[qkv]A$", ("fsdp", None)),
    (r"lora_[qkv]B$", (None, "tp")),
    # --- mamba2
    (r"in_proj$", ("fsdp", "tp")),
    (r"out_proj$", ("tp", "fsdp")),
    (r"conv_w$", ("tp", None)),
    (r"conv_b$", ("tp",)),
    # --- embeddings / head
    (r"tok_embed$", ("tp", "fsdp")),
    (r"head$", ("fsdp", "tp")),
    (r"mtp_proj$", ("fsdp", "tp")),
    # --- everything else (norm scales, biases, A_log, D, dt_bias): replicated
]


def _spec_for_shape(shape: tuple[int, ...], roles: tuple[Optional[str], ...],
                    axes: MeshAxes) -> P:
    """Apply role template to a shape, right-aligned (leading dims = stack)."""
    n_stack = len(shape) - len(roles)
    spec: list = [None] * len(shape)
    for i, role in enumerate(roles):
        dim = n_stack + i
        if dim < 0 or role is None:
            continue
        ax = {"fsdp": axes.fsdp, "tp": axes.tp}[role]
        if ax and shape[dim] % axes.size(ax) == 0 and shape[dim] > 1:
            spec[dim] = ax if len(ax) > 1 else ax[0]
    return P(*spec)


def param_pspecs(params, axes: MeshAxes):
    """PartitionSpec pytree matching ``params``."""
    def leaf_spec(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        for pat, roles in _PARAM_RULES:
            if re.search(pat, pstr):
                if len(leaf.shape) < len(roles):
                    # e.g. 1-D bias matched by a 2-D rule: replicate
                    return P()
                return _spec_for_shape(tuple(leaf.shape), roles, axes)
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def opt_pspecs(opt_state, params, param_specs, axes: MeshAxes):
    """Derive optimizer-state specs from param specs by shape matching.

    AdaLomo r (= param.shape[:-1]) inherits the spec minus the last dim;
    c (= shape[:-2] + shape[-1:]) minus the second-to-last; same-shape
    states (Adam m/v, unfactored v) inherit the full spec.
    """
    flat_p = {tuple(s.shape): spec for s, spec in zip(
        jax.tree.leaves(params), jax.tree.leaves(
            param_specs, is_leaf=lambda x: isinstance(x, P)))}

    # Build a per-param lookup keyed by id of abstract shape — instead walk
    # moments in parallel with params where possible; fall back on shapes.
    def leaf_spec(leaf):
        sh = tuple(leaf.shape)
        if sh == ():
            return P()
        if sh in flat_p:
            return flat_p[sh]
        # factored r: param shape minus last dim
        for psh, spec in flat_p.items():
            parts = list(spec) + [None] * (len(psh) - len(spec))
            if sh == psh[:-1]:
                return P(*parts[:-1]) if len(parts) == len(psh) else P()
            if len(psh) >= 2 and sh == psh[:-2] + psh[-1:]:
                return P(*(parts[:-2] + parts[-1:]))
        return P()

    return jax.tree.map(leaf_spec, opt_state)


def batch_pspecs(batch, axes: MeshAxes):
    """Shard the leading (batch) dim of every input over dp axes."""
    ba = axes.batch if len(axes.batch) > 1 else (
        axes.batch[0] if axes.batch else None)

    def leaf_spec(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] % axes.size(axes.batch) == 0 and leaf.shape[0] > 1:
            return P(ba, *([None] * (leaf.ndim - 1)))
        return P()

    return jax.tree.map(leaf_spec, batch)


def cache_pspecs(cache, axes: MeshAxes, batch_size: int):
    """KV/state caches: batch over dp when divisible; cache length (axis 2
    of [L,B,W,...] tensors) over tp for long-context cells; otherwise the
    KV-head/state dims stay local."""
    dp_size = axes.size(axes.batch)
    tp_size = axes.size(axes.tp)
    ba = axes.batch if len(axes.batch) > 1 else (
        axes.batch[0] if axes.batch else None)
    tpa = axes.tp[0] if axes.tp else None

    def leaf_spec(leaf):
        if leaf.ndim <= 1:
            return P()
        spec: list = [None] * leaf.ndim
        # [L, B, W, ...] layout: axis 1 = batch, axis 2 = window/length
        if leaf.ndim >= 3 and leaf.shape[1] == batch_size:
            if batch_size % dp_size == 0 and batch_size > 1:
                spec[1] = ba
            if tpa and leaf.shape[2] % tp_size == 0 and leaf.shape[2] > 1:
                spec[2] = tpa
        elif leaf.shape[0] == batch_size and batch_size % dp_size == 0 \
                and batch_size > 1:
            spec[0] = ba
        return P(*spec)

    return jax.tree.map(leaf_spec, cache)


def _reshard_use(x, use_sh: NamedSharding, grad_sh: NamedSharding):
    """Identity with asymmetric sharding: the primal is constrained to the
    use-sharding (forcing a *bf16* all-gather of the resting ZeRO-3 shard
    before any dtype legalization can upcast it), while the cotangent is
    constrained straight to the resting sharding (a reduce-scatter instead
    of the default full all-reduce).  §Perf H3/H4."""

    @jax.custom_vjp
    def f(v):
        return jax.lax.with_sharding_constraint(v, use_sh)

    def fwd(v):
        return f(v), None

    def bwd(_, g):
        return (jax.lax.with_sharding_constraint(g, grad_sh),)

    f.defvjp(fwd, bwd)
    return f(x)


def make_param_constraint(mesh: Mesh, axes: MeshAxes, params):
    """Transient weight gather for the fused scan (ZeRO-3 'use' path).

    Per layer slice: dense/attention weights are gathered to full
    replication for the duration of the layer (their resting state stays
    256-way sharded); MoE expert tensors keep their expert-parallel 'tp'
    dim (never gathered — 11 GB/layer for deepseek-v3).  Gradients
    reduce-scatter back to the resting sharding via the custom vjp.

    Returns ``fn(stack_name) -> (layer_params -> layer_params)``.
    """
    specs = param_pspecs(params, axes)

    def for_stack(stack_name: str):
        sub = specs["stacks"][stack_name]

        def leaf_plan(path, spec):
            pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            rest = list(spec)[1:]  # strip stacked layer dim
            if re.search(r"moe/w_(gate|up|down)", pstr):
                # keep EP axis, drop only fsdp axes
                use = [a if a and set(_as_tuple(a)) <= set(axes.tp) else None
                       for a in rest]
            else:
                use = [None] * len(rest)
            return (NamedSharding(mesh, P(*use)),
                    NamedSharding(mesh, P(*rest)))

        plans = jax.tree_util.tree_map_with_path(
            leaf_plan, sub, is_leaf=lambda x: isinstance(x, P))

        def constrain(layer_p):
            return _apply_plans(layer_p, plans)

        return constrain

    return for_stack


def _apply_plans(layer_p, plans):
    treedef = jax.tree.structure(layer_p)
    leaves = treedef.flatten_up_to(layer_p)
    plan_leaves = treedef.flatten_up_to(plans)
    out = [_reshard_use(v, u, g) for v, (u, g) in zip(leaves, plan_leaves)]
    return treedef.unflatten(out)


def _as_tuple(a):
    return a if isinstance(a, tuple) else (a,)


def make_grad_constraint(mesh: Mesh, axes: MeshAxes, params):
    """Per-stack gradient constraints (§Perf H2): constrain each layer
    gradient to its parameter's sharding before the optimizer consumes it.
    Turns the fp32 full-tensor all-reduce of dW into a bf16 reduce-scatter;
    the factored (r,c) statistics then cost only O(m+n) cross-shard traffic.

    Returns ``fn(stack_name) -> (g_layer_tree -> constrained tree)``.
    """
    specs = param_pspecs(params, axes)

    def for_stack(stack_name: str):
        sub = specs["stacks"][stack_name]

        def slice_sharding(spec: P):
            # strip the leading (layer) dim of the stacked spec
            parts = list(spec)
            return NamedSharding(mesh, P(*parts[1:]) if parts else P())

        shardings = jax.tree.map(slice_sharding, sub,
                                 is_leaf=lambda x: isinstance(x, P))

        def constrain(g_tree):
            return jax.tree.map(
                lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
                g_tree, shardings)

        return constrain

    return for_stack


def make_residual_constraint(mesh: Mesh, axes: MeshAxes):
    """Sequence-shard saved layer-input residuals: [B, S, d] → P(dp, tp, ∅).

    This is what keeps fused-backward activation memory on-chip at
    train_4k×global-batch-256 scale (DESIGN.md §2); XLA inserts
    reduce-scatter/all-gather pairs around the saved values.
    """
    ba = axes.batch if len(axes.batch) > 1 else (
        axes.batch[0] if axes.batch else None)
    tpa = axes.tp[0] if axes.tp else None
    dp_size = axes.size(axes.batch)
    tp_size = axes.size(axes.tp)

    def constrain(x):
        def leaf(v):
            if not hasattr(v, "ndim") or v.ndim < 3:
                return v
            spec: list = [None] * v.ndim
            if v.shape[0] % dp_size == 0 and v.shape[0] > 1:
                spec[0] = ba
            if tpa and v.shape[1] % tp_size == 0 and v.shape[1] > 1:
                spec[1] = tpa
            return jax.lax.with_sharding_constraint(
                v, NamedSharding(mesh, P(*spec)))
        return jax.tree.map(leaf, x)

    return constrain


def to_shardings(pspec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))

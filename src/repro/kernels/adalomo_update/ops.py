"""Jitted wrapper for the fused AdaLomo update kernel.

``adalomo_update(param, grad, r, c, lr, step)`` — handles padding to block
multiples, the tiny host-side r-sum between the two kernels, leading stack
dims via vmap, and exposes ``interpret=`` for CPU validation against
ref.py.  Falls back to the pure-jnp path for 1-D (unfactored) tensors.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.adalomo import AdaLomoConfig
from repro.kernels.adalomo_update.adalomo_update import (
    DEFAULT_BLOCK, stats_pallas, update_pallas)


def _pad_to(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(jax.jit, static_argnames=("cfg", "block", "interpret"))
def adalomo_update(param, grad, r, c, lr, step, *,
                   cfg: AdaLomoConfig = AdaLomoConfig(),
                   block=DEFAULT_BLOCK, interpret: bool = False):
    """Fused AdaLomo step for a 2-D tensor (or stacked [..., m, n] via vmap).

    Returns (new_param, new_r, new_c). Semantics == ref.adalomo_update_ref.
    """
    if param.ndim > 2:
        fn = functools.partial(adalomo_update, cfg=cfg, block=block,
                               interpret=interpret)
        return jax.vmap(lambda p, g, rr, cc: fn(p, g, rr, cc, lr, step))(
            param, grad, r, c)
    assert param.ndim == 2, param.shape
    m, n = param.shape
    bm, bn = min(block[0], m), min(block[1], n)
    # pad to block multiples (zero rows/cols are inert in every statistic)
    p_p = _pad_to(param, bm, bn)
    g_p = _pad_to(grad, bm, bn)
    r_p = jnp.pad(r, (0, p_p.shape[0] - m))
    c_p = jnp.pad(c, (0, p_p.shape[1] - n))

    new_r, new_c = stats_pallas(g_p, r_p, c_p, beta=cfg.beta,
                                eps_stat=cfg.eps_stat, block=(bm, bn),
                                interpret=interpret)
    denom = jnp.maximum(jnp.sum(new_r), cfg.eps_stat)
    if cfg.bias_correction:
        corr = jnp.maximum(1.0 - cfg.beta ** jnp.asarray(step, jnp.float32),
                           cfg.eps_stat)
    else:
        corr = jnp.float32(1.0)
    inv_denom_corr = 1.0 / (denom * corr)
    lr_eff = jnp.asarray(lr, jnp.float32)
    if cfg.weight_decay:
        # decoupled decay folded into the kernel's lr·û via pre-scaling here
        p_p = (p_p.astype(jnp.float32)
               * (1.0 - lr_eff * cfg.weight_decay)).astype(p_p.dtype)
    new_p = update_pallas(
        p_p, g_p, new_r, new_c, lr=lr_eff, inv_denom_corr=inv_denom_corr,
        eps_div=cfg.eps_div, clip=cfg.clip_threshold, eps_rms=cfg.eps_rms,
        n_elems=m * n, literal=cfg.literal_div_v, block=(bm, bn),
        interpret=interpret)
    return new_p[:m, :n], new_r[:m], new_c[:n]


def make_kernel_rule(cfg: Optional[AdaLomoConfig] = None,
                     interpret: bool = False):
    """AdaLomo as a TensorRule backed by the Pallas kernel for factored
    2-D+ tensors (pure-jnp fallback elsewhere) — drop-in for the fused
    backward engine."""
    from repro.core import adalomo as A
    from repro.core.optimizers import TensorRule, _rule_from_fns
    cfg = cfg or A.AdaLomoConfig()

    def init_fn(p):
        return A.init_state(p, cfg)

    def update_fn(p, g, s, *, lr, step):
        if s.v is None and p.ndim >= 2:
            np_, nr, nc = adalomo_update(p, g, s.r, s.c, lr, step, cfg=cfg,
                                         interpret=interpret)
            return np_, A.FactoredState(r=nr, c=nc, v=None)
        return A.update_tensor(p, g, s, lr=lr, step=step, cfg=cfg)

    return _rule_from_fns("adalomo_kernel", init_fn, update_fn)

"""Jitted wrapper for the fused AdaLomo update kernel.

``adalomo_update(param, grad, r, c, lr, step, beta, weight_decay, clip)``
— handles padding to block multiples, the tiny host-side r-sum between the
two kernels, leading stack dims via vmap, and exposes ``interpret=`` for
CPU validation against ref.py.

All hyperparameters are dynamic operands (Opt v2 contract): lr/β/decay/
clip may be traced scalars, so schedules and per-group overrides never
recompile the kernel.  The structural knobs (ε's, factoring threshold,
``literal_div_v``) stay in the static :class:`AdaLomoConfig`.

This module exposes the raw 2-D kernel entry point only; optimizer-rule
integration is the ``backend="pallas"`` dispatch inside
``repro.core.optimizers.adalomo`` — there is no separately-registered
kernel rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.adalomo import DEFAULT_HPARAMS, AdaLomoConfig
from repro.kernels.adalomo_update.adalomo_update import (
    DEFAULT_BLOCK, stats_pallas, update_pallas)


def _pad_to(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(jax.jit, static_argnames=("cfg", "block", "interpret"))
def adalomo_update(param, grad, r, c, lr, step,
                   beta=DEFAULT_HPARAMS["beta"],
                   weight_decay=DEFAULT_HPARAMS["weight_decay"],
                   clip=DEFAULT_HPARAMS["clip"], *,
                   cfg: AdaLomoConfig = AdaLomoConfig(),
                   block=DEFAULT_BLOCK, interpret: bool = False):
    """Fused AdaLomo step for a 2-D tensor (or stacked [..., m, n] via vmap).

    Returns (new_param, new_r, new_c). Semantics == ref.adalomo_update_ref:
    decoupled weight decay scales θ at the final write, while the RMS(θ)
    trust scale is computed from the un-decayed θ.
    """
    if param.ndim > 2:
        fn = functools.partial(adalomo_update, cfg=cfg, block=block,
                               interpret=interpret)
        return jax.vmap(lambda p, g, rr, cc: fn(
            p, g, rr, cc, lr, step, beta, weight_decay, clip))(
            param, grad, r, c)
    assert param.ndim == 2, param.shape
    m, n = param.shape
    bm, bn = min(block[0], m), min(block[1], n)
    # pad to block multiples (zero rows/cols are inert in every statistic)
    p_p = _pad_to(param, bm, bn)
    g_p = _pad_to(grad, bm, bn)
    r_p = jnp.pad(r, (0, p_p.shape[0] - m))
    c_p = jnp.pad(c, (0, p_p.shape[1] - n))

    new_r, new_c = stats_pallas(g_p, r_p, c_p, beta=beta,
                                eps_stat=cfg.eps_stat, block=(bm, bn),
                                interpret=interpret)
    denom = jnp.maximum(jnp.sum(new_r), cfg.eps_stat)
    if cfg.bias_correction:
        corr = jnp.maximum(
            1.0 - jnp.asarray(beta, jnp.float32)
            ** jnp.asarray(step, jnp.float32), cfg.eps_stat)
    else:
        corr = jnp.float32(1.0)
    inv_denom_corr = 1.0 / (denom * corr)
    lr_eff = jnp.asarray(lr, jnp.float32)
    decay = 1.0 - lr_eff * jnp.asarray(weight_decay, jnp.float32)
    new_p = update_pallas(
        p_p, g_p, new_r, new_c, lr=lr_eff, inv_denom_corr=inv_denom_corr,
        eps_div=cfg.eps_div, clip=clip, eps_rms=cfg.eps_rms,
        n_elems=m * n, decay=decay, literal=cfg.literal_div_v,
        block=(bm, bn), interpret=interpret)
    return new_p[:m, :n], new_r[:m], new_c[:n]

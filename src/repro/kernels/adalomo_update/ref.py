"""Pure-jnp oracle for the fused AdaLomo update kernel.

This is literally the paper-faithful per-tensor update from
``repro.core.adalomo`` — the kernel must match it bit-for-bit in fp32
(modulo reduction-order rounding, covered by allclose tolerances),
including with weight_decay > 0 (the RMS(θ) trust scale comes from the
un-decayed θ in both).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.adalomo import (DEFAULT_HPARAMS, AdaLomoConfig,
                                FactoredState, update_tensor)


def adalomo_update_ref(param, grad, r, c, *, lr, step,
                       beta=DEFAULT_HPARAMS["beta"],
                       weight_decay=DEFAULT_HPARAMS["weight_decay"],
                       clip=DEFAULT_HPARAMS["clip"],
                       cfg: AdaLomoConfig = AdaLomoConfig()):
    """param/grad: [m, n]; r: [m]; c: [n]. Returns (new_param, new_r, new_c).

    Matches core.adalomo.update_tensor with a factored state.
    """
    state = FactoredState(r=r, c=c, v=None)
    new_param, new_state = update_tensor(
        param, grad, state, lr=jnp.asarray(lr, jnp.float32),
        step=jnp.asarray(step, jnp.float32), beta=beta,
        weight_decay=weight_decay, clip=clip, cfg=cfg)
    return new_param, new_state.r, new_state.c

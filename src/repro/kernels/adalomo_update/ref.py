"""Pure-jnp oracle for the fused AdaLomo update kernel.

This is literally the paper-faithful per-tensor update from
``repro.core.adalomo`` — the kernel must match it bit-for-bit in fp32
(modulo reduction-order rounding, covered by allclose tolerances).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.adalomo import AdaLomoConfig, FactoredState, update_tensor


def adalomo_update_ref(param, grad, r, c, *, lr, step,
                       cfg: AdaLomoConfig = AdaLomoConfig()):
    """param/grad: [m, n]; r: [m]; c: [n]. Returns (new_param, new_r, new_c).

    Matches core.adalomo.update_tensor with a factored state.
    """
    state = FactoredState(r=r, c=c, v=None)
    new_param, new_state = update_tensor(
        param, grad, state, lr=jnp.asarray(lr, jnp.float32),
        step=jnp.asarray(step, jnp.float32), cfg=cfg)
    return new_param, new_state.r, new_state.c

"""Pallas TPU kernel: fused AdaLomo optimizer step for one m×n tensor.

Why a kernel: inside the fused backward, the AdaLomo update is the sole
consumer of each layer's gradient.  A naive XLA lowering materializes g²,
the rank-1 reconstruction v = outer(r,c)/sum(r), u, and û as HBM-sized
temporaries; this kernel keeps every [m,n] intermediate in VMEM tiles, so
the only HBM traffic is grad/param reads and the param write, and the only
extra state ever allocated is the O(m+n) factored moments — the Table-1
memory claim enforced at kernel level.

Two ``pallas_call``s (cross-tensor reductions force phase boundaries):

  A (stats):  r' = βr + (1-β)·rowsum(g²+ε),  c' likewise — one sweep of g.
  host:       denom = Σr', bias correction (O(m) work, jnp).
  B (update): phase 0 sweeps g to accumulate Σu² and Σp² in SMEM scratch
              (u recomputed from (r',c'), never stored); phase 1 applies
              û = u/max(1,RMS(u)/d)·max(ε₂,RMS(θ)) and writes θ' in-place.

Block shapes default to (256, 512) fp32 tiles — (8,128)-lane aligned,
~0.5 MB each, comfortably inside the ~16 MB VMEM envelope with all four
operands resident.  Edge tiles are handled by zero-padding in ops.py
(zero rows/cols contribute 0 to every accumulated statistic; true element
counts travel in the scalar operand).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (256, 512)


# --------------------------------------------------------------------------
# Kernel A: factored second-moment statistics
# --------------------------------------------------------------------------

def _stats_kernel(scal_ref, g_ref, r_ref, c_ref, r_out, c_out):
    j = pl.program_id(1)
    i = pl.program_id(0)
    beta = scal_ref[0]
    eps_stat = scal_ref[1]
    g = g_ref[...].astype(jnp.float32)
    g2 = g * g + eps_stat
    row_part = jnp.sum(g2, axis=1)   # [bm]
    col_part = jnp.sum(g2, axis=0)   # [bn]

    @pl.when(j == 0)
    def _():
        r_out[...] = beta * r_ref[...] + (1.0 - beta) * row_part

    @pl.when(j != 0)
    def _():
        r_out[...] = r_out[...] + (1.0 - beta) * row_part

    @pl.when(i == 0)
    def _():
        c_out[...] = beta * c_ref[...] + (1.0 - beta) * col_part

    @pl.when(i != 0)
    def _():
        c_out[...] = c_out[...] + (1.0 - beta) * col_part


def stats_pallas(grad, r, c, *, beta, eps_stat, block=DEFAULT_BLOCK,
                 interpret=False):
    m, n = grad.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0, (
        f"grad shape {(m, n)} not a multiple of block {(bm, bn)}")
    grid = (m // bm, n // bn)
    scal = jnp.array([beta, eps_stat], jnp.float32)
    return pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(scal, grad, r, c)


# --------------------------------------------------------------------------
# Kernel B: grouped-normalized update, applied in place
# --------------------------------------------------------------------------

def _update_kernel(scal_ref, p_ref, g_ref, r_ref, c_ref, p_out, acc_ref):
    phase = pl.program_id(0)
    i, j = pl.program_id(1), pl.program_id(2)
    (inv_denom_corr, eps_div, lr, clip, eps_rms, n_elems,
     literal, decay) = (scal_ref[0], scal_ref[1], scal_ref[2], scal_ref[3],
                        scal_ref[4], scal_ref[5], scal_ref[6], scal_ref[7])

    @pl.when((phase == 0) & (i == 0) & (j == 0))
    def _():
        acc_ref[0] = 0.0   # Σu²
        acc_ref[1] = 0.0   # Σp²

    g = g_ref[...].astype(jnp.float32)
    v_hat = (r_ref[...][:, None] * c_ref[...][None, :]) * inv_denom_corr
    u = jnp.where(literal > 0.5,
                  g / (v_hat + eps_div),
                  g / (jnp.sqrt(v_hat) + eps_div))

    @pl.when(phase == 0)
    def _():
        p = p_ref[...].astype(jnp.float32)
        acc_ref[0] += jnp.sum(u * u)
        acc_ref[1] += jnp.sum(p * p)

    @pl.when(phase == 1)
    def _():
        p = p_ref[...].astype(jnp.float32)
        rms_u = jnp.sqrt(acc_ref[0] / n_elems)
        rms_p = jnp.sqrt(acc_ref[1] / n_elems)
        scale = jnp.maximum(eps_rms, rms_p) / jnp.maximum(1.0, rms_u / clip)
        # Decoupled weight decay applied at write time: Σp² (hence the
        # RMS(θ) trust scale) is accumulated from the *un-decayed* θ in
        # phase 0, matching core.adalomo.update_tensor exactly.
        p_out[...] = (p * decay - lr * u * scale).astype(p_out.dtype)


def update_pallas(param, grad, r_new, c_new, *, lr, inv_denom_corr,
                  eps_div, clip, eps_rms, n_elems, decay=1.0, literal=False,
                  block=DEFAULT_BLOCK, interpret=False):
    m, n = param.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0, (
        f"param shape {(m, n)} not a multiple of block {(bm, bn)}")
    grid = (2, m // bm, n // bn)
    scal = jnp.array([inv_denom_corr, eps_div, lr, clip, eps_rms,
                      float(n_elems), 1.0 if literal else 0.0, decay],
                     jnp.float32)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bn), lambda p, i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda p, i, j: (i, j)),
            pl.BlockSpec((bm,), lambda p, i, j: (i,)),
            pl.BlockSpec((bn,), lambda p, i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda p, i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), param.dtype),
        scratch_shapes=[pltpu.SMEM((2,), jnp.float32)],
        input_output_aliases={1: 0},   # param buffer reused for output
        interpret=interpret,
    )(scal, param, grad, r_new, c_new)

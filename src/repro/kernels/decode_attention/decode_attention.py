"""Pallas TPU kernel: flash-decoding GQA attention for one query token.

The decode_32k / long_500k serving cells attend one query over a deep KV
cache.  The kernel streams KV blocks through VMEM with an online softmax —
the [W]-long score vector never materializes in HBM, and the working set
per grid step is (bw × dh) K/V tiles + (G × bw) scores, independent of W.

Grid: (B, K, W/bw) — batch × kv-head × cache blocks; inner dim fastest, so
the (m, l, acc) VMEM scratch carries across a head's cache sweep and the
output tile is written once on the last block (@pl.when).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_KV_BLOCK = 512
NEG_INF = -1e30


def _decode_kernel(scal_ref, q_ref, k_ref, v_ref, pos_ref, out_ref,
                   m_ref, l_ref, acc_ref):
    wi = pl.program_id(2)
    nw = pl.num_programs(2)
    scale, q_pos, window = scal_ref[0], scal_ref[1], scal_ref[2]

    @pl.when(wi == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # [G, dh]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bw, dh]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bw, dh]
    pos = pos_ref[0, :].astype(jnp.float32)              # [bw]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G,bw]
    valid = (pos >= 0.0) & (pos <= q_pos)
    valid = valid & jnp.where(window > 0.0, q_pos - pos < window, True)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[:, 0]                                  # [G]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])                       # [G, bw]
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]

    @pl.when(wi == nw - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        out_ref[0, 0] = out.astype(out_ref.dtype)


def _paged_decode_kernel(bt_ref, sl_ref, scal_ref, q_ref, k_ref, v_ref,
                         out_ref, m_ref, l_ref, acc_ref):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    n_pages = pl.num_programs(2)
    scale, window = scal_ref[0], scal_ref[1]
    n = sl_ref[b]                                        # tokens in cache
    ps = k_ref.shape[1]

    @pl.when(pi == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # pages wholly past the sequence end contribute nothing — skip them
    @pl.when(pi * ps < n)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)              # [G, dh]
        k = k_ref[0, :, 0].astype(jnp.float32)           # [ps, dh]
        v = v_ref[0, :, 0].astype(jnp.float32)           # [ps, dh]
        pos = (pi * ps
               + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1))[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        valid = pos < n
        valid = valid & jnp.where(window > 0,
                                  (n - 1) - pos < window, True)
        s = jnp.where(valid[None, :], s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(pi == n_pages - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        out_ref[0, 0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention_pallas(q, k_pages, v_pages, block_tables,
                                  seq_lens, *, scale=None, window=None,
                                  interpret=False):
    """Paged flash-decoding: each sequence reads its own page list.

    q: [B,H,dh]; k_pages/v_pages: [N, ps, K, dh] (page pool shared across
    sequences); block_tables: [B,P] int32 page ids in logical order
    (unallocated tail entries must point at a valid page, e.g. the scratch
    page 0 — they are masked by seq_lens); seq_lens: [B] int32 token counts
    *including* the token written this step.  Returns [B,H,dh].

    Grid (B, K, P): the block table is scalar-prefetched so the K/V
    BlockSpec index_map can route each grid step's DMA to the right page —
    the gather never materializes a per-sequence contiguous cache.
    """
    B, H, dh = q.shape
    N, ps, K, _ = k_pages.shape
    P = block_tables.shape[1]
    G = H // K
    scale = scale if scale is not None else dh ** -0.5
    qg = q.reshape(B, K, G, dh)
    scal = jnp.array([scale, float(window or 0)], jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, P),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, dh), lambda b, k, p, bt, sl: (b, k, 0, 0)),
            pl.BlockSpec((1, ps, 1, dh),
                         lambda b, k, p, bt, sl: (bt[b, p], 0, k, 0)),
            pl.BlockSpec((1, ps, 1, dh),
                         lambda b, k, p, bt, sl: (bt[b, p], 0, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh),
                               lambda b, k, p, bt, sl: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        _paged_decode_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, dh), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), seq_lens.astype(jnp.int32),
      scal, qg, k_pages, v_pages)
    return out.reshape(B, H, dh)


@functools.partial(jax.jit,
                   static_argnames=("window", "kv_block", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, kv_pos, q_pos, *,
                            scale=None, window=None,
                            kv_block=DEFAULT_KV_BLOCK, interpret=False):
    """q: [B,H,dh]; caches [B,W,K,dh]; kv_pos [W] (shared across batch);
    q_pos scalar. Returns [B,H,dh]. Uniform-position batched decode —
    matches ref for kv_pos[b] identical across b (the engine's layout)."""
    B, H, dh = q.shape
    W, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else dh ** -0.5
    bw = min(kv_block, W)
    pad = (-W) % bw
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)
    Wp = W + pad
    assert Wp % bw == 0, f"padded window {Wp} not a multiple of {bw}"
    nw = Wp // bw

    qg = q.reshape(B, K, G, dh)
    kt = k_cache.transpose(0, 2, 1, 3)   # [B,K,W,dh]
    vt = v_cache.transpose(0, 2, 1, 3)
    pos2 = kv_pos.reshape(1, Wp)
    scal = jnp.array([scale, jnp.float32(q_pos),
                      float(window or 0)], jnp.float32)

    out = pl.pallas_call(
        _decode_kernel,
        grid=(B, K, nw),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, dh), lambda b, k, w: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, bw, dh), lambda b, k, w: (b, k, w, 0)),
            pl.BlockSpec((1, 1, bw, dh), lambda b, k, w: (b, k, w, 0)),
            pl.BlockSpec((1, bw), lambda b, k, w: (0, w)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, k, w: (b, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
        interpret=interpret,
    )(scal, qg, kt, vt, pos2)
    return out.reshape(B, H, dh)

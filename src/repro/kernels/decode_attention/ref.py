"""Pure-jnp oracle for the flash-decoding kernel: exactly
repro.models.layers.decode_attention (the serving path's attention)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import decode_attention


def decode_attention_ref(q, k_cache, v_cache, *, kv_pos, q_pos,
                         window=None, scale=None):
    """q: [B,H,dh]; caches: [B,W,K,dh]; kv_pos: [B,W]; q_pos: [B].
    Returns [B,H,dh]."""
    out = decode_attention(q[:, None], k_cache, v_cache, kv_pos=kv_pos,
                           q_pos=q_pos, window=window, scale=scale)
    return out[:, 0]


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, seq_lens,
                               *, window=None, scale=None):
    """Dense oracle for the paged kernel: gather each sequence's pages into
    a contiguous cache and run the exact serving-path attention.

    q: [B,H,dh]; k_pages/v_pages: [N, ps, K, dh]; block_tables: [B,P];
    seq_lens: [B] (counts include the current token). Returns [B,H,dh]."""
    B = q.shape[0]
    _, ps, K, dh = k_pages.shape
    P = block_tables.shape[1]
    kc = k_pages[block_tables].reshape(B, P * ps, K, dh)
    vc = v_pages[block_tables].reshape(B, P * ps, K, dh)
    pos = jnp.arange(P * ps, dtype=jnp.int32)
    kv_pos = jnp.where(pos[None, :] < seq_lens[:, None], pos[None, :], -1)
    q_pos = jnp.maximum(seq_lens - 1, 0).astype(jnp.int32)
    out = decode_attention(q[:, None], kc, vc, kv_pos=kv_pos, q_pos=q_pos,
                           window=window, scale=scale)
    return out[:, 0]

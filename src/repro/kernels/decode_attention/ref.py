"""Pure-jnp oracle for the flash-decoding kernel: exactly
repro.models.layers.decode_attention (the serving path's attention)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import decode_attention


def decode_attention_ref(q, k_cache, v_cache, *, kv_pos, q_pos,
                         window=None, scale=None):
    """q: [B,H,dh]; caches: [B,W,K,dh]; kv_pos: [B,W]; q_pos: [B].
    Returns [B,H,dh]."""
    out = decode_attention(q[:, None], k_cache, v_cache, kv_pos=kv_pos,
                           q_pos=q_pos, window=window, scale=scale)
    return out[:, 0]

"""Jitted wrapper for the flash-decoding kernel (batch-uniform positions)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    DEFAULT_KV_BLOCK, decode_attention_pallas)


def decode_attention(q, k_cache, v_cache, kv_pos, q_pos, *, scale=None,
                     window=None, kv_block=DEFAULT_KV_BLOCK,
                     interpret=False):
    """Drop-in for models.layers.decode_attention when positions are
    uniform across the batch (the serving engine's layout).

    q: [B,1,H,dh] → [B,1,H,dh]; kv_pos: [W]; q_pos: python/int scalar."""
    out = decode_attention_pallas(
        q[:, 0], k_cache, v_cache, jnp.asarray(kv_pos),
        q_pos, scale=scale, window=window, kv_block=kv_block,
        interpret=interpret)
    return out[:, None]

"""Jitted wrappers for the flash-decoding kernels (uniform + paged)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    DEFAULT_KV_BLOCK, decode_attention_pallas, paged_decode_attention_pallas)


def decode_attention(q, k_cache, v_cache, kv_pos, q_pos, *, scale=None,
                     window=None, kv_block=DEFAULT_KV_BLOCK,
                     interpret=False):
    """Drop-in for models.layers.decode_attention when positions are
    uniform across the batch (the serving engine's layout).

    q: [B,1,H,dh] → [B,1,H,dh]; kv_pos: [W]; q_pos: python/int scalar."""
    out = decode_attention_pallas(
        q[:, 0], k_cache, v_cache, jnp.asarray(kv_pos),
        q_pos, scale=scale, window=window, kv_block=kv_block,
        interpret=interpret)
    return out[:, None]


def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                           scale=None, window=None, use_kernel=None,
                           interpret=False):
    """Paged decode attention with kernel/oracle dispatch.

    The Pallas kernel streams pages through VMEM via a scalar-prefetched
    block table; the jnp path gathers pages into a contiguous cache and is
    the CPU/backstop implementation.  ``use_kernel=None`` auto-selects the
    kernel on TPU only.

    q: [B,1,H,dh]; k_pages/v_pages: [N, ps, K, dh]; block_tables: [B,P];
    seq_lens: [B] incl. the current token. Returns [B,1,H,dh]."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        out = paged_decode_attention_pallas(
            q[:, 0], k_pages, v_pages, block_tables, seq_lens,
            scale=scale, window=window, interpret=interpret)
        return out[:, None]
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    out = paged_decode_attention_ref(
        q[:, 0], k_pages, v_pages, block_tables, seq_lens,
        scale=scale, window=window)
    return out[:, None]

"""Elastic restore: resume the same RunSpec on a different device mesh.

Checkpoints are mesh-independent (full logical arrays; see
``checkpoint/manager.py``), so "we lost a pod" is a spec edit, not a
migration: change ``spec.mesh.shape`` and resume.  This module owns the
three pieces that make that real:

  * :func:`mesh_from_spec` — rebuild a concrete ``jax.sharding.Mesh``
    from the declarative ``MeshSpec.shape`` (a *subset* of the visible
    devices, so shrinking below the device count is legal — exactly the
    lost-pod case);
  * :func:`program_shardings` — derive (params, opt_state, batch)
    NamedShardings for the program's abstract signature from the
    partition rules in ``sharding/rules.py``.  AdaLomo's factored (r, c)
    second-moment vectors land on the devices that own the rows/columns
    they describe (``opt_pspecs`` shape-suffix matching) — the regime of
    Anil et al., *Memory-Efficient Adaptive Optimization*;
  * :func:`run_elastic` — re-jit the *same* ``StepProgram.fn`` under
    those shardings and drive it through the stock ``run()`` loop with a
    checkpoint manager that restores straight onto the new mesh
    (``restore(shardings=...)``), keeping every fleet property (resume,
    preemption, fault recovery, hooks) identical to the single-process
    path.

Numerics contract (tests/fleet/test_elastic.py): resuming on the *same*
mesh is bitwise; resuming on a *different* mesh matches to tight
tolerance (cross-device reduction order is the only difference).
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.run.program import StepProgram, build_step_program
from repro.run.spec import MeshSpec, RunSpec
from repro.sharding import rules as R

_AXES_BY_NDIM = {1: ("data",), 2: ("data", "model"),
                 3: ("pod", "data", "model")}


def mesh_from_spec(mesh: MeshSpec) -> Mesh:
    """Build the concrete mesh ``mesh.shape`` names, from a prefix of the
    visible devices (a sub-mesh, so elastic shrink works on a partially
    lost fleet)."""
    if mesh.shape is None:
        raise ValueError("MeshSpec.shape is required for an elastic mesh")
    need = mesh.n_devices()
    devices = jax.devices()
    if need > len(devices):
        raise ValueError(
            f"mesh shape {mesh.shape} needs {need} devices, only "
            f"{len(devices)} visible (start with --virtual-devices "
            f"{need} on CPU, or shrink spec.mesh.shape)")
    devs = np.array(devices[:need]).reshape(mesh.shape)
    return Mesh(devs, _AXES_BY_NDIM[len(mesh.shape)])


def program_shardings(program: StepProgram, mesh: Mesh):
    """(params, opt_state, batch, hparams[, sentinel]) NamedShardings for
    the program's abstract signature on ``mesh`` — derived from the
    partition rules, so the elastic step is sharded exactly like the
    production pjit path.  The sentinel slot (five 0-d scalars,
    replicated) appears only when the program carries the guard, matching
    ``abstract_args``."""
    axes = R.MeshAxes(mesh)
    args = program.abstract_args()
    params_sds, opt_sds, batch_sds, hp_sds = args[:4]
    p_specs = R.param_pspecs(params_sds, axes)
    o_specs = R.opt_pspecs(opt_sds, params_sds, p_specs, axes)
    b_specs = R.batch_pspecs(batch_sds, axes)
    rep = NamedSharding(mesh, P())
    out = (R.to_shardings(p_specs, mesh),
           R.to_shardings(o_specs, mesh),
           R.to_shardings(b_specs, mesh),
           jax.tree.map(lambda _: rep, hp_sds))
    if len(args) == 5:
        out += (jax.tree.map(lambda _: rep, args[4]),)
    return out


class ElasticCheckpoints:
    """A CheckpointManager view whose ``restore`` defaults to re-sharding
    onto the elastic mesh — the runner's resume and fault-recovery paths
    then place restored state correctly without knowing about meshes."""

    def __init__(self, inner, shardings):
        self._inner = inner
        self._shardings = shardings

    def restore(self, step=None, *, template=None, shardings=None):
        if shardings is None:
            shardings = self._shardings
        return self._inner.restore(step, template=template,
                                   shardings=shardings)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def run_elastic(spec: RunSpec, *, arch=None, hooks=(), params=None,
                opt_state=None, batch_iter=None, eval_iter=None,
                ckpt_manager=None, start_step: int = 0, groups=None,
                inject=None, log_fn=print):
    """``run()`` with the step executed on the ``spec.mesh.shape`` mesh.

    Called by ``run()`` itself whenever the spec names a mesh shape; the
    signature mirrors ``run()``'s overrides.  Builds the program once,
    re-jits its pure ``fn`` under rule-derived shardings (donated, like
    the single-process step), places initial state on the mesh, and
    hands everything back to the stock loop — resume/recovery restore
    through :class:`ElasticCheckpoints`, landing state on the new mesh.
    """
    mesh = mesh_from_spec(spec.mesh)
    program = build_step_program(spec, arch, groups=groups, inject=inject)
    shardings = program_shardings(program, mesh)
    p_sh, o_sh, b_sh, hp_sh = shardings[:4]

    # out_shardings pins the donated (params, opt_state) outputs to the
    # *input* shardings: without it GSPMD may propagate a different
    # layout (e.g. a factored [r] vector ending up P('data')) and the
    # next step's in_shardings reject the fed-back state.  loss/metrics
    # are scalars — replicated.
    rep = NamedSharding(mesh, P())
    if len(shardings) == 5:
        # sentinel-guarded 5-arg signature: the SentinelState rides
        # replicated through the same jitted step
        sent_sh = shardings[4]
        sharded_step = jax.jit(
            program.fn,
            in_shardings=(p_sh, o_sh, b_sh, hp_sh, sent_sh),
            out_shardings=(p_sh, o_sh, rep, rep, sent_sh),
            donate_argnums=(0, 1))

        def step(params, opt_state, batch, hp, sent):
            batch = jax.device_put(batch, b_sh)
            return sharded_step(params, opt_state, batch, hp, sent)
    else:
        sharded_step = jax.jit(program.fn,
                               in_shardings=(p_sh, o_sh, b_sh, hp_sh),
                               out_shardings=(p_sh, o_sh, rep, rep),
                               donate_argnums=(0, 1))

        def step(params, opt_state, batch, hp):
            # commit the host batch to its mesh sharding before dispatch
            # (the runner materializes batches on the default device
            # otherwise)
            batch = jax.device_put(batch, b_sh)
            return sharded_step(params, opt_state, batch, hp)

    step._cache_size = sharded_step._cache_size  # zero-recompile introspection
    program.step = step

    if params is None:
        params, opt_state = program.init(spec.seed)
    elif opt_state is None:
        opt_state = program.opt.init(params)
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    ck = spec.checkpoint
    if ckpt_manager is None and ck.dir:
        from repro.checkpoint.manager import CheckpointManager
        ckpt_manager = CheckpointManager(ck.dir, keep_last=ck.keep_last,
                                         gc_incomplete=ck.gc_incomplete)
    if ckpt_manager is not None:
        ckpt_manager = ElasticCheckpoints(ckpt_manager, (p_sh, o_sh))

    log_fn(f"elastic mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
           f"({math.prod(mesh.devices.shape)} of {len(jax.devices())} "
           f"devices)")

    from repro.run.runner import run
    return run(spec, arch=program.arch, program=program, hooks=hooks,
               params=params, opt_state=opt_state, batch_iter=batch_iter,
               eval_iter=eval_iter, ckpt_manager=ckpt_manager,
               start_step=start_step, groups=groups, log_fn=log_fn)

"""Fault-injection harness: kill runs at configurable steps, resume them,
and prove recovery is exact.

The PR 3/PR 6 rewind contract says a fault-recovered run reproduces the
uninterrupted run bitwise — history, eval curve, metrics JSONL and final
state.  This module extends that contract from *in-process transient
errors* to *process deaths*: :func:`chaos_run` executes a spec as a
sequence of runs, each killed at a scheduled step boundary (after the
checkpoint hooks for that boundary fired, like a preemption; or with the
boundary's checkpoint destroyed, like a crash mid-write), each restarted
via the normal ``checkpoint.resume`` path, until one survives to the end.
Because the data/eval streams are pure functions of the step and
checkpoints are atomic, the surviving run's record must equal the
uninterrupted run's — ``tests/fleet/test_chaos.py`` asserts it bitwise.

The PR 10 sentinel extends the harness from process deaths to *optimizer
faults*: pass ``inject=Injection(kind="nan_grads", at_step=k)`` (re-
exported here from :mod:`repro.sentinel.inject`) through ``run_kw`` and
the in-graph guard takes the hit instead of the moments — injected chaos
runs must complete, skip the poisoned update bitwise, and still resume
bitwise across kills (``tests/sentinel/test_injected_run.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.run import hooks as hooks_lib
from repro.sentinel.inject import INJECT_KINDS, Injection  # noqa: F401 (re-export)


class SimulatedKill(BaseException):
    """The chaos harness killed the run at ``step`` (boundary).  Derives
    from BaseException so no retry/recovery machinery can swallow it —
    like a real SIGKILL, nothing in the run layer gets to object."""

    def __init__(self, step: int):
        self.step = step
        super().__init__(f"chaos kill at step boundary {step}")


class KillAtHook(hooks_lib.Hook):
    """Raise :class:`SimulatedKill` at the ``at_step`` boundary.  As a
    user hook it runs after the default pipeline, so the boundary's
    checkpoint/metrics writes have already happened — the kill lands
    between "state durable" and "next step", the preemption-shaped
    worst case for bookkeeping."""

    def __init__(self, at_step: int):
        self.at_step = at_step

    def on_step_end(self, ctx, ev: hooks_lib.StepEvent) -> None:
        if ev.step + 1 == self.at_step:
            raise SimulatedKill(self.at_step)


def _wreck_latest(manager_dir) -> None:
    """Turn the newest checkpoint into a crash-mid-write orphan (delete
    its ``_COMPLETE`` marker) — the ``gc_incomplete`` machinery must then
    resume from the previous complete step."""
    from pathlib import Path
    steps = sorted(Path(manager_dir).glob("step_*"))
    if steps:
        marker = steps[-1] / "_COMPLETE"
        if marker.exists():
            marker.unlink()


@dataclasses.dataclass
class ChaosReport:
    kills: list            # [(step, resumed_from_step)]
    result: object         # final RunResult


def chaos_run(spec, kill_at: Sequence[int], *, wreck_last_save: bool = False,
              log_fn=lambda s: None, **run_kw) -> ChaosReport:
    """Run ``spec`` to completion through ``len(kill_at)`` kill/restore
    cycles.

    ``spec`` must have a checkpoint dir (``every > 0``); every attempt
    runs with ``resume=True`` + ``gc_incomplete=True`` so each restart is
    exactly what a re-invoked launcher would do.  ``wreck_last_save=True``
    additionally corrupts the newest checkpoint after each kill (crash
    mid-write), forcing resume from the previous complete step.
    ``run_kw`` is forwarded to every ``run()`` call (e.g. ``arch=`` for
    ad-hoc configs).
    """
    from repro.run.runner import run

    ck = spec.checkpoint
    if not (ck.dir and ck.every):
        raise ValueError("chaos_run requires checkpoint.dir and .every")
    spec = dataclasses.replace(
        spec, checkpoint=dataclasses.replace(ck, resume=True,
                                             gc_incomplete=True))

    kills = []
    for at in kill_at:
        try:
            run(spec, hooks=(KillAtHook(at),), log_fn=log_fn, **run_kw)
            raise AssertionError(
                f"kill at step {at} never fired (total={spec.steps.total})")
        except SimulatedKill:
            pass
        if wreck_last_save:
            _wreck_latest(ck.dir)
        from repro.checkpoint.manager import CheckpointManager
        # discovery already ignores incomplete dirs; the *next* run's
        # gc_incomplete reclaims them (the crash-mid-write machinery)
        resumed_from = CheckpointManager(ck.dir).latest_step() or 0
        kills.append((at, resumed_from))
        log_fn(f"chaos: killed at {at}, next resume from {resumed_from}")

    result = run(spec, log_fn=log_fn, **run_kw)
    return ChaosReport(kills=kills, result=result)

"""Preemption safety: SIGTERM/SIGINT → boundary checkpoint → resumable exit.

Preemptible capacity is the cheapest capacity there is, and the paper's
whole pitch is lowering the hardware barrier — so a run must treat
"the scheduler wants this machine back" as a normal event, not a crash.
The protocol:

  1. :class:`PreemptionHook` installs SIGTERM/SIGINT handlers for the
     duration of the run (main thread only; originals restored on exit).
  2. A first signal only sets a flag — the in-flight jitted step finishes.
  3. At the next step boundary the hook saves ``(params, opt_state)``
     through the run's checkpoint manager (even between regular
     ``checkpoint.every`` boundaries), writes the manager's
     ``_PREEMPTED.json`` marker, and raises :class:`Preempted`.
  4. ``run()``'s ``finally`` gives every hook its ``on_exit`` (metrics
     files close, async saves drain), then the launcher maps
     :class:`Preempted` to :data:`PREEMPTED_EXIT_CODE` (75, EX_TEMPFAIL:
     "retry me") so schedulers and the sweep driver can distinguish
     preemption from success (0) and crash (anything else).
  5. A second signal restores the original handlers, so a double Ctrl-C
     still force-quits a wedged run.

The resumed run (``checkpoint.resume=True``) restores the boundary
checkpoint, consumes (clears) the marker, and — because the data/eval
streams are pure functions of the step — reproduces the uninterrupted
run bitwise (``repro.fleet.chaos`` proves this end-to-end).
"""
from __future__ import annotations

import signal
import threading
from typing import Optional

from repro.run import hooks as hooks_lib

# EX_TEMPFAIL: the sysexits.h "temporary failure; retry" code.
PREEMPTED_EXIT_CODE = 75

_SIGNALS = (signal.SIGTERM, signal.SIGINT)


class Preempted(Exception):
    """The run checkpointed and exited on a preemption signal; it is
    resumable from ``step`` (also recorded in the checkpoint dir's
    ``_PREEMPTED.json`` marker)."""

    def __init__(self, step: int, signum: int):
        self.step = step
        self.signum = signum
        super().__init__(f"preempted by signal {signum}; "
                         f"checkpointed at step {step} (resumable)")


class PreemptionHook(hooks_lib.Hook):
    """Catch SIGTERM/SIGINT, checkpoint at the next step boundary, exit
    resumable.  Registered by the default pipeline whenever the run has a
    checkpoint manager (``spec.fault.preempt``); placed *after*
    CheckpointHook so a boundary that coincides with a scheduled save
    reuses it instead of saving twice."""

    def __init__(self, manager=None):
        self.manager = manager         # default: ctx.ckpt_manager
        self.requested: Optional[int] = None
        self.fired = False
        self._originals: dict = {}

    # signal handlers are process-global state: only install when we own
    # the main thread (signal.signal raises ValueError elsewhere)
    def _installable(self) -> bool:
        return threading.current_thread() is threading.main_thread()

    def _handler(self, signum, frame) -> None:
        if self.requested is not None:
            # second signal: restore default behavior → force quit works
            self._restore()
            signal.raise_signal(signum)
            return
        self.requested = signum

    def _restore(self) -> None:
        for sig, original in self._originals.items():
            signal.signal(sig, original)
        self._originals = {}

    def on_run_start(self, ctx) -> None:
        if self.manager is None:
            self.manager = ctx.ckpt_manager
        if self.manager is not None:
            # this run consumes any marker a preempted predecessor left
            self.manager.clear_preempt_marker()
        if self._installable():
            for sig in _SIGNALS:
                self._originals[sig] = signal.signal(sig, self._handler)

    def on_step_end(self, ctx, ev: hooks_lib.StepEvent) -> None:
        if self.requested is None:
            return
        step = ev.step + 1
        signum = self.requested
        if self.manager is not None:
            if self.manager.latest_step() != step:
                # off-boundary save: the whole point of the protocol
                self.manager.save(step, (ctx.params, ctx.opt_state),
                                  extra={"data_step": step,
                                         "preempted": True})
            self.manager.wait()        # durable before we report resumable
            self.manager.write_preempt_marker(step, signum=int(signum))
        metrics = hooks_lib.find_metrics_hook(ctx.hooks)
        if metrics is not None:
            metrics.annotate("preempted", step, signum=int(signum))
        self.fired = True
        ctx.log(f"preempted (signal {signum}): checkpointed step {step}, "
                f"exiting resumable")
        raise Preempted(step, signum)

    def on_exit(self, ctx) -> None:
        self._restore()

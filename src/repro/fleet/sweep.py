"""Sweep driver: fan one base RunSpec across declarative overrides.

The tensor2tensor idiom this replaces — ``trainer_utils.py``'s
experiment-fn + flag soup — made every sweep an ad-hoc shell script.
Here a sweep is data: a base :class:`~repro.run.spec.RunSpec` plus a
list of override dicts (dotted spec paths → values, e.g.
``{"opt.lr": 3e-3, "opt.name": "adamw"}``), or a grid expanded into one.
Each member becomes a fully materialized RunSpec under its own directory:

  sweep_dir/
    report.json                 # merged, ranked (written/refreshed last)
    00_opt.lr=0.001/
      spec.json                 # the member's exact RunSpec (replayable)
      ckpt/                     # member checkpoints (+ preempt marker)
      metrics.jsonl             # MetricsHook stream (throughput+liveness)
      history.json              # HistoryHook curves
      DONE.json                 # completion marker → re-invokes skip it
    01_.../

Fleet properties, all inherited from the run layer rather than re-built:

  * **crash isolation** — members run sequentially in-process (failures
    recorded, sweep continues) or as subprocesses (``mode="subprocess"``,
    bounded by ``parallel``) where a member death cannot touch the driver;
  * **individual resumability** — member specs force ``resume=True`` +
    ``gc_incomplete=True``; re-invoking the sweep skips DONE members and
    resumes killed/preempted ones from their last complete checkpoint
    (preemption = child exit :data:`~repro.fleet.preempt.
    PREEMPTED_EXIT_CODE`);
  * **one report** — :func:`build_report` merges every member's
    HistoryHook/MetricsHook outputs (final/best loss, eval curve minimum,
    mean real-token throughput, straggler/stall event counts) into one
    JSON ranked by objective.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import subprocess
import sys
import time
from pathlib import Path
from typing import Any, Mapping, Optional, Sequence

from repro.run.spec import CheckpointSpec, RunSpec

DONE_MARKER = "DONE.json"


# --------------------------------------------------------------------------
# Declarative overrides
# --------------------------------------------------------------------------

def expand_grid(grid: Mapping[str, Sequence[Any]]) -> list[dict]:
    """Cartesian product of ``{dotted.path: [values...]}`` → override
    dicts, in deterministic (sorted-key, given-value-order) order."""
    keys = sorted(grid)
    return [dict(zip(keys, combo))
            for combo in itertools.product(*(grid[k] for k in keys))]


def apply_overrides(spec: RunSpec, overrides: Mapping[str, Any]) -> RunSpec:
    """Rebuild ``spec`` with each dotted path replaced — pure dataclass
    surgery, so an unknown field fails loudly with its full path."""
    for path in sorted(overrides):
        spec = _replace_path(spec, path.split("."), overrides[path], path)
    return spec


def _replace_path(obj, parts, value, full_path):
    if not dataclasses.is_dataclass(obj):
        raise ValueError(f"override {full_path!r}: {type(obj).__name__} "
                         "is not a spec node")
    name = parts[0]
    if not any(f.name == name for f in dataclasses.fields(obj)):
        raise ValueError(
            f"override {full_path!r}: {type(obj).__name__} has no field "
            f"{name!r} (fields: "
            f"{[f.name for f in dataclasses.fields(obj)]})")
    if len(parts) == 1:
        return dataclasses.replace(obj, **{name: value})
    return dataclasses.replace(
        obj, **{name: _replace_path(getattr(obj, name), parts[1:], value,
                                    full_path)})


def member_name(index: int, overrides: Mapping[str, Any]) -> str:
    """Deterministic, filesystem-safe member id: ``00_opt.lr=0.001``."""
    slug = "-".join(f"{k}={overrides[k]}" for k in sorted(overrides))
    slug = "".join(c if c.isalnum() or c in ".=-_" else "_" for c in slug)
    return f"{index:02d}_{slug[:80]}" if slug else f"{index:02d}_base"


@dataclasses.dataclass(frozen=True)
class SweepMember:
    name: str
    overrides: dict
    spec: RunSpec
    dir: Path

    @property
    def done_marker(self) -> Path:
        return self.dir / DONE_MARKER


def materialize(base: RunSpec, variants: Sequence[Mapping[str, Any]],
                sweep_dir) -> list[SweepMember]:
    """Expand variants into fully-specified member RunSpecs: per-member
    checkpoint dir (resume + gc_incomplete forced on), metrics stream,
    spec.json written for replay."""
    sweep_dir = Path(sweep_dir)
    members = []
    for i, ov in enumerate(variants):
        name = member_name(i, ov)
        mdir = sweep_dir / name
        spec = apply_overrides(base, ov)
        every = spec.checkpoint.every or max(1, spec.steps.total // 4)
        spec = dataclasses.replace(
            spec,
            checkpoint=CheckpointSpec(dir=str(mdir / "ckpt"), every=every,
                                      resume=True,
                                      keep_last=spec.checkpoint.keep_last,
                                      gc_incomplete=True),
            metrics_path=str(mdir / "metrics.jsonl"))
        mdir.mkdir(parents=True, exist_ok=True)
        (mdir / "spec.json").write_text(spec.to_json(indent=1))
        members.append(SweepMember(name=name, overrides=dict(ov),
                                   spec=spec, dir=mdir))
    return members


# --------------------------------------------------------------------------
# Execution
# --------------------------------------------------------------------------

def _write_done(member: SweepMember, history: dict) -> None:
    (member.dir / "history.json").write_text(json.dumps(history))
    final = history.get("loss", [])
    member.done_marker.write_text(json.dumps(
        {"name": member.name, "steps": member.spec.steps.total,
         "final_loss": final[-1] if final else None}))


def _run_member_inproc(member: SweepMember, *, log_fn, member_hooks,
                       run_kwargs) -> str:
    """One member in this process; returns its status.  Any exception is
    contained (crash isolation) — only KeyboardInterrupt and the chaos
    harness's SimulatedKill propagate, so tests can kill a member
    mid-sweep exactly like a process death."""
    from repro.fleet.preempt import Preempted
    from repro.run.runner import run
    hooks = tuple(member_hooks(member)) if member_hooks else ()
    try:
        res = run(member.spec, hooks=hooks, log_fn=log_fn,
                  **(run_kwargs or {}))
    except Preempted as e:
        log_fn(f"[{member.name}] preempted at step {e.step} (resumable)")
        return "preempted"
    except KeyboardInterrupt:
        raise
    except Exception as e:
        (member.dir / "error.txt").write_text(
            f"{type(e).__name__}: {e}\n")
        log_fn(f"[{member.name}] failed: {type(e).__name__}: {e}")
        return "failed"
    _write_done(member, res.history)
    return "done"


def _run_members_subprocess(todo: list[SweepMember], *, parallel: int,
                            extra_args: Sequence[str], log_fn) -> dict:
    """Crash-isolated members: each is ``python -m repro.launch.train
    --spec <member>/spec.json``, at most ``parallel`` in flight."""
    from repro.fleet.preempt import PREEMPTED_EXIT_CODE
    statuses: dict[str, str] = {}
    pending = list(todo)
    live: list[tuple[SweepMember, subprocess.Popen, Any]] = []
    while pending or live:
        while pending and len(live) < max(1, parallel):
            m = pending.pop(0)
            log = open(m.dir / "stdout.log", "w")
            cmd = [sys.executable, "-m", "repro.launch.train",
                   "--spec", str(m.dir / "spec.json"),
                   "--history-out", str(m.dir / "history.json"),
                   *extra_args]
            live.append((m, subprocess.Popen(
                cmd, stdout=log, stderr=subprocess.STDOUT), log))
            log_fn(f"[{m.name}] launched (pid "
                   f"{live[-1][1].pid}, {len(live)} in flight)")
        still = []
        for m, proc, log in live:
            rc = proc.poll()
            if rc is None:
                still.append((m, proc, log))
                continue
            log.close()
            if rc == 0:
                hist_file = m.dir / "history.json"
                hist = (json.loads(hist_file.read_text())
                        if hist_file.exists() else {})
                _write_done(m, hist)
                statuses[m.name] = "done"
            elif rc == PREEMPTED_EXIT_CODE:
                statuses[m.name] = "preempted"
            else:
                statuses[m.name] = "failed"
            log_fn(f"[{m.name}] exit {rc} → {statuses[m.name]}")
        live = still
        if live:
            time.sleep(0.05)
    return statuses


def run_sweep(base: RunSpec, variants: Sequence[Mapping[str, Any]],
              sweep_dir, *, mode: str = "inproc", parallel: int = 1,
              extra_args: Sequence[str] = (), member_hooks=None,
              run_kwargs: Optional[dict] = None, objective: str = "loss",
              log_fn=print) -> dict:
    """Drive the sweep to (partial) completion and write the merged
    report.  Idempotent: re-invoke after any crash/preemption and DONE
    members are skipped while the rest resume from their checkpoints.

    ``member_hooks(member) -> hooks`` (inproc only) injects per-member
    hooks — the chaos tests' kill switch; ``run_kwargs`` forwards to
    ``run()`` (e.g. ``arch=`` for ad-hoc archs); ``extra_args`` appends
    to the subprocess command line (e.g. ``--virtual-devices 4``)."""
    assert mode in ("inproc", "subprocess"), mode
    sweep_dir = Path(sweep_dir)
    members = materialize(base, variants, sweep_dir)

    statuses: dict[str, str] = {}
    todo = []
    for m in members:
        if m.done_marker.exists():
            statuses[m.name] = "done"
            log_fn(f"[{m.name}] already done, skipping")
        else:
            todo.append(m)

    if mode == "inproc":
        for m in todo:
            log_fn(f"[{m.name}] running ({len(statuses)+1}/{len(members)})")
            statuses[m.name] = _run_member_inproc(
                m, log_fn=log_fn, member_hooks=member_hooks,
                run_kwargs=run_kwargs)
    else:
        statuses.update(_run_members_subprocess(
            todo, parallel=parallel, extra_args=extra_args, log_fn=log_fn))

    report = build_report(base, members, statuses, objective=objective)
    (sweep_dir / "report.json").write_text(json.dumps(report, indent=1,
                                                      sort_keys=True))
    return report


# --------------------------------------------------------------------------
# Report
# --------------------------------------------------------------------------

def _member_stats(member: SweepMember) -> dict:
    """Merge one member's HistoryHook + MetricsHook artifacts."""
    stats: dict[str, Any] = {}
    hist_file = member.dir / "history.json"
    if hist_file.exists():
        h = json.loads(hist_file.read_text())
        if h.get("loss"):
            stats["final_loss"] = h["loss"][-1]
            stats["best_loss"] = min(h["loss"])
        if h.get("eval_loss"):
            stats["best_eval_loss"] = min(h["eval_loss"])
    metrics = member.dir / "metrics.jsonl"
    if metrics.exists():
        # versioned-stream aware (Telemetry v1): the lenient reader skips
        # the schema header and truncated tails; classify() keeps probe /
        # gauge records out of the step statistics.
        from repro.telemetry.schema import classify, iter_data_records
        steps, tps, events, last_loss = [], [], {}, None
        anomalies = 0
        for r in iter_data_records(metrics.read_text().splitlines()):
            kind = classify(r)
            if kind == "event":
                events[r["event"]] = events.get(r["event"], 0) + 1
            elif kind == "anomaly":
                anomalies += 1
            elif kind == "step":
                steps.append(r["step"])
                last_loss = r.get("loss", last_loss)
                if r.get("tokens_per_s"):
                    tps.append(r["tokens_per_s"])
        if steps:
            stats["steps_done"] = max(steps) + 1
            # partial runs (killed/preempted) have no history.json yet;
            # the metrics stream still gives a best-effort loss
            stats.setdefault("final_loss", last_loss)
        if tps[1:]:     # drop the compile step's throughput
            stats["mean_tokens_per_s"] = sum(tps[1:]) / len(tps[1:])
        if events:
            stats["events"] = events
        if anomalies:
            stats["anomalies"] = anomalies
    return stats


def build_report(base: RunSpec, members: Sequence[SweepMember],
                 statuses: Mapping[str, str], *,
                 objective: str = "loss") -> dict:
    """The one merged sweep artifact: per-member stats + a ranking of
    completed members by ``objective`` ("loss" → final_loss ascending,
    "eval_loss" → best_eval_loss ascending)."""
    key = {"loss": "final_loss", "eval_loss": "best_eval_loss"}[objective]
    rows = []
    for m in members:
        rows.append({"name": m.name, "overrides": m.overrides,
                     "status": statuses.get(m.name, "pending"),
                     **_member_stats(m)})
    ranked = sorted(
        (r for r in rows if r["status"] == "done" and r.get(key) is not None),
        key=lambda r: r[key])
    return {"objective": key,
            "n_members": len(rows),
            "n_done": sum(1 for r in rows if r["status"] == "done"),
            "ranking": [r["name"] for r in ranked],
            "best": (ranked[0] if ranked else None),
            "members": rows,
            "base_spec": base.to_dict()}

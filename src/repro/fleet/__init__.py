"""Elastic training fleet: the resilience layer over the Run API.

Single-shot ``run(spec)`` plus mesh-independent checkpoints already
contain every primitive a preemptible fleet needs; this package is the
layer that uses them (DESIGN.md §"Elastic training fleet"):

  * ``elastic``  — resume the same RunSpec on a different device mesh
                   (``MeshSpec.shape``); factored AdaLomo state reshards
                   losslessly;
  * ``preempt``  — SIGTERM/SIGINT → boundary checkpoint → resumable
                   marker → exit :data:`PREEMPTED_EXIT_CODE`;
  * ``chaos``    — fault injection: kill/resume cycles that must stay
                   bitwise-equal to the uninterrupted run;
  * ``sweep``    — fan a base RunSpec across declarative overrides into
                   crash-isolated, individually resumable members with
                   one merged, ranked report (``launch/sweep.py`` CLI).
"""
from repro.fleet.chaos import (INJECT_KINDS, ChaosReport, Injection,
                               KillAtHook, SimulatedKill, chaos_run)
from repro.fleet.elastic import ElasticCheckpoints, mesh_from_spec, \
    program_shardings, run_elastic
from repro.fleet.preempt import PREEMPTED_EXIT_CODE, Preempted, \
    PreemptionHook
from repro.fleet.sweep import SweepMember, apply_overrides, build_report, \
    expand_grid, materialize, member_name, run_sweep

__all__ = [
    "mesh_from_spec", "program_shardings", "run_elastic",
    "ElasticCheckpoints",
    "Preempted", "PreemptionHook", "PREEMPTED_EXIT_CODE",
    "SimulatedKill", "KillAtHook", "chaos_run", "ChaosReport",
    "Injection", "INJECT_KINDS",
    "expand_grid", "apply_overrides", "materialize", "member_name",
    "SweepMember", "run_sweep", "build_report",
]

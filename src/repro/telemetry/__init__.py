"""Telemetry v1 — one observability layer for train, serve, and kernels
(DESIGN.md §"Telemetry v1").

Everything emits into a single schema-versioned JSONL stream format
(:mod:`repro.telemetry.schema` — a superset of the MetricsHook format):

* **optimizer-health probes** (:mod:`~repro.telemetry.probes`) — folded
  into the jitted step program, riding the runner's one bundled per-step
  ``device_get`` (zero extra recompiles, zero extra host syncs);
* **serve gauges** (:mod:`~repro.telemetry.serve`) — pool / scheduler /
  time-split sampling at the engine's chunk boundaries;
* **kernel roofline counters** (:mod:`~repro.telemetry.kernels`) +
  Chrome-trace export (:mod:`~repro.telemetry.trace`);
* one merging CLI: ``python -m repro.telemetry.report``.
"""
from repro.telemetry.kernels import (KernelCounters, adalomo_update_counters,
                                     counters_for,
                                     paged_decode_attention_counters,
                                     zoo_cases)
from repro.telemetry.probes import ObservabilitySpec, instrument_step
from repro.telemetry.schema import (SCHEMA_VERSION, SchemaError,
                                    TelemetryStream, classify, header_record,
                                    iter_data_records, jsonify,
                                    parse_records, read_stream,
                                    validate_bench, validate_bench_dir,
                                    validate_record)
from repro.telemetry.serve import ServeTelemetry
from repro.telemetry.trace import chrome_trace, write_chrome_trace
from repro.telemetry.writer import TelemetryWriter

__all__ = [
    "SCHEMA_VERSION", "SchemaError", "TelemetryStream", "classify",
    "header_record", "iter_data_records", "jsonify", "parse_records",
    "read_stream", "validate_record", "validate_bench",
    "validate_bench_dir",
    "ObservabilitySpec", "instrument_step",
    "ServeTelemetry", "TelemetryWriter",
    "KernelCounters", "counters_for", "adalomo_update_counters",
    "paged_decode_attention_counters", "zoo_cases",
    "chrome_trace", "write_chrome_trace",
]

"""Append-only JSONL telemetry writer — the one producer-side path into
a schema-v1 stream (DESIGN.md §"Telemetry v1").

Producers (MetricsHook, ServeTelemetry, the roofline benchmark) share
the same write discipline the PR 6 MetricsHook established: line-
buffered appends, flush per record so a crash loses at most the
partially-written tail line (which the non-strict reader skips), and a
header written exactly once per file — re-opening an existing stream
for resume fast-forwards past the header instead of duplicating it.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

import json

from repro.telemetry.schema import header_record, jsonify


class TelemetryWriter:
    """Appends v1 records to one JSONL stream file.

    ``stream`` names the producer family for the header ("train",
    "serve", "kernel").  On open: a missing/empty file gets a fresh
    header; a non-empty file is assumed mid-stream (resume) and is
    appended to as-is — stream-level rewind (dropping records from a
    rolled-back step) stays the owner's job, as in MetricsHook.
    """

    def __init__(self, path, *, stream: str, **meta):
        self.path = Path(path)
        self.stream = stream
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fresh = not (self.path.exists() and self.path.stat().st_size > 0)
        self._f = open(self.path, "a", buffering=1)
        if fresh:
            self.write(header_record(stream, **meta))

    def write(self, rec: dict) -> None:
        self._f.write(json.dumps(jsonify(rec)) + "\n")
        self._f.flush()

    # -- typed record helpers ------------------------------------------
    def probe(self, family: str, step: int, **payload) -> None:
        self.write({"probe": family, "step": int(step), **payload})

    def gauge(self, family: str, t_s: float, **payload) -> None:
        self.write({"gauge": family, "t_s": float(t_s), **payload})

    def kernel(self, name: str, *, flops: float, bytes: float,
               **payload) -> None:
        self.write({"kernel": name, "flops": float(flops),
                    "bytes": float(bytes), **payload})

    def event(self, name: str, step: int, **payload) -> None:
        self.write({"event": name, "step": int(step), **payload})

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "TelemetryWriter":
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.close()
        return None

"""``python -m repro.telemetry.report`` — merge telemetry streams into
one ranked summary (text or JSON).

Reads any number of schema-v1 (or legacy, headerless) JSONL streams —
a training run's MetricsHook file, a serve engine's gauge stream, a
roofline benchmark's kernel stream — and merges them into a single
summary: training curve endpoints and throughput, optimizer-probe
families with their latest values, serve pool/queue/time-split state,
and kernel launches ranked by measured wall time.

Reproduction contract (asserted by ``tests/telemetry/test_report.py``):
the summary's ``final_loss``, ``tokens_per_s.final`` and
``pool_utilization.final`` are the recorded stream values **verbatim** —
no re-derivation, no rounding — so the report is bitwise-faithful to the
run it summarizes, and its output on a fixed stream is golden-stable.

    PYTHONPATH=src python -m repro.telemetry.report out/metrics.jsonl \
        [serve.jsonl ...] [--json] [--out report.json] [--chrome-trace t.json]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Optional, Sequence

from repro.telemetry.schema import TelemetryStream, read_stream


def _mean(xs: list) -> Optional[float]:
    return sum(xs) / len(xs) if xs else None


def _summarize_train(streams: Sequence[TelemetryStream]) -> Optional[dict]:
    steps, events, probes, anomalies = [], {}, {}, {}
    anomaly_last = None
    for st in streams:
        steps.extend(st.steps())
        for r in st.events():
            events[r["event"]] = events.get(r["event"], 0) + 1
        for r in st.anomalies():
            anomalies[r["anomaly"]] = anomalies.get(r["anomaly"], 0) + 1
            anomaly_last = r["step"]
        for r in st.probes():
            fam = probes.setdefault(r["probe"], {"records": 0})
            fam["records"] += 1
            fam["last_step"] = r["step"]
            fam["last"] = {k: v for k, v in r.items()
                           if k not in ("probe", "step")}
    if not steps and not probes and not events and not anomalies:
        return None
    out: dict = {"steps": len(steps)}
    if steps:
        steps.sort(key=lambda r: r["step"])
        last = steps[-1]
        out["first_step"] = steps[0]["step"]
        out["last_step"] = last["step"]
        # verbatim stream values — the bitwise reproduction contract
        out["final_loss"] = last.get("loss")
        losses = [r["loss"] for r in steps if r.get("loss") is not None]
        out["min_loss"] = min(losses) if losses else None
        tps = [r["tokens_per_s"] for r in steps
               if r.get("tokens_per_s") is not None]
        out["tokens_per_s"] = {
            "final": tps[-1] if tps else None,
            # drop the compile step, as BENCH_step_time does
            "mean_after_first": _mean(tps[1:]),
        }
        pe = [r["padding_efficiency"] for r in steps
              if r.get("padding_efficiency") is not None]
        if pe:
            out["padding_efficiency"] = {"final": pe[-1], "mean": _mean(pe)}
    if events:
        out["events"] = dict(sorted(events.items()))
    if anomalies:
        out["anomalies"] = {"records": sum(anomalies.values()),
                            "by_reason": dict(sorted(anomalies.items())),
                            "last_step": anomaly_last}
    if probes:
        out["probes"] = dict(sorted(probes.items()))
    return out


def _summarize_serve(streams: Sequence[TelemetryStream]) -> Optional[dict]:
    gauges = []
    for st in streams:
        gauges.extend(st.gauges())
    if not gauges:
        return None
    gauges.sort(key=lambda r: r["t_s"])
    last = gauges[-1]
    util = [r["pool_util"] for r in gauges if "pool_util" in r]
    out = {
        "samples": len(gauges),
        "pool_utilization": {
            "final": util[-1] if util else None,   # verbatim — bitwise
            "max": max(util) if util else None,
            "mean": _mean(util),
        },
        "queue_depth_max": max((r.get("queue_depth", 0) for r in gauges),
                               default=0),
        "running_max": max((r.get("running", 0) for r in gauges),
                           default=0),
    }
    for key in ("admitted", "preempted", "finished", "evicted_pages",
                "timed_out", "prefill_s", "decode_s", "chunks"):
        if key in last:
            out[key] = last[key]
    if out.get("prefill_s") is not None and out.get("decode_s") is not None:
        tot = out["prefill_s"] + out["decode_s"]
        out["prefill_frac"] = out["prefill_s"] / tot if tot > 0 else None
    return out


def _summarize_kernels(streams: Sequence[TelemetryStream]) -> Optional[dict]:
    rows = []
    for st in streams:
        rows.extend(st.kernels())
    if not rows:
        return None
    # ranked: measured launches by wall time desc, analytic rows after
    rows.sort(key=lambda r: (-float(r.get("wall_us", -1.0)),
                             r["kernel"], json.dumps(r.get("shape", {}),
                                                     sort_keys=True)))
    return {"launches": len(rows), "ranked": rows}


def summarize(streams: Sequence[TelemetryStream]) -> dict:
    """Merge parsed streams into the one summary dict."""
    out: dict = {
        "schema_versions": sorted({st.schema for st in streams}),
        "streams": [st.path or "<memory>" for st in streams],
    }
    for key, fn in (("train", _summarize_train),
                    ("serve", _summarize_serve),
                    ("kernels", _summarize_kernels)):
        section = fn(streams)
        if section is not None:
            out[key] = section
    return out


# --------------------------------------------------------------------------
# Text rendering (golden-stable: fixed ordering, repr for verbatim values)
# --------------------------------------------------------------------------

def _fmt(x) -> str:
    """Derived quantities: short, stable formatting."""
    if x is None:
        return "-"
    if isinstance(x, float):
        return f"{x:.6g}"
    return str(x)


def render_text(summary: dict) -> str:
    lines = [f"telemetry report — streams: {len(summary['streams'])} "
             f"(schema {', '.join(map(str, summary['schema_versions']))})"]
    tr = summary.get("train")
    if tr:
        lines.append("")
        lines.append(f"train: {tr['steps']} steps")
        if "final_loss" in tr:
            lines.append(f"  steps {tr['first_step']}..{tr['last_step']}  "
                         f"final_loss {tr['final_loss']!r}  "
                         f"min_loss {_fmt(tr['min_loss'])}")
            tps = tr["tokens_per_s"]
            lines.append(f"  tokens_per_s final {tps['final']!r}  "
                         f"mean[1:] {_fmt(tps['mean_after_first'])}")
            if "padding_efficiency" in tr:
                pe = tr["padding_efficiency"]
                lines.append(f"  padding_efficiency final "
                             f"{_fmt(pe['final'])}  mean {_fmt(pe['mean'])}")
        for name, count in (tr.get("events") or {}).items():
            lines.append(f"  event {name}: {count}")
        an = tr.get("anomalies")
        if an:
            reasons = "  ".join(f"{k} {v}" for k, v in
                                an["by_reason"].items())
            lines.append(f"  anomalies: {an['records']} ({reasons}), "
                         f"last @ step {an['last_step']}")
        for name, fam in (tr.get("probes") or {}).items():
            lines.append(f"  probe {name}: {fam['records']} records, "
                         f"last @ step {fam['last_step']}")
    sv = summary.get("serve")
    if sv:
        lines.append("")
        pu = sv["pool_utilization"]
        lines.append(f"serve: {sv['samples']} gauge samples")
        lines.append(f"  pool_utilization final {pu['final']!r}  "
                     f"max {_fmt(pu['max'])}  mean {_fmt(pu['mean'])}")
        lines.append(f"  queue_depth_max {sv['queue_depth_max']}  "
                     f"running_max {sv['running_max']}")
        counters = [f"{k} {sv[k]}" for k in
                    ("admitted", "preempted", "finished", "evicted_pages",
                     "timed_out")
                    if k in sv]
        if counters:
            lines.append("  " + "  ".join(counters))
        if sv.get("prefill_frac") is not None:
            lines.append(f"  time split: prefill {_fmt(sv['prefill_s'])}s "
                         f"/ decode {_fmt(sv['decode_s'])}s "
                         f"(prefill_frac {_fmt(sv['prefill_frac'])})")
    kn = summary.get("kernels")
    if kn:
        lines.append("")
        lines.append(f"kernels: {kn['launches']} launches (ranked)")
        for r in kn["ranked"]:
            wall = (f"{float(r['wall_us']):.1f} us"
                    if "wall_us" in r else "analytic")
            frac = (f"  {100 * float(r['frac_of_peak']):.1f}% of peak"
                    if "frac_of_peak" in r else "")
            lines.append(
                f"  {r['kernel']:<24} {wall:>12}  "
                f"{float(r['flops']) / 1e6:10.3f} MFLOP  "
                f"{float(r['bytes']) / 1e6:10.3f} MB  "
                f"AI {float(r.get('intensity', 0.0)):.2f}{frac}")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("streams", nargs="+", help="telemetry JSONL stream(s)")
    ap.add_argument("--json", action="store_true",
                    help="print the summary as JSON instead of text")
    ap.add_argument("--out", default=None,
                    help="also write the JSON summary to this path")
    ap.add_argument("--chrome-trace", default=None,
                    help="also export a Chrome-trace/Perfetto JSON of the "
                         "first stream")
    ap.add_argument("--lenient", action="store_true",
                    help="skip malformed lines instead of failing")
    args = ap.parse_args(argv)

    streams = [read_stream(p, strict=not args.lenient)
               for p in args.streams]
    summary = summarize(streams)
    if args.out:
        Path(args.out).write_text(
            json.dumps(summary, indent=1, sort_keys=True) + "\n")
    if args.chrome_trace:
        from repro.telemetry.trace import write_chrome_trace
        write_chrome_trace(streams[0], args.chrome_trace)
    text = (json.dumps(summary, indent=1, sort_keys=True)
            if args.json else render_text(summary))
    sys.stdout.write(text if text.endswith("\n") else text + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

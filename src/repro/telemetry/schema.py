"""Telemetry stream schema v1 — one versioned JSONL format for train,
serve, and kernel telemetry (DESIGN.md §"Telemetry v1").

A *stream* is a JSONL file.  Version-1 streams open with a **header
record** ``{"schema": 1, "stream": ...}``; every later line is a data
record of exactly one kind, discriminated by its marker key:

  ================  ==========================  =========================
  kind              marker                      required fields
  ================  ==========================  =========================
  ``header``        ``schema``                  ``schema`` (int >= 1)
  ``step``          none of the below           ``step``
  ``event``         ``event``                   ``event``, ``step``
  ``probe``         ``probe``                   ``probe``, ``step``
  ``gauge``         ``gauge``                   ``gauge``, ``t_s``
  ``kernel``        ``kernel``                  ``kernel``, ``flops``,
                                                ``bytes``
  ``anomaly``       ``anomaly``                 ``anomaly``, ``step``
  ================  ==========================  =========================

``step`` records are the pre-v1 MetricsHook format unchanged (step, loss,
lr, dt_s, ntokens, tokens_per_s, ...); ``event`` records are the PR 7
liveness annotations.  v1 *adds* probe / gauge / kernel kinds and the
header — a legacy stream (no header) is schema 0 and still reads
cleanly, which is the back-compat contract ``tests/run`` asserts.

The reader is validating: :func:`read_stream` classifies every record,
raises :class:`SchemaError` on a record that claims a kind but misses its
required fields or on a header from the future, and (non-strict mode)
skips crash-truncated trailing lines exactly like the resume path in
``run.hooks.MetricsHook``.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Iterable, Iterator, Optional

SCHEMA_VERSION = 1

# record kind -> (marker key, required fields)
_KINDS = {
    "event": ("event", ("event", "step")),
    "probe": ("probe", ("probe", "step")),
    "gauge": ("gauge", ("gauge", "t_s")),
    "kernel": ("kernel", ("kernel", "flops", "bytes")),
    # training-sentinel verdicts: marker value is the detection reason
    # ("nonfinite" | "spike" | "trust"), step is where it fired
    "anomaly": ("anomaly", ("anomaly", "step")),
}


class SchemaError(ValueError):
    """A telemetry record (or stream) violates the v1 schema."""


def header_record(stream: str, **meta) -> dict:
    """The version-1 stream opener.  ``stream`` names the producer family
    ("train", "serve", "kernel", ...); ``meta`` rides along verbatim."""
    return {"schema": SCHEMA_VERSION, "stream": stream, **meta}


def classify(rec: dict) -> str:
    """Record kind by marker key (no validation): header | event | probe |
    gauge | kernel | anomaly | step."""
    if "schema" in rec:
        return "header"
    for kind, (marker, _) in _KINDS.items():
        if marker in rec:
            return kind
    return "step"


def validate_record(rec: Any) -> str:
    """Validate one record against the v1 schema; returns its kind."""
    if not isinstance(rec, dict):
        raise SchemaError(f"record is {type(rec).__name__}, not an object")
    kind = classify(rec)
    if kind == "header":
        v = rec["schema"]
        if not isinstance(v, int) or v < 1:
            raise SchemaError(f"header schema={v!r} is not a version >= 1")
        if v > SCHEMA_VERSION:
            raise SchemaError(
                f"stream schema v{v} is newer than this reader "
                f"(v{SCHEMA_VERSION}) — refusing to guess at its records")
        return kind
    if kind == "step":
        if "step" not in rec:
            raise SchemaError(f"step record without 'step': {rec!r}")
        return kind
    _, required = _KINDS[kind]
    missing = [k for k in required if k not in rec]
    if missing:
        raise SchemaError(f"{kind} record missing {missing}: {rec!r}")
    return kind


def jsonify(x):
    """Host metric values -> JSON scalars/lists: numpy arrays via
    ``tolist``, 0-d values via ``float``; dicts/lists recurse.  Values are
    host-side by the StepEvent contract — this is formatting, not a sync."""
    if isinstance(x, dict):
        return {k: jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonify(v) for v in x]
    if hasattr(x, "tolist"):
        return x.tolist()
    if hasattr(x, "ndim") and x.ndim == 0:
        return float(x)
    return x


@dataclasses.dataclass
class TelemetryStream:
    """A parsed stream: schema version (0 = legacy, headerless), the
    header (None for legacy), and records classified by kind."""

    path: Optional[str]
    schema: int
    header: Optional[dict]
    records: list            # [(kind, record), ...] in file order

    def of_kind(self, kind: str, family: Optional[str] = None) -> list:
        marker = _KINDS.get(kind, (None,))[0]
        return [r for k, r in self.records
                if k == kind and (family is None or r.get(marker) == family)]

    def steps(self) -> list:
        return self.of_kind("step")

    def events(self, family: Optional[str] = None) -> list:
        return self.of_kind("event", family)

    def probes(self, family: Optional[str] = None) -> list:
        return self.of_kind("probe", family)

    def gauges(self, family: Optional[str] = None) -> list:
        return self.of_kind("gauge", family)

    def kernels(self) -> list:
        return self.of_kind("kernel")

    def anomalies(self, family: Optional[str] = None) -> list:
        """Sentinel anomaly records, optionally filtered by reason."""
        return self.of_kind("anomaly", family)


def parse_records(lines: Iterable[str], *, strict: bool = True,
                  path: Optional[str] = None) -> TelemetryStream:
    """Classify + validate an iterable of JSONL lines into a
    :class:`TelemetryStream`.  Non-strict mode skips unparseable lines
    (crash-truncated tails) instead of raising."""
    schema, header = 0, None
    records: list = []
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            if strict:
                raise SchemaError(
                    f"{path or '<stream>'}:{i + 1}: not valid JSON")
            continue
        kind = validate_record(rec)
        if kind == "header":
            if header is not None and strict:
                raise SchemaError(
                    f"{path or '<stream>'}:{i + 1}: duplicate header")
            schema, header = rec["schema"], rec
            continue
        records.append((kind, rec))
    return TelemetryStream(path=path, schema=schema, header=header,
                           records=records)


def read_stream(path, *, strict: bool = True) -> TelemetryStream:
    """Read + validate one JSONL telemetry stream (legacy or v1)."""
    p = Path(path)
    return parse_records(p.read_text().splitlines(), strict=strict,
                         path=str(p))


def iter_data_records(lines: Iterable[str]) -> Iterator[dict]:
    """Lenient record iterator for consumers that only want data records
    (headers and broken lines skipped) — the ``find_metrics_hook``-
    consumer back-compat surface: works on legacy and v1 streams alike."""
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            continue
        if not isinstance(rec, dict) or "schema" in rec:
            continue
        yield rec


# --------------------------------------------------------------------------
# Committed-benchmark (BENCH_*.json) validation
# --------------------------------------------------------------------------

# Required top-level keys per committed baseline; every BENCH file must at
# minimum be a non-empty JSON object.  CI runs validate_bench_dir over
# benchmarks/ so a half-written or hand-edited baseline fails fast.
BENCH_REQUIRED = {
    "BENCH_roofline": ("backend", "peak", "kernels"),
    "BENCH_serve": ("config", "paged", "legacy", "pool_utilization"),
    "BENCH_step_time": (),
    "BENCH_sweep": (),
    "BENCH_packing": (),
}


def validate_bench(path) -> dict:
    """Validate one committed ``BENCH_*.json``; returns the payload."""
    p = Path(path)
    try:
        payload = json.loads(p.read_text())
    except ValueError as e:
        raise SchemaError(f"{p.name}: not valid JSON ({e})")
    if not isinstance(payload, dict) or not payload:
        raise SchemaError(f"{p.name}: expected a non-empty JSON object")
    required = BENCH_REQUIRED.get(p.stem, ())
    missing = [k for k in required if k not in payload]
    if missing:
        raise SchemaError(f"{p.name}: missing required keys {missing}")
    if p.stem == "BENCH_roofline":
        for row in payload["kernels"]:
            for k in ("kernel", "flops", "bytes", "wall_us"):
                if k not in row:
                    raise SchemaError(
                        f"{p.name}: kernel row missing {k!r}: {row!r}")
    return payload


def validate_bench_dir(bench_dir) -> list:
    """Validate every committed BENCH_*.json under ``bench_dir``; returns
    the validated file names (CI fails on the first SchemaError)."""
    names = []
    for p in sorted(Path(bench_dir).glob("BENCH_*.json")):
        validate_bench(p)
        names.append(p.name)
    return names


def main(argv=None) -> int:
    """CI entry: ``python -m repro.telemetry.schema benchmarks`` validates
    every committed BENCH_*.json (scripts/ci.sh static stage)."""
    import argparse
    ap = argparse.ArgumentParser(
        description="validate committed BENCH_*.json baselines")
    ap.add_argument("bench_dir", help="directory holding BENCH_*.json")
    args = ap.parse_args(argv)
    names = validate_bench_dir(args.bench_dir)
    print(f"schema-validated {len(names)} committed benchmarks: "
          f"{', '.join(names) or '(none)'}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Chrome-trace / Perfetto exporter for telemetry streams.

Converts a schema-v1 :class:`~repro.telemetry.schema.TelemetryStream`
into the Trace Event JSON format (``chrome://tracing`` / Perfetto's
legacy loader): step records become duration events on a ``train`` track
(dur = the step's host wall ``dt_s``), probe and event records become
instant events at their step's end, serve gauges become counter tracks
(pool utilization / queue depth plotted over time), and kernel records
become duration events on a ``kernels`` track when they carry a measured
``wall_us``.

Timebases: train tracks place events on the cumulative step clock
(Σ dt_s); serve tracks use the gauge records' own ``t_s``.  Both are
microseconds in the output, as the format requires.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.telemetry.schema import TelemetryStream, jsonify

# stable pid per producer family → stable track grouping in the UI
_PIDS = {"train": 1, "serve": 2, "kernel": 3}

_GAUGE_COUNTERS = ("pool_util", "queue_depth", "running",
                   "block_table_occupancy")


def _ev(name, ph, ts, pid, tid, **kw) -> dict:
    out = {"name": name, "ph": ph, "ts": ts, "pid": pid, "tid": tid}
    out.update(kw)
    return out


def chrome_trace(stream: TelemetryStream) -> dict:
    """Render one stream as a Trace Event JSON object."""
    events = []
    fam = (stream.header or {}).get("stream", "train")
    pid = _PIDS.get(fam, 9)
    events.append(_ev("process_name", "M", 0, pid, 0,
                      args={"name": f"repro/{fam}"}))

    # ---- train: steps on the cumulative step clock -------------------
    t_us = 0.0
    step_end_us = {}
    for rec in stream.steps():
        dur = float(rec.get("dt_s", 0.0)) * 1e6
        args = {k: v for k, v in rec.items() if k not in ("step", "dt_s")}
        events.append(_ev(f"step", "X", t_us, pid, 0, dur=dur,
                          args=jsonify({"step": rec["step"], **args})))
        t_us += dur
        step_end_us[rec["step"]] = t_us
    for kind, track in (("probe", 1), ("event", 2)):
        for rec in stream.of_kind(kind):
            ts = step_end_us.get(rec["step"], t_us)
            events.append(_ev(f"{kind}:{rec[kind]}", "i", ts, pid, track,
                              s="t", args=jsonify(rec)))

    # ---- serve: counter tracks on the gauge clock --------------------
    for rec in stream.gauges():
        ts = float(rec["t_s"]) * 1e6
        for key in _GAUGE_COUNTERS:
            if key in rec:
                events.append(_ev(key, "C", ts, pid, 0,
                                  args={key: rec[key]}))

    # ---- kernels: measured launches as duration events ---------------
    k_us = 0.0
    for rec in stream.kernels():
        dur = float(rec.get("wall_us", 0.0))
        events.append(_ev(rec["kernel"], "X", k_us, pid, 0, dur=dur,
                          args=jsonify({k: v for k, v in rec.items()
                                        if k != "kernel"})))
        k_us += dur
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(stream: TelemetryStream, path) -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(chrome_trace(stream), indent=1) + "\n")
    return p

"""Serve-engine gauges: pool/scheduler/timing observability for the
continuous-batching engine (DESIGN.md §"Telemetry v1").

:class:`ServeTelemetry` samples the engine's *host-side bookkeeping* at
chunk boundaries — the page allocator's free list, the scheduler's slot
table and queue, and the lifecycle counters — plus the prefill-vs-decode
wall-time split the engine accumulates.  Nothing here reads a device
array: the sample is O(batch) host arithmetic after the chunk's one
sanctioned ``device_get``, so the gauge path adds zero host syncs to the
decode loop (repro-lint R2).

Gauge record (one per sampled chunk boundary)::

    {"gauge": "serve", "t_s": <s since attach>,
     "pool_util":   allocated / allocatable pages   (page 0 excluded),
     "pool_free":   free pages,
     "block_table_occupancy": owned page slots / (max_batch * P),
     "queue_depth": waiting requests, "running": active slots,
     "admitted": ..., "preempted": ..., "finished": ...,   # cumulative
     "evicted_pages": ..., "timed_out": ...,               # cumulative
     "prefill_s": ..., "decode_s": ..., "chunks": ...}     # cumulative
"""
from __future__ import annotations

import time
from typing import Optional

from repro.telemetry.writer import TelemetryWriter


class ServeTelemetry:
    """Owns the serve gauge stream for one engine.

    ``every`` is the sampling cadence in chunk boundaries (1 = every
    chunk).  The engine calls :meth:`note_prefill` / :meth:`note_decode`
    with wall seconds as they happen and :meth:`sample` after each
    ``step()``; everything else is derived here.
    """

    def __init__(self, path, *, every: int = 1, **meta):
        self.writer = TelemetryWriter(path, stream="serve", **meta)
        self.every = max(1, int(every))
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.chunks = 0
        self._t0 = time.monotonic()

    # -- time accounting (called by the engine) ------------------------
    def note_prefill(self, dt_s: float) -> None:
        self.prefill_s += dt_s

    def note_decode(self, dt_s: float) -> None:
        self.decode_s += dt_s
        self.chunks += 1

    # -- sampling -------------------------------------------------------
    def sample(self, engine, *, force: bool = False) -> Optional[dict]:
        """Emit one gauge record from the engine's host state (cadenced;
        ``force=True`` samples regardless, e.g. a final drain sample)."""
        if not force and (self.chunks % self.every):
            return None
        alloc = engine.allocator
        sched = engine.scheduler
        usable = alloc.num_pages - 1          # page 0 is scratch
        owned = sum(len(r.pages) for r in sched.running())
        slots = sched.n_slots * sched.max_pages_per_seq
        rec = {
            "pool_util": (usable - alloc.n_free) / max(usable, 1),
            "pool_free": alloc.n_free,
            "block_table_occupancy": owned / max(slots, 1),
            "queue_depth": len(sched.queue),
            "running": len(sched.running()),
            **sched.counters,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "chunks": self.chunks,
        }
        self.writer.gauge("serve", time.monotonic() - self._t0, **rec)
        return rec

    def close(self) -> None:
        self.writer.close()

"""Kernel roofline counter registry: analytic FLOPs / bytes per Pallas
kernel, keyed on shapes (DESIGN.md §"Telemetry v1").

Every kernel in ``src/repro/kernels/`` gets a counter function that
derives its arithmetic work and minimum memory traffic *from the shape
alone* — the numerator of achieved-vs-peak roofline fractions, and the
denominator of arithmetic intensity.  The counts model the algorithm the
kernel implements (what any implementation must do), not one backend's
instruction stream, so they are stable across jnp-oracle / Pallas /
interpret dispatch and usable to compare them.

``benchmarks/roofline.py`` drives this registry over CPU smoke shapes
(measured) and the config-zoo shapes of ``configs/shapes.py`` (analytic
only) to produce the committed ``BENCH_roofline.json`` baseline and a
``kernel``-kind telemetry stream for ``repro.telemetry.report``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict


@dataclasses.dataclass(frozen=True)
class KernelCounters:
    """Analytic cost of one kernel launch at one shape."""

    kernel: str
    flops: float           # arithmetic operations (adds + muls + divs...)
    bytes: float           # minimum HBM traffic (reads + writes)
    shape: dict            # the shape key these counts were derived from
    note: str = ""

    @property
    def intensity(self) -> float:
        """Arithmetic intensity, FLOPs/byte — the roofline x-axis."""
        return self.flops / max(self.bytes, 1.0)

    def record(self, **extra) -> dict:
        """As a schema-v1 ``kernel`` stream record."""
        return {"kernel": self.kernel, "flops": self.flops,
                "bytes": self.bytes, "intensity": self.intensity,
                "shape": dict(self.shape), **extra}


# --------------------------------------------------------------------------
# adalomo_update — fused factored-moment + grouped-norm update, one m×n
# tensor (kernels/adalomo_update; stacked [L, m, n] tensors vmap L launches)
# --------------------------------------------------------------------------

def adalomo_update_counters(m: int, n: int, *, stacks: int = 1,
                            itemsize: int = 4) -> KernelCounters:
    """Per-element work (both passes over the tile grid):

    stats pass — g² (1), accumulate into the r row-sum and c col-sum
    marginals (2); EMA fold of r/c is O(m+n).  update pass — v̂ = r·c·
    inv_denom (2), û = g/(√v̂+ε) (3, incl. the rsqrt), û² accumulation for
    the grouped RMS norm (2), trust-ratio scale + clip (2), θ ← decay·θ −
    lr·û (3) — 13 FLOPs/element + 6(m+n) for the marginal EMAs and the
    final r/c writes.

    Traffic: the stats pass reads g; the update pass reads θ and g and
    writes θ (4 m·n elements at ``itemsize``); r and c are read+written
    in f32 by both passes (≈ 4(m+n) f32 round-trips).
    """
    e = m * n
    flops = stacks * (13.0 * e + 6.0 * (m + n))
    bytes_ = stacks * (4.0 * e * itemsize + 4.0 * (m + n) * 4)
    return KernelCounters(
        kernel="adalomo_update", flops=flops, bytes=bytes_,
        shape={"m": m, "n": n, "stacks": stacks, "itemsize": itemsize},
        note="fused factored-moment + grouped-norm update, 2 grid passes")


# --------------------------------------------------------------------------
# paged_decode_attention — one decode step over the paged KV pool
# (kernels/decode_attention; q [B, H, dh] against block-tabled pages)
# --------------------------------------------------------------------------

def paged_decode_attention_counters(batch: int, q_heads: int, kv_heads: int,
                                    head_dim: int, seq_len: int, *,
                                    page_size: int = 16,
                                    pages_per_seq: int = 0,
                                    itemsize: int = 4) -> KernelCounters:
    """Per (batch row × q head): q·K over L cached tokens (2·L·dh), a
    5-op/token streaming softmax (exp, max/sum folds, scale), and the
    attention-weighted V sum (2·L·dh) — ``4·B·H·L·dh + 5·B·H·L`` FLOPs.

    Traffic is *page-granular*: the kernel streams whole K/V pages
    through VMEM, so each sequence moves ``ceil(L / page_size)`` pages —
    or the full fixed grid of ``pages_per_seq`` when given (the
    ``max_pages_per_seq`` cost the ROADMAP's ragged-grid item targets;
    pass it to model today's kernel, omit it for the ideal).  K/V pages
    are stored per kv head (GQA shares them across ``q_heads/kv_heads``
    query heads), plus the q read and the output write.
    """
    L = seq_len
    flops = batch * q_heads * (4.0 * L * head_dim + 5.0 * L)
    touched = pages_per_seq or math.ceil(L / page_size)
    kv_bytes = (batch * touched * page_size * kv_heads * head_dim
                * itemsize * 2)                       # K and V
    qo_bytes = 2 * batch * q_heads * head_dim * itemsize
    return KernelCounters(
        kernel="paged_decode_attention", flops=flops,
        bytes=float(kv_bytes + qo_bytes),
        shape={"batch": batch, "q_heads": q_heads, "kv_heads": kv_heads,
               "head_dim": head_dim, "seq_len": seq_len,
               "page_size": page_size, "pages_per_seq": pages_per_seq,
               "itemsize": itemsize},
        note="page-granular KV streaming; GQA shares pages across q heads")


REGISTRY: Dict[str, Callable[..., KernelCounters]] = {
    "adalomo_update": adalomo_update_counters,
    "paged_decode_attention": paged_decode_attention_counters,
}


def counters_for(kernel: str, **shape) -> KernelCounters:
    """Look up + evaluate a registered counter function."""
    if kernel not in REGISTRY:
        raise KeyError(f"no roofline counters registered for {kernel!r}; "
                       f"known: {sorted(REGISTRY)}")
    return REGISTRY[kernel](**shape)


def zoo_cases() -> list:
    """Analytic roofline rows at production config-zoo scale
    (``configs/shapes.py`` decode cells on a dense-7B-ish head layout,
    and the matching train-step update shapes) — no timing, pure model;
    the scale the ROADMAP kernel-speed program optimizes for."""
    from repro.configs.shapes import SHAPES
    cases = []
    for cell in ("decode_32k", "long_500k"):
        s = SHAPES[cell]
        cases.append(("paged_decode_attention",
                      {"batch": s.global_batch, "q_heads": 32,
                       "kv_heads": 8, "head_dim": 128,
                       "seq_len": s.seq_len, "page_size": 16},
                      cell))
    # train_4k's per-tensor update: a d_model x d_ff projection (4096 wide)
    cases.append(("adalomo_update",
                  {"m": 4096, "n": 11008}, "train_4k"))
    return cases

"""Optimizer-health probes — on-device reductions folded into the step
program (DESIGN.md §"Telemetry v1").

AdaLomo's correctness hinges on internals the loss curve does not show:
the grouped update normalization (Alg. 1 line 11) and the non-negative
factorization of the second moment (Eq. 5-7) — the exact place low-memory
optimizers silently degrade.  :func:`instrument_step` wraps the step
program's pure callable so that every step additionally returns, inside
the metrics pytree under ``"opt_health"``:

* **per-GroupSpec update/param norm ratios** — ``‖Δθ‖/‖θ‖`` accumulated
  over each Opt-v2 param group (the trust-ratio health signal: a group
  whose ratio explodes or collapses is diverging or frozen);
* **an effective-lr histogram** — the per-tensor-unit relative update
  ``RMS(Δθ)/RMS(θ)`` binned into fixed log10 buckets (stacked ``[L, ...]``
  leaves contribute one value per layer slice, matching the per-matrix
  grouped normalization), plus its mean/max;
* **factored-moment reconstruction error** on the K largest factored
  tensors.  The exact ``‖v − r cᵀ/Σr‖`` needs the unfactored v, which the
  low-memory state deliberately never materializes; what *is* exactly
  computable from the (pre, post) state transition is the **rank-1
  transition residual**: with the implied per-step statistics
  ``R = (rₜ − β rₜ₋₁)/(1−β)`` (and C likewise, both exact marginals of
  this step's g²), compare v̂(rₜ,cₜ) against ``β·v̂(rₜ₋₁,cₜ₋₁) +
  (1−β)·v̂(R,C)``.  The residual is zero exactly when the factored EMA
  recursion commutes with the rank-1 reconstruction — i.e. when the
  factorization is faithful this step — and grows with the non-rank-1
  mass the factored state is discarding.  Tensors that carry an
  *unfactored* ``v`` (1-D params, or groups forced ``factored=False``)
  get the literal ``‖v − v_r v_cᵀ/Σv_r‖/‖v‖`` instead, since v exists.

Contract (asserted in ``tests/telemetry/test_probes.py``): the wrapper
adds **zero steady-state recompiles** (same jaxpr every step — probes are
computed in-graph each step; the *recording* cadence is host-side) and
**zero new host syncs** — the probe scalars ride the runner's one bundled
per-step ``device_get`` inside the metrics pytree (repro-lint R2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.adalomo import FactoredState
from repro.core.api import STACKS_KEY, path_str

_TINY = 1e-30
# Relative updates are measured against max(RMS(θ), _RMS_FLOOR) — the
# Adafactor/AdaLomo eps2 convention — so zero-initialized groups (e.g.
# zero-centered norm scales) report against the floor instead of ∞.
_RMS_FLOOR = 1e-3


@dataclasses.dataclass(frozen=True)
class ObservabilitySpec:
    """Per-probe cadence + shape knobs for the telemetry layer, on
    :class:`~repro.run.spec.RunSpec` as the ``observe`` field.

    ``optimizer_every=0`` disables the optimizer-health probes entirely
    (the step program is not wrapped).  When enabled, probe tensors are
    computed in-graph every step (cheap reductions, constant structure —
    zero recompiles); the cadences below govern how often the stream
    *records* them:

    ``optimizer_every``  group-ratio + effective-lr records;
    ``factored_every``   reconstruction-residual records (0 = follow
                         ``optimizer_every``);
    ``sample_tensors``   how many of the largest factored (and unfactored
                         >= 2-D) moment tensors get the residual probe;
    ``hist_bins`` / ``hist_range``  fixed log10 bin layout of the
                         effective-lr histogram (fixed shape — the jit
                         signature never depends on the data).
    """

    optimizer_every: int = 0
    factored_every: int = 0
    sample_tensors: int = 2
    hist_bins: int = 16
    hist_range: tuple = (-8.0, 0.0)

    def __post_init__(self):
        if self.optimizer_every < 0 or self.factored_every < 0:
            raise ValueError("probe cadences must be >= 0")
        if self.sample_tensors < 0 or self.hist_bins < 1:
            raise ValueError(
                f"sample_tensors={self.sample_tensors} hist_bins="
                f"{self.hist_bins}")
        lo, hi = self.hist_range
        if not lo < hi:
            raise ValueError(f"hist_range {self.hist_range} must be (lo, hi)")
        # normalize (JSON round-trips lists) so specs compare equal
        object.__setattr__(self, "hist_range",
                           (float(lo), float(hi)))

    @property
    def enabled(self) -> bool:
        return self.optimizer_every > 0

    def resolved_factored_every(self) -> int:
        return self.factored_every or self.optimizer_every


# --------------------------------------------------------------------------
# In-graph reductions
# --------------------------------------------------------------------------

def _is_stacked(path: str, leaf) -> bool:
    parts = path.split("/") if path else []
    return bool(parts) and parts[0] == STACKS_KEY and \
        getattr(leaf, "ndim", 0) >= 1


def _unit_rms(x, stacked: bool):
    """RMS over the per-tensor unit: the whole leaf, or each layer slice
    of a stacked ``[L, ...]`` leaf — one value per unit, flattened."""
    x = x.astype(jnp.float32)
    axes = tuple(range(1, x.ndim)) if stacked else None
    if axes == ():                       # stacked scalar-per-layer
        return jnp.abs(x).reshape(-1)
    r = jnp.sqrt(jnp.mean(jnp.square(x), axis=axes))
    return r.reshape(-1)


def group_ratios(p_old, p_new, opt) -> dict:
    """``‖Δθ‖ / max(‖θ‖, eps2·√n)`` per Opt-v2 param group (f32 scalars,
    one per group name, group 'default' first).  The denominator floor is
    the group-norm equivalent of ``RMS(θ) >= _RMS_FLOOR``."""
    labels = jax.tree.leaves(opt.labels(p_old))
    old = jax.tree.leaves(p_old)
    new = jax.tree.leaves(p_new)
    names = ["default"] + [g.name for g in opt.groups]
    upd = [jnp.zeros((), jnp.float32) for _ in names]
    par = [jnp.zeros((), jnp.float32) for _ in names]
    cnt = [0 for _ in names]
    for o, n, lab in zip(old, new, labels):
        d = (n.astype(jnp.float32) - o.astype(jnp.float32))
        upd[lab] = upd[lab] + jnp.sum(jnp.square(d))
        par[lab] = par[lab] + jnp.sum(jnp.square(o.astype(jnp.float32)))
        cnt[lab] += int(o.size)
    return {name: jnp.sqrt(u) / jnp.maximum(
                jnp.sqrt(p), _RMS_FLOOR * max(c, 1) ** 0.5)
            for name, u, p, c in zip(names, upd, par, cnt)}


def effective_lr_hist(p_old, p_new, ospec: ObservabilitySpec) -> dict:
    """Fixed-shape histogram of per-unit relative updates
    ``log10(RMS(Δθ)/RMS(θ))``, plus mean/max of the raw ratio."""
    flat, _ = jax.tree_util.tree_flatten_with_path(p_old)
    new_leaves = jax.tree.leaves(p_new)
    rels = []
    for (kp, o), n in zip(flat, new_leaves):
        stacked = _is_stacked(path_str(kp), o)
        d_rms = _unit_rms(n.astype(jnp.float32) - o.astype(jnp.float32),
                          stacked)
        p_rms = _unit_rms(o, stacked)
        rels.append(d_rms / jnp.maximum(p_rms, _RMS_FLOOR))
    rel = jnp.concatenate(rels)
    lo, hi = ospec.hist_range
    edges = jnp.linspace(lo, hi, ospec.hist_bins + 1)
    counts, _ = jnp.histogram(jnp.log10(jnp.maximum(rel, _TINY)),
                              bins=edges)
    return {"counts": counts, "lo": lo, "hi": hi,
            "n_units": rel.shape[0],
            "rel_update_mean": jnp.mean(rel),
            "rel_update_max": jnp.max(rel)}


def _recon(r, c):
    """v̂ = outer(r, c) / Σr — rank-1 NMF reconstruction, leading dims
    batched (stacked moments)."""
    denom = jnp.maximum(jnp.sum(r, axis=-1, keepdims=True), _TINY)
    return (r[..., :, None] * c[..., None, :]) / denom[..., None]


def _fro(x):
    return jnp.sqrt(jnp.sum(jnp.square(x), axis=(-2, -1)))


def transition_residual(r_old, c_old, r_new, c_new, beta):
    """Rank-1 transition residual of the factored EMA (see module doc):
    ‖v̂ₜ − (β v̂ₜ₋₁ + (1−β) v̂(R,C))‖_F / ‖v̂ₜ‖_F, mean over leading dims."""
    b = jnp.asarray(beta, jnp.float32)
    one_m_b = jnp.maximum(1.0 - b, _TINY)
    r_imp = jnp.maximum(r_new - b * r_old, 0.0) / one_m_b
    c_imp = jnp.maximum(c_new - b * c_old, 0.0) / one_m_b
    v_new = _recon(r_new, c_new)
    pred = b * _recon(r_old, c_old) + (1.0 - b) * _recon(r_imp, c_imp)
    res = _fro(v_new - pred) / jnp.maximum(_fro(v_new), _TINY)
    return jnp.mean(res)


def factorization_error(v):
    """Literal ‖v − v_r v_cᵀ/Σv_r‖_F / ‖v‖_F for a materialized v (>= 2-D)
    — the error a rank-1 factorization of this tensor WOULD incur now."""
    r = jnp.sum(v, axis=-1)
    c = jnp.sum(v, axis=-2)
    res = _fro(v - _recon(r, c)) / jnp.maximum(_fro(v), _TINY)
    return jnp.mean(res)


def _moment_leaves(moments):
    """[(path, FactoredState)] — per-tensor moment states with paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        moments, is_leaf=lambda x: isinstance(x, FactoredState))
    return [(path_str(kp), st) for kp, st in flat
            if isinstance(st, FactoredState)]


def _sample(pairs, k):
    """Deterministic sample: the k largest by reconstructed-tensor size,
    ties broken by path (static — baked into the jaxpr once)."""
    return sorted(pairs, key=lambda ps: (-ps[1], ps[0]))[:k]


def _recon_size(st: FactoredState) -> int:
    """Element count of the tensor v̂(r, c) reconstructs (incl. stacks)."""
    lead = 1
    for d in st.r.shape[:-1]:
        lead *= int(d)
    return lead * int(st.r.shape[-1]) * int(st.c.shape[-1])


def factored_health(s_old, s_new, beta, ospec: ObservabilitySpec) -> dict:
    """Reconstruction-error probes over sampled moment tensors.  Returns
    ``{"recon/<path>": residual}`` (+ ``"fact_err/<path>"`` for tensors
    carrying an explicit v).  Empty when the rule's state is not the
    AdaLomo factored layout or ``beta`` is unavailable."""
    out: dict = {}
    if beta is None:
        return out
    old = dict(_moment_leaves(s_old))
    new = dict(_moment_leaves(s_new))
    fact = [(p, _recon_size(st)) for p, st in new.items()
            if st.r is not None and st.c is not None and p in old]
    for p, _sz in _sample(fact, ospec.sample_tensors):
        so, sn = old[p], new[p]
        out[f"recon/{p}"] = transition_residual(so.r, so.c, sn.r, sn.c,
                                                beta)
    dense = [(p, int(st.v.size)) for p, st in new.items()
             if st.v is not None and st.v.ndim >= 2]
    for p, _sz in _sample(dense, ospec.sample_tensors):
        out[f"fact_err/{p}"] = factorization_error(new[p].v)
    return out


def optimizer_health(p_old, p_new, s_old, s_new, hp, *, opt,
                     ospec: ObservabilitySpec) -> dict:
    """The full per-step health pytree (all f32 device scalars + one
    fixed-shape histogram).  Structure depends only on (params, opt,
    ospec) — identical every step, so the jitted step never recompiles."""
    resolved = opt.resolve(hp)[0]
    beta = resolved.get("beta")
    return {
        "group_ratio": group_ratios(p_old, p_new, opt),
        "eff_lr": effective_lr_hist(p_old, p_new, ospec),
        "factored": factored_health(s_old.moments, s_new.moments, beta,
                                    ospec),
    }


def instrument_step(inner, *, opt, ospec: ObservabilitySpec):
    """Wrap a step callable ``(params, opt_state, batch, hp) -> (params',
    opt_state', loss, metrics)`` so metrics additionally carries
    ``"opt_health"``.  Folded in *before* jit by ``build_step_program``:
    one program, one compile, one bundled per-step transfer."""

    def instrumented(params, opt_state, batch, hp):
        p2, s2, loss, metrics = inner(params, opt_state, batch, hp)
        health = optimizer_health(params, p2, opt_state, s2, hp,
                                  opt=opt, ospec=ospec)
        return p2, s2, loss, {**metrics, "opt_health": health}

    return instrumented

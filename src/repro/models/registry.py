"""Architecture registry: ``--arch <id>`` → family functions + input specs.

Each entry binds a config module to its family implementation and provides
``input_specs`` / ``cache_specs`` ShapeDtypeStruct stand-ins for the dry-run
(weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeSpec, cells_for

SDS = jax.ShapeDtypeStruct

_CONFIG_MODULES = {
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube_3_4b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "whisper-base": "repro.configs.whisper_base",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
}

ARCH_IDS = tuple(_CONFIG_MODULES)


@dataclasses.dataclass
class Arch:
    arch_id: str
    family: str
    cfg: Any

    # ---- construction -----------------------------------------------------
    def init_params(self, key):
        return self._family_mod().init_params(key, self.cfg)

    def _family_mod(self):
        from repro.models import encdec, hybrid, mamba2, transformer
        return {"transformer": transformer, "mamba2": mamba2,
                "hybrid": hybrid, "encdec": encdec}[self.family]

    # ---- train ------------------------------------------------------------
    def make_fused_train_step(self, opt, *, residual_constraint=None,
                              global_grad_norm=None, grad_constraint=None,
                              param_constraint=None):
        """``opt`` is a v2 ``repro.core.api.Opt``; the returned step is
        ``step(params, opt_state, batch, *, hparams)`` with hparams as
        call-time data (Opt v2 contract)."""
        from repro.core.fused import fused_train_step
        if self.family == "encdec":
            from repro.models.encdec import make_fused_train_step
            step = make_fused_train_step(self.cfg, opt)
            return partial(step, residual_constraint=residual_constraint,
                           grad_constraint=grad_constraint)
        spec = self._family_mod().make_fused_spec(self.cfg)
        if param_constraint is not None:
            # ZeRO-3 'use' path: gather the layer's weights transiently
            # (bf16), reduce-scatter their grads (custom vjp).
            def wrap(body, pc):
                return lambda p, c, x, aux: body(pc(p), c, x, aux)

            spec = spec._replace(bodies={
                name: wrap(b, param_constraint(name))
                for name, b in spec.bodies.items()})

        def train_step(params, opt_state, batch, *, hparams=None):
            return fused_train_step(
                spec, opt, params, opt_state, batch, hparams=hparams,
                residual_constraint=residual_constraint,
                global_grad_norm=global_grad_norm,
                grad_constraint=grad_constraint)

        return train_step

    def make_loss_fn(self):
        """(params, batch) -> (loss, metrics), for jax.grad baselines."""
        if self.family == "encdec":
            from repro.models.encdec import loss_fn
            return partial(loss_fn, self.cfg)
        from repro.core.fused import unfused_loss_fn
        spec = self._family_mod().make_fused_spec(self.cfg)
        return partial(unfused_loss_fn, spec)

    # ---- serve ------------------------------------------------------------
    def make_prefill_step(self, **kw):
        return self._family_mod().make_prefill_step(self.cfg, **kw)

    def make_decode_step(self):
        return self._family_mod().make_decode_step(self.cfg)

    def init_cache(self, batch: int, max_len: int):
        mod = self._family_mod()
        if self.family == "mamba2":
            return mod.init_state_cache(self.cfg, batch)
        return mod.init_cache(self.cfg, batch, max_len)

    # ---- paged serving (continuous batching; transformer GQA only) --------
    def supports_paged_serving(self) -> bool:
        return (self.family == "transformer"
                and getattr(self.cfg, "mla", None) is None
                and not getattr(self.cfg, "prefix_lm", False))

    def make_prefill_kv_step(self):
        assert self.supports_paged_serving(), self.arch_id
        return self._family_mod().make_prefill_kv_step(self.cfg)

    def make_paged_decode_step(self, *, use_kernel=None, interpret=False):
        assert self.supports_paged_serving(), self.arch_id
        return self._family_mod().make_paged_decode_step(
            self.cfg, use_kernel=use_kernel, interpret=interpret)

    def init_page_pool(self, num_pages: int, page_size: int):
        assert self.supports_paged_serving(), self.arch_id
        return self._family_mod().init_page_pool(self.cfg, num_pages,
                                                 page_size)

    # ---- dry-run specs ------------------------------------------------------
    def supported_cells(self) -> list[str]:
        cells = cells_for(self.arch_id)
        return cells

    def supports_packing(self) -> bool:
        """Packed-segment batches need the transformer train path with
        plain causal/SWA masks (no prefix/modality prefix/MTP)."""
        cfg = self.cfg
        return (self.family == "transformer"
                and not getattr(cfg, "prefix_lm", False)
                and not getattr(cfg, "n_prefix_tokens", 0)
                and not getattr(cfg, "mtp", False))

    def train_batch_specs(self, batch: int, seq_len: int,
                          *, labels: bool = True,
                          packed: bool = False) -> dict:
        """ShapeDtypeStruct train batch for an explicit (batch, seq_len) —
        the signature contract between the data layer
        (``repro.run.data.make_batch_iter`` yields exactly these leaves)
        and the step program (``StepProgram.abstract_args`` lowers on
        them).  ``labels=False`` gives the prefill subset; ``packed=True``
        adds the packed-segment leaves (DESIGN.md "Packed sequence
        layout")."""
        cfg = self.cfg
        B, S = batch, seq_len
        if packed and not self.supports_packing():
            raise ValueError(
                f"packing is not supported for arch {self.arch_id!r} "
                f"(family={self.family}; prefix-LM/modality-prefix/MTP "
                f"batches have extra sequence structure packing would "
                f"break)")
        out = {"tokens": SDS((B, S), jnp.int32)}
        if labels:
            out["labels"] = SDS((B, S), jnp.int32)
        if packed:
            out["segment_ids"] = SDS((B, S), jnp.int32)
            out["positions"] = SDS((B, S), jnp.int32)
            out["loss_mask"] = SDS((B, S), jnp.bool_)
            return out
        if self.family == "encdec":
            out["frames"] = SDS((B, cfg.n_frames, cfg.d_model),
                                jnp.float32)
        if getattr(cfg, "prefix_lm", False):
            out["prefix_embed"] = SDS((B, cfg.n_prefix_tokens, cfg.d_model),
                                      jnp.float32)
            out["prefix_len"] = SDS((B,), jnp.int32)
        if getattr(cfg, "mtp", False) and labels:
            out["labels_mtp"] = SDS((B, S), jnp.int32)
        return out

    def input_specs(self, shape_name: str, *, packed: bool = False) -> dict:
        """ShapeDtypeStruct batch for the given assigned shape."""
        sh = SHAPES[shape_name]
        if sh.kind in ("train", "prefill"):
            return self.train_batch_specs(sh.global_batch, sh.seq_len,
                                          labels=sh.kind == "train",
                                          packed=packed and
                                          sh.kind == "train")
        # decode: one new token against a seq_len-deep cache
        return {"tokens": SDS((sh.global_batch, 1), jnp.int32)}

    def cache_specs(self, shape_name: str) -> Any:
        sh = SHAPES[shape_name]
        assert sh.kind == "decode", shape_name
        cache = jax.eval_shape(
            lambda: self.init_cache(sh.global_batch, sh.seq_len))
        return cache


def get_arch(arch_id: str, *, smoke: bool = False) -> Arch:
    mod = importlib.import_module(_CONFIG_MODULES[arch_id])
    cfg = mod.smoke_config() if smoke else mod.config()
    return Arch(arch_id=arch_id, family=mod.FAMILY, cfg=cfg)


# --------------------------------------------------------------------------
# The paper's own pre-training config (TinyLlama-1.1B, paper §4.3)
# --------------------------------------------------------------------------

def paper_llama_1b():
    """LLaMA-architecture 1.1B used for the from-scratch C4 run (Fig. 4)."""
    from repro.models.transformer import LMConfig
    return Arch(
        arch_id="llama-1.1b-paper", family="transformer",
        cfg=LMConfig(name="llama-1.1b-paper", n_layers=22, d_model=2048,
                     n_heads=32, n_kv_heads=4, d_ff=5632, vocab=32000))

"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, n_frames, d_model).  Deviations noted in
DESIGN.md: decoder positions are sinusoidal (whisper: learned) so the
decode_32k dry-run cell isn't dominated by a 32k-entry learned position
table that the real model doesn't have.

This family exercises the fused engine's cross-stream gradient path: the
decoder's backward scan accumulates d(enc_out) through the ctx cotangent,
which is then pushed through the encoder's backward scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import fused as F
from repro.models import layers as L
from repro.models.transformer import cross_entropy

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    n_frames: int = 1500
    norm: str = "layernorm"
    act: str = "gelu"
    dtype: Any = jnp.bfloat16

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        import math
        shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), self))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        return self.param_count()


def _sinusoid(S: int, d: int) -> Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / (d // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _attn_init(key, cfg: EncDecConfig, d_kv_src: int) -> dict:
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = cfg.dtype
    return {
        "wq": L.linear_init(ks[0], d, H * dh, dtype=dt),
        "bq": jnp.zeros((H * dh,), dt),
        "wk": L.linear_init(ks[1], d_kv_src, K * dh, dtype=dt),
        "wv": L.linear_init(ks[2], d_kv_src, K * dh, dtype=dt),
        "bv": jnp.zeros((K * dh,), dt),
        "wo": L.linear_init(ks[3], H * dh, d, dtype=dt),
        "bo": jnp.zeros((d,), dt),
    }


def _mlp_init(key, cfg: EncDecConfig) -> dict:
    ks = jax.random.split(key, 2)
    dt = cfg.dtype
    return {
        "w_up": L.linear_init(ks[0], cfg.d_model, cfg.d_ff, dtype=dt),
        "b_up": jnp.zeros((cfg.d_ff,), dt),
        "w_down": L.linear_init(ks[1], cfg.d_ff, cfg.d_model, dtype=dt),
        "b_down": jnp.zeros((cfg.d_model,), dt),
    }


def init_params(key, cfg: EncDecConfig) -> dict:
    k_e, k_enc, k_dec = jax.random.split(key, 3)
    d = cfg.d_model

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": L.norm_init(d, cfg.norm),
                "attn": _attn_init(k1, cfg, d),
                "ln2": L.norm_init(d, cfg.norm),
                "mlp": _mlp_init(k2, cfg)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": L.norm_init(d, cfg.norm),
                "self_attn": _attn_init(k1, cfg, d),
                "ln_x": L.norm_init(d, cfg.norm),
                "cross_attn": _attn_init(k2, cfg, d),
                "ln2": L.norm_init(d, cfg.norm),
                "mlp": _mlp_init(k3, cfg)}

    outer = {
        "tok_embed": L.embed_init(k_e, cfg.vocab, d, dtype=cfg.dtype),
        "enc_norm": L.norm_init(d, cfg.norm),
        "dec_norm": L.norm_init(d, cfg.norm),
    }
    enc = jax.vmap(enc_block)(jax.random.split(k_enc, cfg.n_enc_layers))
    dec = jax.vmap(dec_block)(jax.random.split(k_dec, cfg.n_dec_layers))
    return {"outer": outer, "shared": {},
            "stacks": {"enc": enc, "dec": dec}}


def _mha(p, cfg: EncDecConfig, hq: Array, hkv: Array, *, causal: bool,
         q_pos, kv_pos) -> Array:
    B, Sq, _ = hq.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L.dense(hq, p["wq"], p["bq"]).reshape(B, Sq, H, dh)
    k = L.dense(hkv, p["wk"]).reshape(B, hkv.shape[1], K, dh)
    v = L.dense(hkv, p["wv"], p["bv"]).reshape(B, hkv.shape[1], K, dh)
    o = L.attention(q, k, v, spec=L.MaskSpec(causal=causal),
                    q_pos=q_pos, kv_pos=kv_pos)
    return L.dense(o.reshape(B, Sq, H * dh), p["wo"], p["bo"])


def make_enc_body(cfg: EncDecConfig):
    def body(p, ctx, x, aux_idx):
        del ctx, aux_idx
        S = x.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)
        h = L.norm_apply(p["ln1"], x, kind=cfg.norm)
        x = x + _mha(p["attn"], cfg, h, h, causal=False, q_pos=pos,
                     kv_pos=pos)
        h = L.norm_apply(p["ln2"], x, kind=cfg.norm)
        return x + L.mlp(p["mlp"], h, cfg.act)

    return body


def make_dec_body(cfg: EncDecConfig):
    def body(p, ctx, x, aux_idx):
        del aux_idx
        _, enc_out = ctx
        S = x.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)
        epos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        h = L.norm_apply(p["ln1"], x, kind=cfg.norm)
        x = x + _mha(p["self_attn"], cfg, h, h, causal=True, q_pos=pos,
                     kv_pos=pos)
        h = L.norm_apply(p["ln_x"], x, kind=cfg.norm)
        x = x + _mha(p["cross_attn"], cfg, h, enc_out, causal=False,
                     q_pos=pos, kv_pos=epos)
        h = L.norm_apply(p["ln2"], x, kind=cfg.norm)
        return x + L.mlp(p["mlp"], h, cfg.act)

    return body


# --------------------------------------------------------------------------
# Fused + unfused train steps
# --------------------------------------------------------------------------

def _decoder_inputs(outer, cfg: EncDecConfig, tokens: Array) -> Array:
    x = outer["tok_embed"][tokens]
    return x + _sinusoid(tokens.shape[1], cfg.d_model).astype(x.dtype)


def _loss_from_dec(outer, cfg: EncDecConfig, x: Array, batch):
    h = L.norm_apply(outer["dec_norm"], x, kind=cfg.norm)
    logits = jnp.einsum("...d,dv->...v", h, outer["tok_embed"].T,
                        preferred_element_type=jnp.float32)
    loss_sum, ntok, correct = cross_entropy(logits, batch["labels"])
    denom = jnp.maximum(ntok, 1).astype(jnp.float32)
    loss = loss_sum / denom
    metrics = jax.lax.stop_gradient({
        "loss": loss, "ntokens": ntok.astype(jnp.float32),
        "accuracy": correct.astype(jnp.float32) / denom})
    return loss, metrics


def make_fused_train_step(cfg: EncDecConfig, opt):
    enc_body = make_enc_body(cfg)
    dec_body = make_dec_body(cfg)

    def train_step(params, opt_state, batch, *, hparams=None,
                   residual_constraint=None, grad_constraint=None):
        rule = opt.rule
        hp = opt.resolve(hparams)
        labels = opt.labels(params)
        step = opt_state.step + 1
        stepf = step.astype(jnp.float32)
        m = opt_state.moments
        outer, stacks = params["outer"], params["stacks"]
        frames = batch["frames"].astype(cfg.dtype)
        x_e0 = frames + _sinusoid(frames.shape[1],
                                  cfg.d_model).astype(cfg.dtype)

        # ---- forward ----
        enc_res = F.stack_forward(enc_body, stacks["enc"], ((), ()), x_e0,
                                  residual_constraint=residual_constraint)
        enc_out, enc_norm_vjp = jax.vjp(
            lambda o, xx: L.norm_apply(o["enc_norm"], xx, kind=cfg.norm),
            outer, enc_res.x_out)
        x_d0, dec_pro_vjp = jax.vjp(
            lambda o: _decoder_inputs(o, cfg, batch["tokens"]), outer)
        dec_res = F.stack_forward(dec_body, stacks["dec"], ((), enc_out),
                                  x_d0,
                                  residual_constraint=residual_constraint)
        loss, epi_vjp, metrics = jax.vjp(
            lambda o, xx: _loss_from_dec(o, cfg, xx, batch),
            outer, dec_res.x_out, has_aux=True)

        # ---- backward + inline updates ----
        g_outer_epi, dxd = epi_vjp(jnp.ones_like(loss))
        gc_dec = grad_constraint("dec") if grad_constraint is not None \
            else None
        gc_enc = grad_constraint("enc") if grad_constraint is not None \
            else None
        dxd0, (_, d_enc_out), new_dec, new_dec_m = F.stack_backward_update(
            dec_body, rule, stacks["dec"], m["stacks"]["dec"],
            ((), enc_out), dec_res, dxd, labels=labels["stacks"]["dec"],
            hp=hp, step=stepf, grad_constraint=gc_dec)
        g_outer_dpro, = dec_pro_vjp(dxd0)
        g_outer_enorm, dxe_out = enc_norm_vjp(d_enc_out)
        dxe0, _, new_enc, new_enc_m = F.stack_backward_update(
            enc_body, rule, stacks["enc"], m["stacks"]["enc"],
            ((), ()), enc_res, dxe_out, labels=labels["stacks"]["enc"],
            hp=hp, step=stepf, grad_constraint=gc_enc)
        del dxe0  # frames are inputs, no params upstream

        g_outer = F._tree_add(F._tree_add(g_outer_epi, g_outer_dpro),
                              g_outer_enorm)
        new_outer, new_outer_m = F.apply_rule_tree(
            rule, outer, g_outer, m["outer"], labels["outer"], hp, stepf)

        new_params = {"outer": new_outer, "shared": {},
                      "stacks": {"enc": new_enc, "dec": new_dec}}
        new_opt = F.OptState(
            step=step,
            moments={"outer": new_outer_m, "shared": {},
                     "stacks": {"enc": new_enc_m, "dec": new_dec_m}})
        return new_params, new_opt, loss, metrics

    return train_step


def loss_fn(cfg: EncDecConfig, params, batch):
    """Unfused forward (for jax.grad baselines and equivalence tests)."""
    enc_body = make_enc_body(cfg)
    dec_body = make_dec_body(cfg)
    outer, stacks = params["outer"], params["stacks"]
    frames = batch["frames"].astype(cfg.dtype)
    x_e = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(cfg.dtype)
    x_e = F.stack_forward(enc_body, stacks["enc"], ((), ()), x_e).x_out
    enc_out = L.norm_apply(outer["enc_norm"], x_e, kind=cfg.norm)
    x_d = _decoder_inputs(outer, cfg, batch["tokens"])
    x_d = F.stack_forward(dec_body, stacks["dec"], ((), enc_out), x_d).x_out
    return _loss_from_dec(outer, cfg, x_d, batch)


# --------------------------------------------------------------------------
# Serving: encode once, cache cross-KV, decode with self-KV ring cache
# --------------------------------------------------------------------------

def init_cache(cfg: EncDecConfig, batch: int, max_len: int) -> dict:
    K, dh = cfg.n_kv_heads, cfg.head_dim
    Ld = cfg.n_dec_layers
    return {
        "self_k": jnp.zeros((Ld, batch, max_len, K, dh), cfg.dtype),
        "self_v": jnp.zeros((Ld, batch, max_len, K, dh), cfg.dtype),
        "cross_k": jnp.zeros((Ld, batch, cfg.n_frames, K, dh), cfg.dtype),
        "cross_v": jnp.zeros((Ld, batch, cfg.n_frames, K, dh), cfg.dtype),
        "pos": jnp.full((max_len,), -1, jnp.int32),
        "cur": jnp.zeros((), jnp.int32),
    }


def make_prefill_step(cfg: EncDecConfig, max_decode_len: int = 448):
    """Encode the audio frames and precompute per-layer cross K/V."""
    enc_body = make_enc_body(cfg)

    def prefill_step(params, batch):
        outer, stacks = params["outer"], params["stacks"]
        frames = batch["frames"].astype(cfg.dtype)
        B = frames.shape[0]
        x_e = frames + _sinusoid(frames.shape[1],
                                 cfg.d_model).astype(cfg.dtype)
        x_e = F.stack_forward(enc_body, stacks["enc"], ((), ()), x_e).x_out
        enc_out = L.norm_apply(outer["enc_norm"], x_e, kind=cfg.norm)
        K, dh = cfg.n_kv_heads, cfg.head_dim

        def per_layer(p):
            ck = L.dense(enc_out, p["cross_attn"]["wk"]).reshape(
                B, -1, K, dh)
            cv = L.dense(enc_out, p["cross_attn"]["wv"],
                         p["cross_attn"]["bv"]).reshape(B, -1, K, dh)
            return ck, cv

        ck, cv = jax.vmap(per_layer)(stacks["dec"])
        cache = init_cache(cfg, B, max_decode_len)
        cache["cross_k"], cache["cross_v"] = ck, cv
        return enc_out, cache

    return prefill_step


def make_decode_step(cfg: EncDecConfig):
    def decode_step(params, cache, batch):
        outer = params["outer"]
        tokens = batch["tokens"]  # [B,1]
        B = tokens.shape[0]
        cur = cache["cur"]
        x = outer["tok_embed"][tokens]
        pos_emb = _sinusoid(2 ** 16, cfg.d_model)  # static table, sliced
        x = x + jax.lax.dynamic_slice_in_dim(
            pos_emb, jnp.minimum(cur, 2 ** 16 - 1), 1, axis=0
        ).astype(x.dtype)[None]
        H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        W = cache["pos"].shape[0]
        slot = jnp.mod(cur, W)
        # mark the current slot before attention so the token sees itself
        cache = dict(cache)
        cache["pos"] = cache["pos"].at[slot].set(cur)

        def body(x, xs):
            p, sk, sv, ck, cv = xs
            h = L.norm_apply(p["ln1"], x, kind=cfg.norm)
            q = L.dense(h, p["self_attn"]["wq"],
                        p["self_attn"]["bq"]).reshape(B, 1, H, dh)
            k = L.dense(h, p["self_attn"]["wk"]).reshape(B, 1, K, dh)
            v = L.dense(h, p["self_attn"]["wv"],
                        p["self_attn"]["bv"]).reshape(B, 1, K, dh)
            sk = jax.lax.dynamic_update_slice_in_dim(sk, k, slot, axis=1)
            sv = jax.lax.dynamic_update_slice_in_dim(sv, v, slot, axis=1)
            o = L.decode_attention(
                q, sk, sv,
                kv_pos=jnp.broadcast_to(cache["pos"][None], (B, W)),
                q_pos=jnp.full((B,), cur, jnp.int32))
            x = x + L.dense(o.reshape(B, 1, H * dh), p["self_attn"]["wo"],
                            p["self_attn"]["bo"])
            h = L.norm_apply(p["ln_x"], x, kind=cfg.norm)
            q = L.dense(h, p["cross_attn"]["wq"],
                        p["cross_attn"]["bq"]).reshape(B, 1, H, dh)
            T = ck.shape[1]
            o = L.decode_attention(
                q, ck, cv,
                kv_pos=jnp.broadcast_to(jnp.arange(T)[None], (B, T)),
                q_pos=jnp.full((B,), 2 ** 30, jnp.int32))
            x = x + L.dense(o.reshape(B, 1, H * dh), p["cross_attn"]["wo"],
                            p["cross_attn"]["bo"])
            h = L.norm_apply(p["ln2"], x, kind=cfg.norm)
            x = x + L.mlp(p["mlp"], h, cfg.act)
            return x, (sk, sv)

        x, (sk_stk, sv_stk) = jax.lax.scan(
            body, x, (params["stacks"]["dec"], cache["self_k"],
                      cache["self_v"], cache["cross_k"], cache["cross_v"]))
        h = L.norm_apply(outer["dec_norm"], x, kind=cfg.norm)
        logits = jnp.einsum("...d,dv->...v", h, outer["tok_embed"].T,
                            preferred_element_type=jnp.float32)[:, 0]
        new_cache = dict(cache)
        new_cache["self_k"], new_cache["self_v"] = sk_stk, sv_stk
        new_cache["cur"] = cur + 1
        return logits, new_cache

    return decode_step

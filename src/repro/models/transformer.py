"""Decoder-only LM family: llama/mistral-style dense, MoE (DeepSeek),
MLA attention (DeepSeek-V3), prefix-LM VLM backbone (PaliGemma).

One configurable family = one code path exercised by 7 of the 10 assigned
architectures.  Written scan-over-layers with stacked params so the fused
AdaLomo backward (core/fused.py) applies; also provides prefill/decode
serving steps with ring-buffer KV caches (bounded cache for SWA archs —
what makes danube long_500k sub-quadratic).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.moe import MoEConfig, capacity, moe_ffn, moe_init
from repro.sharding.act import shard_act

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    norm: str = "rmsnorm"
    qk_norm: bool = False
    window: Optional[int] = None          # SWA
    rope_theta: float = 10000.0
    rope_pct: float = 1.0
    act: str = "silu"
    glu: bool = True
    tie_embeddings: bool = False
    embed_scale: bool = False             # gemma-style sqrt(d) embed scaling
    # prefix-LM / stub modality frontend (paligemma)
    prefix_lm: bool = False
    n_prefix_tokens: int = 0              # stub patch/frame embeds prepended
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mtp: bool = False                     # deepseek-v3 multi-token prediction
    mtp_weight: float = 0.1
    z_loss: float = 0.0
    dtype: Any = jnp.bfloat16

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Total parameters (for 6·N·D roofline bookkeeping)."""
        import math
        shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), self))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top-k routed only)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        E, K, f, d = (self.moe.n_routed, self.moe.top_k,
                      self.moe.d_ff_expert, self.d_model)
        routed = self.n_layers * E * 3 * d * f
        active_routed = self.n_layers * K * 3 * d * f
        return total - routed + active_routed


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def _attn_init(key, cfg: LMConfig) -> dict:
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 10)
    dt = cfg.dtype
    if cfg.mla is not None:
        m = cfg.mla
        p = {
            "w_dq": L.linear_init(ks[0], d, m.q_lora_rank, dtype=dt),
            "q_ln": L.norm_init(m.q_lora_rank, "rmsnorm"),
            "w_uq": L.linear_init(ks[1], m.q_lora_rank,
                                  H * (m.d_nope + m.d_rope), dtype=dt),
            "w_dkv": L.linear_init(ks[2], d, m.kv_lora_rank, dtype=dt),
            "kv_ln": L.norm_init(m.kv_lora_rank, "rmsnorm"),
            "w_kr": L.linear_init(ks[3], d, m.d_rope, dtype=dt),
            "w_uk": L.linear_init(ks[4], m.kv_lora_rank, H * m.d_nope,
                                  dtype=dt),
            "w_uv": L.linear_init(ks[5], m.kv_lora_rank, H * m.d_v, dtype=dt),
            "wo": L.linear_init(ks[6], H * m.d_v, d,
                                scale=(2 * cfg.n_layers) ** -0.5, dtype=dt),
        }
        return p
    p = {
        "wq": L.linear_init(ks[0], d, H * dh, dtype=dt),
        "wk": L.linear_init(ks[1], d, K * dh, dtype=dt),
        "wv": L.linear_init(ks[2], d, K * dh, dtype=dt),
        "wo": L.linear_init(ks[3], H * dh, d,
                            scale=(2 * cfg.n_layers) ** -0.5, dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.norm_init(dh, "rmsnorm")
        p["k_norm"] = L.norm_init(dh, "rmsnorm")
    return p


def _block_init(key, cfg: LMConfig) -> dict:
    ks = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.dtype
    p = {
        "ln1": L.norm_init(d, cfg.norm),
        "ln2": L.norm_init(d, cfg.norm),
        "attn": _attn_init(ks[0], cfg),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[1], d, cfg.moe, dtype=dt)
    elif cfg.glu:
        p["mlp"] = {
            "w_gate": L.linear_init(ks[1], d, f, dtype=dt),
            "w_up": L.linear_init(ks[2], d, f, dtype=dt),
            "w_down": L.linear_init(ks[3], f, d,
                                    scale=(2 * cfg.n_layers) ** -0.5,
                                    dtype=dt),
        }
    else:
        p["mlp"] = {
            "w_up": L.linear_init(ks[1], d, f, dtype=dt),
            "b_up": jnp.zeros((f,), dt),
            "w_down": L.linear_init(ks[2], f, d, dtype=dt),
            "b_down": jnp.zeros((d,), dt),
        }
    return p


def init_params(key, cfg: LMConfig) -> dict:
    """Params in the fused-engine layout: {outer, shared, stacks}."""
    k_e, k_b, k_h, k_m = jax.random.split(key, 4)
    outer = {
        "tok_embed": L.embed_init(k_e, cfg.vocab, cfg.d_model,
                                  dtype=cfg.dtype),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        outer["head"] = L.linear_init(k_h, cfg.d_model, cfg.vocab,
                                      dtype=cfg.dtype)
    if cfg.mtp:
        # MTP block is dense (the routed experts live in the main stack).
        mtp_cfg = dataclasses.replace(cfg, moe=None, mtp=False)
        outer["mtp_proj"] = L.linear_init(k_m, 2 * cfg.d_model, cfg.d_model,
                                          dtype=cfg.dtype)
        outer["mtp_block"] = _block_init(k_m, mtp_cfg)
        outer["mtp_norm"] = L.norm_init(cfg.d_model, cfg.norm)
    blocks = jax.vmap(lambda k: _block_init(k, cfg))(
        jax.random.split(k_b, cfg.n_layers))
    return {"outer": outer, "shared": {}, "stacks": {"blocks": blocks}}


# --------------------------------------------------------------------------
# Attention paths
# --------------------------------------------------------------------------

def _gqa_attn(p: dict, cfg: LMConfig, h: Array, pos: Array,
              prefix_len: Optional[Array],
              seg: Optional[Array] = None) -> Array:
    B, S, _ = h.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = shard_act(L.dense(h, p["wq"]).reshape(B, S, H, dh), "heads")
    k = shard_act(L.dense(h, p["wk"]).reshape(B, S, K, dh), "heads")
    v = shard_act(L.dense(h, p["wv"]).reshape(B, S, K, dh), "heads")
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"]["scale"])
        k = L.rmsnorm(k, p["k_norm"]["scale"])
    d_rot = int(dh * cfg.rope_pct) // 2 * 2
    # packed batches: pos is (B, S) with per-segment restarts, so RoPE
    # phases restart at each document boundary (sin/cos broadcast per row)
    sin, cos = L.rope_sincos(pos, d_rot, cfg.rope_theta)
    q = L.apply_rope(q, sin, cos, cfg.rope_pct)
    k = L.apply_rope(k, sin, cos, cfg.rope_pct)
    spec = L.MaskSpec(causal=True, window=cfg.window,
                      has_prefix=cfg.prefix_lm, segmented=seg is not None)
    o = L.attention(q, k, v, spec=spec, q_pos=pos, kv_pos=pos,
                    prefix_len=prefix_len, q_seg=seg, kv_seg=seg)
    o = shard_act(o, "heads")
    return shard_act(L.dense(o.reshape(B, S, H * dh), p["wo"]), "hidden")


def _mla_attn(p: dict, cfg: LMConfig, h: Array, pos: Array,
              prefix_len: Optional[Array],
              seg: Optional[Array] = None) -> Array:
    """MLA (train/prefill path): latent KV is up-projected per head."""
    m = cfg.mla
    B, S, _ = h.shape
    H = cfg.n_heads
    q = shard_act(
        L.dense(L.rmsnorm(L.dense(h, p["w_dq"]), p["q_ln"]["scale"]),
                p["w_uq"]).reshape(B, S, H, m.d_nope + m.d_rope), "heads")
    q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
    ckv = L.rmsnorm(L.dense(h, p["w_dkv"]), p["kv_ln"]["scale"])  # [B,S,r]
    k_rope = L.dense(h, p["w_kr"]).reshape(B, S, 1, m.d_rope)
    sin, cos = L.rope_sincos(pos, m.d_rope, cfg.rope_theta)
    q_rope = L.apply_rope(q_rope, sin, cos)
    k_rope = L.apply_rope(k_rope, sin, cos)
    k_nope = shard_act(L.dense(ckv, p["w_uk"]).reshape(B, S, H, m.d_nope),
                       "heads")
    v = shard_act(L.dense(ckv, p["w_uv"]).reshape(B, S, H, m.d_v), "heads")
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (B, S, H, m.d_rope))],
                        axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    spec = L.MaskSpec(causal=True, window=cfg.window,
                      has_prefix=cfg.prefix_lm, segmented=seg is not None)
    scale = (m.d_nope + m.d_rope) ** -0.5
    o = shard_act(L.attention(qf, k, v, spec=spec, q_pos=pos, kv_pos=pos,
                              prefix_len=prefix_len, q_seg=seg, kv_seg=seg,
                              scale=scale), "heads")
    return shard_act(L.dense(o.reshape(B, S, H * m.d_v), p["wo"]), "hidden")


# --------------------------------------------------------------------------
# Fused-engine spec (train path)
# --------------------------------------------------------------------------

def make_block_body(cfg: LMConfig):
    def body(p, ctx, carry, aux_idx):
        del aux_idx
        _, ctx_act = ctx
        x, aux_loss = carry
        pos = jax.lax.stop_gradient(ctx_act["pos"]).astype(jnp.int32)
        prefix_len = ctx_act.get("prefix")
        if prefix_len is not None:
            prefix_len = jax.lax.stop_gradient(prefix_len).astype(jnp.int32)
        seg = ctx_act.get("seg")
        if seg is not None:
            seg = jax.lax.stop_gradient(seg).astype(jnp.int32)
        h = L.norm_apply(p["ln1"], x, kind=cfg.norm)
        if cfg.mla is not None:
            x = x + _mla_attn(p["attn"], cfg, h, pos, prefix_len, seg)
        else:
            x = x + _gqa_attn(p["attn"], cfg, h, pos, prefix_len, seg)
        h = L.norm_apply(p["ln2"], x, kind=cfg.norm)
        if cfg.moe is not None:
            y, aux = moe_ffn(p["moe"], h, cfg.moe)
            x = x + y
            aux_loss = aux_loss + aux
        elif cfg.glu:
            x = x + L.glu_mlp(p["mlp"], h, cfg.act)
        else:
            x = x + L.mlp(p["mlp"], h, cfg.act)
        return (x, aux_loss)

    return body


def _embed(outer: dict, cfg: LMConfig, tokens: Array) -> Array:
    x = outer["tok_embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _logits(outer: dict, cfg: LMConfig, h: Array) -> Array:
    w = outer["tok_embed"].T if cfg.tie_embeddings else outer["head"]
    return shard_act(jnp.einsum("...d,dv->...v", h, w,
                                preferred_element_type=jnp.float32),
                     "vocab")


def cross_entropy(logits: Array, labels: Array, z_loss: float = 0.0
                  ) -> tuple[Array, Array, Array]:
    """Masked CE. labels < 0 are ignored. Returns (loss, ntok, ncorrect)."""
    mask = (labels >= 0)
    lab = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    loss = jnp.sum(nll)
    if z_loss:
        loss = loss + z_loss * jnp.sum(jnp.square(lse) * mask)
    ntok = jnp.sum(mask)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == lab) & mask)
    return loss, ntok, correct


def make_prologue(cfg: LMConfig):
    def prologue(outer, batch):
        x = _embed(outer, cfg, batch["tokens"])
        if cfg.n_prefix_tokens:
            # stub modality frontend: precomputed patch/frame embeddings
            x = jnp.concatenate(
                [batch["prefix_embed"].astype(x.dtype), x], axis=1)
        return (x, jnp.zeros((), jnp.float32))

    return prologue


def make_pro_ctx(cfg: LMConfig):
    def pro_ctx(outer, batch):
        # ctx activations are float32 so the fused engine's generic
        # zero-cotangent plumbing stays vjp-safe; bodies stop_gradient
        # and cast back to int32.
        if "segment_ids" in batch:
            if cfg.prefix_lm or cfg.n_prefix_tokens or cfg.mtp:
                raise ValueError(
                    "packed (segment-id) batches are not supported for "
                    "prefix-LM / modality-prefix / MTP architectures")
            return {"pos": batch["positions"].astype(jnp.float32),
                    "seg": batch["segment_ids"].astype(jnp.float32)}
        S = batch["tokens"].shape[1] + cfg.n_prefix_tokens
        ctx = {"pos": jnp.arange(S, dtype=jnp.float32)}
        if cfg.prefix_lm:
            ctx["prefix"] = batch["prefix_len"].astype(jnp.float32)
        return ctx

    return pro_ctx


def make_epilogue(cfg: LMConfig):
    def epilogue(outer, carry, batch):
        x, aux_loss = carry
        if cfg.n_prefix_tokens:
            x = x[:, cfg.n_prefix_tokens:]
        h = L.norm_apply(outer["final_norm"], x, kind=cfg.norm)
        logits = _logits(outer, cfg, h)
        loss_sum, ntok, correct = cross_entropy(logits, batch["labels"],
                                                cfg.z_loss)
        denom = jnp.maximum(ntok, 1).astype(jnp.float32)
        loss = loss_sum / denom + aux_loss
        if cfg.mtp:
            # Multi-token prediction (deepseek-v3): one extra block predicts
            # token t+2 from [h_t ; emb(token_{t+1})].
            emb_next = _embed(outer, cfg, batch["tokens"])
            mtp_in = jnp.concatenate([h, emb_next], axis=-1)
            hm = L.dense(mtp_in, outer["mtp_proj"])
            body = make_block_body(
                dataclasses.replace(cfg, mtp=False, moe=None))
            S = hm.shape[1]
            ctx = ({}, {"pos": jnp.arange(S, dtype=jnp.float32)})
            hm, _ = body(outer["mtp_block"], ctx,
                         (hm, jnp.zeros((), jnp.float32)), 0)
            hm = L.norm_apply(outer["mtp_norm"], hm, kind=cfg.norm)
            mtp_logits = _logits(outer, cfg, hm)
            mtp_loss, mtp_ntok, _ = cross_entropy(mtp_logits,
                                                  batch["labels_mtp"])
            loss = loss + cfg.mtp_weight * mtp_loss / jnp.maximum(
                mtp_ntok, 1).astype(jnp.float32)
        metrics = jax.lax.stop_gradient({
            "loss": loss,
            "ntokens": ntok.astype(jnp.float32),
            "accuracy": correct.astype(jnp.float32) / denom,
        })
        return loss, metrics

    return epilogue


def make_fused_spec(cfg: LMConfig):
    from repro.core.fused import FusedSpec
    return FusedSpec(
        prologue=make_prologue(cfg),
        bodies={"blocks": make_block_body(cfg)},
        epilogue=make_epilogue(cfg),
        pro_ctx=make_pro_ctx(cfg),
    )


# --------------------------------------------------------------------------
# Serving: prefill + single-token decode with (ring) KV cache
# --------------------------------------------------------------------------

def cache_window(cfg: LMConfig, max_len: int) -> int:
    """SWA archs only ever need a window-sized ring cache."""
    return min(cfg.window, max_len) if cfg.window else max_len


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    W = cache_window(cfg, max_len)
    Lr, dt = cfg.n_layers, cfg.dtype
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((Lr, batch, W, m.kv_lora_rank), dt),
            "kr": jnp.zeros((Lr, batch, W, m.d_rope), dt),
            "pos": jnp.full((W,), -1, jnp.int32),
            "cur": jnp.zeros((), jnp.int32),
        }
    K, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((Lr, batch, W, K, dh), dt),
        "v": jnp.zeros((Lr, batch, W, K, dh), dt),
        "pos": jnp.full((W,), -1, jnp.int32),
        "cur": jnp.zeros((), jnp.int32),
    }


def _decode_gqa(p, cfg: LMConfig, h, kc, vc, pos_tab, cur):
    """One-token GQA decode; writes ring slot cur % W. h: [B,1,d]."""
    B = h.shape[0]
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L.dense(h, p["wq"]).reshape(B, 1, H, dh)
    k = L.dense(h, p["wk"]).reshape(B, 1, K, dh)
    v = L.dense(h, p["wv"]).reshape(B, 1, K, dh)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"]["scale"])
        k = L.rmsnorm(k, p["k_norm"]["scale"])
    posv = cur[None].astype(jnp.float32)
    d_rot = int(dh * cfg.rope_pct) // 2 * 2
    sin, cos = L.rope_sincos(posv, d_rot, cfg.rope_theta)
    q = L.apply_rope(q, sin, cos, cfg.rope_pct)
    k = L.apply_rope(k, sin, cos, cfg.rope_pct)
    W = kc.shape[1]
    slot = jnp.mod(cur, W)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
    kv_pos = jnp.broadcast_to(pos_tab[None], (B, W))
    o = L.decode_attention(q, kc, vc, kv_pos=kv_pos,
                           q_pos=jnp.full((B,), cur, jnp.int32),
                           window=cfg.window)
    return L.dense(o.reshape(B, 1, H * dh), p["wo"]), kc, vc


def _decode_mla(p, cfg: LMConfig, h, ckv_c, kr_c, pos_tab, cur):
    """Absorbed-matmul MLA decode: scores in latent space, cache = latent."""
    m = cfg.mla
    B = h.shape[0]
    H = cfg.n_heads
    q = L.dense(L.rmsnorm(L.dense(h, p["w_dq"]), p["q_ln"]["scale"]),
                p["w_uq"]).reshape(B, 1, H, m.d_nope + m.d_rope)
    q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
    posv = cur[None].astype(jnp.float32)
    sin, cos = L.rope_sincos(posv, m.d_rope, cfg.rope_theta)
    q_rope = L.apply_rope(q_rope, sin, cos)
    ckv = L.rmsnorm(L.dense(h, p["w_dkv"]), p["kv_ln"]["scale"])  # [B,1,r]
    kr = L.dense(h, p["w_kr"]).reshape(B, 1, 1, m.d_rope)
    kr = L.apply_rope(kr, sin, cos).reshape(B, 1, m.d_rope)
    W = ckv_c.shape[1]
    slot = jnp.mod(cur, W)
    ckv_c = jax.lax.dynamic_update_slice_in_dim(ckv_c, ckv, slot, axis=1)
    kr_c = jax.lax.dynamic_update_slice_in_dim(kr_c, kr, slot, axis=1)
    # absorb W_uk into the query: q_lat [B,H,r]
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.d_nope)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    s_nope = jnp.einsum("bhr,bwr->bhw", q_lat, ckv_c)
    s_rope = jnp.einsum("bhd,bwd->bhw", q_rope[:, 0], kr_c)
    scale = (m.d_nope + m.d_rope) ** -0.5
    logits = (s_nope + s_rope).astype(jnp.float32) * scale
    valid = (pos_tab >= 0) & (pos_tab <= cur)
    logits = jnp.where(valid[None, None, :], logits, L.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(ckv_c.dtype)
    o_lat = jnp.einsum("bhw,bwr->bhr", probs, ckv_c)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.d_v)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv).reshape(B, 1, H * m.d_v)
    return L.dense(o, p["wo"]), ckv_c, kr_c


def make_decode_step(cfg: LMConfig):
    """decode_step(params, cache, batch{'tokens': (B,1)}) -> (logits, cache)."""
    def decode_step(params, cache, batch):
        outer = params["outer"]
        x = _embed(outer, cfg, batch["tokens"])  # [B,1,d]
        cur = cache["cur"]
        W0 = cache["pos"].shape[0]
        # mark the current slot *before* attention so the token sees itself
        cache = dict(cache)
        cache["pos"] = cache["pos"].at[jnp.mod(cur, W0)].set(cur)
        aux0 = jnp.zeros((), jnp.float32)

        def body(carry, xs):
            x, _ = carry
            if cfg.mla is not None:
                layer_p, ckv_c, kr_c = xs
                h = L.norm_apply(layer_p["ln1"], x, kind=cfg.norm)
                a, ckv_c, kr_c = _decode_mla(layer_p["attn"], cfg, h,
                                             ckv_c, kr_c, cache["pos"], cur)
                new_slices = (ckv_c, kr_c)
            else:
                layer_p, kc, vc = xs
                h = L.norm_apply(layer_p["ln1"], x, kind=cfg.norm)
                a, kc, vc = _decode_gqa(layer_p["attn"], cfg, h, kc, vc,
                                        cache["pos"], cur)
                new_slices = (kc, vc)
            x = x + a
            h = L.norm_apply(layer_p["ln2"], x, kind=cfg.norm)
            if cfg.moe is not None:
                y, _ = moe_ffn(layer_p["moe"], h, cfg.moe)
                x = x + y
            elif cfg.glu:
                x = x + L.glu_mlp(layer_p["mlp"], h, cfg.act)
            else:
                x = x + L.mlp(layer_p["mlp"], h, cfg.act)
            return (x, aux0), new_slices

        blocks = params["stacks"]["blocks"]
        if cfg.mla is not None:
            xs = (blocks, cache["ckv"], cache["kr"])
        else:
            xs = (blocks, cache["k"], cache["v"])
        (x, _), new_cache_stk = jax.lax.scan(body, (x, aux0), xs)
        h = L.norm_apply(outer["final_norm"], x, kind=cfg.norm)
        logits = _logits(outer, cfg, h)[:, 0]
        if cfg.mla is not None:
            new_cache = {"ckv": new_cache_stk[0], "kr": new_cache_stk[1],
                         "pos": cache["pos"], "cur": cur + 1}
        else:
            new_cache = {"k": new_cache_stk[0], "v": new_cache_stk[1],
                         "pos": cache["pos"], "cur": cur + 1}
        return logits, new_cache

    return decode_step


# --------------------------------------------------------------------------
# Paged serving: prefill emits full per-layer K/V; decode reads/writes a
# shared page pool through per-sequence block tables (serve/paging.py).
# --------------------------------------------------------------------------

def make_prefill_kv_step(cfg: LMConfig):
    """prefill(params, batch{'tokens': [B,S], 'length': [B]}) ->
    (logits [B,vocab] at position length-1, k [L,B,S,K,dh], v [L,B,S,K,dh]).

    Unlike :func:`make_prefill_step` this keeps the *full* per-layer K/V
    (no ring truncation) so the engine can scatter it into KV pages; SWA is
    enforced by the decode-attention mask instead of cache truncation.
    Right-padding is harmless: with a causal mask, K/V at positions < length
    never see the pad tail, and logits are gathered at length-1."""
    assert cfg.mla is None, "paged serving supports GQA caches only"
    assert not cfg.prefix_lm, "paged serving: prefix-LM not plumbed yet"

    def prefill(params, batch):
        outer = params["outer"]
        tokens = batch["tokens"]
        length = batch["length"].astype(jnp.int32)
        B, S = tokens.shape
        x = _embed(outer, cfg, tokens)
        pos = jnp.arange(S, dtype=jnp.int32)
        body_train = make_block_body(cfg)

        def body(carry, layer_p):
            x, aux = carry
            ctx = ({}, {"pos": pos.astype(jnp.float32)})
            x2, aux2 = body_train(layer_p, ctx, (x, aux), 0)
            h = L.norm_apply(layer_p["ln1"], x, kind=cfg.norm)
            K, dh = cfg.n_kv_heads, cfg.head_dim
            k = L.dense(h, layer_p["attn"]["wk"]).reshape(B, S, K, dh)
            if cfg.qk_norm:
                k = L.rmsnorm(k, layer_p["attn"]["k_norm"]["scale"])
            d_rot = int(dh * cfg.rope_pct) // 2 * 2
            sin, cos = L.rope_sincos(pos.astype(jnp.float32), d_rot,
                                     cfg.rope_theta)
            k = L.apply_rope(k, sin, cos, cfg.rope_pct)
            v = L.dense(h, layer_p["attn"]["wv"]).reshape(B, S, K, dh)
            return (x2, aux2), (k, v)

        (x, _), (k_stk, v_stk) = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            params["stacks"]["blocks"])
        x_last = jnp.take_along_axis(
            x, jnp.maximum(length - 1, 0)[:, None, None], axis=1)
        h = L.norm_apply(outer["final_norm"], x_last, kind=cfg.norm)
        logits = _logits(outer, cfg, h)[:, 0]
        return logits, k_stk, v_stk

    return prefill


def make_paged_decode_step(cfg: LMConfig, *, use_kernel=None,
                           interpret=False):
    """decode(params, pages, batch) -> (logits [B,vocab], new pages).

    pages: {'k','v': [L, N, ps, K, dh]} — the shared page pool.
    batch: tokens [B,1]; block_tables [B,P] (page ids, logical order,
    unallocated tail = scratch page 0); seq_lens [B] tokens already cached
    (== position of the incoming token); emit [B] bool — rows that are
    live this step.  Frozen rows write their K/V to the scratch page and
    their logits are garbage by construction; the engine masks them."""
    assert cfg.mla is None, "paged serving supports GQA caches only"
    from repro.kernels.decode_attention.ops import paged_decode_attention

    def decode(params, pages, batch):
        outer = params["outer"]
        tokens = batch["tokens"]
        bt = batch["block_tables"].astype(jnp.int32)
        n = batch["seq_lens"].astype(jnp.int32)            # [B]
        emit = batch["emit"]
        B = tokens.shape[0]
        ps = pages["k"].shape[2]
        H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

        x = _embed(outer, cfg, tokens)                     # [B,1,d]
        # page/slot the incoming token lands in; frozen rows -> scratch 0
        pidx = jnp.where(emit, bt[jnp.arange(B), n // ps], 0)
        slot = jnp.where(emit, n % ps, 0)
        n_incl = n + 1                                     # incl. this token
        posv = n.astype(jnp.float32)[:, None]              # [B,1]
        d_rot = int(dh * cfg.rope_pct) // 2 * 2
        sin, cos = L.rope_sincos(posv, d_rot, cfg.rope_theta)
        aux0 = jnp.zeros((), jnp.float32)

        def body(carry, xs):
            x, _ = carry
            layer_p, kp, vp = xs
            h = L.norm_apply(layer_p["ln1"], x, kind=cfg.norm)
            q = L.dense(h, layer_p["attn"]["wq"]).reshape(B, 1, H, dh)
            k = L.dense(h, layer_p["attn"]["wk"]).reshape(B, 1, K, dh)
            v = L.dense(h, layer_p["attn"]["wv"]).reshape(B, 1, K, dh)
            if cfg.qk_norm:
                q = L.rmsnorm(q, layer_p["attn"]["q_norm"]["scale"])
                k = L.rmsnorm(k, layer_p["attn"]["k_norm"]["scale"])
            q = L.apply_rope(q, sin, cos, cfg.rope_pct)
            k = L.apply_rope(k, sin, cos, cfg.rope_pct)
            kp = kp.at[pidx, slot].set(k[:, 0])
            vp = vp.at[pidx, slot].set(v[:, 0])
            o = paged_decode_attention(q, kp, vp, bt, n_incl,
                                       window=cfg.window,
                                       use_kernel=use_kernel,
                                       interpret=interpret)
            a = L.dense(o.reshape(B, 1, H * dh), layer_p["attn"]["wo"])
            x = x + a
            h = L.norm_apply(layer_p["ln2"], x, kind=cfg.norm)
            if cfg.moe is not None:
                y, _ = moe_ffn(layer_p["moe"], h, cfg.moe)
                x = x + y
            elif cfg.glu:
                x = x + L.glu_mlp(layer_p["mlp"], h, cfg.act)
            else:
                x = x + L.mlp(layer_p["mlp"], h, cfg.act)
            return (x, aux0), (kp, vp)

        xs = (params["stacks"]["blocks"], pages["k"], pages["v"])
        (x, _), (k_new, v_new) = jax.lax.scan(body, (x, aux0), xs)
        h = L.norm_apply(outer["final_norm"], x, kind=cfg.norm)
        logits = _logits(outer, cfg, h)[:, 0]
        return logits, {"k": k_new, "v": v_new}

    return decode


def init_page_pool(cfg: LMConfig, num_pages: int, page_size: int) -> dict:
    """Zeroed shared KV page pool (page 0 is the engine's scratch page)."""
    assert cfg.mla is None, "paged serving supports GQA caches only"
    shape = (cfg.n_layers, num_pages, page_size, cfg.n_kv_heads,
             cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.dtype),
            "v": jnp.zeros(shape, cfg.dtype)}


def make_prefill_step(cfg: LMConfig):
    """prefill_step(params, batch) -> (last_logits, cache). Computes the
    full-sequence forward and materializes the KV cache for decoding."""
    def prefill_step(params, batch):
        outer = params["outer"]
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = _embed(outer, cfg, tokens)
        if cfg.n_prefix_tokens:
            x = jnp.concatenate([batch["prefix_embed"].astype(x.dtype), x],
                                axis=1)
            S = S + cfg.n_prefix_tokens
        pos = jnp.arange(S, dtype=jnp.int32)
        prefix_len = None
        if cfg.prefix_lm:
            prefix_len = batch["prefix_len"].astype(jnp.int32)
        W = cache_window(cfg, S)
        body_train = make_block_body(cfg)

        def body(carry, layer_p):
            x, aux = carry
            ctx = ({}, {"pos": pos.astype(jnp.float32)}
                   if prefix_len is None else
                   {"pos": pos.astype(jnp.float32),
                    "prefix": prefix_len.astype(jnp.float32)})
            (x2, aux2) = body_train(layer_p, ctx, (x, aux), 0)
            # recompute this layer's KV for the cache (last W positions)
            h = L.norm_apply(layer_p["ln1"], x, kind=cfg.norm)
            if cfg.mla is not None:
                m = cfg.mla
                ckv = L.rmsnorm(L.dense(h, layer_p["attn"]["w_dkv"]),
                                layer_p["attn"]["kv_ln"]["scale"])
                kr = L.dense(h, layer_p["attn"]["w_kr"]).reshape(
                    B, S, 1, m.d_rope)
                sin, cos = L.rope_sincos(pos.astype(jnp.float32), m.d_rope,
                                         cfg.rope_theta)
                kr = L.apply_rope(kr, sin, cos).reshape(B, S, m.d_rope)
                cache_slice = (ckv[:, S - W:], kr[:, S - W:])
            else:
                K, dh = cfg.n_kv_heads, cfg.head_dim
                k = L.dense(h, layer_p["attn"]["wk"]).reshape(B, S, K, dh)
                if cfg.qk_norm:
                    k = L.rmsnorm(k, layer_p["attn"]["k_norm"]["scale"])
                d_rot = int(dh * cfg.rope_pct) // 2 * 2
                sin, cos = L.rope_sincos(pos.astype(jnp.float32), d_rot,
                                         cfg.rope_theta)
                k = L.apply_rope(k, sin, cos, cfg.rope_pct)
                v = L.dense(h, layer_p["attn"]["wv"]).reshape(B, S, K, dh)
                cache_slice = (k[:, S - W:], v[:, S - W:])
            return (x2, aux2), cache_slice

        (x, _), cache_stk = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            params["stacks"]["blocks"])
        h = L.norm_apply(outer["final_norm"], x[:, -1:], kind=cfg.norm)
        logits = _logits(outer, cfg, h)[:, 0]
        pos_tab = pos[S - W:]
        if cfg.mla is not None:
            cache = {"ckv": cache_stk[0], "kr": cache_stk[1],
                     "pos": pos_tab, "cur": jnp.asarray(S, jnp.int32)}
        else:
            cache = {"k": cache_stk[0], "v": cache_stk[1],
                     "pos": pos_tab, "cur": jnp.asarray(S, jnp.int32)}
        return logits, cache

    return prefill_step

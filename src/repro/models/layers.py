"""Shared neural-net layers for the model zoo (pure JAX, scan-friendly).

Everything here is a pure function over explicit parameter pytrees so that
layers compose with the fused-backward engine (``core/fused.py``) and shard
cleanly under pjit.  Attention supports GQA/MQA, sliding windows (SWA),
qk-norm, prefix-LM masks and cross-attention, with a two-level blockwise
(flash-style) path for long sequences that never materializes an S×S score
matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.act import shard_act

Array = jax.Array

# Sequences at or below this use the direct einsum attention path; above it,
# the blockwise online-softmax path (bounded memory, compile-friendly scans).
# 2048 keeps the S×S score tensor out of HBM at the train_4k production
# shape (§Perf H3); tests/decode paths pass force_direct explicitly.
_DIRECT_ATTN_MAX_SEQ = 2048
_Q_BLOCK = 1024
_KV_BLOCK = 1024

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_apply(params: dict, x: Array, *, kind: str, eps: float = 1e-6) -> Array:
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"], eps)
    return layernorm(x, params["scale"], params["bias"], eps)


def norm_init(d: int, kind: str):
    if kind == "rmsnorm":
        # stored as (scale - 1) so zeros-init == identity; see rmsnorm().
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_sincos(positions: Array, d_rot: int, theta: float = 10000.0
                ) -> tuple[Array, Array]:
    """positions: (...,) int -> sin/cos tables (..., d_rot/2) fp32."""
    half = d_rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: Array, sin: Array, cos: Array, rope_pct: float = 1.0
               ) -> Array:
    """x: (..., S, H, dh); sin/cos: (S, d_rot/2) or broadcastable."""
    dh = x.shape[-1]
    d_rot = int(dh * rope_pct)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    # sin/cos broadcast over batch & head dims: (S, half) -> (S, 1, half)
    s = sin[..., :, None, :]
    c = cos[..., :, None, :]
    rot = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([rot.astype(x.dtype), xp], axis=-1)


# --------------------------------------------------------------------------
# Masks (computed from positions on the fly — never S×S in HBM for long S)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MaskSpec:
    causal: bool = True
    window: Optional[int] = None       # SWA: attend to [pos-window+1, pos]
    # prefix-LM: kv positions < prefix_len[b] are visible to every query
    has_prefix: bool = False
    # packed-segment batches: attention also requires equal segment ids
    # (q_seg/kv_seg arrays travel alongside positions); incompatible with
    # has_prefix.  Static at trace time like every other MaskSpec field.
    segmented: bool = False


def _mask_block(q_pos: Array, kv_pos: Array, spec: MaskSpec,
                prefix_len: Optional[Array], q_seg: Optional[Array] = None,
                kv_seg: Optional[Array] = None) -> Array:
    """Bool mask block (..., Sq, Skv) from position (and segment) vectors."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], kv_pos.shape[-1]),
                 dtype=bool)
    q = q_pos[..., :, None]
    k = kv_pos[..., None, :]
    if spec.causal:
        m = m & (q >= k)
    if spec.window is not None:
        m = m & (q - k < spec.window)
    if q_seg is not None:
        m = m & (q_seg[..., :, None] == kv_seg[..., None, :])
    if spec.has_prefix and prefix_len is not None:
        pl = prefix_len.reshape(prefix_len.shape + (1, 1))
        m = m | (k < pl)
        if spec.window is not None:
            m = m & ((q - k < spec.window) | (k < pl))
    return m


def _scan_block_mask(qp: Array, kp: Array, qs: Optional[Array],
                     ks: Optional[Array], spec: MaskSpec,
                     pl4: Optional[Array]) -> Array:
    """Mask for one (q_block, kv_block) pair inside the blockwise scans.

    qp: (T, qb) tile-shared metadata or (B, T, qb) per-row (packed
    segments); kp: (kb,) or (B, kb) correspondingly; qs/ks: segment-id
    blocks of the same shapes, or None.  Returns a mask broadcastable
    against score blocks [B, T, K, G, qb, kb]: leading dim 1 when the
    metadata is row-invariant, B otherwise.
    """
    batched = qp.ndim == 3
    qe = qp[..., :, None]                           # (T,qb,1) | (B,T,qb,1)
    ke = kp[:, None, None, :] if batched else kp[None, None, :]
    m = jnp.ones(jnp.broadcast_shapes(qe.shape, ke.shape), bool)
    if spec.causal:
        m = m & (qe >= ke)
    if spec.window is not None:
        m = m & (qe - ke < spec.window)
    if qs is not None:
        kse = ks[:, None, None, :] if batched else ks[None, None, :]
        m = m & (qs[..., :, None] == kse)
    if spec.has_prefix and pl4 is not None:
        # prefix-LM is unpacked-only (1-D metadata): lift to (B,T,qb,kb)
        m = m[None] | (ke[None] < pl4)
        if spec.window is not None:
            m = m & ((qe - ke < spec.window)[None] | (ke[None] < pl4))
        return m[:, :, None, None]                  # (B,T,1,1,qb,kb)
    if batched:
        return m[:, :, None, None]                  # (B,T,1,1,qb,kb)
    return m[None, :, None, None]                   # (1,T,1,1,qb,kb)


def _q_meta_blocks(a: Array, T: int, Sloc: int, pq: int, qb: int,
                   fill) -> Array:
    """Tile + pad + block query metadata (positions / segment ids):
    (Sq,) -> [nq, T, qb]; (B, Sq) -> [nq, B, T, qb]."""
    a = a.reshape(a.shape[:-1] + (T, Sloc))
    if pq:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pq)],
                    constant_values=fill)
    nq = (Sloc + pq) // qb
    a = a.reshape(a.shape[:-1] + (nq, qb))
    if a.ndim == 3:
        return a.transpose(1, 0, 2)
    return a.transpose(2, 0, 1, 3)


def _kv_meta_blocks(a: Array, pk: int, kb: int, fill) -> Array:
    """Pad + block kv metadata: (Skv,) -> [nk, kb]; (B, Skv) -> [nk, B, kb]."""
    if pk:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pk)],
                    constant_values=fill)
    nk = a.shape[-1] // kb
    a = a.reshape(a.shape[:-1] + (nk, kb))
    return a if a.ndim == 2 else a.transpose(1, 0, 2)


# Fill values for padded metadata slots: a padded query (pos -1, seg -1)
# and a padded kv (pos 2**30, seg -2) can never satisfy causal/window or
# segment-equality terms against any real slot.
_QPOS_FILL, _KPOS_FILL = -1, 2 ** 30
_QSEG_FILL, _KSEG_FILL = -1, -2


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

def _direct_attention(q, k, v, mask, scale):
    """q: [B,Sq,K,G,dh] k/v: [B,Skv,K,dh] mask: broadcastable [B,1,1,Sq,Skv]."""
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out


def _block_attention(q, k, v, q_pos, kv_pos, spec, prefix_len, scale,
                     q_block: int, kv_block: int, tiles: int = 1,
                     return_lse: bool = False, q_seg=None, kv_seg=None):
    """Two-level blockwise attention with online softmax (flash-style).

    q: [B,Sq,K,G,dh]; k/v: [B,Skv,K,dh]; q_pos: (Sq,) shared across rows,
    or (B,Sq) per-row for packed-segment batches (then q_seg/kv_seg carry
    matching segment ids and attention never crosses a segment).
    Scans query blocks (outer) and KV blocks (inner); score blocks of shape
    [B,T,K,G,qb,kb] are the only O(S·block) intermediates.

    ``tiles`` > 1 enables *sequence-tiled* execution (§Perf): the query
    sequence is split into T tiles carried as a tensor dim sharded over the
    model axis, so the q-block scan axis stays unsharded — every device
    processes its own S/T query rows each step (context parallelism in
    plain pjit, no shard_map).
    """
    B, Sq, K, G, dh = q.shape
    dv = v.shape[-1]
    Skv = k.shape[1]
    if q_seg is not None and q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos, (B, Sq))
        kv_pos = jnp.broadcast_to(kv_pos, (B, Skv))
    T = tiles if (tiles > 1 and Sq % tiles == 0) else 1
    Sloc = Sq // T
    qb = min(q_block, Sloc)
    kb = min(kv_block, Skv)
    # pad local q length and kv to block multiples
    pq = (-Sloc) % qb
    pk = (-Skv) % kb
    # metadata (positions / segment ids) -> padded per-tile blocks; fills
    # chosen so padded slots can never pass the mask against real slots
    qps = _q_meta_blocks(q_pos, T, Sloc, pq, qb, _QPOS_FILL)
    qss = (_q_meta_blocks(q_seg, T, Sloc, pq, qb, _QSEG_FILL)
           if q_seg is not None else None)
    kps = _kv_meta_blocks(kv_pos, pk, kb, _KPOS_FILL)
    kss = (_kv_meta_blocks(kv_seg, pk, kb, _KSEG_FILL)
           if kv_seg is not None else None)
    seg = qss is not None
    if pq:  # pad within each tile: reshape → pad → flatten
        q = q.reshape(B, T, Sloc, K, G, dh)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        q = q.reshape(B, T * (Sloc + pq), K, G, dh)
        Sloc += pq
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = Sloc // qb, k.shape[1] // kb

    # [nq, B, T, qb, K, G, dh]; the T dim carries the tp sharding
    qs = shard_act(q.reshape(B, T, nq, qb, K, G, dh), "q_tiled"
                   ).transpose(2, 0, 1, 3, 4, 5, 6)
    ks = k.reshape(B, nk, kb, K, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kb, K, dv).transpose(1, 0, 2, 3, 4)

    pl4 = (prefix_len.reshape(B, 1, 1, 1)
           if prefix_len is not None else None)

    def q_step(_, q_in):
        if seg:
            qi, qp, qsg = q_in  # [B,T,qb,K,G,dh], (T,qb)|(B,T,qb), seg ids
        else:
            (qi, qp), qsg = q_in, None

        def kv_step(carry, kv_in):
            m_run, l_run, acc = carry
            if seg:
                ki, vi, kp, ksg = kv_in
            else:
                (ki, vi, kp), ksg = kv_in, None
            logits = jnp.einsum("btqkgd,bskd->btkgqs", qi, ki,
                                preferred_element_type=jnp.float32) * scale
            mask = _scan_block_mask(qp, kp, qsg, ksg, spec, pl4)
            logits = jnp.where(mask, logits, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("btkgqs,bskd->btkgqd", p.astype(vi.dtype), vi)
            acc = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, T, K, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, T, K, G, qb), jnp.float32)
        a0 = jnp.zeros((B, T, K, G, qb, dv), jnp.float32)
        kv_xs = (ks, vs, kps, kss) if seg else (ks, vs, kps)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), kv_xs)
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        out = out.astype(v.dtype)
        lse = m_f + jnp.log(jnp.maximum(l_f, 1e-30))  # [B,T,K,G,qb]
        return None, (out.transpose(0, 1, 4, 2, 3, 5),  # [B,T,qb,K,G,dv]
                      lse.transpose(0, 1, 4, 2, 3))     # [B,T,qb,K,G]

    q_xs = (qs, qps, qss) if seg else (qs, qps)
    _, (outs, lses) = jax.lax.scan(q_step, None, q_xs)
    out = outs.transpose(1, 2, 0, 3, 4, 5, 6).reshape(
        B, T * nq * qb, K, G, dv)
    lse = lses.transpose(1, 2, 0, 3, 4, 5).reshape(B, T * nq * qb, K, G)
    if pq:
        out = out.reshape(B, T, Sloc, K, G, dv)[:, :, :Sloc - pq].reshape(
            B, Sq, K, G, dv)
        lse = lse.reshape(B, T, Sloc, K, G)[:, :, :Sloc - pq].reshape(
            B, Sq, K, G)
    if return_lse:
        return out, lse
    return out


def _flash_attention(q, k, v, q_pos, kv_pos, spec, prefix_len, scale,
                     q_block: int, kv_block: int, tiles: int,
                     q_seg=None, kv_seg=None):
    """Blockwise attention with a flash-style custom VJP.

    Differentiating through the online-softmax scan makes jax save every
    per-block softmax intermediate — stacked [nk, B, T, K, G, qb, kb] fp32
    tensors that dominated the qwen3 train cell's memory term (§Perf H5).
    The custom VJP saves only (q, k, v, out, lse) and *recomputes* the
    probabilities blockwise in the backward pass, exactly like
    FlashAttention's backward.  Segment masking (packed batches) is part
    of the recomputed mask, so the backward drops cross-segment terms the
    same way the forward does.
    """
    if q_seg is not None and q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos, (q.shape[0], q.shape[1]))
        kv_pos = jnp.broadcast_to(kv_pos, (k.shape[0], k.shape[1]))

    @jax.custom_vjp
    def fa(q, k, v):
        return _block_attention(q, k, v, q_pos, kv_pos, spec, prefix_len,
                                scale, q_block, kv_block, tiles,
                                q_seg=q_seg, kv_seg=kv_seg)

    def fwd(q, k, v):
        out, lse = _block_attention(q, k, v, q_pos, kv_pos, spec,
                                    prefix_len, scale, q_block, kv_block,
                                    tiles, return_lse=True,
                                    q_seg=q_seg, kv_seg=kv_seg)
        return out, (q, k, v, out, lse)

    def bwd(res, dout):
        q, k, v, out, lse = res
        B, Sq, K, G, dh = q.shape
        dvd = v.shape[-1]
        Skv = k.shape[1]
        T = tiles if (tiles > 1 and Sq % tiles == 0) else 1
        Sloc = Sq // T
        qb = min(q_block, Sloc)
        kb = min(kv_block, Skv)
        pq = (-Sloc) % qb
        pk = (-Skv) % kb
        seg = q_seg is not None
        D = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)  # [B,Sq,K,G]

        def pad_q(x, fill=0.0):
            x = x.reshape((B, T, Sloc) + x.shape[2:])
            if pq:
                pad = [(0, 0), (0, 0), (0, pq)] + [(0, 0)] * (x.ndim - 3)
                x = jnp.pad(x, pad, constant_values=fill)
            return x

        qt = pad_q(q)
        dot_ = pad_q(dout)
        lset = pad_q(lse, fill=0.0)
        Dt = pad_q(D)
        qps = _q_meta_blocks(q_pos, T, Sloc, pq, qb, _QPOS_FILL)
        qss = (_q_meta_blocks(q_seg, T, Sloc, pq, qb, _QSEG_FILL)
               if seg else None)
        kps = _kv_meta_blocks(kv_pos, pk, kb, _KPOS_FILL)
        kss = (_kv_meta_blocks(kv_seg, pk, kb, _KSEG_FILL)
               if seg else None)
        Slp = Sloc + pq
        nq = Slp // qb
        if pk:
            k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        nk = k.shape[1] // kb

        # [nq, B, T, qb, ...] blocks
        def blk(x):
            return x.reshape((B, T, nq, qb) + x.shape[3:]).transpose(
                (2, 0, 1, 3) + tuple(range(4, x.ndim + 1)))

        qs, dos = blk(qt), blk(dot_)
        lses, Ds = blk(lset), blk(Dt)
        ks = k.reshape(B, nk, kb, K, dh).transpose(1, 0, 2, 3, 4)
        vs = v.reshape(B, nk, kb, K, dvd).transpose(1, 0, 2, 3, 4)
        pl4 = (prefix_len.reshape(B, 1, 1, 1)
               if prefix_len is not None else None)

        def q_step(carry, xs):
            dk_acc, dv_acc = carry  # [nk,B,kb,K,dh/dv] fp32
            if seg:
                qi, doi, lsei, Di, qp, qsg = xs
            else:
                (qi, doi, lsei, Di, qp), qsg = xs, None
            # btkgq layouts for lse/D
            lse_t = lsei.transpose(0, 1, 3, 4, 2)  # [B,T,K,G,qb]
            D_t = Di.transpose(0, 1, 3, 4, 2)

            def kv_step(dq_acc, xs2):
                if seg:
                    ki, vi, kp, ksg = xs2
                else:
                    (ki, vi, kp), ksg = xs2, None
                logits = jnp.einsum(
                    "btqkgd,bskd->btkgqs", qi, ki,
                    preferred_element_type=jnp.float32) * scale
                maskb = _scan_block_mask(qp, kp, qsg, ksg, spec, pl4)
                p = jnp.where(maskb,
                              jnp.exp(logits - lse_t[..., None]), 0.0)
                dv_b = jnp.einsum("btkgqs,btqkgv->bskv", p,
                                  doi.astype(jnp.float32))
                dp = jnp.einsum("btqkgv,bskv->btkgqs",
                                doi.astype(jnp.float32),
                                vi.astype(jnp.float32))
                ds = p * (dp - D_t[..., None])
                dq_b = jnp.einsum("btkgqs,bskd->btqkgd", ds,
                                  ki.astype(jnp.float32)) * scale
                dk_b = jnp.einsum("btkgqs,btqkgd->bskd", ds,
                                  qi.astype(jnp.float32)) * scale
                return dq_acc + dq_b, (dk_b, dv_b)

            dq0 = jnp.zeros(qi.shape, jnp.float32)
            kv_xs = (ks, vs, kps, kss) if seg else (ks, vs, kps)
            dq_i, (dk_js, dv_js) = jax.lax.scan(kv_step, dq0, kv_xs)
            return (dk_acc + dk_js, dv_acc + dv_js), dq_i

        dk0 = jnp.zeros((nk, B, kb, K, dh), jnp.float32)
        dv0 = jnp.zeros((nk, B, kb, K, dvd), jnp.float32)
        q_xs = ((qs, dos, lses, Ds, qps, qss) if seg
                else (qs, dos, lses, Ds, qps))
        (dk_stk, dv_stk), dq_blocks = jax.lax.scan(
            q_step, (dk0, dv0), q_xs)
        dq = dq_blocks.transpose(1, 2, 0, 3, 4, 5, 6).reshape(
            B, T, Slp, K, G, dh)[:, :, :Sloc].reshape(B, Sq, K, G, dh)
        dk = dk_stk.transpose(1, 0, 2, 3, 4).reshape(
            B, nk * kb, K, dh)[:, :Skv]
        dvv = dv_stk.transpose(1, 0, 2, 3, 4).reshape(
            B, nk * kb, K, dvd)[:, :Skv]
        return (dq.astype(q.dtype), dk.astype(k.dtype),
                dvv.astype(v.dtype))

    fa.defvjp(fwd, bwd)
    return fa(q, k, v)


def _swa_gather_attention(q, k, v, q_pos, kv_pos, spec, scale, q_block: int):
    """Sliding-window path: each query block gathers only its KV window —
    O(S·(W+qb)) work instead of O(S²) (danube SWA prefill at 32k+)."""
    B, Sq, K, G, dh = q.shape
    W = spec.window
    qb = min(q_block, Sq)
    pq = (-Sq) % qb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pq), constant_values=-1)
    nq = q.shape[1] // qb
    span = W + qb  # static window slice length per query block
    # pad kv on the left by span so dynamic_slice never clamps awkwardly
    k_pad = jnp.pad(k, ((0, 0), (span, 0), (0, 0), (0, 0)))
    v_pad = jnp.pad(v, ((0, 0), (span, 0), (0, 0), (0, 0)))
    kvp_pad = jnp.pad(kv_pos, (span, 0), constant_values=-(2**30))

    qs = q.reshape(B, nq, qb, K, G, dh).transpose(1, 0, 2, 3, 4, 5)
    qps = q_pos.reshape(nq, qb)
    starts = jnp.arange(nq) * qb  # query block start index into kv

    def q_step(_, q_in):
        qi, qp, s = q_in
        # kv window covering original [s - W, s + qb): padded index p maps
        # to original p - span, so slice at p0 = s + qb, length span.
        p0 = s + qb
        ki = jax.lax.dynamic_slice_in_dim(k_pad, p0, span, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(v_pad, p0, span, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(kvp_pad, p0, span, axis=0)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki,
                            preferred_element_type=jnp.float32) * scale
        mask = _mask_block(qp, kp, spec, None)[None, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(vi.dtype), vi)
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qs, qps, starts))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qb, K, G, dh)
    return out[:, :Sq]


def attention(
    q: Array,              # [B, Sq, H, dh]
    k: Array,              # [B, Skv, K, dh]
    v: Array,              # [B, Skv, K, dh]
    *,
    spec: MaskSpec,
    q_pos: Array,          # (Sq,) int32 positions, or (B, Sq) when packed
    kv_pos: Array,         # (Skv,) int32, or (B, Skv)
    prefix_len: Optional[Array] = None,   # (B,) for prefix-LM
    q_seg: Optional[Array] = None,        # (B, Sq) segment ids (packed)
    kv_seg: Optional[Array] = None,       # (B, Skv)
    scale: Optional[float] = None,
    force_direct: bool = False,
    use_flash_vjp: bool = True,   # False inside lax.cond (jax lowering bug)
) -> Array:
    """GQA attention dispatcher. Returns [B, Sq, H, dv] (dv = v head dim)."""
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    assert H % K == 0, (H, K)
    assert k.shape[-1] == dh, (k.shape, dh)
    assert spec.segmented == (q_seg is not None), \
        "MaskSpec.segmented must match whether segment ids are passed"
    if q_seg is not None:
        assert not spec.has_prefix, \
            "packed-segment batches are incompatible with prefix-LM masks"
    dv = v.shape[-1]
    G = H // K
    qg = q.reshape(B, Sq, K, G, dh)
    scale = scale if scale is not None else dh ** -0.5
    Skv = k.shape[1]

    if force_direct or max(Sq, Skv) <= _DIRECT_ATTN_MAX_SEQ:
        mask = _mask_block(q_pos, kv_pos, spec, prefix_len,
                           q_seg=q_seg, kv_seg=kv_seg)
        mask = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
        out = _direct_attention(qg, k, v, mask, scale)
    elif (spec.window is not None and not spec.has_prefix and q_seg is None
          and Skv > spec.window + _Q_BLOCK):
        out = _swa_gather_attention(qg, k, v, q_pos, kv_pos, spec, scale,
                                    _Q_BLOCK)
    else:
        from repro.sharding.act import seq_tiles
        k = shard_act(k, "kv_full")
        v = shard_act(v, "kv_full")
        impl = _flash_attention if use_flash_vjp else _block_attention
        out = impl(qg, k, v, q_pos, kv_pos, spec, prefix_len,
                   scale, _Q_BLOCK, _KV_BLOCK, tiles=seq_tiles(Sq),
                   q_seg=q_seg, kv_seg=kv_seg)
    return out.reshape(B, Sq, H, dv)


def decode_attention(
    q: Array,              # [B, 1, H, dh]
    k_cache: Array,        # [B, W, K, dh]  (ring buffer or linear cache)
    v_cache: Array,
    *,
    kv_pos: Array,         # [B, W] int32 absolute positions, -1 = empty
    q_pos: Array,          # [B] int32
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> Array:
    """Single-token decode attention over a KV cache. O(W) per token."""
    B, _, H, dh = q.shape
    K = k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else dh ** -0.5
    qg = q.reshape(B, 1, K, G, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = (kv_pos >= 0) & (kv_pos[:, :] <= q_pos[:, None])
    if window is not None:
        valid = valid & (q_pos[:, None] - kv_pos < window)
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, dh)


# --------------------------------------------------------------------------
# Dense / linear helpers
# --------------------------------------------------------------------------

def dense(x: Array, w: Array, b: Optional[Array] = None) -> Array:
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def glu_mlp(params: dict, x: Array, act: str = "silu") -> Array:
    """SwiGLU/GeGLU: down( act(gate(x)) * up(x) )."""
    g = shard_act(dense(x, params["w_gate"]), "ffn")
    u = shard_act(dense(x, params["w_up"]), "ffn")
    return shard_act(dense(ACTS[act](g) * u, params["w_down"]), "hidden")


def mlp(params: dict, x: Array, act: str = "gelu") -> Array:
    """Plain 2-layer MLP (whisper)."""
    h = ACTS[act](shard_act(dense(x, params["w_up"], params.get("b_up")),
                            "ffn"))
    return shard_act(dense(h, params["w_down"], params.get("b_down")),
                     "hidden")


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, *, scale: float = 1.0,
                dtype=jnp.float32) -> Array:
    std = scale * (d_in ** -0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, *, dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)

"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), scan-friendly.

Implements the chunked SSD algorithm: within chunks of length Q the model
computes the quadratic 'attention-like' form; across chunks a linear
recurrence carries the SSM state.  This is the TPU-appropriate formulation
(big einsums for the MXU + a short lax.scan across chunks) rather than the
CUDA-style per-timestep selective scan.

Decode is the O(1) recurrent update on the state (B, H, dh, ds) plus a
rolling conv window — the reason the mamba2/zamba2 cells run long_500k.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.act import shard_act

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    chunk: int = 256
    norm: str = "rmsnorm"
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    def param_count(self) -> int:
        import math
        shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), self))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        return self.param_count()


def _block_init(key, cfg: Mamba2Config) -> dict:
    ks = jax.random.split(key, 4)
    d, di, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    dt = cfg.dtype
    d_in_proj = 2 * di + 2 * cfg.n_groups * cfg.d_state + H
    return {
        "ln": L.norm_init(d, cfg.norm),
        "in_proj": L.linear_init(ks[0], d, d_in_proj, dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_dim, cfg.d_conv),
                                     jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((cfg.conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": L.norm_init(di, "rmsnorm"),
        "out_proj": L.linear_init(ks[3], di, d,
                                  scale=(2 * cfg.n_layers) ** -0.5, dtype=dt),
    }


def init_params(key, cfg: Mamba2Config) -> dict:
    k_e, k_b, k_h = jax.random.split(key, 3)
    outer = {
        "tok_embed": L.embed_init(k_e, cfg.vocab, cfg.d_model,
                                  dtype=cfg.dtype),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        outer["head"] = L.linear_init(k_h, cfg.d_model, cfg.vocab,
                                      dtype=cfg.dtype)
    blocks = jax.vmap(lambda k: _block_init(k, cfg))(
        jax.random.split(k_b, cfg.n_layers))
    return {"outer": outer, "shared": {}, "stacks": {"blocks": blocks}}


# --------------------------------------------------------------------------
# Causal depthwise conv (kernel k, train form) and SSD chunked scan
# --------------------------------------------------------------------------

def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """x: [B,S,C]; w: [C,k] depthwise causal conv along S."""
    k = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # sum_j x[t-k+1+j] * w[:, j]
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j:j + x.shape[1], :] * w[None, None, :, j]
    return out + b[None, None, :]


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int,
                init_state: Optional[Array] = None,
                return_state: bool = False):
    """Chunked SSD. Shapes:
      x:  [B,S,H,P]  (P = headdim)     dt: [B,S,H]   A: [H] (negative)
      Bm: [B,S,G,N]  Cm: [B,S,G,N]     D: [H]
    Returns y [B,S,H,P] (and final state [B,H,P,N] if requested).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // Q
    rep = H // G  # heads per B/C group

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, G, N)
    Cc = Cm.reshape(Bsz, nc, Q, G, N)

    dA = dtc * A[None, None, None, :]                  # [B,nc,Q,H] (negative)
    cums = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum
    seg_end = cums[:, :, -1, :]                        # [B,nc,H]

    # intra-chunk (quadratic) term: attention-like with decay mask
    # L[b,c,h,i,j] = exp(cums_i - cums_j) for i >= j
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # [B,nc,Q,Q,H]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Ldec = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcqgn,bckgn->bcqkg", Cc, Bc)      # [B,nc,Q,Q,G]
    CB = jnp.repeat(CB, rep, axis=-1)                  # → H
    att = CB * Ldec * dtc[:, :, None, :, :]            # scale by dt_j
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", att, xc)

    # chunk-level states: S_c = sum_j exp(seg_end - cums_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(seg_end[:, :, None, :] - cums)   # [B,nc,Q,H]
    w = decay_to_end * dtc                                   # [B,nc,Q,H]
    Bh = jnp.repeat(Bc, rep, axis=-2)                        # [B,nc,Q,H,N]
    chunk_state = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w, Bh, xc)

    # inter-chunk recurrence over nc chunks
    seg_dec = jnp.exp(seg_end)                               # [B,nc,H]
    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_fn(s, inp):
        st_c, dec_c = inp          # [B,H,P,N], [B,H]
        s_out = s                  # state entering this chunk
        s_new = s * dec_c[:, :, None, None] + st_c
        return s_new, s_out

    st_sw = jnp.moveaxis(chunk_state, 1, 0).astype(jnp.float32)
    dec_sw = jnp.moveaxis(seg_dec, 1, 0)
    s_final, s_in = jax.lax.scan(scan_fn, s0, (st_sw, dec_sw))
    s_in = jnp.moveaxis(s_in, 0, 1)                          # [B,nc,H,P,N]

    # inter-chunk contribution: y_j += C_j^T exp(cums_j) S_in
    Ch = jnp.repeat(Cc, rep, axis=-2)                        # [B,nc,Q,H,N]
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch,
                         s_in.astype(Ch.dtype), jnp.exp(cums))
    y = (y_intra + y_inter).reshape(Bsz, nc * Q, H, P)[:, :S]
    y = y + x.reshape(Bsz, nc * Q, H, P)[:, :S] * D[None, None, :, None]
    if return_state:
        return y, s_final
    return y


def _split_proj(z: Array, cfg: Mamba2Config):
    di, G, N, H = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    zx, gate, dt = jnp.split(z, [di + 2 * G * N, 2 * di + 2 * G * N], -1)
    xBC = zx
    return xBC, gate, dt  # xBC: [.., di+2GN], gate: [.., di], dt: [.., H]


def mamba2_mix(p: dict, cfg: Mamba2Config, h: Array,
               conv_state: Optional[Array] = None,
               ssm_state: Optional[Array] = None,
               decode: bool = False):
    """The mamba2 mixer. Train/prefill: full-sequence chunked SSD.
    Decode (S==1): recurrent update; requires conv_state [B,k-1,C] and
    ssm_state [B,H,P,N]; returns (y, new_conv_state, new_ssm_state)."""
    B, S, _ = h.shape
    di, G, N, H, P = (cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads,
                      cfg.headdim)
    z = shard_act(L.dense(h, p["in_proj"]), "ffn")
    xBC, gate, dt = _split_proj(z, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])   # [B,S,H]
    A = -jnp.exp(p["A_log"])                              # [H] negative

    if not decode:
        xBC = L.ACTS["silu"](_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        x, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
        x = x.reshape(B, S, H, P)
        Bm = Bm.reshape(B, S, G, N)
        Cm = Cm.reshape(B, S, G, N)
        y = ssd_chunked(x.astype(jnp.float32), dt, A,
                        Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                        p["D"], cfg.chunk)
        y = y.reshape(B, S, di).astype(h.dtype) * L.ACTS["silu"](gate)
        y = L.rmsnorm(y, p["out_norm"]["scale"])
        return shard_act(L.dense(y, p["out_proj"]), "hidden")

    # ---- decode: one token ----
    k = cfg.d_conv
    xBC_new = xBC[:, 0]                                   # [B,C]
    window = jnp.concatenate([conv_state, xBC_new[:, None]], axis=1)  # [B,k,C]
    conv = jnp.sum(window * p["conv_w"].T[None], axis=1) + p["conv_b"]
    xBC_t = L.ACTS["silu"](conv)                          # [B,C]
    x, Bm, Cm = jnp.split(xBC_t, [di, di + G * N], axis=-1)
    x = x.reshape(B, H, P).astype(jnp.float32)
    Bm = Bm.reshape(B, G, N).astype(jnp.float32)
    Cm = Cm.reshape(B, G, N).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                      # [B,H,N]
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt0 = dt[:, 0]                                        # [B,H]
    dec = jnp.exp(dt0 * A[None])                          # [B,H]
    s_new = (ssm_state * dec[:, :, None, None]
             + jnp.einsum("bh,bhn,bhp->bhpn", dt0, Bh, x))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, s_new) + x * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(h.dtype) * L.ACTS["silu"](gate)
    y = L.rmsnorm(y.reshape(B, 1, di), p["out_norm"]["scale"])
    return (L.dense(y, p["out_proj"]), window[:, 1:], s_new)


# --------------------------------------------------------------------------
# Fused-engine spec + serve steps
# --------------------------------------------------------------------------

def make_block_body(cfg: Mamba2Config):
    def body(p, ctx, carry, aux_idx):
        del ctx, aux_idx
        x, aux = carry
        h = L.norm_apply(p["ln"], x, kind=cfg.norm)
        x = x + mamba2_mix(p, cfg, h)
        return (x, aux)

    return body


def make_fused_spec(cfg: Mamba2Config):
    from repro.core.fused import FusedSpec
    from repro.models.transformer import cross_entropy

    def prologue(outer, batch):
        return (outer["tok_embed"][batch["tokens"]],
                jnp.zeros((), jnp.float32))

    def epilogue(outer, carry, batch):
        x, aux = carry
        h = L.norm_apply(outer["final_norm"], x, kind=cfg.norm)
        w = (outer["tok_embed"].T if cfg.tie_embeddings else outer["head"])
        logits = jnp.einsum("...d,dv->...v", h, w,
                            preferred_element_type=jnp.float32)
        loss_sum, ntok, correct = cross_entropy(logits, batch["labels"])
        denom = jnp.maximum(ntok, 1).astype(jnp.float32)
        loss = loss_sum / denom + aux
        metrics = jax.lax.stop_gradient({
            "loss": loss, "ntokens": ntok.astype(jnp.float32),
            "accuracy": correct.astype(jnp.float32) / denom})
        return loss, metrics

    return FusedSpec(prologue=prologue,
                     bodies={"blocks": make_block_body(cfg)},
                     epilogue=epilogue)


def init_state_cache(cfg: Mamba2Config, batch: int) -> dict:
    """Decode cache: conv window + SSM state per layer. O(1) in seq len."""
    H, P, N = cfg.n_heads, cfg.headdim, cfg.d_state
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1,
                           cfg.conv_dim), cfg.dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        "cur": jnp.zeros((), jnp.int32),
    }


def make_decode_step(cfg: Mamba2Config):
    def decode_step(params, cache, batch):
        outer = params["outer"]
        x = outer["tok_embed"][batch["tokens"]]  # [B,1,d]

        def body(x, xs):
            p, conv_s, ssm_s = xs
            h = L.norm_apply(p["ln"], x, kind=cfg.norm)
            y, conv_s, ssm_s = mamba2_mix(p, cfg, h, conv_s, ssm_s,
                                          decode=True)
            return x + y, (conv_s, ssm_s)

        (x), (conv_stk, ssm_stk) = jax.lax.scan(
            body, x, (params["stacks"]["blocks"], cache["conv"],
                      cache["ssm"]))
        h = L.norm_apply(outer["final_norm"], x, kind=cfg.norm)
        w = (outer["tok_embed"].T if cfg.tie_embeddings else outer["head"])
        logits = jnp.einsum("...d,dv->...v", h, w,
                            preferred_element_type=jnp.float32)[:, 0]
        return logits, {"conv": conv_stk, "ssm": ssm_stk,
                        "cur": cache["cur"] + 1}

    return decode_step


def make_prefill_step(cfg: Mamba2Config):
    def prefill_step(params, batch):
        outer = params["outer"]
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = outer["tok_embed"][tokens]

        def body(x, p):
            h = L.norm_apply(p["ln"], x, kind=cfg.norm)
            # full mixer + extract final states for the cache
            z = L.dense(h, p["in_proj"])
            xBC, gate, dt = _split_proj(z, cfg)
            conv_tail = xBC[:, S - (cfg.d_conv - 1):]      # pre-activation
            xBC_c = L.ACTS["silu"](_causal_conv(xBC, p["conv_w"],
                                                p["conv_b"]))
            di, G, N, H, P = (cfg.d_inner, cfg.n_groups, cfg.d_state,
                              cfg.n_heads, cfg.headdim)
            xs_, Bm, Cm = jnp.split(xBC_c, [di, di + G * N], axis=-1)
            dtf = jax.nn.softplus(dt.astype(jnp.float32)
                                  + p["dt_bias"][None, None, :])
            A = -jnp.exp(p["A_log"])
            y, s_final = ssd_chunked(
                xs_.reshape(B, S, H, P).astype(jnp.float32), dtf, A,
                Bm.reshape(B, S, G, N).astype(jnp.float32),
                Cm.reshape(B, S, G, N).astype(jnp.float32),
                p["D"], cfg.chunk, return_state=True)
            y = y.reshape(B, S, di).astype(h.dtype) * L.ACTS["silu"](gate)
            y = L.rmsnorm(y, p["out_norm"]["scale"])
            x = x + L.dense(y, p["out_proj"])
            return x, (conv_tail, s_final)

        x, (conv_stk, ssm_stk) = jax.lax.scan(
            body, x, params["stacks"]["blocks"])
        h = L.norm_apply(outer["final_norm"], x[:, -1:], kind=cfg.norm)
        w = (outer["tok_embed"].T if cfg.tie_embeddings else outer["head"])
        logits = jnp.einsum("...d,dv->...v", h, w,
                            preferred_element_type=jnp.float32)[:, 0]
        cache = {"conv": conv_stk, "ssm": ssm_stk,
                 "cur": jnp.asarray(S, jnp.int32)}
        return logits, cache

    return prefill_step

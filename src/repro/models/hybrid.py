"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block.

The shared transformer block (attention + MLP, one set of weights) is
applied every ``attn_every`` layers on ``concat(x, x0)`` (x0 = the embedding
output), with a per-application LoRA delta on the qkv projections — the
Zamba2 parameter-sharing trick (arXiv:2411.15242).

Exercises two distinctive paths of the fused engine:
  * shared weights — gradients accumulate across applications in the
    backward-scan carry and are updated once per step;
  * x0 rides in the scan carry so its gradient flows back to the embedding.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M2

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    chunk: int = 128
    attn_every: int = 6          # shared block applied at layers 0, 6, 12, …
    lora_rank: int = 128
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def mamba_cfg(self) -> M2.Mamba2Config:
        return M2.Mamba2Config(
            name=self.name + "-mamba", n_layers=self.n_layers,
            d_model=self.d_model, vocab=self.vocab, d_state=self.d_state,
            d_conv=self.d_conv, expand=self.expand, headdim=self.headdim,
            n_groups=self.n_groups, chunk=self.chunk, norm=self.norm,
            dtype=self.dtype)

    def n_attn_applications(self) -> int:
        return len(range(0, self.n_layers, self.attn_every))

    def param_count(self) -> int:
        import math
        shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), self))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        return self.param_count()


def init_params(key, cfg: HybridConfig) -> dict:
    k_e, k_b, k_s, k_l = jax.random.split(key, 4)
    mc = cfg.mamba_cfg()
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype

    outer = {
        "tok_embed": L.embed_init(k_e, cfg.vocab, d, dtype=dt),
        "final_norm": L.norm_init(d, cfg.norm),
    }
    if not cfg.tie_embeddings:
        outer["head"] = L.linear_init(k_e, d, cfg.vocab, dtype=dt)

    # shared attention block: consumes concat(x, x0) -> d
    ks = jax.random.split(k_s, 8)
    shared = {
        "in_ln": L.norm_init(2 * d, cfg.norm),
        "wq": L.linear_init(ks[0], 2 * d, H * dh, dtype=dt),
        "wk": L.linear_init(ks[1], 2 * d, K * dh, dtype=dt),
        "wv": L.linear_init(ks[2], 2 * d, K * dh, dtype=dt),
        "wo": L.linear_init(ks[3], H * dh, d, dtype=dt),
        "mlp_ln": L.norm_init(d, cfg.norm),
        "w_gate": L.linear_init(ks[4], d, cfg.d_ff, dtype=dt),
        "w_up": L.linear_init(ks[5], d, cfg.d_ff, dtype=dt),
        "w_down": L.linear_init(ks[6], cfg.d_ff, d, dtype=dt),
    }

    def block_init(k):
        km, kl = jax.random.split(k)
        r = cfg.lora_rank
        return {
            "mamba": M2._block_init(km, mc),
            # LoRA deltas for the shared qkv (zero-init B side)
            "lora_qA": L.linear_init(kl, 2 * d, r, dtype=dt),
            "lora_qB": jnp.zeros((r, H * dh), dt),
            "lora_kA": L.linear_init(jax.random.fold_in(kl, 1), 2 * d, r,
                                     dtype=dt),
            "lora_kB": jnp.zeros((r, K * dh), dt),
            "lora_vA": L.linear_init(jax.random.fold_in(kl, 2), 2 * d, r,
                                     dtype=dt),
            "lora_vB": jnp.zeros((r, K * dh), dt),
        }

    blocks = jax.vmap(block_init)(jax.random.split(k_b, cfg.n_layers))
    return {"outer": outer, "shared": shared, "stacks": {"blocks": blocks}}


def _shared_attn(shared: dict, p: dict, cfg: HybridConfig, x: Array,
                 x0: Array, pos: Array,
                 cache=None, cur=None):
    """Shared attention block on concat(x, x0) with per-layer LoRA.
    Train path when cache is None; else single-token decode."""
    B = x.shape[0]
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cat = jnp.concatenate([x, x0], axis=-1)
    hN = L.norm_apply(shared["in_ln"], cat, kind=cfg.norm)
    q = (L.dense(hN, shared["wq"])
         + L.dense(L.dense(hN, p["lora_qA"]), p["lora_qB"]))
    k = (L.dense(hN, shared["wk"])
         + L.dense(L.dense(hN, p["lora_kA"]), p["lora_kB"]))
    v = (L.dense(hN, shared["wv"])
         + L.dense(L.dense(hN, p["lora_vA"]), p["lora_vB"]))
    S = x.shape[1]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, K, dh)
    v = v.reshape(B, S, K, dh)
    sin, cos = L.rope_sincos(pos, dh, cfg.rope_theta)
    q = L.apply_rope(q, sin, cos)
    k = L.apply_rope(k, sin, cos)
    if cache is None:
        # use_flash_vjp=False: this call sits inside the lax.cond of the
        # hybrid block body; custom_vjp-in-cond trips a jax lowering-cache
        # bug ("no constant handler for DynamicJaxprTracer").
        o = L.attention(q, k, v, spec=L.MaskSpec(causal=True),
                        q_pos=pos.astype(jnp.int32),
                        kv_pos=pos.astype(jnp.int32), use_flash_vjp=False)
        new_cache = None
    else:
        kc, vc, pos_tab = cache
        W = kc.shape[1]
        slot = jnp.mod(cur, W)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        o = L.decode_attention(q, kc, vc,
                               kv_pos=jnp.broadcast_to(pos_tab[None], (B, W)),
                               q_pos=jnp.full((B,), cur, jnp.int32))
        new_cache = (kc, vc)
    a = L.dense(o.reshape(B, S, H * dh), shared["wo"])
    x = x + a
    hM = L.norm_apply(shared["mlp_ln"], x, kind=cfg.norm)
    x = x + L.glu_mlp({"w_gate": shared["w_gate"], "w_up": shared["w_up"],
                       "w_down": shared["w_down"]}, hM)
    return x, new_cache


def make_block_body(cfg: HybridConfig):
    mc = cfg.mamba_cfg()

    def body(p, ctx, carry, idx):
        shared, ctx_act = ctx
        x, x0, aux = carry
        pos = jax.lax.stop_gradient(ctx_act["pos"])
        h = L.norm_apply(p["mamba"]["ln"], x, kind=cfg.norm)
        x = x + M2.mamba2_mix(p["mamba"], mc, h)

        def with_attn(operand):
            x, x0 = operand
            y, _ = _shared_attn(shared, p, cfg, x, x0, pos)
            return y

        x = jax.lax.cond(jnp.mod(idx, cfg.attn_every) == 0,
                         with_attn, lambda o: o[0], (x, x0))
        return (x, x0, aux)

    return body


def make_fused_spec(cfg: HybridConfig):
    from repro.core.fused import FusedSpec
    from repro.models.transformer import cross_entropy

    def prologue(outer, batch):
        x = outer["tok_embed"][batch["tokens"]]
        return (x, x, jnp.zeros((), jnp.float32))

    def pro_ctx(outer, batch):
        S = batch["tokens"].shape[1]
        return {"pos": jnp.arange(S, dtype=jnp.float32)}

    def epilogue(outer, carry, batch):
        x, _, aux = carry
        h = L.norm_apply(outer["final_norm"], x, kind=cfg.norm)
        w = (outer["tok_embed"].T if cfg.tie_embeddings else outer["head"])
        logits = jnp.einsum("...d,dv->...v", h, w,
                            preferred_element_type=jnp.float32)
        loss_sum, ntok, correct = cross_entropy(logits, batch["labels"])
        denom = jnp.maximum(ntok, 1).astype(jnp.float32)
        loss = loss_sum / denom + aux
        metrics = jax.lax.stop_gradient({
            "loss": loss, "ntokens": ntok.astype(jnp.float32),
            "accuracy": correct.astype(jnp.float32) / denom})
        return loss, metrics

    return FusedSpec(prologue=prologue,
                     bodies={"blocks": make_block_body(cfg)},
                     epilogue=epilogue, pro_ctx=pro_ctx)


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------

def init_cache(cfg: HybridConfig, batch: int, max_len: int) -> dict:
    """Mamba states are O(1); attention caches exist only for the layers
    where the shared block applies (the hybrid's long-context advantage)."""
    mc = cfg.mamba_cfg()
    n_app = cfg.n_attn_applications()
    K, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1,
                           mc.conv_dim), cfg.dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, mc.n_heads, mc.headdim,
                          mc.d_state), jnp.float32),
        "attn_k": jnp.zeros((n_app, batch, max_len, K, dh), cfg.dtype),
        "attn_v": jnp.zeros((n_app, batch, max_len, K, dh), cfg.dtype),
        "pos": jnp.full((max_len,), -1, jnp.int32),
        "cur": jnp.zeros((), jnp.int32),
    }


def make_prefill_step(cfg: HybridConfig, max_len: Optional[int] = None):
    """Full-sequence forward; extracts mamba final states + attn KV caches."""
    mc = cfg.mamba_cfg()

    def prefill_step(params, batch):
        outer = params["outer"]
        tokens = batch["tokens"]
        B, S = tokens.shape
        W = max_len or S
        x0 = outer["tok_embed"][tokens]
        x = x0
        pos = jnp.arange(S, dtype=jnp.float32)
        shared = params["shared"]
        blocks = params["stacks"]["blocks"]
        conv_list, ssm_list, k_list, v_list = [], [], [], []
        H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        for lo in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[lo], blocks)
            h = L.norm_apply(p["mamba"]["ln"], x, kind=cfg.norm)
            # mamba mixer with state extraction
            z = L.dense(h, p["mamba"]["in_proj"])
            xBC, gate, dt = M2._split_proj(z, mc)
            conv_list.append(xBC[:, S - (mc.d_conv - 1):])
            xBC_c = L.ACTS["silu"](M2._causal_conv(
                xBC, p["mamba"]["conv_w"], p["mamba"]["conv_b"]))
            di, G, N = mc.d_inner, mc.n_groups, mc.d_state
            xs_, Bm, Cm = jnp.split(xBC_c, [di, di + G * N], axis=-1)
            dtf = jax.nn.softplus(dt.astype(jnp.float32)
                                  + p["mamba"]["dt_bias"][None, None, :])
            A = -jnp.exp(p["mamba"]["A_log"])
            y, s_fin = M2.ssd_chunked(
                xs_.reshape(B, S, mc.n_heads, mc.headdim).astype(jnp.float32),
                dtf, A, Bm.reshape(B, S, G, N).astype(jnp.float32),
                Cm.reshape(B, S, G, N).astype(jnp.float32),
                p["mamba"]["D"], mc.chunk, return_state=True)
            ssm_list.append(s_fin)
            y = (y.reshape(B, S, di).astype(h.dtype)
                 * L.ACTS["silu"](gate))
            y = L.rmsnorm(y, p["mamba"]["out_norm"]["scale"])
            x = x + L.dense(y, p["mamba"]["out_proj"])
            if lo % cfg.attn_every == 0:
                # shared attention + record its KV (padded to W)
                cat = jnp.concatenate([x, x0], axis=-1)
                hN = L.norm_apply(shared["in_ln"], cat, kind=cfg.norm)
                kk = (L.dense(hN, shared["wk"])
                      + L.dense(L.dense(hN, p["lora_kA"]), p["lora_kB"])
                      ).reshape(B, S, K, dh)
                vv = (L.dense(hN, shared["wv"])
                      + L.dense(L.dense(hN, p["lora_vA"]), p["lora_vB"])
                      ).reshape(B, S, K, dh)
                sin, cos = L.rope_sincos(pos, dh, cfg.rope_theta)
                kk = L.apply_rope(kk, sin, cos)
                pad = ((0, 0), (0, W - S), (0, 0), (0, 0))
                k_list.append(jnp.pad(kk, pad))
                v_list.append(jnp.pad(vv, pad))
                x, _ = _shared_attn(shared, p, cfg, x, x0, pos)
        h = L.norm_apply(outer["final_norm"], x[:, -1:], kind=cfg.norm)
        w = (outer["tok_embed"].T if cfg.tie_embeddings else outer["head"])
        logits = jnp.einsum("...d,dv->...v", h, w,
                            preferred_element_type=jnp.float32)[:, 0]
        pos_tab = jnp.pad(jnp.arange(S, dtype=jnp.int32), (0, W - S),
                          constant_values=-1)
        cache = {"conv": jnp.stack(conv_list), "ssm": jnp.stack(ssm_list),
                 "attn_k": jnp.stack(k_list), "attn_v": jnp.stack(v_list),
                 "pos": pos_tab, "cur": jnp.asarray(S, jnp.int32)}
        return logits, cache

    return prefill_step


def make_decode_step(cfg: HybridConfig):
    mc = cfg.mamba_cfg()

    def decode_step(params, cache, batch):
        outer = params["outer"]
        x0 = outer["tok_embed"][batch["tokens"]]  # [B,1,d]
        cur = cache["cur"]
        shared = params["shared"]
        W0 = cache["pos"].shape[0]
        # mark the current slot before attention so the token sees itself
        pos_tab = cache["pos"].at[jnp.mod(cur, W0)].set(cur)
        n_layers = cfg.n_layers

        # python loop over attn applications, scan over mamba spans between
        x = x0
        blocks = params["stacks"]["blocks"]
        attn_i = 0
        new_conv, new_ssm = [], []
        new_k, new_v = [], []
        for lo in range(0, n_layers, cfg.attn_every):
            hi = min(lo + cfg.attn_every, n_layers)
            span = jax.tree.map(lambda a: a[lo:hi], blocks)
            conv_span = cache["conv"][lo:hi]
            ssm_span = cache["ssm"][lo:hi]

            def mbody(x, xs):
                p, conv_s, ssm_s = xs
                h = L.norm_apply(p["mamba"]["ln"], x, kind=cfg.norm)
                y, conv_s, ssm_s = M2.mamba2_mix(p["mamba"], mc, h, conv_s,
                                                 ssm_s, decode=True)
                return x + y, (conv_s, ssm_s)

            # shared attention first (applies at layer lo), then mamba span.
            # order within the block body is mamba-then-attn; replicate:
            # apply mamba for layer lo..hi with attn after layer lo's mamba.
            p_lo = jax.tree.map(lambda a: a[lo], blocks)
            h = L.norm_apply(p_lo["mamba"]["ln"], x, kind=cfg.norm)
            y, conv_lo, ssm_lo = M2.mamba2_mix(
                p_lo["mamba"], mc, h, cache["conv"][lo], cache["ssm"][lo],
                decode=True)
            x = x + y
            x, (kc, vc) = _shared_attn(
                shared, p_lo, cfg, x, x0,
                cur[None].astype(jnp.float32),
                cache=(cache["attn_k"][attn_i], cache["attn_v"][attn_i],
                       pos_tab), cur=cur)
            new_k.append(kc)
            new_v.append(vc)
            attn_i += 1
            if hi > lo + 1:
                rest = jax.tree.map(lambda a: a[lo + 1:hi], blocks)
                x, (conv_r, ssm_r) = jax.lax.scan(
                    mbody, x, (rest, cache["conv"][lo + 1:hi],
                               cache["ssm"][lo + 1:hi]))
                new_conv.append(jnp.concatenate([conv_lo[None], conv_r]))
                new_ssm.append(jnp.concatenate([ssm_lo[None], ssm_r]))
            else:
                new_conv.append(conv_lo[None])
                new_ssm.append(ssm_lo[None])

        h = L.norm_apply(outer["final_norm"], x, kind=cfg.norm)
        w = (outer["tok_embed"].T if cfg.tie_embeddings else outer["head"])
        logits = jnp.einsum("...d,dv->...v", h, w,
                            preferred_element_type=jnp.float32)[:, 0]
        new_cache = {
            "conv": jnp.concatenate(new_conv), "ssm": jnp.concatenate(new_ssm),
            "attn_k": jnp.stack(new_k), "attn_v": jnp.stack(new_v),
            "pos": pos_tab, "cur": cur + 1,
        }
        return logits, new_cache

    return decode_step

"""Fine-grained Mixture-of-Experts FFN (DeepSeek-MoE / DeepSeek-V3 style).

TPU-native dispatch: tokens are scattered into a per-expert capacity buffer
``[B, E, C, d]`` (scatter-add over token rows — O(tokens·d), never a
``[T, E, C]`` one-hot), experts run as one batched einsum, and results
gather back.  Expert parallelism comes from sharding the E axis of both the
buffer and the expert weights over the 'model'/'expert' mesh axis — XLA
inserts the token→expert all-to-all at the sharding boundary.

Capacity-based token dropping (GShard-style) keeps shapes static; dropped
tokens fall through on the residual path.  The switch-style load-balance
auxiliary loss is returned per call and accumulated through the scan carry
(see models/transformer.py), which keeps it differentiable under the fused
backward engine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.act import shard_act

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int                 # routed experts (E)
    top_k: int
    d_ff_expert: int              # fine-grained expert width
    n_shared: int = 0             # always-on shared experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # deepseek-v3 uses sigmoid routing with normalized top-k weights
    router_score: str = "softmax"  # or "sigmoid"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_init(key, d_model: int, cfg: MoEConfig, *, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    E, f = cfg.n_routed, cfg.d_ff_expert
    p = {
        "router": L.linear_init(ks[0], d_model, E, dtype=jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d_model, f), jnp.float32)
                   * d_model ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, f), jnp.float32)
                 * d_model ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d_model), jnp.float32)
                   * f ** -0.5).astype(dtype),
    }
    if cfg.n_shared:
        fs = cfg.n_shared * f
        p["shared_mlp"] = {
            "w_gate": L.linear_init(ks[4], d_model, fs, dtype=dtype),
            "w_up": L.linear_init(ks[5], d_model, fs, dtype=dtype),
            "w_down": L.linear_init(ks[4], fs, d_model, dtype=dtype),
        }
    return p


def capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = int(cfg.top_k * tokens_per_group * cfg.capacity_factor
            / cfg.n_routed) + 1
    return _round_up(max(c, 4), 4)


def moe_ffn(params: dict, x: Array, cfg: MoEConfig
            ) -> tuple[Array, Array]:
    """MoE FFN dispatcher: explicit shard_map expert parallelism when a
    mesh policy is installed (XLA SPMD cannot partition the batched
    scatter/gather dispatch — it replicates the global batch, §Perf H6);
    plain single-device path otherwise."""
    from repro.sharding.act import current_policy
    pol = current_policy()
    if (pol is not None and pol.tp is not None
            and cfg.n_routed % pol.tp_size == 0):
        return _moe_ffn_shardmap(params, x, cfg, pol)
    return _moe_ffn_local(params, x, cfg)


def _moe_ffn_local(params: dict, x: Array, cfg: MoEConfig
                   ) -> tuple[Array, Array]:
    """x: [B, S, d] (B = token groups, sharded over data axis).

    Returns (y, aux_loss).  Routing/dispatch per group of S tokens.
    """
    B, S, d = x.shape
    E, K = cfg.n_routed, cfg.top_k
    C = capacity(S, cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])  # fp32 routing
    if cfg.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(scores, K)           # [B,S,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)      # renormalize

    # Load-balance aux loss (Switch/GShard): E * sum_e f_e * p_e
    probs_mean = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=(0, 1))  # [E]
    top1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
    frac_tokens = jnp.mean(top1, axis=(0, 1))
    aux = cfg.router_aux_weight * E * jnp.sum(frac_tokens * probs_mean)

    # Position of each (token, slot) within its expert's capacity buffer.
    # Flatten slots in (s, k) order; cumulative count per expert via cumsum
    # over a [S*K, E] one-hot — O(S·K·E) int work, no [T,E,C] tensor.
    flat_idx = expert_idx.reshape(B, S * K)
    oh = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)          # [B,SK,E]
    pos_in_e = jnp.cumsum(oh, axis=1) - 1                      # [B,SK,E]
    pos = jnp.take_along_axis(
        pos_in_e, flat_idx[..., None], axis=-1)[..., 0]        # [B,SK]
    keep = pos < C
    slot = jnp.where(keep, pos, C).reshape(B, S, K)  # C = waste slot
    idx_sk = expert_idx  # [B,S,K]

    # Scatter tokens into [B, E, C+1, d]; one scatter per top-k slot so the
    # token tensor is never repeated K times in HBM.
    buf = jnp.zeros((B, E, C + 1, d), x.dtype)
    b_ix = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
    for k in range(K):
        buf = buf.at[b_ix, idx_sk[:, :, k], slot[:, :, k]].add(
            x, unique_indices=False)
    # expert-parallel resharding boundary: token-sharded → expert-sharded
    # (XLA inserts the all-to-all here)
    buf = shard_act(buf[:, :, :C], "experts")  # [B,E,C,d]

    # Expert computation — batched over E (shard E over the expert axis).
    h = (L.ACTS["silu"](jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
         * jnp.einsum("becd,edf->becf", buf, params["w_up"]))
    h = shard_act(h, "experts")
    y_buf = shard_act(jnp.einsum("becf,efd->becd", h, params["w_down"]),
                      "experts")
    y_buf = jnp.pad(y_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))  # waste slot = 0

    # Gather back per slot and combine with gate weights.
    keep_sk = keep.reshape(B, S, K)
    y = jnp.zeros_like(x)
    for k in range(K):
        yk = y_buf[b_ix, idx_sk[:, :, k], slot[:, :, k]]       # [B,S,d]
        w = (gate_vals[:, :, k] * keep_sk[:, :, k]).astype(yk.dtype)
        y = y + yk * w[..., None]

    if cfg.n_shared:
        y = y + L.glu_mlp(params["shared_mlp"], x)
    return y, aux


# --------------------------------------------------------------------------
# Explicit expert parallelism (shard_map): each model-axis rank owns
# E/tp experts; tokens are all-gathered over the model axis (they arrive
# sequence-sharded from the SP residual stream), each rank scatters only
# the tokens routed to *its* experts, computes them, and the partial
# outputs reduce-scatter straight back to the sequence-sharded layout.
# All collectives are explicit, bf16, and O(B·S·d) per layer.
# --------------------------------------------------------------------------

def _moe_ffn_shardmap(params: dict, x: Array, cfg: MoEConfig, pol
                      ) -> tuple[Array, Array]:
    from jax.sharding import PartitionSpec as P

    E, K = cfg.n_routed, cfg.top_k
    tp_axis = pol.tp
    tp = pol.tp_size
    dp_spec = pol.dp
    all_axes = tuple(pol.axes.batch) + (tp_axis,)
    B, S, d = x.shape
    seq_sharded = S % tp == 0

    def local_moe(x_loc, router_w, w_gate, w_up, w_down):
        # x_loc: [B_loc, S_loc, d]; expert weights: local shard [E_loc,...]
        if seq_sharded:
            x_full = jax.lax.all_gather(x_loc, tp_axis, axis=1, tiled=True)
        else:
            x_full = x_loc
        Bl, Sf, _ = x_full.shape
        logits = jnp.einsum("bsd,de->bse", x_full.astype(jnp.float32),
                            router_w)
        if cfg.router_score == "sigmoid":
            scores = jax.nn.sigmoid(logits)
        else:
            scores = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(scores, K)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        probs_mean = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=(0, 1))
        top1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
        frac = jnp.mean(top1, axis=(0, 1))
        # exact global load-balance loss: average the E-vectors first
        probs_mean = jax.lax.pmean(probs_mean, all_axes)
        frac = jax.lax.pmean(frac, all_axes)
        aux = cfg.router_aux_weight * E * jnp.sum(frac * probs_mean)

        # slot assignment across ALL experts (identical on every rank)
        C = capacity(Sf, cfg)
        flat_idx = expert_idx.reshape(Bl, Sf * K)
        oh = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(oh, axis=1) - 1,
                                  flat_idx[..., None], axis=-1)[..., 0]
        keep = (pos < C).reshape(Bl, Sf, K)
        slot = jnp.where(pos < C, pos, C).reshape(Bl, Sf, K)

        # my expert range
        r = jax.lax.axis_index(tp_axis)
        E_loc = E // tp
        idx_sk = expert_idx - r * E_loc     # local expert id, may be OOB
        mine = (idx_sk >= 0) & (idx_sk < E_loc)
        idx_cl = jnp.clip(idx_sk, 0, E_loc - 1)
        slot_m = jnp.where(mine, slot, C)   # waste slot if not mine
        buf = jnp.zeros((Bl, E_loc, C + 1, d), x_loc.dtype)
        b_ix = jnp.broadcast_to(jnp.arange(Bl)[:, None], (Bl, Sf))
        for k in range(K):
            buf = buf.at[b_ix, idx_cl[:, :, k], slot_m[:, :, k]].add(x_full)
        buf = buf[:, :, :C]

        h = (L.ACTS["silu"](jnp.einsum("becd,edf->becf", buf, w_gate))
             * jnp.einsum("becd,edf->becf", buf, w_up))
        y_buf = jnp.einsum("becf,efd->becd", h, w_down)
        y_buf = jnp.pad(y_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))

        y = jnp.zeros_like(x_full)
        for k in range(K):
            yk = y_buf[b_ix, idx_cl[:, :, k], slot_m[:, :, k]]
            w = (gate_vals[:, :, k] * keep[:, :, k]
                 * mine[:, :, k]).astype(yk.dtype)
            y = y + yk * w[..., None]
        # sum expert contributions across ranks; land sequence-sharded
        if seq_sharded:
            y = jax.lax.psum_scatter(y, tp_axis, scatter_dimension=1,
                                     tiled=True)
        else:
            y = jax.lax.psum(y, tp_axis)
        return y, aux

    seq = tp_axis if seq_sharded else None
    y, aux = jax.shard_map(
        local_moe,
        mesh=pol.mesh,
        in_specs=(P(dp_spec, seq, None), P(None, None),
                  P(tp_axis, None, None), P(tp_axis, None, None),
                  P(tp_axis, None, None)),
        out_specs=(P(dp_spec, seq, None), P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])

    if cfg.n_shared:
        y = y + L.glu_mlp(params["shared_mlp"], x)
    return y, aux

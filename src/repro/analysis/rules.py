"""repro-lint rule set R1..R7.

Each rule is a stateless object with ``id``, ``title``, ``invariant``
(what guarantee it protects — surfaced by ``--list-rules`` and the DESIGN
table) and ``check(model) -> [Finding]``.  Rules reason over the shared
:class:`~repro.analysis.core.ModuleModel`: canonical import resolution,
traced-context inference and taint come from there, so every rule handles
aliased imports (``from jax import numpy as jnp``), decorated and nested
jitted functions identically.

The rule IDs are stable API — suppression comments and baseline entries
reference them — so new checks extend a rule's scope or claim a new ID,
never repurpose an old one.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.core import (Finding, Func, ModuleModel, Taint, dotted,
                                 stmt_exprs as _stmt_exprs)

_COMPARE_IDENTITY = (ast.Is, ast.IsNot, ast.In, ast.NotIn)


# --------------------------------------------------------------------------
# R1 — recompile hazards inside traced code
# --------------------------------------------------------------------------


class RecompileHazard:
    """Python control flow / concretization on traced values, and
    non-hashable static args: each forces a retrace (or a
    ConcretizationTypeError), breaking the zero-steady-state-recompile
    contract the serving engine and hook pipeline assert at runtime."""

    id = "R1"
    title = "recompile-hazard"
    invariant = ("zero steady-state recompiles: no Python branching/"
                 "formatting on traced values, no unhashable static args")

    def check(self, model: ModuleModel) -> list:
        out = []
        for func in model.funcs:
            if not func.traced:
                continue
            out.extend(self._check_traced(model, func))
        out.extend(self._check_static_args(model))
        return out

    # -------------------------------------------------- traced-body checks
    def _check_traced(self, model: ModuleModel, func: Func) -> Iterator:
        taint = Taint(model, func)
        for stmt in func.own_statements():
            if isinstance(stmt, (ast.If, ast.While)):
                if self._value_branch(taint, stmt.test):
                    yield model.finding(
                        self.id, stmt.test,
                        "Python branch on a traced value inside traced "
                        "code — concretizes at trace time (retrace per "
                        "value or ConcretizationTypeError); use "
                        "jnp.where/lax.cond/lax.select")
            for node in _stmt_exprs(stmt):
                if isinstance(node, ast.IfExp) and \
                        self._value_branch(taint, node.test):
                    yield model.finding(
                        self.id, node,
                        "conditional expression on a traced value inside "
                        "traced code — use jnp.where/lax.select")
                elif isinstance(node, ast.JoinedStr):
                    for part in node.values:
                        if isinstance(part, ast.FormattedValue) and \
                                taint.tainted(part.value):
                            yield model.finding(
                                self.id, node,
                                "f-string formats a traced value inside "
                                "traced code — forces host concretization "
                                "at trace time")
                            break
                elif isinstance(node, ast.Call):
                    target = model.resolve(node.func)
                    if target in ("int", "bool") and node.args and \
                            taint.tainted(node.args[0]):
                        yield model.finding(
                            self.id, node,
                            f"{target}() on a traced value inside traced "
                            "code — shape/value must be static here, or "
                            "stay on device (jnp cast)")
                    elif (isinstance(node.func, ast.Attribute)
                          and node.func.attr == "format"
                          and any(taint.tainted(a) for a in node.args)):
                        yield model.finding(
                            self.id, node,
                            "str.format() of a traced value inside traced "
                            "code — forces host concretization")
            taint.advance(stmt)

    @staticmethod
    def _value_branch(taint: Taint, test: ast.AST) -> bool:
        """Tainted test that is a *value* branch (identity/membership
        tests like ``x is None`` stay legal trace-time Python)."""
        if not taint.tainted(test):
            return False
        if isinstance(test, ast.Compare) and \
                all(isinstance(op, _COMPARE_IDENTITY) for op in test.ops):
            return False
        return True

    # ---------------------------------------------- static-argument checks
    def _check_static_args(self, model: ModuleModel) -> Iterator:
        """``f = jax.jit(g, static_argnums=(2,))`` then ``f(a, b, [..])``:
        an unhashable literal at a static position raises (or, for
        drifting values, retraces) on every call."""
        static_pos: dict[str, set] = {}
        static_names: dict[str, set] = {}
        for node in ast.walk(model.tree):
            if not isinstance(node, ast.Assign):
                continue
            call = node.value
            if not (isinstance(call, ast.Call)
                    and model.resolve(call.func) == "jax.jit"):
                continue
            pos, names = _jit_static_spec(call)
            if not pos and not names:
                continue
            for t in node.targets:
                d = dotted(t)
                if d:
                    static_pos[d] = pos
                    static_names[d] = names
        if not static_pos:
            return
        for node in ast.walk(model.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d not in static_pos:
                continue
            for i, arg in enumerate(node.args):
                if i in static_pos[d] and _unhashable_literal(arg):
                    yield model.finding(
                        self.id, arg,
                        f"unhashable literal passed at static position "
                        f"{i} of jitted `{d}` — static args must be "
                        "hashable and stable, or every call retraces")
            for kw in node.keywords:
                if kw.arg in static_names[d] and \
                        _unhashable_literal(kw.value):
                    yield model.finding(
                        self.id, kw.value,
                        f"unhashable literal passed as static arg "
                        f"`{kw.arg}` of jitted `{d}`")


def _jit_static_spec(call: ast.Call) -> tuple:
    pos: set = set()
    names: set = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    pos.add(n.value)
        elif kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
    return pos, names


def _unhashable_literal(node: ast.AST) -> bool:
    return isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp))


# --------------------------------------------------------------------------
# R2 — host syncs in hot paths
# --------------------------------------------------------------------------

# the per-step hot path of the serving engines (decode loop)
_ENGINE_HOT = {"step", "run", "_run_chunk", "_collect"}
_SYNC_CALLS = {"jax.device_get", "jax.block_until_ready"}


class HostSyncInHotPath:
    """Blocking device→host transfers in per-step/per-token paths: the
    serve decode loop, ``on_step_end`` hooks, and traced step programs.
    One stray ``.item()`` / ``float(tracer)`` serializes the device
    pipeline every step.  StepEvent fields are host scalars by contract
    (the runner does ONE bundled transfer per step), so coercions of
    ``ev.*`` in hooks are either a sync (bug) or redundant."""

    id = "R2"
    title = "host-sync-in-hot-path"
    invariant = ("hot paths make at most one deliberate (suppressed) "
                 "host sync per step/chunk boundary")

    def check(self, model: ModuleModel) -> list:
        out = []
        for func in model.funcs:
            if func.traced:
                out.extend(self._check_traced(model, func))
            elif func.name == "on_step_end":
                out.extend(self._check_hook(model, func))
            elif (func.cls and "Engine" in func.cls
                  and func.name in _ENGINE_HOT):
                out.extend(self._check_engine(model, func))
        return out

    def _check_traced(self, model: ModuleModel, func: Func) -> Iterator:
        taint = Taint(model, func)
        for stmt in func.own_statements():
            for node in _stmt_exprs(stmt):
                if not isinstance(node, ast.Call):
                    continue
                target = model.resolve(node.func)
                if target in _SYNC_CALLS:
                    yield model.finding(
                        self.id, node,
                        f"{target.split('.')[-1]}() inside traced code — "
                        "host transfer during trace/execution of the step "
                        "program")
                elif target in ("numpy.asarray", "numpy.array") and \
                        node.args and taint.tainted(node.args[0]):
                    yield model.finding(
                        self.id, node,
                        "np.asarray/np.array on a traced value — implicit "
                        "device→host transfer inside the step program")
                elif target == "float" and node.args and \
                        taint.tainted(node.args[0]):
                    yield model.finding(
                        self.id, node,
                        "float() on a traced value inside traced code — "
                        "blocking device sync / concretization")
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args and \
                        taint.tainted(node.func.value):
                    yield model.finding(
                        self.id, node,
                        ".item() on a traced value inside traced code — "
                        "blocking device sync")
            taint.advance(stmt)

    def _check_hook(self, model: ModuleModel, func: Func) -> Iterator:
        params = func.params()
        # protocol: on_step_end(self, ctx, ev) — bind by position so
        # renamed parameters are still covered
        ctx_name = params[1] if len(params) > 1 else "ctx"
        ev_name = params[2] if len(params) > 2 else "ev"

        def device_rooted(node: ast.AST) -> bool:
            root = _root_chain(node)
            if root is None:
                return False
            base, first = root
            if base == ev_name:
                return True
            return base == ctx_name and first in ("params", "opt_state")

        for node in func.own_nodes():
            if not isinstance(node, ast.Call):
                continue
            target = model.resolve(node.func)
            if target in _SYNC_CALLS:
                yield model.finding(
                    self.id, node,
                    f"{target.split('.')[-1]}() in on_step_end — blocking "
                    "host sync on the per-step hook path; move the "
                    "transfer to the runner's single bundled per-step "
                    "device_get")
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                yield model.finding(
                    self.id, node,
                    ".item() in on_step_end — blocking per-step host sync")
            elif target in ("float", "int", "numpy.asarray",
                            "numpy.array") and node.args and \
                    device_rooted(node.args[0]):
                yield model.finding(
                    self.id, node,
                    f"{target.split('.')[-1]}() on `{ev_name}.*`/"
                    f"`{ctx_name}.params`-rooted value in on_step_end — "
                    "StepEvent carries host scalars (runner does one "
                    "bundled transfer per step); coercing here is a sync "
                    "on device values and redundant on host ones")

    def _check_engine(self, model: ModuleModel, func: Func) -> Iterator:
        for node in func.own_nodes():
            if not isinstance(node, ast.Call):
                continue
            target = model.resolve(node.func)
            if target in _SYNC_CALLS:
                yield model.finding(
                    self.id, node,
                    f"{target.split('.')[-1]}() in {func.qualname} — the "
                    "decode loop syncs once per chunk boundary only; "
                    "suppress deliberately if this IS that sync")
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                yield model.finding(
                    self.id, node,
                    f".item() in {func.qualname} — per-token host sync in "
                    "the decode loop")


def _root_chain(node: ast.AST) -> Optional[tuple]:
    """(base name, first attribute) of an expression rooted at a name,
    descending through attribute/subscript/call chains:
    ``ev.metrics.get("x")`` -> ("ev", "metrics")."""
    first = None
    while True:
        if isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Attribute):
            first = node.attr
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Name):
            return node.id, first
        else:
            return None


# --------------------------------------------------------------------------
# R3 — donated-buffer safety
# --------------------------------------------------------------------------


class DonationSafety:
    """Reading a buffer after passing it to a jitted call that donates
    that argument: the callee may have reused the storage, so the read
    returns garbage or raises — the PR 3 fault-policy flaw class.  The
    analysis is module-local and source-ordered: a donated name is dead
    from the donating call until rebound (binding the call's own result
    to the same name, the ``x, .. = f(x, ..)`` idiom, is the fix)."""

    id = "R3"
    title = "donation-safety"
    invariant = ("no use of a buffer after it was donated to a jitted "
                 "call (rebind from the call's results)")

    def check(self, model: ModuleModel) -> list:
        donors = self._collect_donors(model)
        out = []
        for func in model.funcs:
            out.extend(self._check_func(model, func, donors))
        return out

    @staticmethod
    def _collect_donors(model: ModuleModel) -> dict:
        """dotted callable name -> set of donated positional indices."""
        donors: dict[str, set] = {}
        for node in ast.walk(model.tree):
            if not isinstance(node, ast.Assign):
                continue
            call = node.value
            if not (isinstance(call, ast.Call)
                    and model.resolve(call.func) == "jax.jit"):
                continue
            donated = _donated_positions(call)
            if not donated:
                continue
            for t in node.targets:
                d = dotted(t)
                if d:
                    donors[d] = donated
        return donors

    def _check_func(self, model: ModuleModel, func: Func,
                    donors: dict) -> Iterator:
        dead: dict[str, str] = {}   # donated name -> callee it died in
        for stmt in func.own_statements():
            # 1) reads of already-dead names
            for node in _stmt_exprs(stmt):
                if not isinstance(node, (ast.Name, ast.Attribute)):
                    continue
                if not isinstance(getattr(node, "ctx", None), ast.Load):
                    continue
                d = dotted(node)
                if d in dead:
                    yield model.finding(
                        self.id, node,
                        f"`{d}` read after being donated to "
                        f"`{dead[d]}` — the donated buffer may have been "
                        "reused; rebind it from the call's results")
                    dead.pop(d, None)
            # 2) new donations in this statement
            targets = _assigned_dotted(stmt)
            for node in _stmt_exprs(stmt):
                if not isinstance(node, ast.Call):
                    continue
                callee, donated = self._donation_of(model, node, donors)
                if not donated:
                    continue
                for i in donated:
                    if i < len(node.args):
                        d = dotted(node.args[i])
                        if d and d not in targets:
                            dead[d] = callee
            # 3) rebinding resurrects
            for d in targets:
                dead.pop(d, None)

    @staticmethod
    def _donation_of(model: ModuleModel, call: ast.Call,
                     donors: dict) -> tuple:
        d = dotted(call.func)
        if d in donors:
            return d, donors[d]
        # immediate-call form: jax.jit(f, donate_argnums=..)(args)
        if isinstance(call.func, ast.Call) and \
                model.resolve(call.func.func) == "jax.jit":
            donated = _donated_positions(call.func)
            if donated:
                return "jax.jit(...)", donated
        return None, set()


def _donated_positions(jit_call: ast.Call) -> set:
    out: set = set()
    for kw in jit_call.keywords:
        if kw.arg == "donate_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    out.add(n.value)
    return out


def _assigned_dotted(stmt: ast.stmt) -> set:
    out: set = set()
    targets: list = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.For):
        targets = [stmt.target]
    for t in targets:
        for node in ast.walk(t):
            d = dotted(node)
            if d:
                out.add(d)
    return out


# --------------------------------------------------------------------------
# R4 — Pallas kernel hygiene
# --------------------------------------------------------------------------


class PallasHygiene:
    """Kernel-call hygiene: no ``interpret=True`` left on in production
    code (CPU interpreter masquerading as the TPU path), grids derived by
    floor division must assert divisibility (a silently truncated grid
    skips tail elements), and SMEM holds scalars/vectors only (matrix
    tiles belong in VMEM)."""

    id = "R4"
    title = "pallas-hygiene"
    invariant = ("kernel launches are exact (divisibility asserted), "
                 "production-mode (no interpret=True), and SMEM-sane")

    def check(self, model: ModuleModel) -> list:
        out = []
        if not model.is_test:
            for node in ast.walk(model.tree):
                if isinstance(node, ast.Call):
                    for kw in node.keywords:
                        if kw.arg == "interpret" and \
                                isinstance(kw.value, ast.Constant) and \
                                kw.value.value is True:
                            out.append(model.finding(
                                self.id, kw.value,
                                "literal interpret=True outside tests — "
                                "the Pallas interpreter is a test/debug "
                                "mode; thread a flag instead"))
        for func in model.funcs:
            out.extend(self._check_grids(model, func))
        out.extend(self._check_smem(model))
        return out

    def _check_grids(self, model: ModuleModel, func: Func) -> Iterator:
        calls = [n for n in func.own_nodes() if isinstance(n, ast.Call)
                 and model.resolve(n.func) is not None
                 and model.resolve(n.func).endswith(".pallas_call")]
        if not calls:
            return
        has_div_assert = self._has_divisibility_assert(func)
        for call in calls:
            grid_exprs = self._grid_exprs(model, func, call)
            for expr in grid_exprs:
                if self._has_floordiv(func, expr) and not has_div_assert:
                    yield model.finding(
                        self.id, expr,
                        "pallas_call grid derived by floor division "
                        "without a divisibility assert in this function — "
                        "a non-multiple shape silently drops the tail "
                        "block (assert `x % block == 0` or pad first)")

    def _grid_exprs(self, model: ModuleModel, func: Func,
                    call: ast.Call) -> list:
        out = []
        for kw in call.keywords:
            if kw.arg == "grid":
                out.append(kw.value)
            elif kw.arg == "grid_spec":
                spec = self._resolve_local(func, kw.value)
                if isinstance(spec, ast.Call):
                    for skw in spec.keywords:
                        if skw.arg == "grid":
                            out.append(skw.value)
        return out

    def _has_floordiv(self, func: Func, expr: ast.AST) -> bool:
        expr = self._resolve_local(func, expr) or expr
        for node in ast.walk(expr):
            if isinstance(node, ast.BinOp) and \
                    isinstance(node.op, ast.FloorDiv):
                return True
            # elements of a grid tuple may themselves be local names
            if isinstance(node, ast.Name) and node is not expr:
                rhs = self._lookup_assign(func, node.id)
                if rhs is not None and any(
                        isinstance(n, ast.BinOp)
                        and isinstance(n.op, ast.FloorDiv)
                        for n in ast.walk(rhs)):
                    return True
        return False

    def _resolve_local(self, func: Func, expr: ast.AST):
        if isinstance(expr, ast.Name):
            return self._lookup_assign(func, expr.id)
        return expr

    @staticmethod
    def _lookup_assign(func: Func, name: str):
        rhs = None
        for stmt in func.own_statements():
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name) and n.id == name:
                            rhs = stmt.value
        return rhs

    @staticmethod
    def _has_divisibility_assert(func: Func) -> bool:
        for stmt in func.own_statements():
            if isinstance(stmt, ast.Assert):
                for n in ast.walk(stmt.test):
                    if isinstance(n, ast.BinOp) and \
                            isinstance(n.op, ast.Mod):
                        return True
        return False

    def _check_smem(self, model: ModuleModel) -> Iterator:
        for node in ast.walk(model.tree):
            if not isinstance(node, ast.Call):
                continue
            target = model.resolve(node.func)
            if target is None:
                continue
            if target.endswith("pallas.tpu.SMEM") and node.args and \
                    isinstance(node.args[0], ast.Tuple) and \
                    len(node.args[0].elts) > 1:
                yield model.finding(
                    self.id, node,
                    "multi-dimensional SMEM scratch — SMEM is the scalar "
                    "memory; matrix tiles belong in pltpu.VMEM")
            elif target.endswith(".BlockSpec"):
                is_smem = any(
                    kw.arg == "memory_space"
                    and (model.resolve(kw.value) or "").endswith("SMEM")
                    for kw in node.keywords)
                if is_smem and node.args and \
                        isinstance(node.args[0], ast.Tuple) and \
                        len(node.args[0].elts) > 1:
                    yield model.finding(
                        self.id, node,
                        "multi-dimensional BlockSpec in SMEM — scalar "
                        "operands only (use VMEM for tiles)")


# --------------------------------------------------------------------------
# R5 — impurity inside traced code
# --------------------------------------------------------------------------

_IMPURE_EXACT = {
    "time.time": "wall-clock read",
    "time.perf_counter": "wall-clock read",
    "time.monotonic": "wall-clock read",
    "time.sleep": "host sleep",
    "datetime.datetime.now": "wall-clock read",
    "print": "host I/O (use jax.debug.print)",
    "open": "host I/O",
    "input": "host I/O",
}
_IMPURE_PREFIX = {
    "numpy.random.": "host RNG (use jax.random with an explicit key)",
    "random.": "host RNG (use jax.random with an explicit key)",
}


class TracedImpurity:
    """Side effects inside traced code execute once at trace time and
    never again (or at recompile, non-deterministically) — wall-clock
    reads, host RNG, I/O and global mutation silently freeze into the
    compiled program and break bitwise resume."""

    id = "R5"
    title = "traced-impurity"
    invariant = ("traced code is pure: no host RNG, clocks, I/O or "
                 "global mutation baked into the compiled program")

    def check(self, model: ModuleModel) -> list:
        out = []
        for func in model.funcs:
            if not func.traced:
                continue
            for stmt in func.own_statements():
                if isinstance(stmt, ast.Global):
                    out.append(model.finding(
                        self.id, stmt,
                        "`global` mutation inside traced code — the "
                        "side effect happens once at trace time, not "
                        "per step"))
            for node in func.own_nodes():
                if not isinstance(node, ast.Call):
                    continue
                target = model.resolve(node.func)
                if target is None:
                    continue
                why = _IMPURE_EXACT.get(target)
                if why is None:
                    for prefix, reason in _IMPURE_PREFIX.items():
                        if target.startswith(prefix):
                            why = reason
                            break
                if why is not None:
                    out.append(model.finding(
                        self.id, node,
                        f"{target}() inside traced code — {why}; the "
                        "value freezes at trace time and breaks "
                        "bitwise-reproducible steps"))
        return out


# --------------------------------------------------------------------------
# R6 — RunSpec serialization drift
# --------------------------------------------------------------------------


class SpecDrift:
    """Every RunSpec field must round-trip: nested dataclass fields must
    be re-hydrated in ``from_dict`` and every field must be constructible
    from ``from_cli_args`` — a field added to the dataclass but not the
    (de)serializers silently drops config on spec replay, which breaks
    the spec-addressed artifact contract."""

    id = "R6"
    title = "spec-drift"
    invariant = ("RunSpec fields round-trip through to_json/from_json "
                 "and are reachable from the CLI")

    def check(self, model: ModuleModel) -> list:
        spec_cls = None
        for node in ast.walk(model.tree):
            if isinstance(node, ast.ClassDef) and node.name == "RunSpec":
                if self._is_dataclass(model, node):
                    spec_cls = node
                break
        if spec_cls is None:
            return []
        out = []
        dataclass_names = self._module_dataclasses(model)
        fields = self._fields(spec_cls)
        nested = {name: ann for name, ann in fields.items()
                  if self._nested_dataclass(ann, dataclass_names)}

        from_dict = self._method(spec_cls, "from_dict")
        if from_dict is not None:
            mentioned = _str_constants(from_dict)
            for name in nested:
                if name not in mentioned:
                    out.append(model.finding(
                        self.id, self._field_node(spec_cls, name),
                        f"nested field `{name}` is not re-hydrated in "
                        "RunSpec.from_dict — from_json would return a "
                        "plain dict for it (lossy round-trip)"))

        to_dict = self._method(spec_cls, "to_dict")
        if to_dict is not None and not self._uses_asdict(model, to_dict):
            mentioned = _str_constants(to_dict)
            for name in fields:
                if name not in mentioned:
                    out.append(model.finding(
                        self.id, self._field_node(spec_cls, name),
                        f"field `{name}` missing from hand-rolled "
                        "RunSpec.to_dict — to_json drops it"))

        cli = None
        for f in model.funcs:
            if f.name == "from_cli_args" and f.parent is None and \
                    f.cls is None:
                cli = f
        if cli is not None:
            kwargs = self._spec_ctor_kwargs(cli)
            if kwargs is not None:
                for name in fields:
                    if name not in kwargs:
                        out.append(model.finding(
                            self.id, self._field_node(spec_cls, name),
                            f"field `{name}` is never passed by "
                            "from_cli_args — the CLI cannot express it "
                            "(wire a flag or construct it explicitly)"))
        return out

    @staticmethod
    def _is_dataclass(model: ModuleModel, node: ast.ClassDef) -> bool:
        for deco in node.decorator_list:
            target = model.resolve(deco.func if isinstance(deco, ast.Call)
                                   else deco)
            if target and target.endswith("dataclass"):
                return True
        return False

    def _module_dataclasses(self, model: ModuleModel) -> set:
        out = set()
        for node in ast.walk(model.tree):
            if isinstance(node, ast.ClassDef) and \
                    self._is_dataclass(model, node):
                out.add(node.name)
        return out

    @staticmethod
    def _fields(cls: ast.ClassDef) -> dict:
        out = {}
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name):
                ann_names = {n.id for n in ast.walk(stmt.annotation)
                             if isinstance(n, ast.Name)}
                if "ClassVar" in ann_names:
                    continue
                out[stmt.target.id] = ann_names
        return out

    @staticmethod
    def _nested_dataclass(ann_names: set, dataclass_names: set) -> bool:
        if ann_names & dataclass_names:
            return True
        # imported spec/config types follow the *Spec/*Config convention
        return any(n.endswith("Spec") or n.endswith("Config")
                   for n in ann_names)

    @staticmethod
    def _method(cls: ast.ClassDef, name: str):
        for stmt in cls.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and stmt.name == name:
                return stmt
        return None

    @staticmethod
    def _field_node(cls: ast.ClassDef, name: str) -> ast.AST:
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name) and \
                    stmt.target.id == name:
                return stmt
        return cls

    @staticmethod
    def _uses_asdict(model: ModuleModel, fn: ast.AST) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                target = model.resolve(node.func)
                if target and target.endswith("asdict"):
                    return True
        return False

    @staticmethod
    def _spec_ctor_kwargs(cli) -> Optional[set]:
        """Keyword names of the RunSpec(...) construction in the CLI
        builder (the call with the most keywords wins, covering helper
        locals)."""
        best = None
        for node in cli.own_nodes():
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id in ("RunSpec", "cls"):
                kwargs = {kw.arg for kw in node.keywords if kw.arg}
                if best is None or len(kwargs) > len(best):
                    best = kwargs
        return best


# --------------------------------------------------------------------------
# R7 — exception hygiene
# --------------------------------------------------------------------------

_BROAD_EXC = {"Exception", "BaseException"}


class ExceptionHygiene:
    """Bare ``except:`` and broad handlers that swallow silently: the
    sentinel/retry/rollback machinery (PR 10) classifies failures into
    *transient* (retry), *anomalous* (skip/rollback) and *fatal*
    (propagate) — a handler that catches everything and does nothing
    erases that classification, hides real faults (including
    AnomalyBudgetExceeded, SimulatedKill, preemption signals) and turns
    loud failures into silent corruption.  Catch the narrow type, or
    handle-and-log, or re-raise."""

    id = "R7"
    title = "exception-hygiene"
    invariant = ("no bare except; broad Exception handlers must act "
                 "(log/re-raise/recover), never silently swallow")

    def check(self, model: ModuleModel) -> list:
        if model.is_test:
            return []
        out = []
        for node in ast.walk(model.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                out.append(model.finding(
                    self.id, node,
                    "bare `except:` — catches SystemExit/KeyboardInterrupt"
                    "/SimulatedKill too; name the exception type"))
            elif self._catches_broad(node.type) and \
                    self._swallows(node.body):
                out.append(model.finding(
                    self.id, node,
                    "`except Exception` with a no-op body silently "
                    "swallows every failure — catch the narrow type, or "
                    "log/re-raise"))
        return out

    @staticmethod
    def _catches_broad(type_node: ast.AST) -> bool:
        elts = (type_node.elts if isinstance(type_node, ast.Tuple)
                else [type_node])
        for e in elts:
            if isinstance(e, ast.Name) and e.id in _BROAD_EXC:
                return True
            if isinstance(e, ast.Attribute) and e.attr in _BROAD_EXC:
                return True
        return False

    @staticmethod
    def _swallows(body: list) -> bool:
        """True when the handler body does nothing observable: only
        ``pass``, ``...``, docstring constants, or ``continue``."""
        for stmt in body:
            if isinstance(stmt, (ast.Pass, ast.Continue)):
                continue
            if isinstance(stmt, ast.Expr) and \
                    isinstance(stmt.value, ast.Constant):
                continue
            return False
        return True


# --------------------------------------------------------------------------


def _str_constants(node: ast.AST) -> set:
    return {n.value for n in ast.walk(node)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)}


ALL_RULES = (RecompileHazard(), HostSyncInHotPath(), DonationSafety(),
             PallasHygiene(), TracedImpurity(), SpecDrift(),
             ExceptionHygiene())

RULES_BY_ID = {r.id: r for r in ALL_RULES}

"""repro-lint — an invariant-checking static analyzer for the
jit/Pallas/hook stack.

Every load-bearing guarantee the repo has accumulated — zero steady-state
recompiles, donated-buffer safety, kernel hygiene, spec round-trip
completeness — is a *structural* property of the source, so it can be
checked at the AST level at commit time instead of re-proved by a runtime
test per subsystem.  The package is:

  * :mod:`repro.analysis.core` — the shared traversal engine: import-alias
    resolution (``import jax.numpy as jnp`` and ``from jax import numpy as
    jnp`` both resolve to ``jax.numpy``), scope-aware function collection,
    traced-context inference (function bodies reachable from ``jax.jit`` /
    ``pl.pallas_call`` / ``lax.scan`` / StepProgram-style ``make_*``
    builders), a conservative taint walk for traced values, and inline
    ``# repro-lint: disable=R2`` suppression parsing;
  * :mod:`repro.analysis.rules` — the rule set (R1..R6, see
    :data:`repro.analysis.rules.ALL_RULES` and DESIGN.md §"Static
    analysis: repro-lint");
  * :mod:`repro.analysis.baseline` — the committed-baseline format (every
    entry carries a one-line justification; stale entries are errors);
  * :mod:`repro.analysis.lint` — the CLI:
    ``python -m repro.analysis.lint [paths] --format text|json``.
"""
from repro.analysis.core import Finding, ModuleModel, analyze_module
from repro.analysis.rules import ALL_RULES

__all__ = ["Finding", "ModuleModel", "analyze_module", "lint_paths",
           "main", "ALL_RULES"]


def __getattr__(name):
    # lint is imported lazily so ``python -m repro.analysis.lint`` doesn't
    # trip runpy's found-in-sys.modules warning.
    if name in ("lint_paths", "main"):
        from repro.analysis import lint
        return getattr(lint, name)
    raise AttributeError(name)

"""Committed-baseline support for repro-lint.

A baseline entry grandfathers ONE existing finding, identified by
``(rule, path suffix, context qualname, stripped line text)`` — line
numbers are deliberately absent so unrelated edits above a finding don't
invalidate the baseline.  Every entry MUST carry a non-empty
``justification``; entries that no longer match any live finding are
*stale* and fail the lint run (the baseline can only shrink silently,
never rot).

Format (``.repro-lint-baseline.json`` at the repo root)::

    {
      "version": 1,
      "entries": [
        {
          "rule": "R2",
          "path": "src/repro/serve/engine.py",
          "context": "PagedEngine._run_chunk",
          "line_text": "out = jax.device_get(...)",
          "justification": "the ONE sanctioned per-chunk sync"
        }
      ]
    }
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, List, Sequence, Tuple

from repro.analysis.core import Finding

BASELINE_NAME = ".repro-lint-baseline.json"
_VERSION = 1


class BaselineError(ValueError):
    """Malformed baseline file (bad schema, missing justification)."""


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str        # posix path suffix, matched against finding paths
    context: str
    line_text: str
    justification: str

    def matches(self, finding: Finding) -> bool:
        key = finding.key()
        return (self.rule == key[0]
                and _suffix_match(self.path, key[1])
                and self.context == key[2]
                and self.line_text == key[3])


def _suffix_match(entry_path: str, finding_path: str) -> bool:
    e = entry_path.strip("/").split("/")
    f = finding_path.strip("/").split("/")
    return len(e) <= len(f) and f[-len(e):] == e


def load(path: pathlib.Path) -> List[BaselineEntry]:
    """Parse and validate a baseline file. Raises BaselineError."""
    try:
        data = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        raise BaselineError(f"{path}: not valid JSON: {e}") from e
    if not isinstance(data, dict) or data.get("version") != _VERSION:
        raise BaselineError(
            f"{path}: expected {{'version': {_VERSION}, 'entries': [..]}}")
    entries = []
    for i, raw in enumerate(data.get("entries", [])):
        missing = [k for k in ("rule", "path", "context", "line_text",
                               "justification") if k not in raw]
        if missing:
            raise BaselineError(
                f"{path}: entry {i} missing {missing}")
        if not str(raw["justification"]).strip():
            raise BaselineError(
                f"{path}: entry {i} ({raw['rule']} {raw['path']}) has an "
                "empty justification — every baselined finding must say "
                "why it is allowed to stay")
        entries.append(BaselineEntry(
            rule=str(raw["rule"]), path=str(raw["path"]),
            context=str(raw["context"]), line_text=str(raw["line_text"]),
            justification=str(raw["justification"])))
    return entries


def save(path: pathlib.Path, findings: Iterable[Finding]) -> None:
    """Write a baseline grandfathering ``findings``; justifications are
    stamped TODO so a human must edit each one before committing."""
    entries = []
    for f in sorted(findings, key=lambda f: f.key()):
        entries.append({
            "rule": f.rule, "path": f.key()[1], "context": f.context,
            "line_text": f.line_text,
            "justification": "TODO: justify or fix",
        })
    path.write_text(json.dumps(
        {"version": _VERSION, "entries": entries}, indent=2) + "\n")


def apply(findings: Sequence[Finding],
          entries: Sequence[BaselineEntry],
          ) -> Tuple[List[Finding], List[BaselineEntry]]:
    """Split findings into (new, stale-entries).

    Each entry may absorb any number of matching findings (a suffix path
    can cover a file moved between fixture roots); an entry that absorbs
    none is stale and must be deleted from the baseline.
    """
    used = [False] * len(entries)
    new: List[Finding] = []
    for f in findings:
        absorbed = False
        for i, e in enumerate(entries):
            if e.matches(f):
                used[i] = True
                absorbed = True
        if not absorbed:
            new.append(f)
    stale = [e for i, e in enumerate(entries) if not used[i]]
    return new, stale

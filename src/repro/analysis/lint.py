"""repro-lint CLI.

    python -m repro.analysis.lint [paths...] [--format text|json]
                                  [--baseline FILE | --no-baseline]
                                  [--rules R1,R2,...] [--write-baseline]
                                  [--list-rules]

Exit codes: 0 clean (all findings baselined-with-justification),
1 findings (new findings, or stale baseline entries), 2 usage/config
error (unreadable path, malformed baseline).

Paths default to ``src``.  Directories are walked for ``*.py``; files
named ``test_*.py``/``conftest.py`` or under a ``tests``/``fixtures``
directory are treated as test code (relaxes R4's interpret=True check)
but are still analyzed when explicitly listed.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List, Optional, Sequence

from repro.analysis import baseline as baseline_mod
from repro.analysis.core import Finding, analyze_module
from repro.analysis.rules import ALL_RULES, RULES_BY_ID

_TEST_DIRS = {"tests", "fixtures"}


def _is_test_path(path: pathlib.Path) -> bool:
    if path.name.startswith("test_") or path.name == "conftest.py":
        return True
    return any(part in _TEST_DIRS for part in path.parts)


def collect_files(paths: Sequence[str]) -> List[pathlib.Path]:
    out: List[pathlib.Path] = []
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.is_file():
            out.append(p)
        else:
            raise FileNotFoundError(raw)
    # dedupe, keep order
    seen = set()
    uniq = []
    for p in out:
        key = p.resolve()
        if key not in seen:
            seen.add(key)
            uniq.append(p)
    return uniq


def lint_paths(paths: Sequence[str],
               rules: Optional[Sequence[str]] = None,
               ) -> List[Finding]:
    """Run the analyzer over ``paths`` and return raw findings
    (suppression comments already applied, baseline NOT applied)."""
    active = list(ALL_RULES)
    if rules:
        unknown = [r for r in rules if r not in RULES_BY_ID]
        if unknown:
            raise KeyError(f"unknown rule id(s): {unknown}; "
                           f"have {sorted(RULES_BY_ID)}")
        active = [RULES_BY_ID[r] for r in rules]
    findings: List[Finding] = []
    for path in collect_files(paths):
        try:
            source = path.read_text()
        except (OSError, UnicodeDecodeError) as e:
            raise FileNotFoundError(f"{path}: {e}") from e
        findings.extend(analyze_module(
            str(path), source, rules=active,
            is_test=_is_test_path(path)))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def _format_text(findings: Sequence[Finding],
                 stale: Sequence[baseline_mod.BaselineEntry]) -> str:
    lines = []
    for f in findings:
        lines.append(f"{f.path}:{f.line}:{f.col}: {f.rule} "
                     f"[{f.context or '<module>'}] {f.message}")
        lines.append(f"    {f.line_text}")
    for e in stale:
        lines.append(f"{e.path}: stale baseline entry ({e.rule} in "
                     f"{e.context or '<module>'}: {e.line_text!r}) — the "
                     "finding is gone; delete the entry")
    if findings or stale:
        lines.append("")
        lines.append(f"repro-lint: {len(findings)} new finding(s), "
                     f"{len(stale)} stale baseline entr(y/ies)")
    else:
        lines.append("repro-lint: clean")
    return "\n".join(lines)


def _format_json(findings: Sequence[Finding],
                 stale: Sequence[baseline_mod.BaselineEntry]) -> str:
    return json.dumps({
        "findings": [
            {"rule": f.rule, "path": f.key()[1], "line": f.line,
             "col": f.col, "context": f.context, "message": f.message,
             "line_text": f.line_text}
            for f in findings
        ],
        "stale_baseline": [
            {"rule": e.rule, "path": e.path, "context": e.context,
             "line_text": e.line_text}
            for e in stale
        ],
    }, indent=2)


def _find_default_baseline(paths: Sequence[str]) -> Optional[pathlib.Path]:
    """Nearest .repro-lint-baseline.json at or above the first lint
    path (so the CLI works from any cwd inside the repo)."""
    start = pathlib.Path(paths[0] if paths else ".").resolve()
    if start.is_file():
        start = start.parent
    for cand in [start, *start.parents]:
        p = cand / baseline_mod.BASELINE_NAME
        if p.is_file():
            return p
    return None


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro-lint: invariant checks for the "
                    "jit/Pallas/hook stack")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories (default: src)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: nearest "
                         f"{baseline_mod.BASELINE_NAME} above the first "
                         "path)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file "
                         "(justifications stamped TODO) and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.id}  {r.title:24s} {r.invariant}")
        return 0

    paths = args.paths or ["src"]
    rules = args.rules.split(",") if args.rules else None
    try:
        findings = lint_paths(paths, rules=rules)
    except (FileNotFoundError, KeyError, SyntaxError) as e:
        print(f"repro-lint: error: {e}", file=sys.stderr)
        return 2

    baseline_path: Optional[pathlib.Path] = None
    entries: List[baseline_mod.BaselineEntry] = []
    if not args.no_baseline:
        baseline_path = (pathlib.Path(args.baseline) if args.baseline
                         else _find_default_baseline(paths))
        if args.baseline and not baseline_path.is_file() \
                and not args.write_baseline:
            print(f"repro-lint: error: baseline {baseline_path} not "
                  "found", file=sys.stderr)
            return 2
        if baseline_path is not None and baseline_path.is_file() \
                and not args.write_baseline:
            try:
                entries = baseline_mod.load(baseline_path)
            except baseline_mod.BaselineError as e:
                print(f"repro-lint: error: {e}", file=sys.stderr)
                return 2

    if args.write_baseline:
        target = baseline_path or pathlib.Path(baseline_mod.BASELINE_NAME)
        baseline_mod.save(target, findings)
        print(f"repro-lint: wrote {len(findings)} entr(y/ies) to "
              f"{target} — edit the TODO justifications before "
              "committing")
        return 0

    new, stale = baseline_mod.apply(findings, entries)
    out = (_format_json if args.format == "json" else _format_text)(
        new, stale)
    print(out)
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    raise SystemExit(main())

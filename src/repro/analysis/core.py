"""Shared traversal engine for repro-lint.

One parse of a module produces a :class:`ModuleModel` every rule shares:

  * **Import table** — local names resolved to canonical dotted paths, so
    ``jnp.asarray`` and ``from jax import numpy as np2; np2.asarray`` both
    canonicalize to ``jax.numpy.asarray`` (rules match on canonical names,
    never on surface spellings).
  * **Function table** — every ``def``/``lambda`` with its qualname,
    enclosing class/function, and scope-chain name lookup (latest *and*
    shadowed bindings, so a ``# noqa: F811`` redefinition seeds both).
  * **Traced-context inference** — the set of function bodies that execute
    under a jax trace: seeds are functions passed to ``jax.jit`` /
    ``pl.pallas_call`` / ``jax.lax.scan``-family / ``jax.vmap``-family
    transforms (as arguments, decorators, or ``functools.partial(jax.jit,
    ...)`` decorators), functions *returned* by a local callee that is
    immediately jitted (``jax.jit(self._make_chunk_fn())``), and — the
    StepProgram/registry convention — closures returned by ``make_*``
    builders.  Tracedness propagates to nested defs and locally-resolvable
    callees (including ``self.method()`` within a class).
  * **Taint** — a conservative source-order walk classifying which local
    names hold traced array values inside a traced function (parameters
    minus ``static_argnames``, results of ``jax.*`` calls) with the static
    escapes (``.shape``/``.dtype``/``.ndim``/``len()``/``isinstance()``)
    untainted, so rules can tell a Python branch on a *shape* (static,
    fine) from a branch on a *value* (concretization / recompile hazard).
  * **Suppressions** — ``# repro-lint: disable=R1[,R2]`` on the finding's
    line or on a comment-only line directly above it.

The engine is pure stdlib ``ast`` — no imports of the analyzed code, so
linting never executes (or requires the dependencies of) the target.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterator, Optional

# --------------------------------------------------------------------------
# findings
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, addressable for suppression and baselining.

    ``key()`` deliberately excludes the line *number*: baselines match on
    (rule, path, enclosing qualname, stripped line text) so unrelated
    edits above a baselined line don't invalidate the entry."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    context: str          # enclosing qualname, or "<module>"
    line_text: str        # stripped source of the offending line

    def key(self) -> tuple:
        return (self.rule, _posix(self.path), self.context, self.line_text)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.context}] {self.message}")


def _posix(path: str) -> str:
    return str(path).replace("\\", "/")


# --------------------------------------------------------------------------
# import-alias resolution
# --------------------------------------------------------------------------


class ImportTable:
    """Maps local names to canonical dotted module/attribute paths."""

    def __init__(self, tree: ast.AST):
        self.names: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.names[a.asname] = a.name
                    else:
                        # ``import jax.numpy`` binds the *root* name
                        self.names[a.name.split(".")[0]] = \
                            a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.names[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted path of a Name/Attribute chain, else None."""
        if isinstance(node, ast.Name):
            return self.names.get(node.id, node.id)
        if isinstance(node, ast.Attribute):
            base = self.resolve(node.value)
            if base is None:
                return None
            return f"{base}.{node.attr}"
        return None


# --------------------------------------------------------------------------
# function table
# --------------------------------------------------------------------------

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# transforms whose function-valued arguments run under a jax trace
_TRACING_CALLS = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.eval_shape",
    "jax.custom_vjp", "jax.custom_jvp", "jax.linearize", "jax.jvp",
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.cond",
    "jax.lax.fori_loop", "jax.lax.map", "jax.lax.switch",
    "jax.lax.associative_scan", "jax.lax.custom_root",
    "jax.experimental.pallas.pallas_call",
    "jax.experimental.shard_map.shard_map",
}


@dataclasses.dataclass
class Func:
    """One function body and everything rules need to reason about it."""

    node: ast.AST                      # FunctionDef / AsyncFunctionDef / Lambda
    name: str
    qualname: str
    parent: Optional["Func"]           # enclosing function, if nested
    cls: Optional[str]                 # enclosing class name, if a method
    static_params: set = dataclasses.field(default_factory=set)
    traced: bool = False
    traced_via: str = ""               # how tracedness was established
    # True when this function is the *direct* operand of a tracing
    # transform (its parameters are tracers); propagation-traced callees
    # keep False — their arguments may be static Python values at the
    # call site, so rules must not assume their params are traced.
    params_traced: bool = False
    # Per-parameter taint inferred from call sites inside traced code:
    # ``helper(x, m * n)`` taints helper's first param only — the second
    # receives a static Python int.
    tainted_params: set = dataclasses.field(default_factory=set)

    def params(self) -> list:
        a = self.node.args
        out = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
        if a.vararg:
            out.append(a.vararg.arg)
        if a.kwarg:
            out.append(a.kwarg.arg)
        return out

    def body(self) -> list:
        b = self.node.body
        return b if isinstance(b, list) else [ast.Expr(b)]  # Lambda

    def own_statements(self) -> Iterator[ast.stmt]:
        """Statements of this function, not descending into nested defs."""
        yield from _iter_own(self.body())

    def own_nodes(self) -> Iterator[ast.AST]:
        """All expression/statement nodes of this function's own body,
        each exactly once, not descending into nested function bodies
        (their nodes belong to the nested :class:`Func`)."""
        for stmt in self.own_statements():
            if isinstance(stmt, _FUNC_NODES):
                # the def statement itself (decorators, defaults) is ours
                for field in ("decorator_list",):
                    for d in getattr(stmt, field, []):
                        yield from ast.walk(d)
                continue
            yield stmt
            yield from stmt_exprs(stmt)


def _iter_own(body: list) -> Iterator[ast.stmt]:
    for stmt in body:
        yield stmt
        if isinstance(stmt, _FUNC_NODES):
            continue
        yield from _iter_own_children(stmt)


def _iter_own_children(stmt: ast.AST) -> Iterator[ast.stmt]:
    for field in stmt._fields:
        value = getattr(stmt, field, None)
        if isinstance(value, list):
            for item in value:
                if isinstance(item, ast.stmt):
                    yield item
                    if not isinstance(item, _FUNC_NODES):
                        yield from _iter_own_children(item)
                elif isinstance(item, ast.AST):
                    # ExceptHandler / match_case hold statement lists
                    yield from _iter_own_children(item)


def stmt_exprs(stmt: ast.AST) -> Iterator[ast.AST]:
    """Expression(-ish) nodes belonging to this statement only — child
    statements are iterated by their own :meth:`Func.own_statements`
    round, nested function bodies by their own :class:`Func`."""
    for child in ast.iter_child_nodes(stmt):
        if isinstance(child, (ast.stmt,) + _FUNC_NODES):
            continue
        yield from _walk_expr_skip_stmts(child)


def _walk_expr_skip_stmts(node: ast.AST) -> Iterator[ast.AST]:
    yield node
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.stmt,) + _FUNC_NODES):
            continue
        yield from _walk_expr_skip_stmts(child)


def _walk_no_funcs(node: ast.AST) -> Iterator[ast.AST]:
    yield node
    for child in ast.iter_child_nodes(node):
        if isinstance(child, _FUNC_NODES):
            continue
        yield from _walk_no_funcs(child)


class _FuncCollector(ast.NodeVisitor):
    def __init__(self):
        self.funcs: list[Func] = []
        self.by_node: dict[int, Func] = {}
        # scope key (id of enclosing Func node, or None) -> name -> [Func]
        self.scopes: dict[Optional[int], dict[str, list[Func]]] = {None: {}}
        self.methods: dict[str, dict[str, list[Func]]] = {}
        self._stack: list[str] = []
        self._func_stack: list[Func] = []
        self._cls_stack: list[str] = []

    def _add(self, node, name) -> Func:
        parent = self._func_stack[-1] if self._func_stack else None
        cls = self._cls_stack[-1] if self._cls_stack else None
        qual = ".".join(self._stack + [name]) if self._stack else name
        f = Func(node=node, name=name, qualname=qual, parent=parent,
                 cls=cls if (parent is None or parent.cls == cls) else None)
        self.funcs.append(f)
        self.by_node[id(node)] = f
        key = id(parent.node) if parent else None
        self.scopes.setdefault(key, {}).setdefault(name, []).append(f)
        if f.cls is not None and parent is None:
            self.methods.setdefault(f.cls, {}).setdefault(name, []).append(f)
        return f

    def _visit_func(self, node):
        f = self._add(node, node.name)
        self._stack.append(node.name)
        self._func_stack.append(f)
        self.generic_visit(node)
        self._func_stack.pop()
        self._stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_Lambda(self, node):
        f = self._add(node, "<lambda>")
        self._func_stack.append(f)
        self.generic_visit(node)
        self._func_stack.pop()

    def visit_ClassDef(self, node):
        self._stack.append(node.name)
        self._cls_stack.append(node.name)
        self.generic_visit(node)
        self._cls_stack.pop()
        self._stack.pop()


# --------------------------------------------------------------------------
# module model
# --------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\s]+)")


class ModuleModel:
    """Everything rules need about one parsed module."""

    def __init__(self, path: str, source: str,
                 is_test: Optional[bool] = None):
        self.path = _posix(path)
        self.source = source
        self._is_test = is_test
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.imports = ImportTable(self.tree)
        c = _FuncCollector()
        c.visit(self.tree)
        self.funcs = c.funcs
        self._by_node = c.by_node
        self._scopes = c.scopes
        self._methods = c.methods
        self.suppressions = self._parse_suppressions()
        self._infer_traced()
        self._infer_param_taint()

    # ------------------------------------------------------------- helpers
    @property
    def is_test(self) -> bool:
        if self._is_test is not None:
            return self._is_test
        parts = Path(self.path).parts
        return ("tests" in parts or "test" in parts
                or Path(self.path).name.startswith("test_"))

    def resolve(self, node: ast.AST) -> Optional[str]:
        return self.imports.resolve(node)

    def func_of(self, node: ast.AST) -> Optional[Func]:
        return self._by_node.get(id(node))

    def enclosing_qualname(self, lineno: int) -> str:
        best = None
        for f in self.funcs:
            n = f.node
            end = getattr(n, "end_lineno", n.lineno)
            if n.lineno <= lineno <= end:
                if best is None or n.lineno >= best.node.lineno:
                    best = f
        return best.qualname if best else "<module>"

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        text = (self.lines[line - 1].strip()
                if 0 < line <= len(self.lines) else "")
        return Finding(rule=rule, path=self.path, line=line, col=col,
                       message=message,
                       context=self.enclosing_qualname(line),
                       line_text=text)

    def lookup(self, name: str, scope: Optional[Func]) -> list:
        """All Funcs bound to ``name`` visible from ``scope`` (scope chain
        then module level).  Returns every binding so shadowed
        redefinitions are seeded too."""
        cur = scope
        while cur is not None:
            hits = self._scopes.get(id(cur.node), {}).get(name)
            if hits:
                return hits
            cur = cur.parent
        return self._scopes.get(None, {}).get(name, [])

    def lookup_method(self, cls: str, name: str) -> list:
        return self._methods.get(cls, {}).get(name, [])

    def nested_funcs(self, f: Func) -> list:
        out = []
        for hits in self._scopes.get(id(f.node), {}).values():
            out.extend(hits)
        return out

    def returned_local_funcs(self, f: Func) -> list:
        """Local defs that ``f`` returns by name (builder convention)."""
        out = []
        for stmt in f.own_statements():
            if isinstance(stmt, ast.Return) and isinstance(stmt.value,
                                                           ast.Name):
                out.extend(self.lookup(stmt.value.id, f))
        return out

    # -------------------------------------------------------- suppressions
    def _parse_suppressions(self) -> dict:
        out: dict[int, set] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                rules = {r.strip().upper() for r in m.group(1).split(",")
                         if r.strip()}
                out[i] = rules
        return out

    def suppressed(self, finding: Finding) -> bool:
        line = finding.line
        if finding.rule in self.suppressions.get(line, ()):
            return True
        # a comment-only line directly above also applies
        prev = self.lines[line - 2].strip() if line >= 2 else ""
        if prev.startswith("#") and \
                finding.rule in self.suppressions.get(line - 1, ()):
            return True
        return False

    # ------------------------------------------------- traced-context pass
    def _infer_traced(self) -> None:
        seeds: list[tuple[Func, str]] = []

        def seed_arg(arg: ast.AST, scope: Optional[Func], via: str,
                     static: set):
            """Mark a function-valued argument of a tracing transform."""
            if isinstance(arg, ast.Name):
                for f in self.lookup(arg.id, scope):
                    f.static_params |= static
                    f.params_traced = True
                    seeds.append((f, via))
            elif isinstance(arg, ast.Lambda):
                f = self.func_of(arg)
                if f is not None:
                    f.static_params |= static
                    f.params_traced = True
                    seeds.append((f, via))
            elif isinstance(arg, ast.Call):
                # jax.jit(self._make_chunk_fn()) / jax.jit(make_step(cfg)):
                # the *returned* local defs of the callee are what trace.
                callee = None
                fn = arg.func
                if isinstance(fn, ast.Name):
                    hits = self.lookup(fn.id, scope)
                    callee = hits[-1] if hits else None
                elif (isinstance(fn, ast.Attribute)
                      and isinstance(fn.value, ast.Name)
                      and fn.value.id == "self" and scope is not None
                      and scope.cls):
                    hits = self.lookup_method(scope.cls, fn.attr)
                    callee = hits[-1] if hits else None
                if callee is not None:
                    for f in self.returned_local_funcs(callee):
                        f.static_params |= static
                        f.params_traced = True
                        seeds.append((f, via))
            elif isinstance(arg, ast.Attribute) and \
                    isinstance(arg.value, ast.Name) and \
                    arg.value.id == "self" and scope is not None and \
                    scope.cls:
                for f in self.lookup_method(scope.cls, arg.attr):
                    f.static_params |= static
                    f.params_traced = True
                    seeds.append((f, via))

        # (a) calls to tracing transforms anywhere in the module
        for owner in [None] + self.funcs:
            nodes = (owner.own_nodes() if owner is not None
                     else self._module_level_nodes())
            for node in nodes:
                if not isinstance(node, ast.Call):
                    continue
                target = self.resolve(node.func)
                if target not in _TRACING_CALLS:
                    continue
                static = _static_argnames(node)
                for arg in node.args:
                    seed_arg(arg, owner, target, static)

        # (b) decorators: @jax.jit / @functools.partial(jax.jit, ...)
        for f in self.funcs:
            for deco in getattr(f.node, "decorator_list", []):
                target, static = self._decorator_trace(deco)
                if target:
                    f.static_params |= static
                    f.params_traced = True
                    seeds.append((f, target))

        # (c) registry/StepProgram builder convention: closures returned
        # by ``make_*`` functions are jitted by their (cross-module)
        # consumers — treat their bodies as traced.
        for f in self.funcs:
            if f.name.startswith("make_"):
                for ret in self.returned_local_funcs(f):
                    ret.params_traced = True
                    seeds.append((ret, "make_* builder"))

        # propagate: nested defs + locally-resolvable callees
        work = list(seeds)
        while work:
            f, via = work.pop()
            if f.traced:
                continue
            f.traced = True
            f.traced_via = via
            for nested in self.nested_funcs(f):
                work.append((nested, via))
            for node in f.own_nodes():
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                if isinstance(fn, ast.Name):
                    for g in self.lookup(fn.id, f):
                        work.append((g, via))
                elif (isinstance(fn, ast.Attribute)
                      and isinstance(fn.value, ast.Name)
                      and fn.value.id == "self" and f.cls):
                    for g in self.lookup_method(f.cls, fn.attr):
                        work.append((g, via))

    def _infer_param_taint(self) -> None:
        """Flow call-site argument taint into locally-resolvable callees
        (to fixpoint): a traced caller passing a traced value taints
        exactly the receiving parameter, so propagation-traced helpers
        get per-param precision instead of all-or-nothing."""
        changed = True
        while changed:
            changed = False
            for f in self.funcs:
                if not f.traced:
                    continue
                taint = Taint(self, f)
                for stmt in f.own_statements():
                    for node in stmt_exprs(stmt):
                        if isinstance(node, ast.Call):
                            changed |= self._flow_call(f, node, taint)
                    taint.advance(stmt)

    def _flow_call(self, caller: Func, call: ast.Call,
                   taint: "Taint") -> bool:
        fn = call.func
        callees: list = []
        if isinstance(fn, ast.Name):
            callees = self.lookup(fn.id, caller)
        elif (isinstance(fn, ast.Attribute)
              and isinstance(fn.value, ast.Name)
              and fn.value.id == "self" and caller.cls):
            callees = self.lookup_method(caller.cls, fn.attr)
        changed = False
        for g in callees:
            params = [p for p in g.params() if p != "self"]
            for i, arg in enumerate(call.args):
                if i < len(params) and taint.tainted(arg) and \
                        params[i] not in g.tainted_params:
                    g.tainted_params.add(params[i])
                    changed = True
            for kw in call.keywords:
                if kw.arg in params and taint.tainted(kw.value) and \
                        kw.arg not in g.tainted_params:
                    g.tainted_params.add(kw.arg)
                    changed = True
        return changed

    def _module_level_nodes(self) -> Iterator[ast.AST]:
        for stmt in _iter_own(self.tree.body):
            if isinstance(stmt, _FUNC_NODES):
                continue
            yield from _walk_no_funcs(stmt)

    def _decorator_trace(self, deco: ast.AST):
        """(canonical transform, static_argnames) if the decorator traces."""
        if self.resolve(deco) in _TRACING_CALLS:
            return self.resolve(deco), set()
        if isinstance(deco, ast.Call):
            target = self.resolve(deco.func)
            if target in _TRACING_CALLS:
                return target, _static_argnames(deco)
            if target == "functools.partial" and deco.args:
                inner = self.resolve(deco.args[0])
                if inner in _TRACING_CALLS:
                    return inner, _static_argnames(deco)
        return None, set()


def _static_argnames(call: ast.Call) -> set:
    """String static_argnames declared on a jit call node."""
    out: set = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and \
                        isinstance(node.value, str):
                    out.add(node.value)
    return out


# --------------------------------------------------------------------------
# taint: which expressions hold traced values inside a traced function
# --------------------------------------------------------------------------

# attribute reads that yield static (trace-time Python) metadata
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}
# builtins whose results are static under trace
_STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "type", "range",
                 "enumerate", "zip", "min", "max", "tuple", "list", "dict",
                 "sorted"}


class Taint:
    """Conservative, source-order taint for one traced function.

    Parameters (minus ``self`` and ``static_argnames``) start tainted;
    results of ``jax.*`` / ``jax.numpy.*`` calls are tainted; shape/dtype
    metadata escapes.  ``advance(stmt)`` folds a statement's assignments
    into the name set; ``tainted(expr)`` classifies an expression.  No
    fixpoint over loops — a name tainted later in the body is clean at
    the top of the loop, which under-reports rather than over-reports.
    """

    def __init__(self, model: ModuleModel, func: Func):
        self.model = model
        self.names: set = set()
        skip = {"self"} | set(func.static_params)
        if func.params_traced:
            for p in func.params():
                if p not in skip:
                    self.names.add(p)
        else:
            # propagation-traced: only call-site-tainted params
            self.names |= func.tainted_params - skip

    def advance(self, stmt: ast.stmt) -> None:
        targets: list = []
        if isinstance(stmt, ast.Assign):
            value, targets = stmt.value, stmt.targets
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            value, targets = stmt.value, [stmt.target]
        elif isinstance(stmt, ast.For):
            value, targets = stmt.iter, [stmt.target]
        else:
            return
        is_tainted = value is not None and self.tainted(value)
        for t in targets:
            for name in _target_names(t):
                if is_tainted:
                    self.names.add(name)
                else:
                    self.names.discard(name)

    def tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.tainted(node.value)
        if isinstance(node, ast.Call):
            target = self.model.resolve(node.func)
            if target in _STATIC_CALLS:
                return False
            if target and (target.startswith("jax.")
                           or target.startswith("jax.numpy")):
                return True
            if isinstance(node.func, ast.Attribute):
                # method on a tainted object (x.astype, x.reshape, ...)
                return self.tainted(node.func.value) or \
                    any(self.tainted(a) for a in node.args)
            return any(self.tainted(a) for a in node.args)
        if isinstance(node, ast.BinOp):
            return self.tainted(node.left) or self.tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.tainted(node.operand)
        if isinstance(node, ast.Compare):
            # identity/membership tests are structural (x is None,
            # "key" in pytree) — static at trace time
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in node.ops):
                return False
            return self.tainted(node.left) or \
                any(self.tainted(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(self.tainted(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return self.tainted(node.body) or self.tainted(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.tainted(node.value)
        return False


def _target_names(target: ast.AST) -> Iterator[str]:
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            yield from _target_names(e)
    elif isinstance(target, ast.Starred):
        yield from _target_names(target.value)


# --------------------------------------------------------------------------
# dotted-path helpers shared by rules
# --------------------------------------------------------------------------


def dotted(node: ast.AST) -> Optional[str]:
    """Surface dotted form of a Name/Attribute chain (``self._pages``),
    used where *identity* of a variable matters, not canonical imports."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def analyze_module(path: str, source: Optional[str] = None,
                   rules=None, is_test: Optional[bool] = None) -> list:
    """Parse + run rules over one module; returns non-suppressed findings
    (suppressed ones are dropped here, baselining happens in the CLI)."""
    from repro.analysis.rules import ALL_RULES
    if source is None:
        source = Path(path).read_text()
    model = ModuleModel(path, source, is_test=is_test)
    out = []
    for rule in (rules if rules is not None else ALL_RULES):
        for f in rule.check(model):
            if not model.suppressed(f):
                out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out

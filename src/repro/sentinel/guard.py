"""In-graph anomaly guard — detection + skip/backoff commit folded into
the step program (DESIGN.md §"Training sentinel").

:func:`guard_step` wraps the step program's pure callable (the same slot
:func:`repro.telemetry.probes.instrument_step` occupies) so that every
step additionally threads a :class:`SentinelState` pytree and returns a
verdict inside the metrics pytree under ``"sentinel"``:

* **non-finite guard** — any NaN/Inf in the loss, the updated params, or
  the updated optimizer moments;
* **spike guard** — global update norm ``‖Δθ‖`` against a bias-corrected
  EMA carried in ``SentinelState`` (armed after ``warmup`` clean steps;
  the fused path never materializes gradients, so the post-normalization
  update norm is the spike signal — it is what actually lands in the
  params);
* **trust guard** — per-GroupSpec trust ratios via
  :func:`repro.telemetry.probes.group_ratios` against
  ``SentinelSpec.trust_max`` (0 disables).

On an anomalous verdict the update is discarded **in-graph** with a
``jnp.where`` select over params AND the full ``OptState`` — moments and
step counter included — so a skipped step is a true no-op on the
optimizer.  This must happen in-graph: the runner donates the input
buffers, so by the time the host sees the verdict the pre-step state is
already gone.

Contract (asserted in ``tests/sentinel/``): constant structure — the
verdict, the committed state, and the state snapshot are computed every
step with the identical jaxpr (``cache_size() == 1``); the verdict rides
the runner's one bundled per-step ``device_get`` inside metrics (no new
host syncs, repro-lint R2); the EMA absorbs only clean steps, so one
anomaly cannot drag the reference level toward the anomaly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.sentinel.spec import SentinelSpec

_TINY = 1e-30

#: Metrics keys that snapshot the post-step device state exactly.  Every
#: value is a 0-d f32 whose payload survives the device→host→checkpoint
#: →device round trip bitwise (int32 and f32 are exact in binary64).
SNAPSHOT_KEYS = ("seen", "clean", "ema", "backoff", "skipped")


class SentinelState(NamedTuple):
    """Cross-step sentinel memory — five 0-d scalars.

    seen     executed-step counter (counts every pass through the guard,
             including skipped and replayed steps — the injection clock);
    clean    count of clean (committed) steps — the EMA's sample count;
    ema      EMA of the update norm over clean steps (spike reference);
    backoff  remaining clean steps of an active lr-backoff window;
    skipped  lifetime count of discarded updates.
    """

    seen: jnp.ndarray
    clean: jnp.ndarray
    ema: jnp.ndarray
    backoff: jnp.ndarray
    skipped: jnp.ndarray


def init_sentinel_state() -> SentinelState:
    return SentinelState(seen=jnp.zeros((), jnp.int32),
                         clean=jnp.zeros((), jnp.int32),
                         ema=jnp.zeros((), jnp.float32),
                         backoff=jnp.zeros((), jnp.int32),
                         skipped=jnp.zeros((), jnp.int32))


def state_from_snapshot(snap: dict) -> SentinelState:
    """Rebuild the device state from a host snapshot (the ``SNAPSHOT_KEYS``
    slice of a ``metrics["sentinel"]`` verdict, or checkpoint extra)."""
    return SentinelState(seen=jnp.asarray(int(snap["seen"]), jnp.int32),
                         clean=jnp.asarray(int(snap["clean"]), jnp.int32),
                         ema=jnp.asarray(float(snap["ema"]), jnp.float32),
                         backoff=jnp.asarray(int(snap["backoff"]), jnp.int32),
                         skipped=jnp.asarray(int(snap["skipped"]), jnp.int32))


def _float_leaves(tree):
    return [l for l in jax.tree.leaves(tree)
            if jnp.issubdtype(l.dtype, jnp.floating)]


def _all_finite(*trees):
    ok = jnp.bool_(True)
    for t in trees:
        for l in _float_leaves(t):
            ok = ok & jnp.all(jnp.isfinite(l))
    return ok


def _update_norm(p_old, p_new):
    """Global ‖Δθ‖ over float leaves (f32 accumulation)."""
    sq = jnp.zeros((), jnp.float32)
    for o, n in zip(_float_leaves(p_old), _float_leaves(p_new)):
        d = n.astype(jnp.float32) - o.astype(jnp.float32)
        sq = sq + jnp.sum(jnp.square(d))
    return jnp.sqrt(sq)


def guard_step(inner, *, opt, sspec: SentinelSpec, ospec=None, inject=None):
    """Wrap ``(params, opt_state, batch, hp) -> (params', opt_state',
    loss, metrics)`` into the 5-arg guarded form ``(params, opt_state,
    batch, hp, sent) -> (params', opt_state', loss, metrics, sent')``.

    ``ospec`` (an enabled ObservabilitySpec) folds the PR 9 optimizer-
    health probes in on the **committed** transition — probes describe
    what actually landed, so a skipped step reports zero update norms.
    ``inject`` (a :class:`repro.sentinel.inject.Injection`) poisons the
    batch/update in-graph, keyed on ``sent.seen`` — the fault-injection
    protocol the chaos harness drives.
    """
    decay = jnp.float32(sspec.ema_decay)
    use_trust = sspec.trust_max > 0.0 and opt is not None
    use_backoff = "backoff" in sspec.ladder

    def guarded(params, opt_state, batch, hp, sent):
        # --- backoff: transient lr scale-down, pure call-time data -----
        lr_scale = jnp.where(use_backoff & (sent.backoff > 0),
                             jnp.float32(sspec.backoff_scale),
                             jnp.float32(1.0))
        hp_eff = dict(hp)
        hp_eff["lr"] = hp["lr"] * lr_scale

        if inject is not None:
            batch = inject.poison_batch(batch, sent.seen)
        p2, s2, loss, metrics = inner(params, opt_state, batch, hp_eff)
        if inject is not None:
            p2, s2, loss = inject.poison_update(params, p2, s2, loss,
                                                sent.seen)

        # --- detection (constant structure, 0-d verdict scalars) -------
        nonfinite = ~(_all_finite(p2, s2) & jnp.all(jnp.isfinite(loss)))
        unorm = _update_norm(params, p2)

        n = sent.clean.astype(jnp.float32)
        ema_ref = sent.ema / jnp.maximum(1.0 - jnp.power(decay, n), _TINY)
        armed = sent.clean >= sspec.warmup
        # NaN unorm fails this comparison (NaN > x is False) — the
        # non-finite guard owns that case.
        spike = armed & (unorm > jnp.float32(sspec.spike_factor) * ema_ref)

        trust_worst = jnp.zeros((), jnp.float32)
        trust = jnp.bool_(False)
        if use_trust:
            from repro.telemetry.probes import group_ratios
            ratios = group_ratios(params, p2, opt)
            trust_worst = jnp.max(jnp.stack(list(ratios.values())))
            trust = trust_worst > jnp.float32(sspec.trust_max)

        anomaly = nonfinite | spike | trust
        keep = ~anomaly

        # --- commit: skip is a true no-op on params AND OptState -------
        sel = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(keep, a, b), new, old)
        p_out = sel(p2, params)
        s_out = sel(s2, opt_state)

        sent_out = SentinelState(
            seen=sent.seen + 1,
            clean=sent.clean + keep.astype(jnp.int32),
            # the EMA absorbs only clean steps — an anomaly must not drag
            # the reference toward itself
            ema=jnp.where(keep, decay * sent.ema + (1.0 - decay) * unorm,
                          sent.ema),
            backoff=(jnp.where(anomaly, jnp.int32(sspec.backoff_window),
                               jnp.maximum(sent.backoff - 1, 0))
                     if use_backoff else sent.backoff),
            skipped=sent.skipped + anomaly.astype(jnp.int32))

        f32 = lambda x: x.astype(jnp.float32)
        verdict = {
            "anomaly": f32(anomaly), "nonfinite": f32(nonfinite),
            "spike": f32(spike), "trust": f32(trust),
            "update_norm": unorm, "ema_ref": ema_ref,
            "trust_worst": trust_worst, "lr_scale": lr_scale,
            # post-step state snapshot: lets the host rebuild the device
            # state exactly (checkpoint extra → state_from_snapshot)
            "seen": f32(sent_out.seen), "clean": f32(sent_out.clean),
            "ema": sent_out.ema, "backoff": f32(sent_out.backoff),
            "skipped": f32(sent_out.skipped),
        }
        metrics = {**metrics, "sentinel": verdict}

        if ospec is not None:
            from repro.telemetry.probes import optimizer_health
            metrics["opt_health"] = optimizer_health(
                params, p_out, opt_state, s_out, hp_eff,
                opt=opt, ospec=ospec)

        return p_out, s_out, loss, metrics, sent_out

    return guarded

"""Host-side sentinel policy — budget, escalation, and data quarantine.

The guard (``guard.py``) already made the step safe in-graph: an
anomalous update was discarded before the host ever saw the verdict.
This module owns everything that happens *after* the verdict rides the
runner's one bundled ``device_get``:

* :class:`SentinelMonitor` — lifetime anomaly count against the budget,
  the consecutive-anomaly streak that escalates skip → rollback, the
  quarantined batch ranges, and an exact host mirror of the device
  :class:`~repro.sentinel.guard.SentinelState` (persisted in checkpoint
  extra so resume/rollback rebuild the device state bitwise);
* :class:`AnomalyBudgetExceeded` — deliberately a plain ``RuntimeError``,
  NOT one of the runner's retriable fault types: exhausting the budget
  must abort the run loudly, not trigger another restore cycle;
* :func:`quarantined_batch_iter` — the step-keyed data stream with
  quarantined ranges swapped to an alternate seed stream, so a rollback
  replay takes a different data path past the poison batch while every
  step outside the range stays bitwise on the primary stream.
"""
from __future__ import annotations

from repro.sentinel.guard import SNAPSHOT_KEYS
from repro.sentinel.spec import SentinelSpec

#: Seed offset of the quarantine replacement stream — disjoint from the
#: train stream (offset 0) and the eval stream (EVAL_SEED_OFFSET = 999).
QUARANTINE_SEED_OFFSET = 7777


class AnomalyBudgetExceeded(RuntimeError):
    """The run consumed its whole anomaly budget — fail loudly."""


class SentinelMonitor:
    """Host mirror of the sentinel: counters, escalation, quarantine.

    ``observe`` must run on every step's verdict (it keeps the device-
    state snapshot current for checkpointing); the runner acts on its
    boolean return *after* the hook pipeline has seen the step.
    """

    def __init__(self, sspec: SentinelSpec):
        self.spec = sspec
        self.anomalies = 0                 # lifetime count vs budget
        self.streak = 0                    # consecutive anomalies
        self.rollbacks = 0
        self.quarantined: list = []        # [lo, hi) step ranges
        self.snapshot: dict = {}           # last device-state snapshot

    # -- verdict intake ------------------------------------------------

    def observe(self, step: int, verdict: dict) -> bool:
        """Ingest one step's verdict; returns True when anomalous."""
        self.snapshot = {k: float(verdict[k]) for k in SNAPSHOT_KEYS}
        anomalous = verdict.get("anomaly", 0.0) > 0.0
        if anomalous:
            self.anomalies += 1
            self.streak += 1
        else:
            self.streak = 0
        return anomalous

    @staticmethod
    def classify(verdict: dict) -> str:
        """The dominant anomaly reason, in detection-priority order."""
        for reason in ("nonfinite", "spike", "trust"):
            if verdict.get(reason, 0.0) > 0.0:
                return reason
        return "unknown"

    # -- policy --------------------------------------------------------

    def exhausted(self) -> bool:
        return self.anomalies > self.spec.budget

    def wants_rollback(self) -> bool:
        return ("rollback" in self.spec.ladder
                and self.streak >= self.spec.rollback_after)

    def quarantine(self, lo: int, hi: int):
        """Mark steps [lo, hi) as quarantined and reset the streak (the
        replay takes a different data path, so the streak starts over)."""
        self.rollbacks += 1
        self.streak = 0
        if self.spec.quarantine and hi > lo:
            self.quarantined.append([int(lo), int(hi)])

    def is_quarantined(self, step: int) -> bool:
        return any(lo <= step < hi for lo, hi in self.quarantined)

    # -- persistence (checkpoint extra) --------------------------------

    def to_extra(self) -> dict:
        return {"anomalies": self.anomalies, "streak": self.streak,
                "rollbacks": self.rollbacks,
                "quarantined": [list(r) for r in self.quarantined],
                "state": dict(self.snapshot)}

    def load_extra(self, extra: dict):
        self.anomalies = int(extra.get("anomalies", 0))
        self.streak = int(extra.get("streak", 0))
        self.rollbacks = int(extra.get("rollbacks", 0))
        self.quarantined = [list(r) for r in extra.get("quarantined", [])]
        self.snapshot = dict(extra.get("state", {}))


def quarantined_batch_iter(spec, arch, start_step: int,
                           monitor: SentinelMonitor):
    """Step-keyed train stream with quarantined ranges substituted.

    Batches are a pure function of (spec, step), so substitution is
    exact: outside a quarantined range the primary stream's batch is
    yielded bitwise; inside, the batch comes from the same pipeline
    seeded with :data:`QUARANTINE_SEED_OFFSET` — deterministic across
    re-runs and resumes alike.
    """
    from repro.run.data import make_batch_iter
    primary = make_batch_iter(spec, arch, start_step)
    step = start_step
    while True:
        batch = next(primary)
        if monitor.is_quarantined(step):
            batch = next(make_batch_iter(
                spec, arch, step, seed_offset=QUARANTINE_SEED_OFFSET))
        yield batch
        step += 1

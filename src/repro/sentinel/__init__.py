"""Training sentinel — in-graph anomaly guards, policy ladder, and
fault-injection proof harness (DESIGN.md §"Training sentinel").

Detection lives in the step program (``guard.py``, constant structure,
zero steady-state recompiles), policy and quarantine on the host
(``policy.py``), and the deterministic fault injectors that prove the
whole loop in ``inject.py``.
"""
from repro.sentinel.guard import (SNAPSHOT_KEYS, SentinelState, guard_step,
                                  init_sentinel_state, state_from_snapshot)
from repro.sentinel.inject import INJECT_KINDS, Injection
from repro.sentinel.policy import (QUARANTINE_SEED_OFFSET,
                                   AnomalyBudgetExceeded, SentinelMonitor,
                                   quarantined_batch_iter)
from repro.sentinel.spec import LADDER_RUNGS, SentinelSpec

__all__ = [
    "SNAPSHOT_KEYS", "SentinelState", "guard_step", "init_sentinel_state",
    "state_from_snapshot", "INJECT_KINDS", "Injection",
    "QUARANTINE_SEED_OFFSET", "AnomalyBudgetExceeded", "SentinelMonitor",
    "quarantined_batch_iter", "LADDER_RUNGS", "SentinelSpec",
]

"""SentinelSpec — declarative configuration for the training sentinel.

One frozen dataclass rides :class:`repro.run.spec.RunSpec` (field
``sentinel``) and parameterises the whole detection → policy → recovery
stack:

* **detection thresholds** — spike EMA decay/warmup/factor and the
  per-group trust-ratio ceiling — consumed in-graph by
  :func:`repro.sentinel.guard.guard_step`;
* **policy ladder** — an ordered tuple of rungs drawn from
  ``("skip", "backoff", "rollback")``.  ``skip`` is mandatory and always
  first: every anomalous update is discarded in-graph before any host
  policy runs, so the moments can never be poisoned no matter what the
  host decides afterwards;
* **budget** — a lifetime anomaly allowance; exhausting it raises
  :class:`repro.sentinel.policy.AnomalyBudgetExceeded` (loud failure, not
  silent degradation).

This module is deliberately free of jax imports so ``run/spec.py`` can
import it without pulling in the numeric stack.
"""
from __future__ import annotations

import dataclasses

#: Valid policy rungs, in escalation order.
LADDER_RUNGS = ("skip", "backoff", "rollback")


@dataclasses.dataclass(frozen=True)
class SentinelSpec:
    """Anomaly-guard configuration (all fields have safe defaults).

    enabled        master switch; off keeps the 4-arg step signature and
                   adds zero overhead to the program.
    ladder         policy rungs, ``"skip"`` first.  ``backoff`` adds a
                   transient lr scale-down after each anomaly;
                   ``rollback`` restores the last-good checkpoint after
                   ``rollback_after`` consecutive anomalies and
                   quarantines the offending batch range.
    ema_decay      decay of the update-norm EMA used as the spike
                   reference.
    warmup         number of *clean* steps before the spike guard arms.
    spike_factor   anomaly when ``update_norm > spike_factor * ema``
                   (bias-corrected).
    trust_max      per-GroupSpec trust-ratio ceiling (0 disables the
                   trust guard).
    backoff_scale  lr multiplier while a backoff window is active.
    backoff_window number of clean steps a backoff persists.
    rollback_after consecutive anomalies that escalate skip → rollback.
    budget         lifetime anomaly allowance before the run aborts.
    quarantine     replay rolled-back steps from the quarantine data
                   stream instead of re-feeding the offending batches.
    """

    enabled: bool = False
    ladder: tuple = ("skip",)
    ema_decay: float = 0.9
    warmup: int = 5
    spike_factor: float = 10.0
    trust_max: float = 0.0
    backoff_scale: float = 0.1
    backoff_window: int = 8
    rollback_after: int = 3
    budget: int = 8
    quarantine: bool = True

    def __post_init__(self):
        ladder = tuple(self.ladder)
        object.__setattr__(self, "ladder", ladder)
        if not ladder or ladder[0] != "skip":
            raise ValueError(
                f"sentinel ladder must start with 'skip', got {ladder!r}")
        for rung in ladder:
            if rung not in LADDER_RUNGS:
                raise ValueError(
                    f"unknown sentinel rung {rung!r}; valid: {LADDER_RUNGS}")
        if len(set(ladder)) != len(ladder):
            raise ValueError(f"duplicate sentinel rungs in {ladder!r}")
        if not 0.0 < self.ema_decay < 1.0:
            raise ValueError(f"ema_decay must be in (0, 1), got {self.ema_decay}")
        if self.warmup < 1:
            raise ValueError(f"warmup must be >= 1, got {self.warmup}")
        if self.spike_factor <= 1.0:
            raise ValueError(
                f"spike_factor must be > 1, got {self.spike_factor}")
        if self.trust_max < 0.0:
            raise ValueError(f"trust_max must be >= 0, got {self.trust_max}")
        if not 0.0 < self.backoff_scale <= 1.0:
            raise ValueError(
                f"backoff_scale must be in (0, 1], got {self.backoff_scale}")
        if self.backoff_window < 1:
            raise ValueError(
                f"backoff_window must be >= 1, got {self.backoff_window}")
        if self.rollback_after < 1:
            raise ValueError(
                f"rollback_after must be >= 1, got {self.rollback_after}")
        if self.budget < 1:
            raise ValueError(f"budget must be >= 1, got {self.budget}")

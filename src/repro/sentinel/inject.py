"""In-graph fault injection — the proof harness for the sentinel.

An :class:`Injection` describes ONE deterministic fault: *what* to poison
(``kind``) and *when* (``at_step``, measured on ``SentinelState.seen``,
the executed-step clock).  The guard applies it in-graph via ``jnp.where``
keyed on ``seen == at_step`` — constant structure, zero recompiles, and
bitwise-reproducible on re-run.

Keying on ``seen`` rather than the data-step index is deliberate: ``seen``
counts every pass through the guard and is never rewound, so after a
rollback the replayed data step has a *different* ``seen`` and the fault
does not re-fire — an injected run always completes, which is exactly the
property the chaos tests assert.

Kinds:

``nan_grads`` / ``inf_grads``
    poison every float leaf of the updated params and moments — the
    fused path's equivalent of a NaN/Inf gradient (the gradient never
    materializes; its damage to the update does);
``nan_loss``
    poison only the reported loss;
``nan_batch``
    poison the float leaves of the input batch before the step runs;
``spike``
    scale the update ``Δθ`` by ``scale`` (finite, but large enough to
    trip the EMA spike guard).

Re-exported from :mod:`repro.fleet.chaos` so chaos scripts have one
import surface for kills + faults.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

INJECT_KINDS = ("nan_grads", "inf_grads", "nan_loss", "nan_batch", "spike")


@dataclasses.dataclass(frozen=True)
class Injection:
    """One deterministic in-graph fault.

    kind      one of :data:`INJECT_KINDS`;
    at_step   fires when ``SentinelState.seen == at_step`` (0-based
              executed-step clock, immune to rollback replay);
    scale     update multiplier for ``kind="spike"``.
    """

    kind: str = "nan_grads"
    at_step: int = 0
    scale: float = 100.0

    def __post_init__(self):
        if self.kind not in INJECT_KINDS:
            raise ValueError(
                f"unknown injection kind {self.kind!r}; valid: {INJECT_KINDS}")
        if self.at_step < 0:
            raise ValueError(f"at_step must be >= 0, got {self.at_step}")

    # -- in-graph application (called from the guard only) -------------

    def _fire(self, seen):
        return seen == jnp.int32(self.at_step)

    def poison_batch(self, batch, seen):
        if self.kind != "nan_batch":
            return batch
        fire = self._fire(seen)
        return _poison_floats(batch, fire, jnp.nan)

    def poison_update(self, p_old, p_new, s_new, loss, seen):
        fire = self._fire(seen)
        if self.kind in ("nan_grads", "inf_grads"):
            bad = jnp.nan if self.kind == "nan_grads" else jnp.inf
            return (_poison_floats(p_new, fire, bad),
                    _poison_floats(s_new, fire, bad), loss)
        if self.kind == "nan_loss":
            return p_new, s_new, jnp.where(fire, jnp.nan, loss)
        if self.kind == "spike":
            scaled = jax.tree.map(
                lambda o, n: jnp.where(
                    fire, o + jnp.asarray(self.scale, n.dtype) * (n - o), n)
                if jnp.issubdtype(n.dtype, jnp.floating) else n,
                p_old, p_new)
            return scaled, s_new, loss
        return p_new, s_new, loss          # nan_batch: handled upstream


def _poison_floats(tree, fire, value):
    return jax.tree.map(
        lambda l: jnp.where(fire, jnp.asarray(value, l.dtype), l)
        if jnp.issubdtype(l.dtype, jnp.floating) else l,
        tree)

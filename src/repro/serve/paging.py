"""Paged KV cache: fixed-size pages + host-side block-table allocator.

The device holds one shared pool of KV pages per layer
(``[L, num_pages, page_size, K, dh]``, see
``models.transformer.init_page_pool``).  Sequences own *logical* runs of
pages through a block table — an int32 row of page ids in logical order —
so a sequence's cache never needs to be contiguous and freed pages are
immediately reusable by newly admitted requests (vLLM's PagedAttention
layout, at repro scale).

Page 0 is reserved as the **scratch page**: frozen batch rows and masked
scatter writes are routed there, so the allocator never hands it out and
garbage written to it is never read (every read is masked by ``seq_lens``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


class OutOfPages(RuntimeError):
    """Raised when an allocation cannot be satisfied; callers preempt."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages needed to hold n_tokens."""
    return max(0, -(-n_tokens // page_size))


@dataclasses.dataclass
class PageAllocator:
    """Free-list allocator over the shared page pool (page 0 reserved)."""

    num_pages: int
    page_size: int

    def __post_init__(self):
        assert self.num_pages >= 2, "need at least scratch + 1 usable page"
        # pop() from the tail → pages are handed out in ascending id order,
        # which keeps smoke-test block tables readable.
        self._free = list(range(self.num_pages - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Atomically allocate n pages or raise OutOfPages."""
        if n > len(self._free):
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: list[int]) -> None:
        for p in pages:
            assert 0 < p < self.num_pages, p
            assert p not in self._free, f"double free of page {p}"
            self._free.append(p)


def build_block_tables(page_lists: list[list[int]],
                       max_pages_per_seq: int) -> np.ndarray:
    """Render per-slot page lists as the fixed-shape [B, P] device input.

    Unallocated tail entries point at the scratch page 0; they are never
    read because attention masks positions >= seq_len."""
    B = len(page_lists)
    table = np.zeros((B, max_pages_per_seq), np.int32)
    for i, pages in enumerate(page_lists):
        assert len(pages) <= max_pages_per_seq, (i, len(pages))
        table[i, :len(pages)] = pages
    return table

"""Request scheduler for the continuous-batching engine.

Owns the decode-slot table and the FIFO admission queue.  Between decode
chunks the engine asks the scheduler to

  * ``admit_next()`` queued requests into free slots (only when the page
    allocator can cover the request's prompt — admission is all-or-nothing
    so a half-admitted request never wedges the pool);
  * ``ensure_ahead()`` pages for the tokens the next chunk will write,
    preempting the most-recently-admitted request when the pool is
    exhausted (preempted requests release every page and are requeued at
    the *front*; on re-admission they prefill over prompt + generated
    tokens, which reproduces the decode state exactly);
  * ``finish()`` sequences whose done-mask bit is set (EOS or budget
    exhausted), returning their pages to the allocator;
  * ``expire()`` requests whose TTL deadline has passed — timed-out
    sequences are evicted at the chunk boundary (queued ones are simply
    dropped), their pages go back to the pool immediately, and the
    ``timed_out`` lifetime counter feeds the serve gauges.

The scheduler is pure host-side bookkeeping — it never touches device
arrays — so its policies are unit-testable without compiling anything.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

from repro.serve.paging import OutOfPages, PageAllocator, pages_for

QUEUED, RUNNING, FINISHED = "queued", "running", "finished"
TIMED_OUT = "timed_out"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = dataclasses.field(default_factory=list)
    pages: list[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    status: str = QUEUED
    n_cached: int = 0          # tokens currently in the KV cache
    n_preempted: int = 0
    deadline_s: Optional[float] = None   # absolute clock time; None = no TTL

    @property
    def tokens(self) -> list[int]:
        """Prompt + generated so far — what a (re-)prefill runs over."""
        return self.prompt + self.out

    @property
    def budget(self) -> int:
        """Tokens this request may still emit."""
        return self.max_new_tokens - len(self.out)

    @property
    def max_total_len(self) -> int:
        # the final emitted token is never written to the cache, hence -1
        return len(self.prompt) + self.max_new_tokens - 1


class Scheduler:
    def __init__(self, n_slots: int, allocator: PageAllocator,
                 max_pages_per_seq: int):
        self.n_slots = n_slots
        self.alloc = allocator
        self.max_pages_per_seq = max_pages_per_seq
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self._admit_counter = 0
        self._admit_idx: dict[int, int] = {}   # rid -> admission order
        # lifetime counters sampled by the serve telemetry gauges
        self.counters = {"admitted": 0, "preempted": 0, "finished": 0,
                         "evicted_pages": 0, "timed_out": 0}

    # ---- queries ----------------------------------------------------------
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    def running(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    def free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def page_lists(self) -> list[list[int]]:
        return [r.pages if r is not None else [] for r in self.slots]

    # ---- lifecycle --------------------------------------------------------
    def submit(self, req: Request) -> None:
        max_len = self.max_pages_per_seq * self.alloc.page_size
        if req.max_total_len > max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"max_new {req.max_new_tokens} exceeds per-seq capacity "
                f"{max_len}")
        # a request must fit the pool *alone*, else admission (or the
        # self-preemption cycle) can never make progress -> run() livelock
        pool = self.alloc.num_pages - 1   # page 0 is scratch
        need = pages_for(req.max_total_len, self.alloc.page_size)
        if need > pool:
            raise ValueError(
                f"request {req.rid}: needs up to {need} pages but the "
                f"pool only has {pool}")
        self.queue.append(req)

    def admit_next(self) -> Optional[Request]:
        """Admit the head-of-queue request if a slot + prompt pages exist."""
        slot = self.free_slot()
        if slot is None or not self.queue:
            return None
        req = self.queue[0]
        need = pages_for(len(req.tokens), self.alloc.page_size)
        if need > self.alloc.n_free:
            return None
        self.queue.popleft()
        req.pages = self.alloc.alloc(need)
        req.slot = slot
        req.status = RUNNING
        req.n_cached = 0
        self.slots[slot] = req
        self._admit_idx[req.rid] = self._admit_counter
        self._admit_counter += 1
        self.counters["admitted"] += 1
        return req

    def ensure_ahead(self, req: Request, lookahead: int) -> None:
        """Grow req's page list to cover `lookahead` more cached tokens.

        Raises OutOfPages (caller decides whom to preempt)."""
        target = min(req.n_cached + lookahead, req.max_total_len)
        need = pages_for(target, self.alloc.page_size) - len(req.pages)
        if need > 0:
            req.pages.extend(self.alloc.alloc(need))

    def preempt_latest(self) -> Optional[Request]:
        """Evict the most-recently-admitted running request; requeue it at
        the front so it is the first to come back when pages free up."""
        running = self.running()
        if not running:
            return None
        victim = max(running, key=lambda r: self._admit_idx[r.rid])
        self.counters["preempted"] += 1
        self.counters["evicted_pages"] += len(victim.pages)
        self.alloc.free(victim.pages)
        self.slots[victim.slot] = None
        victim.pages = []
        victim.slot = None
        victim.status = QUEUED
        victim.n_cached = 0
        victim.n_preempted += 1
        self.queue.appendleft(victim)
        return victim

    def expire(self, now: float) -> list[Request]:
        """Evict every request whose ``deadline_s`` has passed.  Running
        victims release all pages and their slot; queued victims are just
        dropped.  Partial output stays on the request (``req.out``) so the
        caller can still hand back what was generated.  Returns the
        newly timed-out requests."""
        expired = []
        for req in self.running():
            if req.deadline_s is not None and now >= req.deadline_s:
                self.alloc.free(req.pages)
                self.slots[req.slot] = None
                req.pages = []
                req.slot = None
                req.status = TIMED_OUT
                self.counters["timed_out"] += 1
                expired.append(req)
        for req in [r for r in self.queue
                    if r.deadline_s is not None and now >= r.deadline_s]:
            self.queue.remove(req)
            req.status = TIMED_OUT
            self.counters["timed_out"] += 1
            expired.append(req)
        return expired

    def finish(self, req: Request) -> None:
        """EOS / budget exhausted: release pages, free the slot."""
        self.alloc.free(req.pages)
        self.slots[req.slot] = None
        req.pages = []
        req.slot = None
        req.status = FINISHED
        self.counters["finished"] += 1

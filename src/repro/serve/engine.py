"""Batched serving engine: prefill once, decode greedily with a KV cache.

Minimal but real: request batching with right-padding, jitted prefill and
decode steps, greedy/temperature sampling, per-sequence stop handling.
The decode step is the same function the dry-run lowers for the
decode_32k / long_500k cells.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    max_len: int = 256
    temperature: float = 0.0      # 0 = greedy
    eos_id: int = -1              # -1 = never stop early
    seed: int = 0


class Engine:
    def __init__(self, arch, params, scfg: ServeConfig):
        self.arch = arch
        self.params = params
        self.scfg = scfg
        self._prefill = jax.jit(arch.make_prefill_step())
        self._decode = jax.jit(arch.make_decode_step(),
                               donate_argnums=(1,))

    def generate(self, prompts: list[list[int]], *,
                 extras: Optional[dict] = None) -> list[list[int]]:
        """prompts: batch of token-id lists (right-padded internally)."""
        scfg = self.scfg
        B = len(prompts)
        Lmax = max(len(p) for p in prompts)
        toks = np.zeros((B, Lmax), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p  # left-aligned; pad tail with 0
        batch = {"tokens": jnp.asarray(toks)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})

        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(scfg.seed)
        out = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        tok = self._sample(logits, key)
        for t in range(scfg.max_new_tokens):
            for i in range(B):
                if not done[i]:
                    out[i].append(int(tok[i]))
                    if int(tok[i]) == scfg.eos_id:
                        done[i] = True
            if done.all():
                break
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": tok[:, None]})
            key = jax.random.fold_in(key, t)
            tok = self._sample(logits, key)
        return out

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

"""Serving engines: legacy static batching + continuous batching over
paged KV.

``Engine`` is the original static-batch path (kept for the dry-run
lowering and as the benchmark baseline), fixed so the decode loop makes a
*single* host transfer per step with a device-side done mask instead of a
per-sequence ``int(tok[i])`` round-trip.

``PagedEngine`` is the production-shaped path:

  * a shared KV **page pool** on device (``serve/paging.py`` allocates,
    ``models/*.make_paged_decode_step`` reads it through the
    ``kernels/decode_attention`` paged Pallas kernel on TPU, or the jnp
    gather oracle on CPU);
  * a **scheduler** (``serve/scheduler.py``) that admits / preempts /
    retires sequences between decode chunks — requests join and leave the
    batch mid-flight;
  * **bucketed prefill**: prompts are right-padded to power-of-two length
    buckets so warmup compiles a bounded set of shapes, and prefill K/V is
    scattered into the page pool by a per-bucket jitted write;
  * one **fixed-shape jitted decode chunk**: ``chunk`` decode steps run
    on device under ``lax.scan`` with a done-mask; the host syncs once per
    chunk boundary (one ``device_get`` of tokens + state), so steady-state
    decoding never recompiles and never blocks per token.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.paging import (OutOfPages, PageAllocator,
                                build_block_tables)
from repro.serve.scheduler import RUNNING, Request, Scheduler
from repro.telemetry.serve import ServeTelemetry


def _sample_tokens(logits, key, temperature):
    """Greedy (temperature<=0) or temperature sampling -> int32 ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    max_len: int = 256
    temperature: float = 0.0      # 0 = greedy
    eos_id: int = -1              # -1 = never stop early
    seed: int = 0


class Engine:
    """Legacy static-batch engine: prefill once, decode greedily."""

    def __init__(self, arch, params, scfg: ServeConfig):
        self.arch = arch
        self.params = params
        self.scfg = scfg
        self._prefill = jax.jit(arch.make_prefill_step())
        self._decode = jax.jit(arch.make_decode_step(),
                               donate_argnums=(1,))
        eos = scfg.eos_id

        def sample_step(logits, key, tok_prev, done):
            tok = self._sample(logits, key)
            tok = jnp.where(done, tok_prev, tok)   # freeze finished rows
            if eos >= 0:
                done = done | (tok == eos)
            return tok, done

        self._sample_step = jax.jit(sample_step)

    def generate(self, prompts: list[list[int]], *,
                 extras: Optional[dict] = None) -> list[list[int]]:
        """prompts: batch of token-id lists (right-padded internally)."""
        scfg = self.scfg
        B = len(prompts)
        Lmax = max(len(p) for p in prompts)
        toks = np.zeros((B, Lmax), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p  # left-aligned; pad tail with 0
        batch = {"tokens": jnp.asarray(toks)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})

        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(scfg.seed)
        done0 = jnp.zeros((B,), bool)
        tok, done = self._sample_step(logits, key, jnp.zeros((B,), jnp.int32),
                                      done0)
        out = [[] for _ in range(B)]
        emitted_done = np.zeros(B, bool)
        for t in range(scfg.max_new_tokens):
            # ONE host sync per decode step: tokens + done mask together.
            tok_h, done_h = jax.device_get((tok, done))
            for i in range(B):
                if not emitted_done[i]:
                    out[i].append(int(tok_h[i]))
            emitted_done = done_h
            if emitted_done.all() or t == scfg.max_new_tokens - 1:
                break
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": tok[:, None]})
            key = jax.random.fold_in(key, t)
            tok, done = self._sample_step(logits, key, tok, done)
        return out

    def _sample(self, logits, key):
        return _sample_tokens(logits, key, self.scfg.temperature)


# ==========================================================================
# Continuous batching over paged KV
# ==========================================================================

@dataclasses.dataclass
class PagedServeConfig:
    page_size: int = 16
    num_pages: int = 128          # shared pool size (incl. scratch page 0)
    max_batch: int = 4            # decode slots
    max_pages_per_seq: int = 16   # block-table width P
    chunk: int = 8                # decode steps between host syncs
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 = greedy
    eos_id: int = -1
    seed: int = 0
    bucket_min: int = 16          # smallest prefill bucket
    use_kernel: Optional[bool] = None   # None = Pallas kernel on TPU only
    interpret: bool = False             # Pallas interpret mode (tests)
    telemetry_path: Optional[str] = None  # serve-gauge JSONL stream
    telemetry_every: int = 1            # sample cadence in chunks
    ttl_s: float = 0.0                  # default request TTL; 0 = none


def _bucket_len(n: int, lo: int) -> int:
    b = max(lo, 1)
    while b < n:
        b *= 2
    return b


class PagedEngine:
    def __init__(self, arch, params, scfg: PagedServeConfig, *,
                 clock=time.monotonic):
        assert arch.supports_paged_serving(), arch.arch_id
        self.arch = arch
        self.params = params
        self.scfg = scfg
        # injectable monotonic clock: TTL tests advance a fake clock
        # instead of sleeping
        self.clock = clock
        B, P, ps = scfg.max_batch, scfg.max_pages_per_seq, scfg.page_size

        self.allocator = PageAllocator(scfg.num_pages, ps)
        self.scheduler = Scheduler(B, self.allocator, P)
        self._rid = itertools.count()
        self.requests: dict[int, Request] = {}
        # gauges read only host bookkeeping (allocator/scheduler state),
        # so sampling never adds a device sync to the serving hot path
        self.telemetry = (ServeTelemetry(scfg.telemetry_path,
                                         every=scfg.telemetry_every)
                          if scfg.telemetry_path else None)

        # --- device state -------------------------------------------------
        self._pages = arch.init_page_pool(scfg.num_pages, ps)
        self._key = jax.random.PRNGKey(scfg.seed)
        self._prefill_count = 0
        # host mirrors of the per-slot decode state (refreshed each chunk)
        self._tok = np.zeros(B, np.int32)
        self._n = np.zeros(B, np.int32)        # tokens in cache
        self._budget = np.zeros(B, np.int32)   # tokens still to emit
        self._done = np.ones(B, bool)          # empty slots are "done"

        # --- jitted programs ----------------------------------------------
        self._prefill = jax.jit(arch.make_prefill_kv_step())
        self._decode_chunk = jax.jit(
            self._make_chunk_fn(), donate_argnums=(1,))
        self._scatter = jax.jit(self._scatter_fn, donate_argnums=(0,))
        temp = scfg.temperature
        self._sample_jit = jax.jit(
            lambda logits, key: _sample_tokens(logits, key, temp))

    # ------------------------------------------------------------------ API
    def submit(self, prompt: list[int],
               max_new_tokens: Optional[int] = None,
               ttl_s: Optional[float] = None) -> int:
        """Queue a request; it joins the running batch at the next chunk
        boundary (mid-flight admission). Returns the request id.

        ``ttl_s`` overrides ``scfg.ttl_s`` for this request; a request
        still unfinished when its deadline passes is evicted at the next
        chunk boundary (status ``timed_out``, pages reclaimed, partial
        output kept)."""
        if max_new_tokens is None:
            max_new_tokens = self.scfg.max_new_tokens
        if ttl_s is None:
            ttl_s = self.scfg.ttl_s
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens,
                      deadline_s=(self.clock() + ttl_s if ttl_s > 0
                                  else None))
        self.requests[req.rid] = req
        self.scheduler.submit(req)
        return req.rid

    def generate(self, prompts: list[list[int]],
                 max_new_tokens: Optional[int] = None) -> list[list[int]]:
        """Convenience: submit a batch, run to completion, return outputs
        in submission order."""
        rids = [self.submit(p, max_new_tokens) for p in prompts]
        self.run()
        return [self.requests[r].out for r in rids]

    def run(self) -> None:
        while self.scheduler.has_work():
            self.step()
        if self.telemetry is not None:
            self.telemetry.sample(self, force=True)

    def output(self, rid: int) -> list[int]:
        return self.requests[rid].out

    def decode_compile_count(self) -> int:
        """Number of compiled decode-chunk executables (recompile probe)."""
        return self._decode_chunk._cache_size()

    def prefill_compile_count(self) -> int:
        return self._prefill._cache_size()

    def warmup(self, prompt_lens: list[int]) -> None:
        """Compile the decode chunk + the whole pow-2 prefill-bucket ladder
        spanning prompt_lens, without touching live state."""
        lo = _bucket_len(min(prompt_lens), self.scfg.bucket_min)
        hi = _bucket_len(max(prompt_lens), self.scfg.bucket_min)
        buckets, b = [], lo
        while b <= hi:
            buckets.append(b)
            b *= 2
        for b in buckets:
            batch = {"tokens": jnp.zeros((1, b), jnp.int32),
                     "length": jnp.ones((1,), jnp.int32)}
            logits, k, v = self._prefill(self.params, batch)
            bt_row = jnp.zeros((self.scfg.max_pages_per_seq,), jnp.int32)
            self._pages = self._scatter(self._pages, k, v, bt_row,
                                        jnp.zeros((), jnp.int32))
            jax.block_until_ready(logits)
        # all slots done=True → every write is routed to the scratch page
        self._run_chunk()

    # ---------------------------------------------------------- scheduling
    def step(self) -> None:
        """One scheduling round: expire, admit, decode one chunk, retire."""
        if self.scheduler.expire(self.clock()):
            # deactivate the freed slots before the next chunk runs
            for i, r in enumerate(self.scheduler.slots):
                if r is None:
                    self._done[i] = True
        self._admit_all()
        if not self.scheduler.running():
            return
        self._ensure_ahead_all()
        t0 = time.perf_counter()
        toks = self._run_chunk()
        if self.telemetry is not None:
            self.telemetry.note_decode(time.perf_counter() - t0)
            # sample before _collect retires finished sequences, so the
            # gauge sees the pool pressure the chunk actually ran under
            self.telemetry.sample(self)
        self._collect(toks)

    def _admit_all(self) -> None:
        while True:
            req = self.scheduler.admit_next()
            if req is None:
                return
            self._start(req)

    def _start(self, req: Request) -> None:
        """(Re-)prefill req's tokens, scatter K/V into its pages, sample
        the first new token, and activate its slot."""
        scfg = self.scfg
        t0 = time.perf_counter()
        tokens = req.tokens
        n = len(tokens)
        bucket = _bucket_len(n, scfg.bucket_min)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = tokens
        logits, k, v = self._prefill(
            self.params, {"tokens": jnp.asarray(toks),
                          "length": jnp.asarray([n], jnp.int32)})
        self._prefill_count += 1
        bt_row = np.zeros((scfg.max_pages_per_seq,), np.int32)
        bt_row[:len(req.pages)] = req.pages
        self._pages = self._scatter(self._pages, k, v,
                                    jnp.asarray(bt_row),
                                    jnp.asarray(n, jnp.int32))
        key = jax.random.fold_in(self._key, 2 ** 20 + self._prefill_count)
        t0_tok = int(jax.device_get(self._sample_jit(logits, key))[0])
        if self.telemetry is not None:
            self.telemetry.note_prefill(time.perf_counter() - t0)
        if req.max_new_tokens > 0:
            req.out.append(t0_tok)
        req.n_cached = n
        s = req.slot
        if (scfg.eos_id >= 0 and t0_tok == scfg.eos_id) or req.budget <= 0:
            self.scheduler.finish(req)
            self._done[s] = True
            return
        self._tok[s] = t0_tok
        self._n[s] = n
        self._budget[s] = req.budget
        self._done[s] = False

    def _ensure_ahead_all(self) -> None:
        """Guarantee every running sequence has pages for the next chunk's
        writes, preempting the youngest sequences on pool exhaustion."""
        for req in sorted(self.scheduler.running(),
                          key=lambda r: self.scheduler._admit_idx[r.rid]):
            if req.status != RUNNING:
                continue   # preempted by an earlier iteration
            while True:
                try:
                    self.scheduler.ensure_ahead(req, self.scfg.chunk)
                    break
                except OutOfPages:
                    victim = self.scheduler.preempt_latest()
                    assert victim is not None
                    # deactivate every slot without a running request
                    for i, r in enumerate(self.scheduler.slots):
                        if r is None:
                            self._done[i] = True
                    if victim is req:
                        break

    def _run_chunk(self) -> np.ndarray:
        """Execute one fixed-shape jitted decode chunk; single host sync."""
        tables = build_block_tables(self.scheduler.page_lists(),
                                    self.scfg.max_pages_per_seq)
        self._pages, tok, n, budget, done, self._key, toks = (
            self._decode_chunk(
                self.params, self._pages,
                jnp.asarray(self._tok), jnp.asarray(self._n),
                jnp.asarray(self._budget), jnp.asarray(self._done),
                self._key, jnp.asarray(tables)))
        # ONE transfer per chunk boundary: all post-chunk state together.
        # repro-lint: disable=R2 — this IS the sanctioned single sync.
        tok, n, budget, done, toks = jax.device_get(
            (tok, n, budget, done, toks))
        # device_get returns read-only views; admissions mutate these
        self._tok, self._n = np.array(tok), np.array(n)
        self._budget, self._done = np.array(budget), np.array(done)
        return toks

    def _collect(self, toks: np.ndarray) -> None:
        """Append emitted tokens; retire finished sequences (frees pages)."""
        for req in list(self.scheduler.running()):
            s = req.slot
            req.out.extend(int(t) for t in toks[s] if t >= 0)
            req.n_cached = int(self._n[s])
            if self._done[s]:
                self.scheduler.finish(req)

    # ------------------------------------------------------------- jitted
    def _make_chunk_fn(self):
        scfg = self.scfg
        decode = self.arch.make_paged_decode_step(
            use_kernel=scfg.use_kernel, interpret=scfg.interpret)
        eos, temp, T = scfg.eos_id, scfg.temperature, scfg.chunk

        def chunk(params, pages, tok, n, budget, done, key, tables):
            def one(carry, _):
                pages, tok, n, budget, done, key = carry
                emit = ~done
                logits, pages = decode(params, pages, {
                    "tokens": tok[:, None], "block_tables": tables,
                    "seq_lens": n, "emit": emit})
                key, sub = jax.random.split(key)
                nxt = _sample_tokens(logits, sub, temp)
                nxt = jnp.where(emit, nxt, tok)
                n = n + emit
                budget = budget - emit
                newly_done = emit & ((nxt == eos) if eos >= 0
                                     else jnp.zeros_like(emit))
                newly_done = newly_done | (emit & (budget <= 0))
                done = done | newly_done
                out_t = jnp.where(emit, nxt, -1)   # -1 = nothing emitted
                return (pages, nxt, n, budget, done, key), out_t

            (pages, tok, n, budget, done, key), toks = jax.lax.scan(
                one, (pages, tok, n, budget, done, key), None, length=T)
            return pages, tok, n, budget, done, key, toks.T   # toks: [B,T]

        return chunk

    @staticmethod
    def _scatter_fn(pages, k, v, bt_row, length):
        """Write prefill K/V ([L,1,S,K,dh]) into the page pool along
        bt_row; positions >= length land on the scratch page."""
        ps = pages["k"].shape[2]
        P = bt_row.shape[0]
        S = k.shape[2]
        j = jnp.arange(S)
        valid = j < length
        pidx = jnp.where(valid, bt_row[jnp.minimum(j // ps, P - 1)], 0)
        slot = jnp.where(valid, j % ps, 0)
        return {"k": pages["k"].at[:, pidx, slot].set(k[:, 0]),
                "v": pages["v"].at[:, pidx, slot].set(v[:, 0])}

"""Training loop: fused (LOMO/AdaLomo) or unfused (AdamW/Adafactor) steps,
LOMO-style microbatching, eval, checkpoint/resume, fault hooks.

Microbatching note (DESIGN.md): classic gradient accumulation materializes
the full gradient pytree — exactly what LOMO exists to avoid.  The fused
path therefore does *sequential per-microbatch updates* (the paper trains
with per-device batches small enough to fit, scaled out with ZeRO-3); the
unfused path supports standard accumulation for the baselines.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import optimizers as opt_lib
from repro.core.api import Opt, no_decay_1d
from repro.train.fault import Heartbeat, StragglerMonitor, retrying
from repro.train.schedules import constant, warmup_cosine


@dataclasses.dataclass
class TrainConfig:
    optimizer: str = "adalomo"
    lr: float = 5e-4
    total_steps: int = 100
    warmup_frac: float = 0.03
    schedule: str = "cosine"          # "cosine" | "constant"
    fused: bool = True                # LOMO-style fused backward
    microbatches: int = 1
    eval_every: int = 0
    ckpt_every: int = 0
    ckpt_dir: Optional[str] = None
    heartbeat_timeout_s: float = 0.0  # 0 = disabled
    log_every: int = 10
    # Static/rule-construction kwargs forwarded to the registry factory
    # (backend=, cfg=, default hparams ...).
    opt_kwargs: dict = dataclasses.field(default_factory=dict)
    # Extra *dynamic* hyperparameters passed with the per-step lr (e.g.
    # {"weight_decay": 0.1}); schedulable without recompiles (Opt v2).
    hparams: dict = dataclasses.field(default_factory=dict)
    # Param groups: () for none.  None = the paper-standard default of
    # no weight decay on 1-D tensors (only active for rules with a
    # weight_decay hparam, where wd=0 makes it a no-op).
    groups: Optional[tuple] = None


class Trainer:
    """Drives one arch (from the registry) through training."""

    def __init__(self, arch, tcfg: TrainConfig, *, mesh=None,
                 log_fn: Callable[[str], None] = print):
        self.arch = arch
        self.tcfg = tcfg
        self.mesh = mesh
        self.log = log_fn
        rule = opt_lib.get_rule(tcfg.optimizer, **tcfg.opt_kwargs)
        groups = tcfg.groups
        if groups is None:
            groups = ((no_decay_1d(),)
                      if "weight_decay" in rule.hparams else ())
        self.opt = Opt(rule, groups=groups)
        self.lr_fn = (warmup_cosine(tcfg.lr, tcfg.total_steps,
                                    tcfg.warmup_frac)
                      if tcfg.schedule == "cosine" else constant(tcfg.lr))
        self.straggler = StragglerMonitor()
        self._build_step()

    # ------------------------------------------------------------------
    def _build_step(self):
        tcfg = self.tcfg
        if tcfg.fused:
            step_fn = self.arch.make_fused_train_step(self.opt)

            def one_step(params, opt_state, batch, hp):
                return step_fn(params, opt_state, batch, hparams=hp)

            if tcfg.microbatches > 1:
                inner = one_step

                def one_step(params, opt_state, batch, hp):  # noqa: F811
                    # LOMO-style: sequential updates per microbatch.
                    mb = jax.tree.map(
                        lambda x: x.reshape((tcfg.microbatches,
                                             x.shape[0] // tcfg.microbatches)
                                            + x.shape[1:]), batch)

                    def body(carry, b):
                        p, s = carry
                        p, s, loss, metrics = inner(p, s, b, hp)
                        return (p, s), (loss, metrics)

                    (params, opt_state), (losses, metrics) = jax.lax.scan(
                        body, (params, opt_state), mb)
                    return (params, opt_state, losses.mean(),
                            jax.tree.map(lambda m: m.mean(), metrics))

            self._step = jax.jit(one_step, donate_argnums=(0, 1))
        else:
            loss_fn = self.arch.make_loss_fn()

            def one_step(params, opt_state, batch, hp):
                if tcfg.microbatches > 1:
                    mb = jax.tree.map(
                        lambda x: x.reshape((tcfg.microbatches,
                                             x.shape[0] // tcfg.microbatches)
                                            + x.shape[1:]), batch)

                    def body(g_acc, b):
                        (loss, metrics), g = jax.value_and_grad(
                            loss_fn, has_aux=True)(params, b)
                        return jax.tree.map(jnp.add, g_acc, g), (loss, metrics)

                    g0 = jax.tree.map(jnp.zeros_like, params)
                    grads, (losses, metrics) = jax.lax.scan(body, g0, mb)
                    grads = jax.tree.map(
                        lambda g: g / tcfg.microbatches, grads)
                    loss = losses.mean()
                    metrics = jax.tree.map(lambda m: m.mean(), metrics)
                else:
                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, batch)
                params2, opt2 = self.opt.step(params, grads, opt_state, hp)
                return params2, opt2, loss, metrics

            self._step = jax.jit(one_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def init(self, seed: int = 0):
        params = self.arch.init_params(jax.random.PRNGKey(seed))
        opt_state = self.opt.init(params)
        return params, opt_state

    def hparams_at(self, step: int) -> dict:
        """The dynamic hparams pytree for (1-based) ``step`` — scheduled lr
        plus any TrainConfig extras; same structure every step, so the
        jitted train step never recompiles.  The schedule is authoritative
        for lr: set it via TrainConfig.lr/schedule, not tcfg.hparams."""
        return {**self.tcfg.hparams, "lr": self.lr_fn(step)}

    def fit(self, params, opt_state, batch_iter, *, start_step: int = 0,
            eval_iter=None, ckpt_manager=None) -> dict:
        tcfg = self.tcfg
        history = {"step": [], "loss": [], "accuracy": [], "lr": [],
                   "eval_loss": [], "eval_step": []}
        hb = None
        if tcfg.heartbeat_timeout_s > 0:
            hb = Heartbeat(tcfg.heartbeat_timeout_s,
                           on_stall=lambda: self.log("HEARTBEAT STALL"))
            hb.start()

        step_callable = retrying(
            self._step,
            on_failure=lambda a, e: self.log(f"step retry {a}: {e}"))

        t_last = time.time()
        for step in range(start_step, tcfg.total_steps):
            batch = next(batch_iter)
            batch = jax.tree.map(jnp.asarray, batch)
            hp = self.hparams_at(step + 1)
            lr = hp["lr"]
            params, opt_state, loss, metrics = step_callable(
                params, opt_state, batch, hp)
            dt = time.time() - t_last
            t_last = time.time()
            self.straggler.observe(step, dt)
            if hb:
                hb.beat()
            if tcfg.log_every and (step % tcfg.log_every == 0
                                   or step == tcfg.total_steps - 1):
                self.log(f"step {step:5d} loss {float(loss):.4f} "
                         f"acc {float(metrics['accuracy']):.3f} "
                         f"lr {float(lr):.2e} ({dt*1e3:.0f} ms)")
            history["step"].append(step)
            history["loss"].append(float(loss))
            history["accuracy"].append(float(metrics["accuracy"]))
            history["lr"].append(float(lr))
            if (eval_iter is not None and tcfg.eval_every
                    and (step + 1) % tcfg.eval_every == 0):
                ev = self.evaluate(params, eval_iter)
                history["eval_loss"].append(ev["loss"])
                history["eval_step"].append(step)
                self.log(f"  eval loss {ev['loss']:.4f} "
                         f"ppl {ev['ppl']:.2f} acc {ev['accuracy']:.3f}")
            if (ckpt_manager is not None and tcfg.ckpt_every
                    and (step + 1) % tcfg.ckpt_every == 0):
                ckpt_manager.save(step + 1, (params, opt_state),
                                  extra={"data_step": step + 1})
        if hb:
            hb.stop()
        if ckpt_manager is not None:
            ckpt_manager.wait()
        return {"params": params, "opt_state": opt_state,
                "history": history}

    def evaluate(self, params, eval_iter, n_batches: int = 4) -> dict:
        loss_fn = getattr(self, "_eval_fn", None)
        if loss_fn is None:
            loss_fn = jax.jit(self.arch.make_loss_fn())
            self._eval_fn = loss_fn
        tot, acc = 0.0, 0.0
        for _ in range(n_batches):
            batch = jax.tree.map(jnp.asarray, next(eval_iter))
            loss, metrics = loss_fn(params, batch)
            tot += float(loss)
            acc += float(metrics["accuracy"])
        tot /= n_batches
        return {"loss": tot, "ppl": float(jnp.exp(tot)),
                "accuracy": acc / n_batches}

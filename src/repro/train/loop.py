"""Legacy Trainer — now a thin compatibility shim over the Run API.

The loop-construction logic that used to live here (fused/unfused ×
microbatch-scan matrix, eval, checkpoint cadence, heartbeat/straggler
wiring) moved to ``repro.run``: ``build_step_program`` owns the step
matrix, the hook pipeline owns the policies, and ``run()`` drives the
loop.  ``Trainer``/``TrainConfig`` remain for existing call sites and
map 1:1 onto a :class:`~repro.run.spec.RunSpec` (see DESIGN.md
§"Run API v1" for the migration table); new code should build a RunSpec
directly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.run.hooks import EvalHook, StragglerHook
from repro.run.program import build_step_program
from repro.run.runner import run
from repro.run.spec import (CheckpointSpec, EvalSpec, FaultSpec, ModelSpec,
                            OptSpec, RunSpec, StepSpec)
from repro.train.fault import StragglerMonitor


@dataclasses.dataclass
class TrainConfig:
    optimizer: str = "adalomo"
    lr: float = 5e-4
    total_steps: int = 100
    warmup_frac: float = 0.03
    schedule: str = "cosine"          # "cosine" | "constant"
    fused: bool = True                # LOMO-style fused backward
    microbatches: int = 1
    eval_every: int = 0
    ckpt_every: int = 0
    ckpt_dir: Optional[str] = None
    heartbeat_timeout_s: float = 0.0  # 0 = disabled
    log_every: int = 10
    # Static/rule-construction kwargs forwarded to the registry factory
    # (backend=, cfg=, default hparams ...).
    opt_kwargs: dict = dataclasses.field(default_factory=dict)
    # Extra *dynamic* hyperparameters passed with the per-step lr (e.g.
    # {"weight_decay": 0.1}); schedulable without recompiles (Opt v2).
    hparams: dict = dataclasses.field(default_factory=dict)
    # Param groups: () for none.  None = the paper-standard default of
    # no weight decay on 1-D tensors (only active for rules with a
    # weight_decay hparam, where wd=0 makes it a no-op).
    groups: Optional[tuple] = None

    def to_run_spec(self, arch) -> RunSpec:
        """The equivalent RunSpec (data supplied at fit time via
        iterators, so ``spec.data`` stays None)."""
        return RunSpec(
            model=ModelSpec(arch=arch.arch_id),
            data=None,
            opt=OptSpec(name=self.optimizer, lr=self.lr,
                        schedule=self.schedule,
                        warmup_frac=self.warmup_frac,
                        kwargs=self.opt_kwargs, hparams=self.hparams),
            steps=StepSpec(total=self.total_steps,
                           microbatches=self.microbatches,
                           fused=self.fused),
            checkpoint=CheckpointSpec(dir=self.ckpt_dir,
                                      every=self.ckpt_every),
            eval=EvalSpec(every=self.eval_every),
            fault=FaultSpec(heartbeat_timeout_s=self.heartbeat_timeout_s),
            log_every=self.log_every)


class Trainer:
    """Compat shim: ``Trainer(arch, tcfg).fit(...)`` ≡ ``run(spec, ...)``."""

    def __init__(self, arch, tcfg: TrainConfig, *, mesh=None,
                 log_fn: Callable[[str], None] = print):
        self.arch = arch
        self.tcfg = tcfg
        self.mesh = mesh
        self.log = log_fn
        self.spec = tcfg.to_run_spec(arch)
        self._program = build_step_program(self.spec, arch,
                                           groups=tcfg.groups)
        self.opt = self._program.opt
        self.straggler = StragglerMonitor()

    @property
    def _step(self):
        return self._program.step

    # ------------------------------------------------------------------
    def init(self, seed: int = 0):
        return self._program.init(seed)

    def hparams_at(self, step: int) -> dict:
        """The dynamic hparams pytree for (1-based) ``step`` — scheduled lr
        plus any TrainConfig extras; same structure every step, so the
        jitted train step never recompiles.  The schedule is authoritative
        for lr: set it via TrainConfig.lr/schedule, not tcfg.hparams."""
        return self._program.hparams_fn(step)

    def fit(self, params, opt_state, batch_iter, *, start_step: int = 0,
            eval_iter=None, ckpt_manager=None) -> dict:
        hooks = [StragglerHook(self.straggler)]
        if eval_iter is not None and self.tcfg.eval_every:
            hooks.append(EvalHook(eval_iter, self.tcfg.eval_every))
        res = run(self.spec, program=self._program, params=params,
                  opt_state=opt_state, batch_iter=batch_iter,
                  ckpt_manager=ckpt_manager, start_step=start_step,
                  hooks=hooks, log_fn=self.log)
        return {"params": res.params, "opt_state": res.opt_state,
                "history": res.history}

    def evaluate(self, params, eval_iter, n_batches: int = 4) -> dict:
        hook = EvalHook(eval_iter, every=0, n_batches=n_batches)
        ctx = _EvalCtx(self._program, params)
        return hook.evaluate(ctx)


@dataclasses.dataclass
class _EvalCtx:
    program: object
    params: object

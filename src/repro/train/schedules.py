"""LR schedules (paper Appendix C/D: warmup = 0.03·total, cosine decay)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr: float, total_steps: int,
                  warmup_frac: float = 0.03, min_ratio: float = 0.1):
    warmup = max(int(total_steps * warmup_frac), 1)

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / warmup
        t = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos).astype(jnp.float32)

    return lr


def constant(base_lr: float):
    def lr(step):
        return jnp.asarray(base_lr, jnp.float32)

    return lr

"""Fault tolerance for long-running multi-host training.

Three layers, all exercised by tests:

  * ``Heartbeat`` — a watchdog thread that marks the process wedged if the
    training loop stops reporting progress (straggler/deadlock detection).
    On a real cluster the coordinator consumes these beats; here the
    watchdog fires a callback that the loop turns into a checkpoint+abort.
  * ``retrying`` — wraps the device-side step; transient failures
    (preempted TPU, ICI link flap → ``XlaRuntimeError``) trigger
    re-initialization from the last checkpoint instead of killing the job.
  * **elastic restart** — on resume the checkpoint is mesh-independent
    (see checkpoint/manager.py), so a job that lost a pod restarts on a
    smaller mesh by just passing different shardings to ``restore``.

Straggler mitigation at step granularity: the loop records an EMA of step
times; steps slower than ``straggler_factor``× the EMA are logged with the
host id so the coordinator can evict the slow host. (With synchronous SPMD
collectives, evict-and-reshard is the only real mitigation; there is no
per-device work stealing inside a pjit step.)
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class Heartbeat:
    def __init__(self, timeout_s: float, on_stall: Callable[[], None]):
        self.timeout_s = timeout_s
        self.on_stall = on_stall
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self.stalled = False

    def start(self):
        self._thread.start()
        return self

    def beat(self):
        self._last = time.monotonic()

    def stop(self):
        self._stop.set()

    def _watch(self):
        while not self._stop.wait(self.timeout_s / 4):
            if time.monotonic() - self._last > self.timeout_s:
                self.stalled = True
                try:
                    self.on_stall()
                finally:
                    return


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, ema: float = 0.9):
        self.factor = factor
        self.ema_coef = ema
        self.ema: Optional[float] = None
        self.events: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step was a straggler."""
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.factor * self.ema
        if slow:
            self.events.append((step, dt, self.ema))
        self.ema = self.ema_coef * self.ema + (1 - self.ema_coef) * dt
        return slow


def retrying(fn: Callable, *, retries: int = 2,
             on_failure: Optional[Callable[[int, Exception], None]] = None,
             retriable: tuple = ()):
    """Retry a step function on transient runtime errors.

    ``retriable`` defaults to jax runtime errors; ``on_failure(attempt, e)``
    is the hook where the loop restores from checkpoint."""
    if not retriable:
        try:
            from jax.errors import JaxRuntimeError  # jax >= 0.4.14
            retriable = (JaxRuntimeError,)
        except ImportError:  # pragma: no cover
            retriable = (RuntimeError,)

    def wrapped(*a, **kw):
        for attempt in range(retries + 1):
            try:
                return fn(*a, **kw)
            except retriable as e:  # pragma: no cover - exercised via mock
                if attempt == retries:
                    raise
                if on_failure is not None:
                    on_failure(attempt, e)
        raise AssertionError("unreachable")

    return wrapped

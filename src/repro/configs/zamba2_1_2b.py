"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block with
per-application LoRA (arXiv:2411.15242)."""
from repro.models.hybrid import HybridConfig

ARCH_ID = "zamba2-1.2b"
FAMILY = "hybrid"


def config() -> HybridConfig:
    return HybridConfig(
        name=ARCH_ID, n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32000, d_state=64, headdim=64, attn_every=6,
        lora_rank=128)


def smoke_config() -> HybridConfig:
    import jax.numpy as jnp
    return HybridConfig(
        name=ARCH_ID + "-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=128, d_state=16, headdim=16,
        attn_every=2, lora_rank=8, chunk=8, dtype=jnp.float32)

"""stablelm-12b [dense] — stablelm-2 family (hf:stabilityai/stablelm-2-1_6b):
LayerNorm + partial rotary (25%)."""
from repro.models.transformer import LMConfig

ARCH_ID = "stablelm-12b"
FAMILY = "transformer"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=13824, vocab=100352, norm="layernorm", rope_pct=0.25,
        act="silu", glu=True)


def smoke_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128, norm="layernorm", rope_pct=0.25,
        dtype=jnp.float32)

"""deepseek-v3-671b [moe] — MLA attention, 1 shared + 256 routed top-8,
sigmoid router, MTP head (arXiv:2412.19437).

Deviation (DESIGN.md): the real model's first 3 layers are dense; here all
61 layers are MoE so the layer stack stays homogeneous for the fused scan.
"""
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig, MLAConfig

ARCH_ID = "deepseek-v3-671b"
FAMILY = "transformer"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=61, d_model=7168, n_heads=128,
        n_kv_heads=128, d_ff=2048, vocab=129280, norm="rmsnorm", act="silu",
        glu=True, mtp=True,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, d_nope=128,
                      d_rope=64, d_v=128),
        moe=MoEConfig(n_routed=256, top_k=8, d_ff_expert=2048, n_shared=1,
                      router_score="sigmoid"))


def smoke_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=32, vocab=128, dtype=jnp.float32, mtp=True,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, d_nope=16, d_rope=8,
                      d_v=16),
        moe=MoEConfig(n_routed=8, top_k=2, d_ff_expert=32, n_shared=1,
                      router_score="sigmoid"))

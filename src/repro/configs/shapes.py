"""Assigned input-shape sets (identical across the 10 LM-family archs).

  train_4k     seq_len=4096    global_batch=256   → train_step
  prefill_32k  seq_len=32768   global_batch=32    → prefill_step
  decode_32k   seq_len=32768   global_batch=128   → decode_step (KV cache)
  long_500k    seq_len=524288  global_batch=1     → decode_step; only for
               sub-quadratic archs (SSM / hybrid / SWA) — see DESIGN.md §4.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic attention / SSM / SWA ring
# cache).  Pure full-attention archs skip it — recorded in EXPERIMENTS.md.
LONG_OK = {
    "mamba2-1.3b", "zamba2-1.2b", "h2o-danube-1.8b", "h2o-danube-3-4b",
}


def cells_for(arch_id: str) -> list[str]:
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_id in LONG_OK:
        cells.append("long_500k")
    return cells

"""qwen3-32b [dense] — qk-norm + GQA (hf:Qwen/Qwen3-8B family).
Qwen3 uses an explicit head_dim=128 (q proj widens 5120 -> 8192)."""
from repro.models.transformer import LMConfig

ARCH_ID = "qwen3-32b"
FAMILY = "transformer"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
        d_head=128, d_ff=25600, vocab=151936, qk_norm=True,
        rope_theta=1_000_000.0, norm="rmsnorm", act="silu", glu=True)


def smoke_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=32, d_ff=128, vocab=128, qk_norm=True,
        dtype=jnp.float32)

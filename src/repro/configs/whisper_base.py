"""whisper-base [audio] — enc-dec; conv frontend STUBBED (input_specs feeds
precomputed frame embeddings).  See DESIGN.md §4 for deviations."""
from repro.models.encdec import EncDecConfig

ARCH_ID = "whisper-base"
FAMILY = "encdec"


def config() -> EncDecConfig:
    return EncDecConfig(
        name=ARCH_ID, n_enc_layers=6, n_dec_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865, n_frames=1500)


def smoke_config() -> EncDecConfig:
    import jax.numpy as jnp
    return EncDecConfig(
        name=ARCH_ID + "-smoke", n_enc_layers=2, n_dec_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, n_frames=24,
        dtype=jnp.float32)

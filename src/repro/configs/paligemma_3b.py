"""paligemma-3b [vlm] — SigLIP frontend STUBBED (input_specs feeds patch
embeddings); gemma-2b decoder backbone with prefix-LM masking
(arXiv:2407.07726)."""
from repro.models.transformer import LMConfig

ARCH_ID = "paligemma-3b"
FAMILY = "transformer"

N_PATCHES = 256  # 224px / 14 -> 16x16 SigLIP patches


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        d_head=256, d_ff=16384, vocab=257216, norm="rmsnorm", act="gelu",
        glu=True, tie_embeddings=True, embed_scale=True, prefix_lm=True,
        n_prefix_tokens=N_PATCHES)


def smoke_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, d_head=16, d_ff=128, vocab=128, act="gelu",
        tie_embeddings=True, embed_scale=True, prefix_lm=True,
        n_prefix_tokens=8, dtype=jnp.float32)

"""h2o-danube-1.8b [dense, SWA] — llama+mistral mix (arXiv:2401.16818)."""
from repro.models.transformer import LMConfig

ARCH_ID = "h2o-danube-1.8b"
FAMILY = "transformer"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
        d_ff=6912, vocab=32000, window=4096, rope_theta=10000.0,
        norm="rmsnorm", act="silu", glu=True)


def smoke_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128, window=8, dtype=jnp.float32)

"""deepseek-moe-16b [moe] — fine-grained experts, 2 shared + 64 routed
top-6 (arXiv:2401.06066)."""
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "deepseek-moe-16b"
FAMILY = "transformer"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=102400, norm="rmsnorm", act="silu", glu=True,
        moe=MoEConfig(n_routed=64, top_k=6, d_ff_expert=1408, n_shared=2))


def smoke_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=32, vocab=128, dtype=jnp.float32,
        moe=MoEConfig(n_routed=8, top_k=2, d_ff_expert=32, n_shared=1))

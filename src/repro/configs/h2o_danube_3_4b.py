"""h2o-danube-3-4b [dense, SWA] — llama+mistral mix (arXiv:2401.16818)."""
from repro.models.transformer import LMConfig

ARCH_ID = "h2o-danube-3-4b"
FAMILY = "transformer"


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_ff=10240, vocab=32000, window=4096, rope_theta=10000.0,
        norm="rmsnorm", act="silu", glu=True)


def smoke_config() -> LMConfig:
    import jax.numpy as jnp
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=96, n_heads=4,
        n_kv_heads=2, d_ff=192, vocab=128, window=8, dtype=jnp.float32)

"""mamba2-1.3b [ssm] — SSD state-space duality (arXiv:2405.21060)."""
from repro.models.mamba2 import Mamba2Config

ARCH_ID = "mamba2-1.3b"
FAMILY = "mamba2"


def config() -> Mamba2Config:
    return Mamba2Config(
        name=ARCH_ID, n_layers=48, d_model=2048, vocab=50280, d_state=128,
        d_conv=4, expand=2, headdim=64, n_groups=1, chunk=128)


def smoke_config() -> Mamba2Config:
    import jax.numpy as jnp
    return Mamba2Config(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, vocab=128,
        d_state=16, d_conv=4, expand=2, headdim=16, chunk=8,
        dtype=jnp.float32)

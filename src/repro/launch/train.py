"""Production training launcher — RunSpec parsing + ``run()`` (Run API v1).

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --smoke --steps 100 --optimizer adalomo --batch 8 --seq 128

  PYTHONPATH=src python -m repro.launch.train --spec runspec.json

On a real cluster this binary runs once per host (jax.distributed
initializes from the standard env vars); in this container it runs
single-process, optionally with a virtual-device mesh (--virtual-devices N
or --virtual-devices=N; must be handled before any jax import because the
device count locks at first jax use).
"""
import os
import sys


def parse_virtual_devices(argv) -> int | None:
    """Extract --virtual-devices from raw argv, before argparse/jax.

    Accepts both ``--virtual-devices N`` and ``--virtual-devices=N``;
    raises SystemExit with a clear message when the value is missing or
    not a positive integer (the old raw-index arithmetic crashed with an
    IndexError/ValueError on the ``=`` form or a trailing flag).
    """
    val = None
    for i, a in enumerate(argv):
        if a == "--virtual-devices":
            if i + 1 >= len(argv) or argv[i + 1].startswith("-"):
                raise SystemExit(
                    "--virtual-devices requires a value (an integer >= 1)")
            val = argv[i + 1]
        elif a.startswith("--virtual-devices="):
            val = a.split("=", 1)[1]
        else:
            continue
        if not val.isdigit() or int(val) < 1:
            raise SystemExit(
                f"--virtual-devices: expected an integer >= 1, got {val!r}")
        return int(val)
    return None


_n = parse_virtual_devices(sys.argv[1:]) if __name__ == "__main__" else None
if _n:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={_n}")


def main(argv=None):
    import argparse
    import json

    from repro.run.spec import RunSpec, add_cli_args, from_cli_args

    ap = argparse.ArgumentParser(description=__doc__)
    add_cli_args(ap)
    ap.add_argument("--spec", default=None,
                    help="RunSpec JSON file (overrides the other flags)")
    ap.add_argument("--elastic-from", default=None, metavar="CKPT_DIR",
                    help="resume this run from an existing checkpoint dir "
                         "onto the CURRENT mesh (combine with --mesh-shape "
                         "to restore onto a different device count after "
                         "pod loss/growth)")
    ap.add_argument("--virtual-devices", type=int, default=None,
                    help="host-platform device count (handled pre-import)")
    ap.add_argument("--history-out", default=None,
                    help="write the training history JSON here")
    args = ap.parse_args(argv)

    if args.spec:
        with open(args.spec) as f:
            spec = RunSpec.from_json(f.read())
    else:
        spec = from_cli_args(args)

    if args.elastic_from:
        # Elastic restore: take the recorded spec as-is, point it at the
        # existing checkpoints, and (optionally) override the mesh shape
        # — CheckpointManager.restore(shardings=...) re-shards the state,
        # including AdaLomo's factored moments, onto the new mesh.
        import dataclasses

        from repro.run.spec import MeshSpec, parse_mesh_shape
        mesh = spec.mesh
        shape = parse_mesh_shape(getattr(args, "mesh_shape", None))
        if shape:
            mesh = MeshSpec(kind="multi", optimized=mesh.optimized,
                            shape=shape)
        spec = dataclasses.replace(
            spec,
            mesh=mesh,
            checkpoint=dataclasses.replace(
                spec.checkpoint, dir=args.elastic_from, resume=True,
                gc_incomplete=True))

    if args.virtual_devices:
        # The XLA flag only takes effect when set before jax initializes —
        # the module-level pre-parse does that for CLI invocations.  Catch
        # programmatic main() calls where it can no longer apply.
        import jax
        if jax.device_count() < args.virtual_devices:
            raise SystemExit(
                f"--virtual-devices={args.virtual_devices} had no effect "
                f"({jax.device_count()} device(s) visible): the flag must "
                "be processed before jax initializes — invoke via "
                "`python -m repro.launch.train` on the command line")

    from repro.fleet.preempt import PREEMPTED_EXIT_CODE, Preempted
    from repro.run import run

    try:
        result = run(spec)
    except Preempted as e:
        # Resumable by re-invoking with --resume / --elastic-from (the
        # sweep driver keys on this exit code).
        print(f"preempted: checkpointed at step {e.step}; exiting "
              f"{PREEMPTED_EXIT_CODE} (resumable)")
        raise SystemExit(PREEMPTED_EXIT_CODE)
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(result.history, f)
    if result.history.get("loss"):
        print(f"final loss {result.history['loss'][-1]:.4f}")
    else:
        # --resume found the run already at total_steps: a no-op resume
        print(f"nothing to do: resumed at step {result.start_step} of "
              f"{spec.steps.total}")


if __name__ == "__main__":
    main()

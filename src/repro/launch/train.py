"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --smoke --steps 100 --optimizer adalomo --batch 8 --seq 128

On a real cluster this binary runs once per host (jax.distributed
initializes from the standard env vars); in this container it runs
single-process, optionally with a virtual-device mesh (--virtual-devices N,
must come first — device count locks at first jax use).
"""
import os
import sys

if "--virtual-devices" in sys.argv:  # must precede any jax import
    _n = sys.argv[sys.argv.index("--virtual-devices") + 1]
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={_n}")

import argparse  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--optimizer", default="adalomo")
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--weight-decay", type=float, default=None,
                    help="decoupled weight decay (Opt v2 dynamic hparam; "
                         "1-D params are auto-grouped to no-decay)")
    ap.add_argument("--opt-backend", default=None,
                    choices=["auto", "jnp", "pallas"],
                    help="AdaLomo update backend (Pallas kernel on TPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--unfused", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--virtual-devices", type=int, default=None)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args(argv)

    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import DataConfig, batches
    from repro.models.registry import get_arch
    from repro.train.loop import TrainConfig, Trainer

    # Paper hyper-parameters (Table 6/7): AdaLomo lr ≈ 5e-4 (IT) / 1e-3
    # (pretrain); AdamW 1e-5..2e-5; LOMO/SGD 1e-2.
    default_lr = {"adalomo": 5e-4, "adafactor": 5e-4, "adamw": 2e-5,
                  "lomo": 1e-2, "sgd": 1e-2, "sgd_momentum": 1e-2,
                  "sgd_variance": 5e-4}
    lr = args.lr if args.lr is not None else default_lr.get(args.optimizer,
                                                            1e-3)
    arch = get_arch(args.arch, smoke=args.smoke)
    hparams = ({} if args.weight_decay is None
               else {"weight_decay": args.weight_decay})
    opt_kwargs = ({} if args.opt_backend is None
                  else {"backend": args.opt_backend})
    tcfg = TrainConfig(optimizer=args.optimizer, lr=lr,
                       total_steps=args.steps, fused=not args.unfused,
                       microbatches=args.microbatches,
                       eval_every=args.eval_every,
                       ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                       hparams=hparams, opt_kwargs=opt_kwargs)
    trainer = Trainer(arch, tcfg)
    params, opt_state = trainer.init(args.seed)

    dcfg = DataConfig(vocab=arch.cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        if args.resume and ckpt.latest_step() is not None:
            start_step, (params, opt_state), extra = ckpt.restore(
                template=(params, opt_state))
            print(f"resumed from step {start_step}")

    def batch_with_extras():
        need_frames = arch.family == "encdec"
        import numpy as np
        rng = np.random.default_rng(args.seed)
        for b in batches(dcfg, start_step):
            if need_frames:
                b = dict(b)
                b["frames"] = rng.standard_normal(
                    (args.batch, arch.cfg.n_frames, arch.cfg.d_model),
                    dtype=np.float32)
            if getattr(arch.cfg, "prefix_lm", False):
                b = dict(b)
                b["prefix_embed"] = rng.standard_normal(
                    (args.batch, arch.cfg.n_prefix_tokens,
                     arch.cfg.d_model), dtype=np.float32)
                b["prefix_len"] = np.full(
                    (args.batch,), arch.cfg.n_prefix_tokens, np.int32)
            if getattr(arch.cfg, "mtp", False):
                b = dict(b)
                lab = b["labels"]
                b["labels_mtp"] = np.concatenate(
                    [lab[:, 1:], -np.ones((lab.shape[0], 1), np.int32)], 1)
            yield b

    out = trainer.fit(params, opt_state, batch_with_extras(),
                      start_step=start_step,
                      eval_iter=batch_with_extras() if args.eval_every else None,
                      ckpt_manager=ckpt)
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(out["history"], f)
    print(f"final loss {out['history']['loss'][-1]:.4f}")


if __name__ == "__main__":
    main()

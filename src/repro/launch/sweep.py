"""Sweep launcher — fan a base RunSpec across declarative overrides.

  # lr grid, sequential in-process members:
  PYTHONPATH=src python -m repro.launch.sweep --base spec.json \
      --dir out/sweep --grid '{"opt.lr": [1e-3, 3e-3], "seed": [0, 1]}'

  # optimizer ablation as crash-isolated subprocesses, 2 at a time,
  # each on a 2x2 virtual-device mesh:
  PYTHONPATH=src python -m repro.launch.sweep --base spec.json \
      --dir out/ablate --variants variants.json --subprocess --parallel 2 \
      --virtual-devices 4

``variants.json`` is a list of override dicts (dotted spec paths):
``[{"opt.name": "adamw", "opt.lr": 2e-4}, {"opt.lr": 1e-3}, ...]``.

Re-invoking the same command is always safe: DONE members are skipped,
killed or preempted members resume from their last complete checkpoint
(see DESIGN.md §"Elastic training fleet").  The merged, ranked report
lands in ``<dir>/report.json``.
"""
import os
import sys

from repro.launch.train import parse_virtual_devices

_n = parse_virtual_devices(sys.argv[1:]) if __name__ == "__main__" else None
if _n:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={_n}")


def _load_variants(args) -> list:
    import json
    if (args.grid is None) == (args.variants is None):
        raise SystemExit("pass exactly one of --grid / --variants")
    from repro.fleet.sweep import expand_grid
    if args.grid:
        text = args.grid
        if text.startswith("@"):
            with open(text[1:]) as f:
                text = f.read()
        return expand_grid(json.loads(text))
    with open(args.variants) as f:
        variants = json.load(f)
    if not isinstance(variants, list):
        raise SystemExit("--variants file must hold a JSON list of "
                         "override dicts")
    return variants


def main(argv=None):
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--base", required=True,
                    help="base RunSpec JSON file")
    ap.add_argument("--dir", required=True,
                    help="sweep directory (members + report.json)")
    ap.add_argument("--grid", default=None,
                    help="JSON {dotted.path: [values...]} expanded as a "
                         "cartesian product (or @file.json)")
    ap.add_argument("--variants", default=None,
                    help="JSON file: explicit list of override dicts")
    ap.add_argument("--subprocess", action="store_true",
                    help="run members as crash-isolated subprocesses "
                         "(default: sequential in-process)")
    ap.add_argument("--parallel", type=int, default=1,
                    help="max subprocess members in flight")
    ap.add_argument("--objective", default="loss",
                    choices=["loss", "eval_loss"],
                    help="ranking key for the report")
    ap.add_argument("--virtual-devices", type=int, default=None,
                    help="host-platform device count (handled pre-import; "
                         "forwarded to subprocess members)")
    args = ap.parse_args(argv)

    variants = _load_variants(args)
    with open(args.base) as f:
        from repro.run.spec import RunSpec
        base = RunSpec.from_json(f.read())

    extra = (["--virtual-devices", str(args.virtual_devices)]
             if args.virtual_devices else [])
    from repro.fleet.sweep import run_sweep
    report = run_sweep(base, variants, args.dir,
                       mode="subprocess" if args.subprocess else "inproc",
                       parallel=args.parallel, extra_args=extra,
                       objective=args.objective)

    done, n = report["n_done"], report["n_members"]
    print(f"\nsweep: {done}/{n} members done; report: "
          f"{os.path.join(args.dir, 'report.json')}")
    for rank, name in enumerate(report["ranking"], 1):
        row = next(r for r in report["members"] if r["name"] == name)
        print(f"  #{rank} {name}  {report['objective']}="
              f"{row[report['objective']]:.4f}  "
              f"overrides={json.dumps(row['overrides'])}")
    if done < n:
        print("  (re-invoke the same command to resume unfinished members)")
        raise SystemExit(1)


if __name__ == "__main__":
    main()

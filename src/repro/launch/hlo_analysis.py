"""Loop-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE — a
``lax.scan`` over 61 layers contributes 1/61 of its true FLOPs, bytes and
collective traffic.  Since every model here is scan-over-layers (that's
what makes the fused AdaLomo backward O(1)-gradient), loop-blind numbers
are useless for a roofline.  This module parses the (SPMD, per-device) HLO
text, builds per-computation symbol tables and the call graph, extracts
while-loop trip counts from condition computations, and multiplies costs
through the graph.

Cost model per instruction:
  * dot:            2 · numel(result) · prod(lhs contracting dims)
  * convolution:    2 · numel(result) · numel(kernel)/out_channels (approx)
  * elementwise/reduce: 1 FLOP per result element (secondary term)
  * HBM bytes:      operands + result of top-level instructions — mirrors
                    XLA's bytes-accessed model (fusion interiors excluded)
  * collectives:    operand bytes, plus derived per-device wire bytes
                    (all-gather ≈ result, all-reduce ≈ 2·result, others ≈
                    operand — ring-algorithm (N-1)/N → 1 for large N)

Known approximations (EXPERIMENTS.md §Method):
  * conditional branches count the max-FLOPs branch;
  * trip count = largest integer constant in the while condition
    (matches jax-lowered scans; validated against known-L models);
  * get-tuple-element/bitcast/tuple are free.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_TOKEN = re.compile(r"\b([a-z]+\d+[a-z0-9]*|pred)\[([\d,]*)\]")


def _numel(dims: tuple) -> int:
    return math.prod(dims) if dims else 1


def _parse_shapes(sig: str) -> list:
    """All (dtype, dims tuple) in a type signature string."""
    out = []
    for t, d in _SHAPE_TOKEN.findall(sig):
        dims = tuple(int(x) for x in d.split(",")) if d else ()
        out.append((t, dims))
    return out


def _shapes_bytes(shapes: list) -> int:
    return sum(_numel(d) * _DTYPE_BYTES.get(t, 4) for t, d in shapes)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_operand: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_wire: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    # bf16-equivalent wire: XLA:CPU legalizes bf16 dots to f32 *before* SPMD
    # partitioning, so weight/grad collectives appear at 2× their TPU width
    # (TPU keeps bf16 through the MXU).  f32 collectives ≥1 MB are counted
    # at half width here; small fp32 reductions (factored stats, RMS
    # scalars) are genuinely fp32 and counted full.  EXPERIMENTS.md §Method.
    coll_wire_bf16: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_count: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.transcendentals += other.transcendentals * times
        for k in _COLLECTIVES:
            self.coll_operand[k] += other.coll_operand[k] * times
            self.coll_wire[k] += other.coll_wire[k] * times
            self.coll_wire_bf16[k] += other.coll_wire_bf16[k] * times
            self.coll_count[k] += int(other.coll_count[k] * times)


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result_shapes: list      # [(dtype, dims)]
    operand_names: list      # ["x.1", ...]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list
    symbols: dict            # name -> [(dtype, dims)]
    is_fused: bool = False


_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z][\w\[\],\s{}()\/]*?)\s+"
    r"([\w\-]+)\((.*)$")
_HEADER = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> tuple:
    """Returns (computations dict, entry name)."""
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    comment = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment.sub("", raw.rstrip())
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        if s.endswith("{") and "->" in s and "=" not in s.split("->")[0]:
            m = _HEADER.match(s)
            if m:
                name, params_sig = m.group(1), m.group(2)
                cur = Computation(name=name, instructions=[], symbols={},
                                  is_fused="fused" in name)
                comps[name] = cur
                if s.lstrip().startswith("ENTRY"):
                    entry = name
                # parameters: "x.1: f32[8,16], w.1: f32[16,4]"
                for pm in re.finditer(
                        r"%?([\w.\-]+)\s*:\s*((?:\([^)]*\))|(?:[\w\[\],]+))",
                        params_sig):
                    cur.symbols[pm.group(1)] = _parse_shapes(pm.group(2))
            continue
        if s.startswith("}"):
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, result_sig, opcode, rest = m.groups()
        depth, j = 1, 0
        while j < len(rest) and depth:
            if rest[j] == "(":
                depth += 1
            elif rest[j] == ")":
                depth -= 1
            j += 1
        args_sig = rest[:j - 1] if j else rest
        result_shapes = _parse_shapes(result_sig)
        operand_names = _OPERAND_NAME.findall(args_sig)
        cur.symbols[name] = result_shapes
        cur.instructions.append(
            Instruction(name, opcode, result_shapes, operand_names, line))
    return comps, entry


_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "clamp",
    "floor", "ceil", "round-nearest-afz", "sign", "remainder",
}
_TRANSCENDENTAL_OPS = {"exponential", "log", "rsqrt", "sqrt", "tanh",
                       "logistic", "power", "sine", "cosine",
                       "exponential-minus-one", "log-plus-one", "erf"}
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "opt-barrier", "domain"}


class _Analyzer:
    def __init__(self, comps: dict):
        self.comps = comps
        self.cache: dict[str, Cost] = {}

    def operand_bytes(self, comp: Computation, instr: Instruction) -> int:
        total = 0
        for nm in instr.operand_names:
            shapes = comp.symbols.get(nm)
            if shapes:
                total += _shapes_bytes(shapes)
        return total

    def comp_cost(self, name: str) -> Cost:
        if name in self.cache:
            return self.cache[name]
        self.cache[name] = Cost()  # cycle guard
        comp = self.comps[name]
        total = Cost()
        for instr in comp.instructions:
            total.add(self.instr_cost(comp, instr))
        self.cache[name] = total
        return total

    def trip_count(self, cond_name: str) -> int:
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1
        best = 1
        for instr in cond.instructions:
            for m in re.finditer(r"constant\((\d+)\)", instr.line):
                best = max(best, int(m.group(1)))
        return best

    def instr_cost(self, comp: Computation, instr: Instruction) -> Cost:
        c = Cost()
        op = instr.opcode
        if op in _FREE_OPS:
            return c
        result_numel = sum(_numel(d) for _, d in instr.result_shapes)
        result_bytes = _shapes_bytes(instr.result_shapes)
        operand_bytes = self.operand_bytes(comp, instr)

        if op == "dot":
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
            contract = 1
            if m and instr.operand_names:
                lhs = comp.symbols.get(instr.operand_names[0])
                if lhs:
                    dims = lhs[0][1]
                    for ax in m.group(1).split(","):
                        if ax and int(ax) < len(dims):
                            contract *= dims[int(ax)]
            c.flops += 2.0 * result_numel * contract
            c.bytes += operand_bytes + result_bytes
        elif op == "convolution":
            kern = (comp.symbols.get(instr.operand_names[1])
                    if len(instr.operand_names) > 1 else None)
            k_numel = _numel(kern[0][1]) if kern else 1
            c.flops += 2.0 * result_numel * max(k_numel // max(
                result_numel and instr.result_shapes[0][1][-1], 1), 1)
            c.bytes += operand_bytes + result_bytes
        elif op == "while":
            mb = re.search(r"body=%?([\w.\-]+)", instr.line)
            mc = re.search(r"condition=%?([\w.\-]+)", instr.line)
            trips = self.trip_count(mc.group(1)) if mc else 1
            if mb and mb.group(1) in self.comps:
                c.add(self.comp_cost(mb.group(1)), times=trips)
            if mc and mc.group(1) in self.comps:
                c.add(self.comp_cost(mc.group(1)), times=trips)
        elif op in ("call", "fusion", "map", "custom-call"):
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", instr.line)
            if m and m.group(1) in self.comps:
                sub = self.comp_cost(m.group(1))
                c.flops += sub.flops
                c.transcendentals += sub.transcendentals
                for k in _COLLECTIVES:
                    c.coll_operand[k] += sub.coll_operand[k]
                    c.coll_wire[k] += sub.coll_wire[k]
                    c.coll_count[k] += sub.coll_count[k]
            c.bytes += operand_bytes + result_bytes  # fusion boundary only
        elif op == "conditional":
            m = re.search(r"branch_computations=\{([^}]*)\}", instr.line)
            best = Cost()
            if m:
                for nm in re.findall(r"%?([\w.\-]+)", m.group(1)):
                    if nm in self.comps:
                        sub = self.comp_cost(nm)
                        if sub.flops >= best.flops:
                            best = sub
            c.add(best)
            c.bytes += operand_bytes + result_bytes
        elif any(op == k or op.startswith(k + "-") for k in _COLLECTIVES):
            if not op.endswith("-done"):
                # fraction of the payload that is fp32 and large (≥1MB):
                # counted at half width in the bf16-equivalent metric
                big_f32 = sum(
                    _numel(d) * 4 for t, d in instr.result_shapes
                    if t == "f32" and _numel(d) * 4 >= 2 ** 20)
                for k in _COLLECTIVES:
                    if op == k or op.startswith(k + "-"):
                        c.coll_count[k] += 1
                        c.coll_operand[k] += operand_bytes
                        if k == "all-gather":
                            wire = result_bytes
                            corr = wire - big_f32 / 2
                        elif k == "all-reduce":
                            wire = 2.0 * result_bytes
                            corr = wire - big_f32
                        else:
                            wire = operand_bytes
                            of32 = sum(
                                _numel(d) * 4
                                for nm in instr.operand_names
                                for t, d in comp.symbols.get(nm, [])
                                if t == "f32" and _numel(d) * 4 >= 2 ** 20)
                            corr = wire - of32 / 2
                        c.coll_wire[k] += wire
                        c.coll_wire_bf16[k] += max(corr, 0.0)
                        break
                c.bytes += operand_bytes + result_bytes
        elif op in _TRANSCENDENTAL_OPS:
            c.transcendentals += result_numel
            c.flops += result_numel
            c.bytes += operand_bytes + result_bytes
        else:
            if op in _ELEMENTWISE_FLOP_OPS or op in ("reduce", "scatter",
                                                     "reduce-window"):
                c.flops += result_numel
            c.bytes += operand_bytes + result_bytes
        return c


def analyze(hlo_text: str, entry: Optional[str] = None) -> dict:
    """Loop-aware cost of the entry computation. Returns plain dict."""
    comps, found_entry = parse_hlo(hlo_text)
    entry = entry or found_entry
    if entry is None or entry not in comps:
        candidates = [n for n in comps if n.startswith("main")]
        entry = candidates[0] if candidates else next(iter(comps))
    an = _Analyzer(comps)
    cost = an.comp_cost(entry)
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "transcendentals": cost.transcendentals,
        "collectives": {
            "operand_bytes": dict(cost.coll_operand),
            "wire_bytes": dict(cost.coll_wire),
            "wire_bytes_bf16eq": dict(cost.coll_wire_bf16),
            "counts": dict(cost.coll_count),
            "total_operand_bytes": sum(cost.coll_operand.values()),
            "total_wire_bytes": sum(cost.coll_wire.values()),
            "total_wire_bytes_bf16eq": sum(cost.coll_wire_bf16.values()),
        },
    }

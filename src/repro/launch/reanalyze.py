"""Re-run hlo_analysis over saved .hlo.gz artifacts (no recompilation)."""
import gzip
import json
import sys
from pathlib import Path

from repro.launch.dryrun import ARTIFACT_DIR
from repro.launch.hlo_analysis import analyze


def main():
    for jpath in sorted(ARTIFACT_DIR.glob("*.json")):
        hpath = jpath.with_suffix(".hlo.gz")
        if not hpath.exists():
            print(f"skip {jpath.name} (no HLO)")
            continue
        d = json.loads(jpath.read_text())
        la = analyze(gzip.open(hpath, "rt").read())
        d["collectives"] = la["collectives"]
        d["flops_per_device"] = la["flops"]
        d["hbm_bytes_per_device"] = la["bytes"]
        d["transcendentals_per_device"] = la["transcendentals"]
        jpath.write_text(json.dumps(d, indent=1))
        print(f"reanalyzed {jpath.name}: flops/dev={la['flops']:.3e}")


if __name__ == "__main__":
    main()

"""Production mesh construction (function, not module-level constant, so
importing this module never touches jax device state)."""
from __future__ import annotations

import jax


def _mk(shape, axes):
    if hasattr(jax.sharding, "AxisType"):
        auto = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=auto)
    # older jax (< 0.5): no AxisType — make_mesh axes are Auto by default
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(n: int = 8, *, multi_pod: bool = False):
    """Small virtual-device mesh for CI-scale distribution tests."""
    if multi_pod:
        assert n % 2 == 0
        return _mk((2, n // 4, 2), ("pod", "data", "model"))
    return _mk((n // 2, 2), ("data", "model"))

"""Per-shape collective breakdown from a saved .hlo.gz — the 'profiler'
view for the §Perf hypothesis loop: which tensors generate the wire bytes.

  PYTHONPATH=src python -m repro.launch.collective_breakdown \
      benchmarks/artifacts/dryrun/qwen3-32b__train_4k__single.hlo.gz
"""
from __future__ import annotations

import gzip
import re
import sys
from collections import defaultdict

from repro.launch.hlo_analysis import (_COLLECTIVES, _Analyzer, parse_hlo,
                                       _shapes_bytes)


def breakdown(hlo_text: str, top: int = 18) -> list:
    comps, entry = parse_hlo(hlo_text)
    an = _Analyzer(comps)
    # count trips per computation by walking from entry
    trips: dict[str, float] = defaultdict(float)

    def walk(name: str, mult: float, seen: tuple):
        if name in seen:
            return
        trips[name] += mult
        comp = comps[name]
        for instr in comp.instructions:
            if instr.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", instr.line)
                mc = re.search(r"condition=%?([\w.\-]+)", instr.line)
                t = an.trip_count(mc.group(1)) if mc else 1
                if mb and mb.group(1) in comps:
                    walk(mb.group(1), mult * t, seen + (name,))
            elif instr.opcode in ("call", "fusion", "map", "custom-call"):
                m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", instr.line)
                if m and m.group(1) in comps:
                    walk(m.group(1), mult, seen + (name,))
            elif instr.opcode == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", instr.line)
                if m:
                    for nm in re.findall(r"%?([\w.\-]+)", m.group(1)):
                        if nm in comps:
                            walk(nm, mult, seen + (name,))

    walk(entry, 1.0, ())

    agg = defaultdict(lambda: [0.0, 0])  # (kind, shape, dtype) -> [bytes, n]
    for cname, mult in trips.items():
        comp = comps[cname]
        for instr in comp.instructions:
            op = instr.opcode
            base = None
            for k in _COLLECTIVES:
                if op == k or op.startswith(k + "-"):
                    base = k
                    break
            if base is None or op.endswith("-done"):
                continue
            rb = _shapes_bytes(instr.result_shapes)
            ob = sum(_shapes_bytes(comp.symbols.get(nm, []))
                     for nm in instr.operand_names)
            wire = rb if base == "all-gather" else (
                2 * rb if base == "all-reduce" else ob)
            groups = re.search(r"replica_groups=\[([\d,]+)\]", instr.line)
            sig = ",".join(f"{t}[{'x'.join(map(str, d))}]"
                           for t, d in instr.result_shapes[:2])
            meta = re.search(r'op_name="([^"]*)"', instr.line)
            tag = (meta.group(1).split("/")[-1][:40] if meta else "")
            key = (base, sig, groups.group(1) if groups else "?", tag)
            agg[key][0] += wire * mult
            agg[key][1] += int(mult)
    rows = sorted(((v[0], v[1], k) for k, v in agg.items()), reverse=True)
    return rows[:top]


def main():
    path = sys.argv[1]
    text = gzip.open(path, "rt").read()
    rows = breakdown(text)
    total = sum(r[0] for r in rows)
    print(f"{'wire GB':>9} {'count':>6}  kind            result"
          f"              groups      op")
    for wire, n, (kind, sig, grp, tag) in rows:
        print(f"{wire/1e9:9.2f} {n:6d}  {kind:<15} {sig:<19} {grp:<11} {tag}")
    print(f"(top rows total {total/1e9:.1f} GB wire)")


if __name__ == "__main__":
    main()

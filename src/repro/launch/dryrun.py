import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

No arrays are ever allocated: parameters, optimizer state, batches and KV
caches are ShapeDtypeStructs; ``jit(...).lower(...).compile()`` proves the
sharding/collective story is coherent and yields ``memory_analysis()`` /
``cost_analysis()`` for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single]

Artifacts: benchmarks/artifacts/dryrun/{arch}__{shape}__{mesh}.json
(existing artifacts are skipped unless --force).
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / \
    "artifacts" / "dryrun"

# TPU v5e constants (per chip) for the roofline terms.
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link (≈ 45e9 measured; see DESIGN.md)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+\d+|pred)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in (SPMD, per-device) HLO.

    Also derives 'wire bytes' per op with the standard algorithm factors:
      all-gather: bytes received ≈ result; all-reduce ≈ 2×result (RS+AG);
      reduce-scatter/all-to-all/collective-permute ≈ operand.
    """
    per_kind_operand = {k: 0 for k in _COLLECTIVES}
    per_kind_wire = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        result_sig, opname = m.group(1), m.group(2)
        # normalize fused variants like all-gather-start
        base = None
        for k in _COLLECTIVES:
            if opname == k or opname.startswith(k + "-"):
                base = k
                break
        if base is None:
            continue
        if opname.endswith("-done"):
            continue  # counted at -start
        counts[base] += 1
        result_bytes = sum(_shape_bytes(d, s_) for d, s_ in
                           _SHAPE_RE.findall(result_sig))
        args = s[s.index("(") + 1:]
        depth, j = 1, 0
        while j < len(args) and depth:
            if args[j] == "(":
                depth += 1
            elif args[j] == ")":
                depth -= 1
            j += 1
        operand_bytes = sum(_shape_bytes(d, s_) for d, s_ in
                            _SHAPE_RE.findall(args[:j - 1]))
        per_kind_operand[base] += operand_bytes
        if base == "all-gather":
            per_kind_wire[base] += result_bytes
        elif base == "all-reduce":
            per_kind_wire[base] += 2 * result_bytes
        else:
            per_kind_wire[base] += operand_bytes
    return {
        "operand_bytes": per_kind_operand,
        "wire_bytes": per_kind_wire,
        "counts": counts,
        "total_operand_bytes": sum(per_kind_operand.values()),
        "total_wire_bytes": sum(per_kind_wire.values()),
    }


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes", "host_generated_code_size_in_bytes",
                 "host_argument_size_in_bytes", "host_output_size_in_bytes",
                 "host_temp_size_in_bytes", "host_alias_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def build_cell(arch_id: str, shape_name: str, mesh, *,
               optimized: bool = True, packed: bool = False):
    """Return (fn, example_args: tuple of SDS pytrees, in_shardings,
    out_shardings, donate_argnums, meta).

    Train cells build through ``repro.run.build_step_program`` — the same
    step-program constructor ``launch/train.py`` executes — so the dry-run
    lowers the identical program it would train (Run API v1 contract; no
    drift between the compiled artifact and production training).

    ``optimized=False`` reproduces the paper-faithful baseline: no
    activation-sharding policy, no gradient reduce-scatter constraint
    (EXPERIMENTS.md §Perf records both).  ``packed=True`` lowers the
    train cell on the segment-packed batch signature (tokens + labels +
    segment_ids + positions + loss_mask) instead of the padded one."""
    from repro.configs.shapes import SHAPES
    from repro.models.registry import get_arch
    from repro.sharding import rules as R
    from repro.sharding.act import ActPolicy, install

    arch = get_arch(arch_id)
    axes = R.MeshAxes(mesh)
    install(ActPolicy(mesh, axes) if optimized else None)
    sh = SHAPES[shape_name]
    params_sds = jax.eval_shape(lambda: arch.init_params(jax.random.PRNGKey(0)))
    p_specs = R.param_pspecs(params_sds, axes)
    p_shard = R.to_shardings(p_specs, mesh)
    batch_sds = arch.input_specs(shape_name, packed=packed)
    b_shard = R.to_shardings(R.batch_pspecs(batch_sds, axes), mesh)
    n_params = sum(x.size for x in jax.tree.leaves(params_sds))

    if sh.kind == "decode":
        tokens_per_step = sh.global_batch
    elif sh.kind == "prefill" and arch.family == "encdec":
        tokens_per_step = sh.global_batch * arch.cfg.n_frames  # encoder only
    else:
        tokens_per_step = sh.global_batch * sh.seq_len
    meta = {"arch": arch_id, "shape": shape_name, "kind": sh.kind,
            "n_params": int(n_params),
            "n_active_params": int(arch.cfg.active_param_count()),
            "tokens_per_step": int(tokens_per_step),
            "global_batch": sh.global_batch, "seq_len": sh.seq_len}

    if sh.kind == "train":
        from repro.data.pipeline import DataConfig
        from repro.run import (MeshSpec, ModelSpec, OptSpec, RunSpec,
                               StepSpec, build_step_program)
        rc = R.make_residual_constraint(mesh, axes)
        gc = (R.make_grad_constraint(mesh, axes, params_sds)
              if optimized else None)
        pc = (R.make_param_constraint(mesh, axes, params_sds)
              if optimized else None)
        spec = RunSpec(
            model=ModelSpec(arch=arch_id),
            data=DataConfig(vocab=arch.cfg.vocab, seq_len=sh.seq_len,
                            global_batch=sh.global_batch, packing=packed),
            opt=OptSpec(name="adalomo", schedule="constant"),
            steps=StepSpec(total=1, fused=True),
            mesh=MeshSpec(kind="multi" if mesh.devices.size > 256
                          else "single", optimized=optimized))
        program = build_step_program(spec, arch, residual_constraint=rc,
                                     grad_constraint=gc,
                                     param_constraint=pc)
        args = program.abstract_args()
        # Shard the batch the program actually takes (its abstract_args),
        # not the input_specs guess above — under packing the train batch
        # carries extra leaves (segment_ids/positions/loss_mask).
        b_shard = R.to_shardings(R.batch_pspecs(args[2], axes), mesh)
        # Provenance: the exact RunSpec this cell lowers, so the artifact
        # is replayable through launch/train.py without reconstruction.
        meta["run_spec"] = spec.to_dict()
        meta["packed"] = bool(packed)
        opt_sds = args[1]
        o_specs = R.opt_pspecs(opt_sds, params_sds, p_specs, axes)
        o_shard = R.to_shardings(o_specs, mesh)
        scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        hp_shard = jax.tree.map(lambda _: scalar, args[3])
        in_sh = (p_shard, o_shard, b_shard, hp_shard)
        out_sh = (p_shard, o_shard, scalar, scalar)
        return program.fn, args, in_sh, out_sh, (0, 1), meta

    if sh.kind == "prefill":
        if arch.family == "encdec":
            fn = arch.make_prefill_step(max_decode_len=448)
            batch_sds = {"tokens": batch_sds["tokens"],
                         "frames": batch_sds["frames"]}
            b_shard = R.to_shardings(R.batch_pspecs(batch_sds, axes), mesh)
        else:
            fn = arch.make_prefill_step()
        in_sh = (p_shard, b_shard)
        args = (params_sds, batch_sds)
        return fn, args, in_sh, None, (), meta

    # decode
    fn = arch.make_decode_step()
    cache_sds = arch.cache_specs(shape_name)
    c_specs = R.cache_pspecs(cache_sds, axes, sh.global_batch)
    c_shard = R.to_shardings(c_specs, mesh)
    in_sh = (p_shard, c_shard, b_shard)
    out_sh = None
    args = (params_sds, cache_sds, batch_sds)
    return fn, args, in_sh, out_sh, (1,), meta


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, *, force=False,
             save=True, optimized: bool = True, packed: bool = False,
             artifact_dir=None) -> dict:
    from repro.launch.mesh import make_production_mesh

    adir = Path(artifact_dir) if artifact_dir else ARTIFACT_DIR
    cell = f"{arch_id}__{shape_name}__{mesh_kind}"
    if packed:
        cell += "__packed"
    out_path = adir / f"{cell}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    fn, args, in_sh, out_sh, donate, meta = build_cell(
        arch_id, shape_name, mesh, optimized=optimized, packed=packed)
    with mesh:
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = _cost_dict(compiled)
    mem = _mem_dict(compiled)
    hlo_text = compiled.as_text()
    # Loop-aware analysis (launch/hlo_analysis.py): XLA's cost_analysis
    # counts scan bodies once; ours multiplies by trip count.
    from repro.launch.hlo_analysis import analyze
    la = analyze(hlo_text)

    res = {
        **meta,
        "mesh": mesh_kind, "n_chips": int(n_chips),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis_xla": {k: v for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "memory_analysis": mem,
        "collectives": la["collectives"],
        "collectives_loop_blind": parse_collectives(hlo_text),
        "flops_per_device": la["flops"],
        "hbm_bytes_per_device": la["bytes"],
        "transcendentals_per_device": la["transcendentals"],
    }
    if save:
        adir.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(res, indent=1))
        if "run_spec" in res:
            # Sidecar: the originating RunSpec alone, loadable with
            # RunSpec.from_json for replay through launch/train.py.
            out_path.with_suffix(".runspec.json").write_text(
                json.dumps(res["run_spec"], indent=1) + "\n")
        import gzip
        with gzip.open(out_path.with_suffix(".hlo.gz"), "wt") as f:
            f.write(hlo_text)
    return res


def roofline_terms(res: dict) -> dict:
    """The three roofline terms (seconds) from a cell artifact.

    The collective term uses the bf16-equivalent wire bytes when present
    (TPU-faithful; XLA:CPU legalizes bf16 dots to fp32 before SPMD, see
    hlo_analysis.Cost.coll_wire_bf16); the raw fp32-as-lowered number is
    reported alongside as collective_s_raw."""
    compute_s = res["flops_per_device"] / PEAK_FLOPS
    memory_s = res["hbm_bytes_per_device"] / HBM_BW
    coll = res["collectives"]
    coll_raw = coll["total_wire_bytes"] / ICI_BW
    coll_s = coll.get("total_wire_bytes_bf16eq",
                      coll["total_wire_bytes"]) / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    # useful-FLOPs ratio: MODEL_FLOPS / HLO_FLOPs(global)
    n = res["n_active_params"]
    toks = res["tokens_per_step"]
    model_flops = (6 if res["kind"] == "train" else 2) * n * toks
    hlo_global = res["flops_per_device"] * res["n_chips"]
    terms.update({
        "collective_s_raw": coll_raw,
        "dominant": dom,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "roofline_fraction": (model_flops / PEAK_FLOPS / res["n_chips"])
        / bound if bound else 0.0,
    })
    return terms


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful sharding (no act-policy / "
                         "grad reduce-scatter); writes to dryrun_baseline/")
    ap.add_argument("--packed", action="store_true",
                    help="lower train cells on the segment-packed batch "
                         "layout (DataConfig.packing=True); non-train and "
                         "non-packable cells are skipped")
    args = ap.parse_args(argv)

    from repro.configs.shapes import SHAPES
    from repro.models.registry import ARCH_IDS, get_arch

    if args.all:
        cells = [(a, s) for a in ARCH_IDS
                 for s in get_arch(a, smoke=True).supported_cells()]
    else:
        assert args.arch, "--arch or --all required"
        shapes = ([args.shape] if args.shape else
                  get_arch(args.arch, smoke=True).supported_cells())
        cells = [(args.arch, s) for s in shapes]
    if args.packed:
        cells = [(a, s) for a, s in cells
                 if SHAPES[s].kind == "train"
                 and get_arch(a, smoke=True).supports_packing()]
        assert cells, "--packed: no packable train cells selected"
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    failures = []
    adir = (ARTIFACT_DIR.parent / "dryrun_baseline" if args.baseline
            else ARTIFACT_DIR)
    for arch_id, shape_name in cells:
        for mk in meshes:
            tag = f"{arch_id} × {shape_name} × {mk}"
            if args.packed:
                tag += " × packed"
            try:
                res = run_cell(arch_id, shape_name, mk, force=args.force,
                               optimized=not args.baseline,
                               packed=args.packed,
                               artifact_dir=adir)
                terms = roofline_terms(res)
                print(f"OK   {tag:55s} compile={res['compile_s']:7.1f}s "
                      f"dom={terms['dominant']:<13s} "
                      f"roofline={terms['roofline_fraction']:.3f}",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — report & continue
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        sys.exit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()

"""Sharding-agnostic checkpointing with atomic manifests and async writes.

Layout (one directory per step):

  ckpt_dir/
    step_000123/
      manifest.json        # tree structure, shapes, dtypes, leaf→file map
      arr_00000.npy ...    # one .npy per leaf (host-gathered)
      _COMPLETE            # written last → atomic visibility

Design points for the 1000+-node story:
  * restore is *mesh-independent*: leaves are saved as full logical arrays
    and re-sharded on load via ``jax.device_put(x, sharding)`` — elastic
    re-scaling (restore onto a different mesh shape) is a test, not a hope;
  * writes go through a background thread (training continues during I/O),
    with ``wait()`` at shutdown;
  * ``keep_last`` GC, ``_COMPLETE`` marker makes partially-written
    checkpoints invisible to discovery after a crash;
  * persists the data-pipeline step so resume is exactly deterministic.

On a real multi-host deployment each host writes only the shards it owns
(process-local ``.npy`` per shard + shard-index in the manifest); the
single-process container exercises the same code path with world size 1.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CorruptCheckpoint(RuntimeError):
    """A complete-looking checkpoint failed payload validation (missing,
    truncated, or garbled leaf file, or a shape/dtype mismatch)."""


class CheckpointManager:
    # Dropped into a checkpoint dir when restore finds its payload
    # corrupt (truncated/garbled leaf, shape/dtype/size mismatch): the
    # dir keeps its ``_COMPLETE`` marker but becomes invisible to
    # discovery, so latest-step restore falls back to the previous
    # complete step and ``gc_incomplete`` reclaims the disk.
    DAMAGED_MARKER = "_DAMAGED"

    def __init__(self, directory: str | Path, *, keep_last: int = 3,
                 async_write: bool = True, gc_incomplete: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        if gc_incomplete:
            self.gc_incomplete()

    def gc_incomplete(self) -> list[str]:
        """Remove crash-orphaned partial checkpoints: ``_tmp_step_*``
        staging dirs, any ``step_*`` dir missing its ``_COMPLETE``
        marker, and any dir restore flagged ``_DAMAGED`` (payload failed
        validation).  Discovery (``_complete_steps``) already ignores
        them, so this is pure disk hygiene — restore semantics are
        unchanged.  Returns the removed dir names."""
        removed = []
        for p in sorted(self.dir.glob("_tmp_step_*")):
            shutil.rmtree(p, ignore_errors=True)
            removed.append(p.name)
        for p in sorted(self.dir.glob("step_*")):
            if p.is_dir() and (not (p / "_COMPLETE").exists()
                               or (p / self.DAMAGED_MARKER).exists()):
                shutil.rmtree(p, ignore_errors=True)
                removed.append(p.name)
        return removed

    # ---------------- save ----------------
    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None):
        """Snapshot ``tree`` at ``step``. Returns immediately if async."""
        leaves, treedef = _flatten_with_paths(tree)
        # Host-gather while the train step owns the devices; numpy copies
        # are cheap relative to a training step at scale.
        host_leaves = [np.asarray(x) for x in leaves]
        self.wait()

        def _write():
            tmp = self.dir / f"_tmp_step_{step:09d}"
            final = self.dir / f"step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            # tree structure is supplied by the caller's template at
            # restore time (mesh-independent); only leaves are persisted.
            manifest = {
                "step": step,
                "n_leaves": len(host_leaves),
                "leaves": [],
                "extra": extra or {},
            }
            for i, a in enumerate(host_leaves):
                fname = f"arr_{i:05d}.npy"
                np.save(tmp / fname, a)
                manifest["leaves"].append(
                    {"file": fname, "shape": list(a.shape),
                     "dtype": str(a.dtype),
                     # payload size on disk: lets restore detect a
                     # truncated leaf without parsing it
                     "nbytes": (tmp / fname).stat().st_size})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / "_COMPLETE").touch()
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self._complete_steps())
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ---------------- restore ----------------
    def _complete_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if ((p / "_COMPLETE").exists()
                    and not (p / self.DAMAGED_MARKER).exists()):
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self._complete_steps()
        return max(steps) if steps else None

    # ---------------- preemption marker ----------------
    # The fleet layer's resumable-exit protocol (repro.fleet.preempt): a
    # preempted run checkpoints at the next step boundary and leaves this
    # marker so launchers/sweep drivers can tell "stopped, resume me"
    # (exit PREEMPTED_EXIT_CODE) from "finished" or "crashed".  The
    # marker is consumed (cleared) by the run that resumes it.
    PREEMPT_MARKER = "_PREEMPTED.json"

    def write_preempt_marker(self, step: int, **info) -> Path:
        marker = self.dir / self.PREEMPT_MARKER
        tmp = self.dir / (self.PREEMPT_MARKER + ".tmp")
        tmp.write_text(json.dumps({"step": step, "resumable": True, **info}))
        tmp.rename(marker)     # atomic: readers never see a partial marker
        return marker

    def read_preempt_marker(self) -> Optional[dict]:
        marker = self.dir / self.PREEMPT_MARKER
        if not marker.exists():
            return None
        return json.loads(marker.read_text())

    def clear_preempt_marker(self) -> None:
        marker = self.dir / self.PREEMPT_MARKER
        if marker.exists():
            marker.unlink()

    def _flag_damaged(self, d: Path, err: str) -> None:
        try:
            (d / self.DAMAGED_MARKER).write_text(err)
        except OSError:
            pass   # flagging is best-effort; discovery re-validates anyway

    def _load_leaves(self, d: Path) -> tuple[dict, list]:
        """Read and validate one checkpoint dir's payload.  Raises
        :class:`CorruptCheckpoint` on any missing, truncated, garbled, or
        mismatched leaf — the caller decides whether to fall back."""
        try:
            manifest = json.loads((d / "manifest.json").read_text())
        except (OSError, json.JSONDecodeError) as e:
            raise CorruptCheckpoint(f"{d.name}: unreadable manifest: {e}")
        leaves = []
        for meta in manifest["leaves"]:
            f = d / meta["file"]
            if not f.exists():
                raise CorruptCheckpoint(f"{d.name}: missing leaf {meta['file']}")
            want = meta.get("nbytes")   # absent in pre-v10 checkpoints
            if want is not None and f.stat().st_size != want:
                raise CorruptCheckpoint(
                    f"{d.name}: {meta['file']} is {f.stat().st_size} bytes, "
                    f"manifest says {want} (truncated?)")
            try:
                a = np.load(f)
            except Exception as e:
                raise CorruptCheckpoint(
                    f"{d.name}: {meta['file']} unparseable: {e}")
            if list(a.shape) != meta["shape"] or str(a.dtype) != meta["dtype"]:
                raise CorruptCheckpoint(
                    f"{d.name}: {meta['file']} is {a.dtype}{list(a.shape)}, "
                    f"manifest says {meta['dtype']}{meta['shape']}")
            leaves.append(a)
        if len(leaves) != manifest.get("n_leaves", len(leaves)):
            raise CorruptCheckpoint(
                f"{d.name}: {len(leaves)} leaves vs n_leaves="
                f"{manifest.get('n_leaves')}")
        return manifest, leaves

    def restore(self, step: Optional[int] = None, *,
                template: Any = None, shardings: Any = None
                ) -> tuple[int, Any, dict]:
        """Load a checkpoint; re-shard onto ``shardings`` if given.

        ``template`` (a pytree with the same structure) is required to
        rebuild the tree; shapes/dtypes/sizes are validated against the
        manifest.  With ``step=None`` a checkpoint whose payload fails
        validation is flagged ``_DAMAGED`` and restore falls back to the
        next older complete step; an explicit ``step`` raises
        :class:`CorruptCheckpoint` instead.  Returns (step, tree, extra).
        """
        if step is None:
            candidates = sorted(self._complete_steps(), reverse=True)
            if not candidates:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
            manifest = leaves = None
            for s in candidates:
                d = self.dir / f"step_{s:09d}"
                try:
                    manifest, leaves = self._load_leaves(d)
                except CorruptCheckpoint as e:
                    self._flag_damaged(d, str(e))
                    continue
                step = s
                break
            if manifest is None:
                raise CorruptCheckpoint(
                    f"every complete checkpoint in {self.dir} is damaged")
        else:
            d = self.dir / f"step_{step:09d}"
            manifest, leaves = self._load_leaves(d)
        assert template is not None, "restore requires a template pytree"
        treedef = jax.tree_util.tree_structure(template)
        tmpl_leaves = treedef.flatten_up_to(template)
        assert len(tmpl_leaves) == len(leaves), \
            f"leaf count mismatch {len(tmpl_leaves)} vs {len(leaves)}"
        if shardings is not None:
            sh_leaves = treedef.flatten_up_to(shardings)
            leaves = [jax.device_put(a, s)
                      for a, s in zip(leaves, sh_leaves)]
        else:
            leaves = [jax.numpy.asarray(a) for a in leaves]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return step, tree, manifest.get("extra", {})

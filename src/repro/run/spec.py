"""RunSpec — the declarative, serializable description of one run.

A :class:`RunSpec` is everything the run layer needs to reconstruct a
training (or dry-run) scenario: which architecture at which shape, the
data configuration, the Opt-v2 optimizer (rule name + static factory
kwargs + dynamic hparams + schedule), mesh/sharding mode, microbatching,
and the checkpoint / eval / fault policies.  It is plain data — nested
frozen dataclasses of JSON-scalar fields — so a spec round-trips through
``to_json`` / ``from_json`` losslessly and can be logged next to every
artifact.  ``launch/train.py`` is just ``RunSpec.from_cli()`` + ``run()``;
``launch/dryrun.py`` lowers the *same* :class:`~repro.run.program.
StepProgram` a spec would train.

Two things are deliberately *not* in the spec:

* **Param groups.**  ``GroupSpec`` predicates are Python callables and
  can't serialize; ``build_step_program(spec, groups=...)`` takes them as
  a Python-level argument.  The default (``None``) is the paper-standard
  no-decay-on-1-D grouping whenever the rule has a ``weight_decay``
  hparam.
* **Live objects** (archs, iterators, hooks).  ``run()`` accepts those as
  overrides for programmatic callers (benchmarks warm-starting params,
  tests injecting batch iterators); the spec stays declarative.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping, Optional

from repro.data.pipeline import DataConfig
from repro.sentinel.spec import SentinelSpec
from repro.telemetry.probes import ObservabilitySpec

# Paper hyper-parameters (Table 6/7): AdaLomo lr ≈ 5e-4 (IT) / 1e-3
# (pretrain); AdamW 1e-5..2e-5; LOMO/SGD 1e-2.
DEFAULT_LRS = {"adalomo": 5e-4, "adafactor": 5e-4, "adamw": 2e-5,
               "lomo": 1e-2, "sgd": 1e-2, "sgd_momentum": 1e-2,
               "sgd_variance": 5e-4}

# Optimizers whose update is fused into the backward scan by default
# (LOMO-mechanism rules); the baselines default to the unfused path.
FUSED_BY_DEFAULT = ("adalomo", "lomo", "sgd")

SCHEDULES = ("cosine", "constant")
MESH_KINDS = ("none", "single", "multi")


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Which architecture, at which scale."""

    arch: str                      # registry id (or a label for ad-hoc archs)
    smoke: bool = False            # reduced CPU-sized config


@dataclasses.dataclass(frozen=True)
class OptSpec:
    """Opt-v2 optimizer: rule + schedule + dynamic hparams.

    ``kwargs`` are *static* rule-factory kwargs (``backend=``, ``cfg=``...);
    ``hparams`` are extra *dynamic* hyperparameters merged into the
    per-step hparams dict (schedulable without recompiles).  ``lr=None``
    picks the paper default for the rule (:data:`DEFAULT_LRS`).
    """

    name: str = "adalomo"
    lr: Optional[float] = None
    schedule: str = "cosine"
    warmup_frac: float = 0.03
    kwargs: dict = dataclasses.field(default_factory=dict)
    hparams: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"schedule {self.schedule!r} not in {SCHEDULES}")

    def resolved_lr(self) -> float:
        if self.lr is not None:
            return self.lr
        return DEFAULT_LRS.get(self.name, 1e-3)


@dataclasses.dataclass(frozen=True)
class StepSpec:
    """The step program's shape: length, fusion, microbatching.

    ``fused=None`` resolves by rule family (:data:`FUSED_BY_DEFAULT`).
    ``microbatches=k`` splits the global batch into k sequential
    microbatches inside one jitted step: the fused path does LOMO-style
    sequential per-microbatch *updates*; the unfused path accumulates
    gradients (see ``build_step_program``).
    """

    total: int = 100
    microbatches: int = 1
    fused: Optional[bool] = None

    def __post_init__(self):
        if self.microbatches < 1:
            raise ValueError(f"microbatches must be >= 1, "
                             f"got {self.microbatches}")

    def resolved_fused(self, opt_name: str) -> bool:
        if self.fused is not None:
            return self.fused
        return opt_name in FUSED_BY_DEFAULT


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Mesh + sharding mode (consumed by dry-run / multi-device paths).

    ``optimized=False`` is the paper-faithful baseline: no activation
    sharding policy, no gradient reduce-scatter constraint.

    ``shape`` is the *elastic* knob: a concrete device-mesh shape
    (1-D = data only, 2-D = (data, model), 3-D = (pod, data, model)).
    When set, ``run()`` executes the step sharded on that mesh
    (``repro.fleet.elastic``), and checkpoint restore re-shards onto it —
    the same RunSpec resumes on a smaller/larger mesh by changing only
    this field.  ``None`` keeps the single-process path.
    """

    kind: str = "none"             # "none" | "single" | "multi"
    optimized: bool = True
    shape: Optional[tuple] = None  # e.g. (4, 2) = 4-way data x 2-way model

    def __post_init__(self):
        if self.kind not in MESH_KINDS:
            raise ValueError(f"mesh kind {self.kind!r} not in {MESH_KINDS}")
        if self.shape is not None:
            shape = tuple(int(n) for n in self.shape)
            if not shape or len(shape) > 3 or any(n < 1 for n in shape):
                raise ValueError(
                    f"mesh shape must be 1-3 positive ints, got {self.shape}")
            # normalize (JSON round-trips lists) so specs compare equal
            object.__setattr__(self, "shape", shape)

    def n_devices(self) -> int:
        n = 1
        for s in self.shape or ():
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class ProfileSpec:
    """jax profiler trace for a step window (ProfilerHook).

    ``dir=None`` disables.  The trace covers steps ``[start, start+steps)``
    (0-based); the artifact directory gets a ``profile.runspec.json``
    sidecar stamping which RunSpec produced it.
    """

    dir: Optional[str] = None
    start: int = 1                 # skip step 0 (compile)
    steps: int = 2

    def __post_init__(self):
        if self.start < 0 or self.steps < 1:
            raise ValueError(
                f"profile window start={self.start} steps={self.steps}")


@dataclasses.dataclass(frozen=True)
class CheckpointSpec:
    dir: Optional[str] = None
    every: int = 0                 # 0 = disabled
    resume: bool = False
    keep_last: int = 3
    gc_incomplete: bool = False    # GC crash-orphaned partial step dirs


@dataclasses.dataclass(frozen=True)
class EvalSpec:
    every: int = 0                 # 0 = disabled
    n_batches: int = 4


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    heartbeat_timeout_s: float = 0.0   # 0 = disabled
    # Max transient-failure recoveries per run: each restores the latest
    # complete checkpoint and rewinds the data stream (donated step
    # buffers make blind re-invocation impossible — see run()).
    retries: int = 2
    # Preemption safety (repro.fleet.preempt): catch SIGTERM/SIGINT,
    # checkpoint at the next step boundary, write a resumable marker and
    # raise Preempted (launchers exit PREEMPTED_EXIT_CODE).  Only active
    # when the run has a checkpoint manager and owns the main thread.
    preempt: bool = True
    # Deterministic (jitterless) exponential backoff between transient-
    # failure recoveries: attempt n sleeps min(base * 2**(n-1), max).
    # base 0.0 = no sleep (restore immediately).
    retry_backoff_s: float = 0.0
    retry_backoff_max_s: float = 30.0


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One run, declaratively.  See module docstring."""

    model: ModelSpec
    data: Optional[DataConfig] = None
    opt: OptSpec = dataclasses.field(default_factory=OptSpec)
    steps: StepSpec = dataclasses.field(default_factory=StepSpec)
    mesh: MeshSpec = dataclasses.field(default_factory=MeshSpec)
    checkpoint: CheckpointSpec = dataclasses.field(
        default_factory=CheckpointSpec)
    eval: EvalSpec = dataclasses.field(default_factory=EvalSpec)
    fault: FaultSpec = dataclasses.field(default_factory=FaultSpec)
    profile: ProfileSpec = dataclasses.field(default_factory=ProfileSpec)
    observe: ObservabilitySpec = dataclasses.field(
        default_factory=ObservabilitySpec)
    sentinel: SentinelSpec = dataclasses.field(default_factory=SentinelSpec)
    log_every: int = 10
    seed: int = 0
    # JSONL metrics export (MetricsHook): step, loss, tokens/s, padding
    # efficiency.  None = disabled.
    metrics_path: Optional[str] = None

    def __post_init__(self):
        if (self.data is not None and self.steps.microbatches > 1
                and self.data.global_batch % self.steps.microbatches):
            raise ValueError(
                f"global_batch={self.data.global_batch} not divisible by "
                f"microbatches={self.steps.microbatches}")

    # ---------------- serialization ----------------
    def to_dict(self) -> dict:
        # JSON-canonical: tuples (e.g. ObservabilitySpec.hist_range)
        # become lists so to_dict() == json round-trip of itself;
        # from_dict normalizes back to tuples.
        def canon(x):
            if isinstance(x, dict):
                return {k: canon(v) for k, v in x.items()}
            if isinstance(x, (list, tuple)):
                return [canon(v) for v in x]
            return x

        return canon(dataclasses.asdict(self))

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RunSpec":
        d = dict(d)

        def sub(key, klass):
            if d.get(key) is not None:
                d[key] = klass(**d[key])

        sub("model", ModelSpec)
        sub("data", DataConfig)
        sub("opt", OptSpec)
        sub("steps", StepSpec)
        sub("mesh", MeshSpec)
        sub("checkpoint", CheckpointSpec)
        sub("eval", EvalSpec)
        sub("fault", FaultSpec)
        sub("profile", ProfileSpec)
        sub("observe", ObservabilitySpec)
        sub("sentinel", SentinelSpec)
        return cls(**d)

    @classmethod
    def from_json(cls, text: str) -> "RunSpec":
        return cls.from_dict(json.loads(text))

    # ---------------- CLI ----------------
    @classmethod
    def from_cli(cls, argv=None) -> "RunSpec":
        import argparse
        ap = argparse.ArgumentParser()
        add_cli_args(ap)
        return from_cli_args(ap.parse_args(argv))


def add_cli_args(ap) -> None:
    """Install the RunSpec flag set on an argparse parser (shared by
    ``launch/train.py``; kept here so the CLI surface and the spec can't
    drift)."""
    ap.add_argument("--arch", default=None,
                    help="architecture registry id (required unless --spec)")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--optimizer", default="adalomo")
    ap.add_argument("--lr", type=float, default=None,
                    help="base lr (default: paper value for the optimizer)")
    ap.add_argument("--schedule", default="cosine", choices=SCHEDULES)
    ap.add_argument("--weight-decay", type=float, default=None,
                    help="decoupled weight decay (Opt v2 dynamic hparam; "
                         "1-D params are auto-grouped to no-decay)")
    ap.add_argument("--opt-backend", default=None,
                    choices=["auto", "jnp", "pallas"],
                    help="AdaLomo update backend (Pallas kernel on TPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--unfused", action="store_true")
    ap.add_argument("--source", default="synthetic",
                    choices=["synthetic", "memmap"])
    ap.add_argument("--data-path", default=None,
                    help="packed .bin token file (--source memmap)")
    ap.add_argument("--packing", action="store_true",
                    help="segment-packed ragged batches (PackedBatch "
                         "layout: segment ids, per-segment positions, "
                         "loss mask)")
    ap.add_argument("--metrics-path", default=None,
                    help="JSONL metrics file (MetricsHook): step, loss, "
                         "tokens/s, padding efficiency")
    ap.add_argument("--observe-every", type=int, default=0,
                    help="record optimizer-health probes (group update/"
                         "param norm ratios, effective-lr histogram) every "
                         "N steps into the metrics stream; 0 = off")
    ap.add_argument("--observe-factored-every", type=int, default=0,
                    help="factored-moment reconstruction-error probe "
                         "cadence (0 = follow --observe-every)")
    ap.add_argument("--observe-tensors", type=int, default=2,
                    help="how many of the largest moment tensors the "
                         "reconstruction probe samples")
    ap.add_argument("--mesh-shape", default=None,
                    help="elastic device-mesh shape, e.g. 4x2 = 4-way data "
                         "x 2-way model (runs the step sharded; checkpoint "
                         "restore re-shards onto it)")
    ap.add_argument("--profile-dir", default=None,
                    help="jax profiler trace output dir (ProfilerHook)")
    ap.add_argument("--profile-start", type=int, default=1,
                    help="first profiled step (0-based; default skips the "
                         "compile step)")
    ap.add_argument("--profile-steps", type=int, default=2,
                    help="number of steps in the trace window")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable the SIGTERM/SIGINT "
                         "checkpoint-and-exit-resumable handler")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--gc-incomplete", action="store_true",
                    help="GC crash-orphaned partial checkpoint dirs at start")
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--heartbeat-timeout", type=float, default=0.0)
    ap.add_argument("--retry-backoff", type=float, default=0.0,
                    help="transient-failure retry backoff base seconds "
                         "(deterministic: attempt n sleeps base * 2^(n-1), "
                         "capped at 30s; 0 = restore immediately)")
    ap.add_argument("--sentinel", action="store_true",
                    help="enable the training sentinel: in-graph anomaly "
                         "guards (non-finite / update-norm spike / trust "
                         "ratio) with skip/backoff/rollback policies")
    ap.add_argument("--sentinel-ladder", default="skip",
                    help="comma-joined policy rungs, 'skip' first "
                         "(skip[,backoff][,rollback])")
    ap.add_argument("--sentinel-spike-factor", type=float, default=10.0,
                    help="anomaly when update norm exceeds this multiple "
                         "of its clean-step EMA")
    ap.add_argument("--sentinel-trust-max", type=float, default=0.0,
                    help="per-group trust-ratio ceiling (0 = guard off)")
    ap.add_argument("--sentinel-budget", type=int, default=8,
                    help="lifetime anomaly allowance before the run "
                         "aborts loudly")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)


def parse_mesh_shape(text: Optional[str]) -> Optional[tuple]:
    """``"4x2"`` / ``"4,2"`` → ``(4, 2)`` with a clear CLI error."""
    if not text:
        return None
    try:
        shape = tuple(int(p) for p in text.replace(",", "x").split("x") if p)
        if not shape or any(n < 1 for n in shape):
            raise ValueError
    except ValueError:
        raise SystemExit(
            f"--mesh-shape: expected e.g. 4x2 or 2x2x2, got {text!r}")
    return shape


def from_cli_args(args) -> RunSpec:
    """Build a RunSpec from parsed :func:`add_cli_args` flags."""
    if not args.arch:
        raise SystemExit("--arch is required (or pass --spec <file.json>)")
    hparams = ({} if args.weight_decay is None
               else {"weight_decay": args.weight_decay})
    kwargs = ({} if args.opt_backend is None
              else {"backend": args.opt_backend})
    mesh_shape = parse_mesh_shape(args.mesh_shape)
    return RunSpec(
        model=ModelSpec(arch=args.arch, smoke=args.smoke),
        # vocab=0 → resolved from the arch config by run()
        data=DataConfig(vocab=0, seq_len=args.seq, global_batch=args.batch,
                        seed=args.seed, source=args.source,
                        path=args.data_path, packing=args.packing),
        opt=OptSpec(name=args.optimizer, lr=args.lr, schedule=args.schedule,
                    kwargs=kwargs, hparams=hparams),
        steps=StepSpec(total=args.steps, microbatches=args.microbatches,
                       fused=(False if args.unfused else None)),
        mesh=(MeshSpec(kind="multi", shape=mesh_shape)
              if mesh_shape else MeshSpec()),
        checkpoint=CheckpointSpec(dir=args.ckpt_dir, every=args.ckpt_every,
                                  resume=args.resume,
                                  gc_incomplete=args.gc_incomplete),
        eval=EvalSpec(every=args.eval_every),
        fault=FaultSpec(heartbeat_timeout_s=args.heartbeat_timeout,
                        preempt=not args.no_preempt,
                        retry_backoff_s=args.retry_backoff),
        profile=ProfileSpec(dir=args.profile_dir, start=args.profile_start,
                            steps=args.profile_steps),
        observe=ObservabilitySpec(
            optimizer_every=args.observe_every,
            factored_every=args.observe_factored_every,
            sample_tensors=args.observe_tensors),
        sentinel=SentinelSpec(
            enabled=args.sentinel,
            ladder=tuple(p for p in args.sentinel_ladder.split(",") if p),
            spike_factor=args.sentinel_spike_factor,
            trust_max=args.sentinel_trust_max,
            budget=args.sentinel_budget),
        log_every=args.log_every,
        seed=args.seed,
        metrics_path=args.metrics_path)

"""StepProgram — the one train-step builder for every scenario.

``build_step_program(spec, arch, opt)`` owns the full step-construction
matrix that used to be inlined in ``Trainer._build_step`` and re-derived
by every launcher/benchmark:

  * **fused × unfused** — LOMO/AdaLomo's update-in-the-backward-scan vs
    the ``jax.value_and_grad`` + ``Opt.step`` baseline path;
  * **microbatching** — the fused path does LOMO-style *sequential
    per-microbatch updates* under ``lax.scan`` (classic accumulation would
    materialize the full gradient pytree — exactly what LOMO avoids); the
    unfused path accumulates gradients and applies one update;
  * **sharding constraints** — residual/grad/param constraints (ZeRO-style)
    are threaded into ``arch.make_fused_train_step`` so multi-device
    dry-runs lower the *same* program single-process training runs.

The resulting :class:`StepProgram` carries the pure callable (``fn``), the
jitted step with (params, opt_state) donation (``step``), the hparam
schedule (``hparams_fn`` — call-time data, zero recompiles, Opt-v2
contract), and the abstract ``ShapeDtypeStruct`` signature
(``abstract_args``) so ``launch/dryrun.py`` lowers exactly what
``launch/train.py`` would execute.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import optimizers as opt_lib
from repro.core.api import Opt, no_decay_1d
from repro.run.spec import RunSpec
from repro.train.schedules import constant, warmup_cosine


def _split_microbatches(batch, k: int):
    """[k*b, ...] -> [k, b, ...] per leaf, with a clear divisibility error."""

    def split(x):
        if x.shape[0] % k:
            raise ValueError(
                f"batch dim {x.shape[0]} not divisible by microbatches={k}")
        return x.reshape((k, x.shape[0] // k) + x.shape[1:])

    return jax.tree.map(split, batch)


def _apply_loss_mask(batch):
    """Packed-batch loss contract: slots where ``loss_mask`` is False
    (padding, cross-segment label shifts) never reach the loss.  The
    packer already emits -1 labels there; masking again at step entry
    makes the contract hold for any injected batch iterator too."""
    if not isinstance(batch, dict) or "loss_mask" not in batch:
        return batch
    batch = dict(batch)
    batch["labels"] = jnp.where(batch["loss_mask"], batch["labels"], -1)
    return batch


@dataclasses.dataclass
class StepProgram:
    """One compiled training step + everything needed to drive or lower it.

    ``fn(params, opt_state, batch, hparams)`` is the pure callable —
    re-jittable under explicit shardings (dry-run); ``step`` is the same
    callable jitted with ``donate_argnums=(0, 1)`` (in-place buffer reuse,
    the low-memory contract).  ``hparams_fn(step)`` returns the dynamic
    hparams pytree for the 1-based step — identical dict structure every
    step, so the jitted step never recompiles under schedules.
    """

    spec: RunSpec
    arch: Any
    opt: Opt
    fused: bool
    fn: Callable
    step: Any
    hparams_fn: Callable[[int], dict]
    _loss_fn: Any = None

    # ---------------- drive ----------------
    def init(self, seed: int = 0):
        params = self.arch.init_params(jax.random.PRNGKey(seed))
        return params, self.opt.init(params)

    @property
    def loss_fn(self):
        """Jitted eval loss fn (lazy; shared by EvalHook / Trainer)."""
        if self._loss_fn is None:
            self._loss_fn = jax.jit(self.arch.make_loss_fn())
        return self._loss_fn

    # ---------------- sentinel ----------------
    @property
    def sentinel_enabled(self) -> bool:
        return self.spec.sentinel.enabled

    def init_sentinel(self):
        """Fresh device SentinelState, or None when the guard is off."""
        if not self.sentinel_enabled:
            return None
        from repro.sentinel.guard import init_sentinel_state
        return init_sentinel_state()

    # ---------------- introspection ----------------
    def abstract_args(self) -> tuple:
        """(params, opt_state, batch, hparams[, sentinel]) as
        ShapeDtypeStruct pytrees — the jit signature, derived from the
        spec with zero allocation.  This is what makes dry-run lower the
        identical program it would train.  The sentinel slot appears only
        when ``spec.sentinel.enabled`` (4-tuple otherwise — the pre-
        sentinel signature every existing consumer unpacks)."""
        if self.spec.data is None:
            raise ValueError("abstract_args requires spec.data")
        params_sds = jax.eval_shape(
            lambda: self.arch.init_params(jax.random.PRNGKey(0)))
        opt_sds = jax.eval_shape(self.opt.init, params_sds)
        d = self.spec.data
        batch_sds = self.arch.train_batch_specs(d.global_batch, d.seq_len,
                                                packed=d.packing)
        hp_sds = jax.tree.map(
            lambda _: jax.ShapeDtypeStruct((), jnp.float32),
            self.hparams_fn(1))
        if self.sentinel_enabled:
            from repro.sentinel.guard import init_sentinel_state
            sent_sds = jax.eval_shape(init_sentinel_state)
            return params_sds, opt_sds, batch_sds, hp_sds, sent_sds
        return params_sds, opt_sds, batch_sds, hp_sds

    def lower(self):
        """Lower the donated jitted step on the abstract signature."""
        return self.step.lower(*self.abstract_args())

    def cache_size(self) -> int:
        """Jit cache entries for the step — 1 after any number of steps is
        the zero-steady-state-recompile guarantee."""
        return self.step._cache_size()


def build_step_program(spec: RunSpec, arch=None, opt: Optional[Opt] = None,
                       *, groups=None, residual_constraint=None,
                       grad_constraint=None, param_constraint=None,
                       global_grad_norm=None, donate: bool = True,
                       inject=None) -> StepProgram:
    """Assemble the :class:`StepProgram` for ``spec``.

    ``arch`` defaults to the registry lookup of ``spec.model``; pass an
    explicit :class:`~repro.models.registry.Arch` for ad-hoc configs
    (benchmarks' tiny proxies).  ``groups=None`` applies the paper-standard
    no-decay-on-1-D grouping when the rule has a ``weight_decay`` hparam.
    The sharding-constraint kwargs mirror ``arch.make_fused_train_step``
    (fused path only) so dry-run cells build through this same function.
    ``inject`` (a :class:`repro.sentinel.inject.Injection`) arms the
    in-graph fault injector inside the sentinel guard — it requires
    ``spec.sentinel.enabled`` because the guard owns the injection point.
    """
    if arch is None:
        from repro.models.registry import get_arch
        arch = get_arch(spec.model.arch, smoke=spec.model.smoke)
    if spec.data is not None and spec.data.packing:
        # fail at build time, not trace time, for unsupported families
        arch.train_batch_specs(spec.data.global_batch, spec.data.seq_len,
                               packed=True)
    if opt is None:
        rule = opt_lib.get_rule(spec.opt.name, **spec.opt.kwargs)
        if groups is None:
            groups = ((no_decay_1d(),)
                      if "weight_decay" in rule.hparams else ())
        opt = Opt(rule, groups=groups)

    fused = spec.steps.resolved_fused(spec.opt.name)
    k = spec.steps.microbatches
    base_lr = spec.opt.resolved_lr()
    lr_fn = (warmup_cosine(base_lr, spec.steps.total, spec.opt.warmup_frac)
             if spec.opt.schedule == "cosine" else constant(base_lr))
    extras = dict(spec.opt.hparams)

    def hparams_fn(step: int) -> dict:
        """Dynamic hparams for the 1-based ``step``: scheduled lr + spec
        extras.  The schedule is authoritative for lr."""
        return {**extras, "lr": lr_fn(step)}

    if fused:
        step_kw = arch.make_fused_train_step(
            opt, residual_constraint=residual_constraint,
            global_grad_norm=global_grad_norm,
            grad_constraint=grad_constraint,
            param_constraint=param_constraint)

        def one_step(params, opt_state, batch, hp):
            batch = _apply_loss_mask(batch)
            return step_kw(params, opt_state, batch, hparams=hp)

        if k > 1:
            inner = one_step

            def one_step(params, opt_state, batch, hp):  # noqa: F811
                # LOMO-style: sequential updates per microbatch.
                mb = _split_microbatches(batch, k)

                def body(carry, b):
                    p, s = carry
                    p, s, loss, metrics = inner(p, s, b, hp)
                    return (p, s), (loss, metrics)

                (params, opt_state), (losses, metrics) = jax.lax.scan(
                    body, (params, opt_state), mb)
                return (params, opt_state, losses.mean(),
                        jax.tree.map(lambda m: m.mean(), metrics))
    else:
        if (residual_constraint is not None or grad_constraint is not None
                or param_constraint is not None
                or global_grad_norm is not None):
            raise ValueError("sharding constraints / global_grad_norm "
                             "require the fused path")
        loss_fn = arch.make_loss_fn()

        def one_step(params, opt_state, batch, hp):
            batch = _apply_loss_mask(batch)
            if k > 1:
                mb = _split_microbatches(batch, k)

                def body(g_acc, b):
                    (loss, metrics), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, b)
                    return jax.tree.map(jnp.add, g_acc, g), (loss, metrics)

                g0 = jax.tree.map(jnp.zeros_like, params)
                grads, (losses, metrics) = jax.lax.scan(body, g0, mb)
                grads = jax.tree.map(lambda g: g / k, grads)
                loss = losses.mean()
                metrics = jax.tree.map(lambda m: m.mean(), metrics)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            params2, opt2 = opt.step(params, grads, opt_state, hp)
            return params2, opt2, loss, metrics

    if inject is not None and not spec.sentinel.enabled:
        raise ValueError("fault injection requires spec.sentinel.enabled "
                         "(the sentinel guard owns the injection point)")
    if spec.sentinel.enabled:
        # Sentinel guard folds into the SAME jitted program (the step
        # signature grows a SentinelState slot): in-graph detection, the
        # jnp.where skip-commit, and the verdict in metrics["sentinel"]
        # riding the runner's one bundled device_get.  When probes are
        # also enabled the guard computes them itself on the COMMITTED
        # transition — a skipped step reports what actually landed.
        from repro.sentinel.guard import guard_step
        one_step = guard_step(
            one_step, opt=opt, sspec=spec.sentinel,
            ospec=spec.observe if spec.observe.enabled else None,
            inject=inject)
    elif spec.observe.enabled:
        # Optimizer-health probes fold into the SAME jitted program: the
        # probe reductions are in-graph (constant metrics structure, so
        # no recompiles) and their scalars ride the runner's one bundled
        # per-step device_get alongside loss/metrics (repro-lint R2).
        from repro.telemetry.probes import instrument_step
        one_step = instrument_step(one_step, opt=opt, ospec=spec.observe)
    jitted = (jax.jit(one_step, donate_argnums=(0, 1)) if donate
              else jax.jit(one_step))
    return StepProgram(spec=spec, arch=arch, opt=opt, fused=fused,
                       fn=one_step, step=jitted, hparams_fn=hparams_fn)

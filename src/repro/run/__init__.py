"""Run API v1 — declarative RunSpec + composable step program + hook
pipeline: one entrypoint for train / dryrun / benchmarks (DESIGN.md
§"Run API v1").

    spec = RunSpec(model=ModelSpec("h2o-danube-1.8b", smoke=True),
                   data=DataConfig(vocab=0, seq_len=128, global_batch=8),
                   opt=OptSpec(name="adalomo"), steps=StepSpec(total=100))
    result = run(spec)                     # result.history, result.params

    program = build_step_program(spec)     # the same jitted step dryrun
    program.lower()                        # lowers — no loop duplication
"""
from repro.run.hooks import (CheckpointHook, EvalHook, HeartbeatHook,
                             HistoryHook, Hook, LoggingHook, MetricsHook,
                             ProfilerHook, StepEvent, StragglerHook,
                             TimingHook, find_metrics_hook)
from repro.run.program import StepProgram, build_step_program
from repro.run.runner import RunContext, RunResult, run
from repro.run.spec import (DEFAULT_LRS, CheckpointSpec, EvalSpec,
                            FaultSpec, MeshSpec, ModelSpec, OptSpec,
                            ProfileSpec, RunSpec, StepSpec)
from repro.sentinel.spec import SentinelSpec
from repro.telemetry.probes import ObservabilitySpec

__all__ = [
    "RunSpec", "ModelSpec", "OptSpec", "StepSpec", "MeshSpec",
    "CheckpointSpec", "EvalSpec", "FaultSpec", "ProfileSpec",
    "ObservabilitySpec", "SentinelSpec",
    "DEFAULT_LRS",
    "StepProgram", "build_step_program",
    "Hook", "StepEvent", "HistoryHook", "LoggingHook", "MetricsHook",
    "EvalHook", "CheckpointHook", "HeartbeatHook", "StragglerHook",
    "TimingHook", "ProfilerHook", "find_metrics_hook",
    "run", "RunResult", "RunContext",
]

"""``run(spec)`` — the one entrypoint for train / dryrun / benchmarks.

Assembles arch + :class:`~repro.run.program.StepProgram` + data + hook
pipeline from a :class:`~repro.run.spec.RunSpec` and drives the loop.
Every knob has a programmatic override (prebuilt program, warm-start
params, injected iterators, extra hooks) so benchmarks and tests compose
scenarios without re-wiring the loop — the spec stays the single source
of truth for what is *declarable*, the overrides carry what is not.

Default hook order (measurement before side effects; see
``repro.run.hooks``): straggler → heartbeat → profiler → history →
logging → metrics → eval → checkpoint → preemption → user hooks.

When ``spec.mesh.shape`` names a concrete device mesh, the loop runs the
*same* step program sharded on it (``repro.fleet.elastic``): checkpoint
restore re-shards onto the mesh, so a run resumes elastically on a
smaller or larger fleet by editing only that field.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional, Sequence, Type

import jax
import jax.numpy as jnp

from repro.run import hooks as hooks_lib
from repro.run.data import EVAL_SEED_OFFSET, make_batch_iter
from repro.run.program import StepProgram, build_step_program
from repro.run.spec import RunSpec


def _host_scalars(tree):
    """Convert the per-step observables (already on host via one bundled
    ``jax.device_get``) to plain Python floats; non-scalar leaves pass
    through as numpy arrays."""
    def conv(x):
        if isinstance(x, (bool, int, float)) or x is None:
            return x
        if getattr(x, "ndim", None) == 0:
            return float(x)
        return x
    return jax.tree.map(conv, tree)


def _retriable_errors() -> tuple:
    """Transient device-side failures worth a checkpoint-restore retry
    (preempted TPU, ICI link flap)."""
    try:
        from jax.errors import JaxRuntimeError  # jax >= 0.4.14
        return (JaxRuntimeError,)
    except ImportError:  # pragma: no cover
        return (RuntimeError,)


@dataclasses.dataclass
class RunContext:
    """What hooks see: the spec, the program, the live (params, opt_state)
    after the most recent step, and the dispatch surface."""

    spec: RunSpec
    program: StepProgram
    params: Any
    opt_state: Any
    log: Callable[[str], None]
    hooks: tuple
    ckpt_manager: Any = None
    start_step: int = 0
    # SentinelMonitor when spec.sentinel.enabled (CheckpointHook persists
    # its to_extra() so resume rebuilds the device SentinelState exactly).
    sentinel: Any = None

    def dispatch_eval(self, step: int, metrics: dict) -> None:
        for h in self.hooks:
            h.on_eval(self, step, metrics)


@dataclasses.dataclass
class RunResult:
    params: Any
    opt_state: Any
    history: dict
    start_step: int
    program: StepProgram
    hooks: tuple

    def find_hook(self, cls: Type) -> Optional[hooks_lib.Hook]:
        for h in self.hooks:
            if isinstance(h, cls):
                return h
        return None


def _default_hooks(spec: RunSpec, *, eval_iter, eval_factory, ckpt_manager,
                   log_fn, user_hooks) -> tuple:
    """The standard pipeline; a user hook of the same class replaces the
    default instance (so e.g. a caller-owned StragglerMonitor keeps
    accumulating across runs)."""
    user = tuple(user_hooks)

    def absent(cls):
        return not any(isinstance(h, cls) for h in user)

    out = []
    if absent(hooks_lib.StragglerHook):
        out.append(hooks_lib.StragglerHook())
    if spec.fault.heartbeat_timeout_s > 0 and absent(hooks_lib.HeartbeatHook):
        out.append(hooks_lib.HeartbeatHook(spec.fault.heartbeat_timeout_s))
    if spec.profile.dir and absent(hooks_lib.ProfilerHook):
        out.append(hooks_lib.ProfilerHook(spec.profile.dir,
                                          start=spec.profile.start,
                                          steps=spec.profile.steps))
    if absent(hooks_lib.HistoryHook):
        out.append(hooks_lib.HistoryHook())
    if spec.log_every and absent(hooks_lib.LoggingHook):
        out.append(hooks_lib.LoggingHook(spec.log_every, log_fn,
                                         total=spec.steps.total))
    if spec.metrics_path and absent(hooks_lib.MetricsHook):
        out.append(hooks_lib.MetricsHook(spec.metrics_path))
    if spec.eval.every and absent(hooks_lib.EvalHook):
        if eval_iter is not None:
            out.append(hooks_lib.EvalHook(eval_iter, spec.eval.every,
                                          spec.eval.n_batches))
        elif eval_factory is not None:
            out.append(hooks_lib.EvalHook(every=spec.eval.every,
                                          n_batches=spec.eval.n_batches,
                                          iter_factory=eval_factory))
    if (ckpt_manager is not None and spec.checkpoint.every
            and absent(hooks_lib.CheckpointHook)):
        out.append(hooks_lib.CheckpointHook(ckpt_manager,
                                            spec.checkpoint.every))
    if spec.fault.preempt and ckpt_manager is not None:
        # after CheckpointHook: a preemption boundary that coincides with
        # a scheduled save reuses it.  Lazy import — the fleet layer
        # builds on repro.run, not the other way around.
        from repro.fleet.preempt import PreemptionHook
        if absent(PreemptionHook):
            out.append(PreemptionHook(ckpt_manager))
    return tuple(out) + user


def run(spec: RunSpec, *, arch=None, program: Optional[StepProgram] = None,
        hooks: Sequence[hooks_lib.Hook] = (), params=None, opt_state=None,
        batch_iter: Optional[Iterator[dict]] = None, eval_iter=None,
        ckpt_manager=None, start_step: int = 0, groups=None,
        inject=None, log_fn: Callable[[str], None] = print) -> RunResult:
    """Drive one run end-to-end.  Overrides (all optional):

    ``arch``       an Arch instance for ad-hoc configs (else registry);
    ``program``    a prebuilt StepProgram (else ``build_step_program``);
    ``params`` / ``opt_state``  warm starts (opt_state defaults to a fresh
                   ``opt.init(params)``);
    ``batch_iter`` / ``eval_iter``  injected data streams (else built from
                   ``spec.data``, eval stream seed-offset);
    ``ckpt_manager``  a CheckpointManager (else built from
                   ``spec.checkpoint.dir``); resume restores the latest
                   complete step and fast-forwards the data stream;
    ``hooks``      appended after the default pipeline (same-class user
                   hooks replace the default instance);
    ``start_step`` begin mid-schedule without a checkpoint;
    ``inject``     an in-graph fault :class:`~repro.sentinel.inject.
                   Injection` (chaos harness; requires
                   ``spec.sentinel.enabled`` and no prebuilt program).
    """
    if program is None:
        if spec.mesh.shape is not None:
            # Elastic path: same spec, sharded step.  run_elastic builds
            # the sharded program and re-enters run() with it, so this
            # cannot recurse.
            from repro.fleet.elastic import run_elastic
            return run_elastic(spec, arch=arch, hooks=hooks, params=params,
                               opt_state=opt_state, batch_iter=batch_iter,
                               eval_iter=eval_iter, ckpt_manager=ckpt_manager,
                               start_step=start_step, groups=groups,
                               inject=inject, log_fn=log_fn)
        program = build_step_program(spec, arch, groups=groups,
                                     inject=inject)
    elif inject is not None:
        raise ValueError("inject requires run() to build the program "
                         "(pass inject to build_step_program instead)")
    arch = program.arch

    # --- training sentinel (host side) --------------------------------
    monitor = None
    sent = program.init_sentinel()
    if program.sentinel_enabled:
        from repro.sentinel.policy import SentinelMonitor
        monitor = SentinelMonitor(spec.sentinel)

    if params is None:
        params, opt_state = program.init(spec.seed)
    elif opt_state is None:
        opt_state = program.opt.init(params)

    if spec.mesh.kind != "none" and spec.mesh.shape is None:
        # A sharding *mode* without a concrete shape is only consumed by
        # dry-run lowering.  Say so rather than silently dropping a
        # declared mode on spec replay (set mesh.shape for elastic
        # execution inside run()).
        log_fn(f"note: spec.mesh.kind={spec.mesh.kind!r} is recorded but "
               "run() executes single-process; use launch/dryrun.py for "
               "mesh lowering or set mesh.shape for elastic execution")

    ck = spec.checkpoint
    if ckpt_manager is None and ck.dir:
        from repro.checkpoint.manager import CheckpointManager
        ckpt_manager = CheckpointManager(ck.dir, keep_last=ck.keep_last,
                                         gc_incomplete=ck.gc_incomplete)
    def _restore_sentinel(extra):
        """Rebuild monitor + device SentinelState from checkpoint extra —
        bitwise resume includes the sentinel's cross-step memory."""
        nonlocal sent
        snap = (extra or {}).get("sentinel")
        if monitor is None or not snap:
            return
        from repro.sentinel.guard import state_from_snapshot
        monitor.load_extra(snap)
        if snap.get("state"):
            sent = state_from_snapshot(snap["state"])

    if (ckpt_manager is not None and ck.resume
            and ckpt_manager.latest_step() is not None):
        start_step, (params, opt_state), _extra = ckpt_manager.restore(
            template=(params, opt_state))
        _restore_sentinel(_extra)
        log_fn(f"resumed from step {start_step}")

    def _train_iter(s):
        """The step-keyed train stream from step ``s`` — with quarantined
        ranges substituted when the sentinel has rolled back."""
        if monitor is not None:
            from repro.sentinel.policy import quarantined_batch_iter
            return quarantined_batch_iter(spec, arch, s, monitor)
        return make_batch_iter(spec, arch, s)

    own_batch_iter = batch_iter is None
    if batch_iter is None:
        batch_iter = _train_iter(start_step)
    eval_factory = None
    if eval_iter is None and spec.eval.every and spec.data is not None:
        # The default held-out stream is a pure function of how many eval
        # batches the run has consumed, so EvalHook can fast-forward on
        # resume and rewind on fault recovery (deterministic eval curve).
        def eval_factory(start_batch, _spec=spec, _arch=arch):
            return make_batch_iter(_spec, _arch, start_batch,
                                   seed_offset=EVAL_SEED_OFFSET)

    pipeline = _default_hooks(spec, eval_iter=eval_iter,
                              eval_factory=eval_factory,
                              ckpt_manager=ckpt_manager, log_fn=log_fn,
                              user_hooks=hooks)
    ctx = RunContext(spec=spec, program=program, params=params,
                     opt_state=opt_state, log=log_fn, hooks=pipeline,
                     ckpt_manager=ckpt_manager, start_step=start_step,
                     sentinel=monitor)

    # Transient-failure policy: the jitted step donates (params, opt_state),
    # so a failed call may have consumed its input buffers — re-invoking
    # with the same arguments can never succeed (the flaw in the old
    # Trainer's blind retry).  Recovery therefore goes through the
    # checkpoint: restore the latest complete step, rewind the (stateless,
    # step-keyed) data stream, and resume the loop from there.  Without a
    # checkpoint — or with a caller-injected batch iterator we cannot
    # rewind — the error propagates immediately.  Hooks re-observe the
    # re-executed steps, so the history is the truthful training record.
    retriable = _retriable_errors()
    failures = 0
    try:
        # on_run_start inside the try: if a hook raises here, earlier
        # hooks that already started (watchdog threads, async writers)
        # still get their on_exit.
        for h in pipeline:
            h.on_run_start(ctx)
        t_last = time.time()
        step = start_step
        while step < spec.steps.total:
            batch = jax.tree.map(jnp.asarray, next(batch_iter))
            hp = program.hparams_fn(step + 1)
            try:
                if sent is None:
                    ctx.params, ctx.opt_state, loss, metrics = program.step(
                        ctx.params, ctx.opt_state, batch, hp)
                else:
                    (ctx.params, ctx.opt_state, loss, metrics,
                     sent) = program.step(ctx.params, ctx.opt_state, batch,
                                          hp, sent)
            except retriable as e:
                failures += 1
                if ckpt_manager is not None:
                    ckpt_manager.wait()  # drain any in-flight async save
                # Every stream must rewind for recovery to reproduce the
                # uninterrupted run: caller-injected train or eval
                # iterators cannot, so the error propagates instead of
                # silently diverging the curves.
                rewindable_eval = all(
                    h.iter_factory is not None for h in pipeline
                    if isinstance(h, hooks_lib.EvalHook) and h.every)
                recoverable = (failures <= spec.fault.retries
                               and own_batch_iter and rewindable_eval
                               and ckpt_manager is not None
                               and ckpt_manager.latest_step() is not None)
                if not recoverable:
                    raise
                # Deterministic (jitterless) exponential backoff before
                # the restore: attempt n waits base * 2^(n-1), capped.
                delay = 0.0
                if spec.fault.retry_backoff_s > 0:
                    delay = min(
                        spec.fault.retry_backoff_s * 2.0 ** (failures - 1),
                        spec.fault.retry_backoff_max_s)
                    time.sleep(delay)
                restored, (p, s), _extra = ckpt_manager.restore(
                    template=(ctx.params, ctx.opt_state))
                _restore_sentinel(_extra)
                log_fn(f"step {step} failed ({type(e).__name__}); "
                       f"restored step {restored} "
                       f"(attempt {failures}/{spec.fault.retries})")
                ctx.params, ctx.opt_state = p, s
                failed_at, step = step, restored
                batch_iter = _train_iter(restored)
                for h in pipeline:
                    h.on_recover(ctx, restored)
                # after on_recover: the truncation must not eat the event
                mh = hooks_lib.find_metrics_hook(pipeline)
                if mh is not None:
                    mh.annotate("recover", restored, attempt=failures,
                                failed_step=failed_at, backoff_s=delay)
                t_last = time.time()
                continue
            now = time.time()
            # The ONE device->host sync of the step loop: hooks receive
            # plain host scalars (the StepEvent contract) so none of them
            # ever blocks on a device value again (repro-lint R2).
            loss_h, metrics_h, hp_h = _host_scalars(
                jax.device_get((loss, metrics, hp)))
            ev = hooks_lib.StepEvent(step=step, loss=loss_h,
                                     metrics=metrics_h,
                                     hparams=hp_h, dt=now - t_last)
            t_last = now
            # The monitor ingests the verdict BEFORE hook dispatch so a
            # boundary checkpoint persists the current device-state
            # snapshot; policy *actions* run after the hooks have seen
            # the step (records first, then recovery).
            anomalous = False
            if monitor is not None:
                verdict = ev.metrics.get("sentinel", {})
                anomalous = monitor.observe(step, verdict)
            for h in pipeline:
                h.on_step_end(ctx, ev)
            if anomalous:
                spc = spec.sentinel
                reason = monitor.classify(verdict)
                mh = hooks_lib.find_metrics_hook(pipeline)
                rewindable_eval = all(
                    h.iter_factory is not None for h in pipeline
                    if isinstance(h, hooks_lib.EvalHook) and h.every)
                rollback = (monitor.wants_rollback() and own_batch_iter
                            and rewindable_eval and ckpt_manager is not None
                            and ckpt_manager.latest_step() is not None)
                action = ("rollback" if rollback else
                          "backoff" if "backoff" in spc.ladder else "skip")
                log_fn(f"sentinel: anomaly at step {step} ({reason}) -> "
                       f"{action} [{monitor.anomalies}/{spc.budget}]")
                if monitor.exhausted():
                    # Loudly, and NOT via a retriable error: a run that
                    # keeps tripping the guard must not silently spin
                    # through restore cycles.
                    from repro.sentinel.policy import AnomalyBudgetExceeded
                    if mh is not None:
                        mh.record_anomaly(step, reason, action="abort",
                                          count=monitor.anomalies)
                    raise AnomalyBudgetExceeded(
                        f"anomaly budget exhausted: {monitor.anomalies} "
                        f"anomalies > budget {spc.budget} "
                        f"(last: {reason} at step {step})")
                if rollback:
                    ckpt_manager.wait()
                    restored, (p, s), _ = ckpt_manager.restore(
                        template=(ctx.params, ctx.opt_state))
                    ctx.params, ctx.opt_state = p, s
                    monitor.quarantine(restored, step + 1)
                    # The device SentinelState deliberately carries
                    # forward: the guard's memory (EMA, seen-clock)
                    # survives the rewind, which also keeps seen-keyed
                    # injected faults from re-firing on replay.
                    batch_iter = _train_iter(restored)
                    for h in pipeline:
                        h.on_recover(ctx, restored)
                    if mh is not None:
                        mh.record_anomaly(restored, reason,
                                          action="rollback",
                                          anomaly_step=step,
                                          quarantine=[restored, step + 1],
                                          count=monitor.anomalies)
                    log_fn(f"sentinel: rolled back to step {restored}; "
                           f"quarantined steps [{restored}, {step + 1})")
                    step = restored
                    t_last = time.time()
                    continue
                if mh is not None:
                    mh.record_anomaly(
                        step, reason, action=action,
                        count=monitor.anomalies,
                        update_norm=verdict.get("update_norm"),
                        ema_ref=verdict.get("ema_ref"))
            step += 1
    finally:
        for h in pipeline:
            h.on_exit(ctx)

    hist = None
    for h in pipeline:
        if isinstance(h, hooks_lib.HistoryHook):
            hist = h.history
            break
    return RunResult(params=ctx.params, opt_state=ctx.opt_state,
                     history=hist if hist is not None else {},
                     start_step=start_step, program=program, hooks=pipeline)

"""The hook pipeline: checkpoint, eval, logging, fault and history capture
as ordered callbacks on a five-event protocol.

Events (dispatched in hook-list order by ``repro.run.runner.run``):

  ``on_run_start(ctx)``                 once, after init/restore, before
                                        the first step;
  ``on_step_end(ctx, ev)``              after every completed step, with a
                                        :class:`StepEvent`;
  ``on_eval(ctx, step, metrics)``       whenever an evaluation ran
                                        (emitted by :class:`EvalHook` via
                                        ``ctx.dispatch_eval`` — every hook
                                        sees it, so history capture and
                                        logging don't special-case eval);
  ``on_recover(ctx, restored_step)``    fault recovery rewound the run to
                                        ``restored_step``: hooks that
                                        accumulate per-step state must
                                        discard entries at/after it, or
                                        they double-count the re-executed
                                        steps;
  ``on_exit(ctx)``                      once, after the last step (also on
                                        the exception path), for draining
                                        async work.

Hooks are host-side only: they read ``ctx.params/opt_state`` and device
scalars but never feed anything back into the jitted step, which is why
the pipeline adds **zero steady-state recompiles** (asserted in
``tests/run/test_hooks.py``).  The default pipeline order (straggler →
heartbeat → history → logging → metrics → eval → checkpoint) puts
measurement before side effects: a checkpoint at step N always contains
exactly the state whose metrics step N's hooks observed.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional

from repro.telemetry.schema import header_record, jsonify
from repro.train.fault import Heartbeat, StragglerMonitor


@dataclasses.dataclass
class StepEvent:
    """What ``on_step_end`` sees: the 0-based step index, **host** scalars
    (loss, metrics dict, hparams pytree — the runner performs ONE bundled
    ``jax.device_get`` per step and converts scalar leaves to Python
    floats before dispatch), and the host wall-clock seconds since the
    previous step.  Hooks must never sync on a device value themselves —
    that is repro-lint rule R2."""

    step: int
    loss: Any
    metrics: Any
    hparams: dict
    dt: float


class Hook:
    """Base class: every event defaults to a no-op, so hooks implement
    only what they observe."""

    def on_run_start(self, ctx) -> None:
        pass

    def on_step_end(self, ctx, ev: StepEvent) -> None:
        pass

    def on_eval(self, ctx, step: int, metrics: dict) -> None:
        pass

    def on_recover(self, ctx, restored_step: int) -> None:
        """Fault recovery rewound the run to ``restored_step``; hooks that
        accumulate per-step state discard everything at or after it so the
        final record matches an uninterrupted run."""
        pass

    def on_exit(self, ctx) -> None:
        pass


class HistoryHook(Hook):
    """Captures the training curve — the benchmarks' history dict
    (kept key-compatible with the old ``Trainer.fit`` output)."""

    def __init__(self):
        self.history = {"step": [], "loss": [], "accuracy": [], "lr": [],
                        "eval_loss": [], "eval_step": []}

    def on_step_end(self, ctx, ev: StepEvent) -> None:
        self.history["step"].append(ev.step)
        self.history["loss"].append(ev.loss)
        self.history["accuracy"].append(ev.metrics["accuracy"])
        self.history["lr"].append(ev.hparams["lr"])

    def on_eval(self, ctx, step: int, metrics: dict) -> None:
        self.history["eval_loss"].append(metrics["loss"])
        self.history["eval_step"].append(step)

    def on_recover(self, ctx, restored_step: int) -> None:
        h = self.history
        keep = sum(1 for s in h["step"] if s < restored_step)
        for k in ("step", "loss", "accuracy", "lr"):
            del h[k][keep:]
        keep_ev = sum(1 for s in h["eval_step"] if s < restored_step)
        for k in ("eval_loss", "eval_step"):
            del h[k][keep_ev:]


class LoggingHook(Hook):
    def __init__(self, every: int, log_fn: Callable[[str], None] = print,
                 total: Optional[int] = None):
        self.every = every
        self.log = log_fn
        self.total = total

    def on_step_end(self, ctx, ev: StepEvent) -> None:
        last = self.total is not None and ev.step == self.total - 1
        if self.every and (ev.step % self.every == 0 or last):
            self.log(f"step {ev.step:5d} loss {ev.loss:.4f} "
                     f"acc {ev.metrics['accuracy']:.3f} "
                     f"lr {ev.hparams['lr']:.2e} "
                     f"({ev.dt*1e3:.0f} ms)")

    def on_eval(self, ctx, step: int, metrics: dict) -> None:
        self.log(f"  eval loss {metrics['loss']:.4f} "
                 f"ppl {metrics['ppl']:.2f} acc {metrics['accuracy']:.3f}")


class MetricsHook(Hook):
    """JSONL metrics exporter: one record per observed step — step, loss,
    lr, wall dt, real-token throughput (tokens/s from the step's masked-CE
    ``ntokens`` metric) and padding efficiency (real tokens / slot
    tokens).  Under segment packing the efficiency column is the padding
    tax the packer recovered; for padded ragged batches it shows what is
    being lost.  Honors the rewind contract like :class:`HistoryHook`:
    ``on_recover`` drops records at/after the restored step and rewrites
    the file, so the JSONL always reads as the uninterrupted run's
    record.  The same contract extends across *process* restarts: a
    resumed run (``ctx.start_step > 0``) fast-forwards by keeping the
    existing records before the restored step and truncating the
    re-executed tail, so one metrics file carries the whole fleet-level
    history of a preempted-and-resumed run.

    Besides per-step records, the stream carries *event* records
    (``{"event": kind, "step": N, ...}``) from the liveness hooks —
    heartbeat stalls and straggler steps annotate themselves here via
    :meth:`annotate` (thread-safe; the heartbeat watchdog fires from its
    own thread), so one JSONL file is the single record of throughput
    *and* liveness.

    Since Telemetry v1 the file is a schema-versioned stream
    (``repro.telemetry.schema``): it opens with a ``{"schema": 1,
    "stream": "train"}`` header, and when the run's
    :class:`~repro.telemetry.probes.ObservabilitySpec` is enabled the
    optimizer-health scalars arriving in ``ev.metrics["opt_health"]``
    (already host values — they rode the runner's one bundled transfer)
    are recorded as ``probe`` records at the spec's cadence.  Headers
    are never stored in ``records`` — the rewind/fast-forward contract
    stays step-keyed over data records only — and legacy headerless
    files still resume cleanly."""

    def __init__(self, path, every: int = 1):
        self.path = str(path)
        self.every = max(1, int(every))
        self.records: list = []
        self._slot_tokens: Optional[int] = None
        self._fh = None
        self._lock = threading.Lock()

    def _rewrite(self) -> None:
        if self._fh is not None:
            self._fh.close()
        self._fh = open(self.path, "w")
        self._fh.write(json.dumps(header_record("train")) + "\n")
        for r in self.records:
            self._fh.write(json.dumps(r) + "\n")
        self._fh.flush()

    def on_run_start(self, ctx) -> None:
        d = ctx.spec.data
        if d is not None:
            self._slot_tokens = d.global_batch * d.seq_len
        p = Path(self.path)
        parent = p.parent
        if str(parent) not in ("", "."):
            parent.mkdir(parents=True, exist_ok=True)
        with self._lock:
            self.records = []
            if ctx.start_step > 0 and p.exists():
                # cross-process resume: keep the pre-restore record,
                # truncate the tail the resumed run re-executes
                for line in p.read_text().splitlines():
                    try:
                        r = json.loads(line)
                    except ValueError:  # crash-truncated last line
                        continue
                    if "schema" in r:
                        continue   # header: re-emitted by _rewrite
                    if r.get("step", ctx.start_step) < ctx.start_step:
                        self.records.append(r)
            self._rewrite()

    def _append(self, rec: dict) -> None:
        with self._lock:
            self.records.append(rec)
            if self._fh is not None:
                self._fh.write(json.dumps(rec) + "\n")
                self._fh.flush()

    def annotate(self, kind: str, step: int, **payload) -> None:
        """Append an event record (liveness signals: heartbeat stalls,
        straggler steps, preemption) to the JSONL stream."""
        self._append({"event": kind, "step": int(step), **payload})

    def record_anomaly(self, step: int, reason: str, **payload) -> None:
        """Append an ``anomaly`` record (training-sentinel verdicts:
        schema kind ``anomaly``, marker = the detection reason).  Rides
        the same rewind contract as every step-keyed record: a rollback's
        own record is written *after* on_recover truncation, stamped with
        the restored step, so it survives in the merged stream."""
        self._append(jsonify(
            {"anomaly": reason, "step": int(step), **payload}))

    def _record_probes(self, ctx, step: int, health) -> None:
        """Record the step's optimizer-health pytree (already host-side)
        as probe records at the ObservabilitySpec cadence.  The device
        computes the probes every step; *recording* is what's cadenced —
        that split is what keeps the jit cache at one entry."""
        ospec = getattr(ctx.spec, "observe", None)
        if ospec is None or not ospec.enabled:
            return
        if step % ospec.optimizer_every == 0:
            self._append(jsonify(
                {"probe": "opt_health", "step": step,
                 "group_ratio": health.get("group_ratio", {}),
                 "eff_lr": health.get("eff_lr", {})}))
        factored = health.get("factored")
        if factored and step % ospec.resolved_factored_every() == 0:
            self._append(jsonify(
                {"probe": "factored", "step": step, **factored}))

    def on_step_end(self, ctx, ev: StepEvent) -> None:
        health = (ev.metrics.get("opt_health")
                  if isinstance(ev.metrics, dict) else None)
        if health is not None:
            self._record_probes(ctx, ev.step, health)
        if ev.step % self.every:
            return
        ntok = ev.metrics.get("ntokens", 0.0)
        rec = {"step": ev.step, "loss": ev.loss,
               "lr": ev.hparams["lr"], "dt_s": ev.dt,
               "ntokens": ntok,
               "tokens_per_s": (ntok / ev.dt) if ev.dt > 0 else 0.0}
        if self._slot_tokens:
            rec["padding_efficiency"] = ntok / self._slot_tokens
        self._append(rec)

    def on_recover(self, ctx, restored_step: int) -> None:
        # Step-keyed records rewind (the replay re-emits them); ``event``
        # records are the host-side incident log (recover, preempt,
        # heartbeat stalls) — replay never re-emits those, so truncating
        # them would erase real faults from the audit trail.
        with self._lock:
            self.records = [r for r in self.records
                            if "event" in r
                            or r.get("step", restored_step) < restored_step]
            self._rewrite()

    def on_exit(self, ctx) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


def find_metrics_hook(hooks) -> Optional["MetricsHook"]:
    """The pipeline's MetricsHook, if any (liveness hooks route their
    signals into its JSONL stream)."""
    for h in hooks:
        if isinstance(h, MetricsHook):
            return h
    return None


class EvalHook(Hook):
    """Runs held-out eval every ``every`` steps and broadcasts the result
    to the whole pipeline via ``ctx.dispatch_eval``.

    Two stream modes: a plain ``eval_iter`` (caller-owned; cannot be
    rewound across resume/recovery), or an ``iter_factory(start_batch)``
    — the default pipeline's mode — which makes the eval stream a pure
    function of how many evals the run has completed, so a resumed or
    fault-recovered run consumes exactly the batches the uninterrupted
    run would have."""

    def __init__(self, eval_iter=None, every: int = 0, n_batches: int = 4,
                 *, iter_factory=None):
        assert (eval_iter is None) != (iter_factory is None), \
            "pass exactly one of eval_iter / iter_factory"
        self.eval_iter = eval_iter
        self.iter_factory = iter_factory
        self.every = every
        self.n_batches = n_batches

    def _rewind(self, step: int) -> None:
        if self.iter_factory is None or not self.every:
            return
        consumed = (step // self.every) * self.n_batches
        self.eval_iter = self.iter_factory(consumed)

    def on_run_start(self, ctx) -> None:
        self._rewind(ctx.start_step)

    def on_recover(self, ctx, restored_step: int) -> None:
        self._rewind(restored_step)

    def evaluate(self, ctx) -> dict:
        import jax
        import jax.numpy as jnp
        loss_fn = ctx.program.loss_fn
        tot, acc = 0.0, 0.0
        for _ in range(self.n_batches):
            batch = jax.tree.map(jnp.asarray, next(self.eval_iter))
            loss, metrics = loss_fn(ctx.params, batch)
            tot += float(loss)
            acc += float(metrics["accuracy"])
        tot /= self.n_batches
        return {"loss": tot, "ppl": float(jnp.exp(tot)),
                "accuracy": acc / self.n_batches}

    def on_step_end(self, ctx, ev: StepEvent) -> None:
        if self.every and (ev.step + 1) % self.every == 0:
            ctx.dispatch_eval(ev.step, self.evaluate(ctx))


class CheckpointHook(Hook):
    """Async checkpoint save every ``every`` steps; drains on exit.  The
    saved tree is ``(params, opt_state)`` with the data step recorded so
    resume is exactly deterministic."""

    def __init__(self, manager, every: int):
        self.manager = manager
        self.every = every

    def on_step_end(self, ctx, ev: StepEvent) -> None:
        if self.every and (ev.step + 1) % self.every == 0:
            extra = {"data_step": ev.step + 1}
            if getattr(ctx, "sentinel", None) is not None:
                # monitor counters + device-state snapshot: a resumed run
                # rebuilds the sentinel's cross-step memory bitwise
                extra["sentinel"] = ctx.sentinel.to_extra()
            self.manager.save(ev.step + 1, (ctx.params, ctx.opt_state),
                              extra=extra)

    def on_exit(self, ctx) -> None:
        self.manager.wait()


class HeartbeatHook(Hook):
    """Watchdog: marks the run wedged if steps stop completing.  A stall
    is annotated into the MetricsHook JSONL stream (``{"event":
    "heartbeat_stall", ...}``) when the pipeline has one, so the metrics
    file carries liveness alongside throughput."""

    def __init__(self, timeout_s: float,
                 on_stall: Optional[Callable[[], None]] = None):
        self.timeout_s = timeout_s
        self._on_stall = on_stall
        self.heartbeat: Optional[Heartbeat] = None
        self._last_step = 0

    def on_run_start(self, ctx) -> None:
        self._last_step = ctx.start_step
        metrics = find_metrics_hook(ctx.hooks)

        def fire():
            # annotate runs from the watchdog thread — MetricsHook locks
            if metrics is not None:
                metrics.annotate("heartbeat_stall", self._last_step,
                                 timeout_s=self.timeout_s)
            if self._on_stall is not None:
                self._on_stall()
            else:
                ctx.log("HEARTBEAT STALL")

        self.heartbeat = Heartbeat(self.timeout_s, on_stall=fire)
        self.heartbeat.start()

    def on_step_end(self, ctx, ev: StepEvent) -> None:
        self._last_step = ev.step
        if self.heartbeat is not None:
            self.heartbeat.beat()

    def on_exit(self, ctx) -> None:
        if self.heartbeat is not None:
            self.heartbeat.stop()


class StragglerHook(Hook):
    """Feeds per-step wall time into a :class:`StragglerMonitor` (EMA
    outlier detection; the coordinator's evict signal at scale).
    Flagged steps are annotated into the MetricsHook JSONL stream
    (``{"event": "straggler", ...}``) when the pipeline has one."""

    def __init__(self, monitor: Optional[StragglerMonitor] = None):
        self.monitor = monitor if monitor is not None else StragglerMonitor()

    def on_step_end(self, ctx, ev: StepEvent) -> None:
        if self.monitor.observe(ev.step, ev.dt):
            metrics = find_metrics_hook(ctx.hooks)
            if metrics is not None:
                _, dt, ema = self.monitor.events[-1]
                metrics.annotate("straggler", ev.step, dt_s=dt, ema_s=ema)


class TimingHook(Hook):
    """Wall-clock accounting: total run seconds and mean us/step."""

    def __init__(self):
        self.t0 = None
        self.wall_s = 0.0
        self.n_steps = 0

    def on_run_start(self, ctx) -> None:
        self.t0 = time.time()

    def on_step_end(self, ctx, ev: StepEvent) -> None:
        self.n_steps += 1

    def on_exit(self, ctx) -> None:
        if self.t0 is not None:
            self.wall_s = time.time() - self.t0

    @property
    def us_per_step(self) -> float:
        return self.wall_s / max(self.n_steps, 1) * 1e6


class ProfilerHook(Hook):
    """jax profiler trace for a configurable step window.

    Traces steps ``[start, start + steps)`` (0-based) into ``dir`` and
    stamps the artifact with the originating RunSpec
    (``<dir>/profile.runspec.json`` sidecar, the dryrun-artifact idiom) so
    a trace is always attributable to the exact spec that produced it.
    The default window skips step 0, which is dominated by compilation.

    Resume/recovery contract: a run restored *past* the window does not
    re-trace (the artifact belongs to the steps that already executed);
    a fault recovery while tracing stops the trace and keeps what was
    captured.  ``on_exit`` stops a still-active trace on any exit path,
    so a preempted run leaves a readable artifact."""

    def __init__(self, dir, start: int = 1, steps: int = 2):
        self.dir = str(dir)
        self.start = int(start)
        self.steps = int(steps)
        self.active = False
        self.done = False

    def _begin(self, ctx) -> None:
        try:
            import jax.profiler
            jax.profiler.start_trace(self.dir)
            self.active = True
        except Exception as e:  # profiler backend unavailable: degrade
            ctx.log(f"profiler disabled: {type(e).__name__}: {e}")
            self.done = True

    def _end(self, ctx) -> None:
        if not self.active:
            return
        try:
            import jax.profiler
            jax.profiler.stop_trace()
        except Exception as e:
            ctx.log(f"profiler stop failed: {type(e).__name__}: {e}")
        self.active = False
        self.done = True

    def on_run_start(self, ctx) -> None:
        out = Path(self.dir)
        out.mkdir(parents=True, exist_ok=True)
        (out / "profile.runspec.json").write_text(ctx.spec.to_json(indent=1))
        if ctx.start_step > self.start:
            self.done = True       # window already executed pre-resume
        elif ctx.start_step == self.start:
            self._begin(ctx)

    def on_step_end(self, ctx, ev: StepEvent) -> None:
        if self.done:
            return
        if self.active and ev.step + 1 >= self.start + self.steps:
            self._end(ctx)
        elif not self.active and ev.step + 1 == self.start:
            self._begin(ctx)

    def on_recover(self, ctx, restored_step: int) -> None:
        self._end(ctx)

    def on_exit(self, ctx) -> None:
        self._end(ctx)

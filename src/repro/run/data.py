"""Run-layer data plumbing: arch-aware batch iterators.

The token pipeline (``repro.data.pipeline``) is family-agnostic; some
architectures need extra per-batch inputs (encoder frames for encdec,
prefix embeddings for prefix-LM, shifted labels for MTP).  This module
owns that adaptation — previously copy-pasted inside ``launch/train.py``
— keyed *per step* so resume reproduces the exact same extras the
uninterrupted run would have seen (the pipeline's stateless-given-step
contract extends to the extras).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.data.pipeline import DataConfig, batches
from repro.run.spec import RunSpec


def resolved_data(spec: RunSpec, arch) -> DataConfig:
    """The spec's DataConfig with ``vocab=0`` resolved to the arch vocab."""
    if spec.data is None:
        raise ValueError("spec.data is required to build a batch iterator")
    if spec.data.vocab:
        return spec.data
    return dataclasses.replace(spec.data, vocab=arch.cfg.vocab)


def _with_extras(b: dict, arch, cfg: DataConfig, step: int) -> dict:
    need_frames = arch.family == "encdec"
    prefix = getattr(arch.cfg, "prefix_lm", False)
    mtp = getattr(arch.cfg, "mtp", False)
    if not (need_frames or prefix or mtp):
        return b
    b = dict(b)
    B = cfg.local_batch
    rng = np.random.default_rng((cfg.seed, 0x5eed, step))
    if need_frames:
        b["frames"] = rng.standard_normal(
            (B, arch.cfg.n_frames, arch.cfg.d_model), dtype=np.float32)
    if prefix:
        b["prefix_embed"] = rng.standard_normal(
            (B, arch.cfg.n_prefix_tokens, arch.cfg.d_model),
            dtype=np.float32)
        b["prefix_len"] = np.full((B,), arch.cfg.n_prefix_tokens, np.int32)
    if mtp:
        lab = b["labels"]
        b["labels_mtp"] = np.concatenate(
            [lab[:, 1:], -np.ones((lab.shape[0], 1), np.int32)], 1)
    return b


def make_batch_iter(spec: RunSpec, arch, start_step: int = 0,
                    *, seed_offset: int = 0) -> Iterator[dict]:
    """Deterministic, resumable batch stream matching
    ``arch.train_batch_specs`` leaf-for-leaf.  ``seed_offset`` derives a
    disjoint stream from the same spec (held-out eval)."""
    cfg = resolved_data(spec, arch)
    if seed_offset:
        cfg = dataclasses.replace(cfg, seed=cfg.seed + seed_offset)
    step = start_step
    for b in batches(cfg, start_step):
        yield _with_extras(b, arch, cfg, step)
        step += 1


# Seed offset for the default held-out eval stream.
EVAL_SEED_OFFSET = 999
